//! TF-IDF vectorization (paper §4.2; Sparck Jones 1972).
//!
//! "TF-IDF is a lightweight and efficient method for converting text into
//! numerical vectors, focusing on word importance rather than deep semantic
//! analysis." Words are hashed into a fixed-dimension feature space (the
//! hashing trick) so the vectorizer needs no global vocabulary; IDF weights
//! are fit per class on the training corpus.

use crate::tokenizer::{fnv1a, Tokenizer};

/// Hashed TF-IDF vectorizer.
#[derive(Debug, Clone)]
pub struct TfIdf {
    dim: usize,
    /// Smoothed inverse document frequency per hashed feature.
    idf: Vec<f32>,
    fitted: bool,
}

impl TfIdf {
    /// Vectorizer with `dim` hashed features.
    pub fn new(dim: usize) -> Self {
        assert!(dim > 0);
        TfIdf { dim, idf: vec![1.0; dim], fitted: false }
    }

    /// Number of hashed features.
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn bucket(&self, word: &str) -> usize {
        (fnv1a(word.as_bytes()) % self.dim as u64) as usize
    }

    /// Fit IDF weights on a corpus: idf = ln((1+N)/(1+df)) + 1 (smoothed,
    /// scikit-learn convention).
    pub fn fit(&mut self, corpus: &[String]) {
        let n = corpus.len();
        let mut df = vec![0u32; self.dim];
        let mut seen = vec![usize::MAX; self.dim];
        for (doc_id, doc) in corpus.iter().enumerate() {
            for w in Tokenizer::words(doc) {
                let b = self.bucket(w);
                if seen[b] != doc_id {
                    seen[b] = doc_id;
                    df[b] += 1;
                }
            }
        }
        for (i, &d) in df.iter().enumerate() {
            self.idf[i] = (((1 + n) as f32) / ((1 + d) as f32)).ln() + 1.0;
        }
        self.fitted = true;
    }

    /// Transform text into an L2-normalized TF-IDF vector with two appended
    /// length features (log word count, log line count). L2 normalization
    /// erases absolute input size from the TF part, but size is the
    /// strongest cost signal an agent input carries — real prompts expose it
    /// through document counts/file sizes — so it is restored explicitly.
    /// The output dimension is `dim() + 2`.
    pub fn transform(&self, text: &str) -> Vec<f32> {
        let mut tf = vec![0f32; self.dim];
        let mut count = 0usize;
        for w in Tokenizer::words(text) {
            tf[self.bucket(w)] += 1.0;
            count += 1;
        }
        let mut v: Vec<f32> = if count == 0 {
            tf
        } else {
            let mut v: Vec<f32> = tf
                .iter()
                .zip(&self.idf)
                .map(|(&t, &i)| if t > 0.0 { (t / count as f32) * i } else { 0.0 })
                .collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                for x in &mut v {
                    *x /= norm;
                }
            }
            v
        };
        let lines = text.lines().count();
        v.push(((1 + count) as f32).ln() / 10.0);
        v.push(((1 + lines) as f32).ln() / 5.0);
        v
    }

    /// Dimension of `transform` output.
    pub fn feature_dim(&self) -> usize {
        self.dim + 2
    }

    /// Whether `fit` has been called.
    pub fn is_fitted(&self) -> bool {
        self.fitted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<String> {
        vec![
            "merge document combine draft".to_string(),
            "merge score rank candidate".to_string(),
            "verify equation math solve".to_string(),
        ]
    }

    #[test]
    fn fit_transform_shapes() {
        let mut t = TfIdf::new(64);
        t.fit(&corpus());
        assert!(t.is_fitted());
        let v = t.transform("merge document");
        assert_eq!(v.len(), t.feature_dim());
        assert_eq!(t.feature_dim(), 66);
        let norm: f32 = v[..64].iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-5);
        // Length features present and positive for non-empty text.
        assert!(v[64] > 0.0 && v[65] > 0.0);
    }

    #[test]
    fn rare_words_weigh_more() {
        let mut t = TfIdf::new(256);
        t.fit(&corpus());
        // "merge" appears in 2 docs, "equation" in 1 → idf(equation) > idf(merge).
        let v_merge = t.transform("merge");
        let v_eq = t.transform("equation");
        let nz = |v: &[f32]| v.iter().cloned().find(|x| *x > 0.0).unwrap();
        // Single-word docs are L2-normalized to 1.0 either way; compare raw
        // idf instead.
        let b_merge = (fnv1a(b"merge") % 256) as usize;
        let b_eq = (fnv1a(b"equation") % 256) as usize;
        assert!(t.idf[b_eq] > t.idf[b_merge]);
        let _ = (nz(&v_merge), nz(&v_eq));
    }

    #[test]
    fn empty_text_is_zero_vector() {
        let mut t = TfIdf::new(16);
        t.fit(&corpus());
        let v = t.transform("");
        assert_eq!(v.len(), t.feature_dim());
        assert!(v.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic() {
        let mut a = TfIdf::new(32);
        let mut b = TfIdf::new(32);
        a.fit(&corpus());
        b.fit(&corpus());
        assert_eq!(a.transform("merge document draft"), b.transform("merge document draft"));
    }

    #[test]
    fn similar_texts_closer_than_dissimilar() {
        let mut t = TfIdf::new(128);
        t.fit(&corpus());
        let cos = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let v1 = t.transform("merge document combine");
        let v2 = t.transform("merge draft combine");
        let v3 = t.transform("verify equation solve");
        assert!(cos(&v1, &v2) > cos(&v1, &v3));
    }
}
