//! Parallel replica simulation determinism (ISSUE 6 acceptance): running a
//! cluster's replicas on a 1-worker vs 8-worker thread pool must produce
//! BYTE-identical merged `RunMetrics` and results JSON. Replicas are
//! independent simulations over disjoint sub-traces; `run_suite_parallel`
//! keeps placement serial and reinstalls engines in replica index order, so
//! thread count can change nothing observable (seeded, three placements).

use justitia::cluster::Placement;
use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::experiments::build_sim_cluster;
use justitia::metrics::RunMetrics;
use justitia::util::json::{obj, Json};
use justitia::workload::trace;
use justitia::workload::Suite;

fn cfg_with(n_agents: usize, seed: u64, replicas: usize, p: Placement) -> Config {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(3.0);
    cfg.cluster.replicas = replicas;
    cfg.cluster.placement = p;
    cfg.event_core = true; // the scale-out production path
    cfg
}

/// Canonical results JSON over the merged metrics — the same kind of
/// artifact the experiment writes. Byte equality of this string is the
/// test's definition of "identical results".
fn results_json(m: &RunMetrics) -> String {
    let jcts: Vec<Json> = m
        .jcts()
        .into_iter()
        .map(|(id, j)| obj([("agent", Json::Num(id as f64)), ("jct", Json::Num(j))]))
        .collect();
    obj([
        ("completed", Json::Num(m.completed_agents() as f64)),
        ("iterations", Json::Num(m.iterations() as f64)),
        ("swap_outs", Json::Num(m.swap_out_count() as f64)),
        ("recomputes", Json::Num(m.recompute_count() as f64)),
        ("engine_time", Json::Num(m.engine_time())),
        ("avg_jct", Json::Num(m.avg_jct())),
        ("p99_jct", Json::Num(m.p99_jct())),
        ("jcts", Json::Arr(jcts)),
    ])
    .pretty()
}

/// Run the cluster over `threads` workers; return the results JSON plus the
/// raw JCT bits (f64-bit-exact, stronger than the printed form).
fn run(cfg: &Config, suite: &Suite, threads: usize) -> (String, Vec<(u32, u64)>) {
    let costs = justitia::cost::oracle_costs(false, suite, CostModel::MemoryCentric);
    let mut cluster = build_sim_cluster(cfg, Policy::Justitia);
    cluster.run_suite_parallel(suite, |a| costs[&a.id], threads);
    let m = cluster.merged_metrics();
    let bits = m.jcts().into_iter().map(|(id, j)| (id, j.to_bits())).collect();
    (results_json(&m), bits)
}

#[test]
fn thread_pool_size_cannot_change_merged_results() {
    for (seed, p) in [
        (42u64, Placement::RoundRobin),
        (7, Placement::LeastLoaded),
        (1234, Placement::ClusterVtime),
    ] {
        let cfg = cfg_with(160, seed, 8, p);
        let suite = trace::build_suite(&cfg.workload);
        let (json1, bits1) = run(&cfg, &suite, 1);
        assert!(json1.contains("\"completed\""));
        for threads in [2usize, 8] {
            let (json_t, bits_t) = run(&cfg, &suite, threads);
            assert_eq!(
                bits1, bits_t,
                "seed {seed} {p:?}: JCT bits diverged at {threads} threads"
            );
            assert_eq!(
                json1, json_t,
                "seed {seed} {p:?}: results JSON diverged at {threads} threads"
            );
        }

        // The serial driver is the same computation by construction — pin it.
        let costs = justitia::cost::oracle_costs(false, &suite, CostModel::MemoryCentric);
        let mut serial = build_sim_cluster(&cfg, Policy::Justitia);
        serial.run_suite(&suite, |a| costs[&a.id]);
        assert_eq!(
            results_json(&serial.merged_metrics()),
            json1,
            "seed {seed} {p:?}: run_suite_parallel(1) differs from run_suite"
        );
    }
}

#[test]
fn legacy_tick_core_is_equally_thread_insensitive() {
    // The guarantee is about the dispatcher, not the engine core: the
    // legacy tick loop must survive parallel replicas identically.
    let mut cfg = cfg_with(120, 42, 4, Placement::ClusterVtime);
    cfg.event_core = false;
    let suite = trace::build_suite(&cfg.workload);
    let (j1, b1) = run(&cfg, &suite, 1);
    let (j8, b8) = run(&cfg, &suite, 8);
    assert_eq!(b1, b8);
    assert_eq!(j1, j8);
}
