//! Paged KV-cache block management (the vLLM substrate, paper §2/§4.1).
//!
//! GPU KV memory is divided into fixed-size pages ("blocks" in vLLM terms) of
//! `page_size` tokens. Each running sequence holds a block table — an ordered
//! list of page ids covering its prompt + generated tokens. The allocator
//! tracks free pages, per-sequence tables, and the swap area (CPU memory) for
//! preempted sequences. This is the resource whose contention the whole paper
//! is about: the scheduler's `M` is `total_pages * page_size` token slots.

use crate::workload::TaskId;
use std::collections::HashMap;

/// Page id within the device pool.
pub type PageId = u32;

/// Where a sequence's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidence {
    /// Resident in the device pool.
    Device,
    /// Stashed in host memory.
    Swapped,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    pages: Vec<PageId>,
    tokens: u32,
    residence: KvResidence,
}

/// Errors from the allocator.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot supply the requested pages.
    #[error("out of KV pages (need {need}, free {free})")]
    OutOfPages { need: u32, free: u32 },
    /// No allocation exists for this sequence.
    #[error("unknown sequence {0}")]
    UnknownSeq(TaskId),
    /// The sequence already holds pages.
    #[error("sequence {0} already allocated")]
    AlreadyAllocated(TaskId),
    /// The operation needs a device-resident sequence.
    #[error("sequence {0} is swapped out")]
    Swapped(TaskId),
}

/// The paged KV-cache allocator.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    page_size: u32,
    total_pages: u32,
    free: Vec<PageId>,
    seqs: HashMap<TaskId, SeqAlloc>,
    /// Token slots occupied on device (for occupancy accounting / Fig. 3).
    device_tokens: u64,
    swapped_tokens: u64,
}

impl BlockAllocator {
    /// Allocator over `total_pages` pages of `page_size` tokens.
    pub fn new(total_pages: u32, page_size: u32) -> Self {
        assert!(page_size > 0 && total_pages > 0);
        BlockAllocator {
            page_size,
            total_pages,
            free: (0..total_pages).rev().collect(),
            seqs: HashMap::new(),
            device_tokens: 0,
            swapped_tokens: 0,
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> u32 {
        self.page_size
    }

    /// Total pool pages.
    pub fn total_pages(&self) -> u32 {
        self.total_pages
    }

    /// Token capacity M (paper's total KV cache space, per-token units).
    pub fn capacity_tokens(&self) -> u64 {
        self.total_pages as u64 * self.page_size as u64
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> u32 {
        self.free.len() as u32
    }

    /// Pages needed to hold `tokens`.
    pub fn pages_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.page_size)
    }

    /// Tokens currently resident on device (running sequences).
    pub fn device_tokens(&self) -> u64 {
        self.device_tokens
    }

    /// Tokens currently swapped to host.
    pub fn swapped_tokens(&self) -> u64 {
        self.swapped_tokens
    }

    /// Whether a new sequence with `prompt_tokens` can be admitted now.
    /// vLLM admits when the prompt fits plus one page of headroom for the
    /// first decode step.
    pub fn can_admit(&self, prompt_tokens: u32) -> bool {
        self.pages_for(prompt_tokens) + 1 <= self.free_pages()
    }

    /// Allocate pages for a newly-admitted sequence's prompt.
    pub fn allocate(&mut self, seq: TaskId, prompt_tokens: u32) -> Result<(), KvError> {
        if self.seqs.contains_key(&seq) {
            return Err(KvError::AlreadyAllocated(seq));
        }
        let need = self.pages_for(prompt_tokens).max(1);
        if need > self.free_pages() {
            return Err(KvError::OutOfPages { need, free: self.free_pages() });
        }
        let pages: Vec<PageId> = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.device_tokens += prompt_tokens as u64;
        self.seqs.insert(seq, SeqAlloc { pages, tokens: prompt_tokens, residence: KvResidence::Device });
        Ok(())
    }

    /// Extend a running sequence by one generated token; may allocate a new
    /// page. Returns Err(OutOfPages) when the pool is exhausted — the engine
    /// then preempts (swaps out) some sequence.
    pub fn append_token(&mut self, seq: TaskId) -> Result<(), KvError> {
        let alloc = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if alloc.residence != KvResidence::Device {
            return Err(KvError::Swapped(seq));
        }
        let cap = alloc.pages.len() as u32 * self.page_size;
        if alloc.tokens + 1 > cap {
            match self.free.pop() {
                Some(p) => alloc.pages.push(p),
                None => return Err(KvError::OutOfPages { need: 1, free: 0 }),
            }
        }
        alloc.tokens += 1;
        self.device_tokens += 1;
        Ok(())
    }

    /// Whether `append_token` would succeed without side effects.
    pub fn can_append(&self, seq: TaskId) -> bool {
        match self.seqs.get(&seq) {
            Some(a) if a.residence == KvResidence::Device => {
                a.tokens + 1 <= a.pages.len() as u32 * self.page_size || !self.free.is_empty()
            }
            _ => false,
        }
    }

    /// Free all pages of a finished sequence.
    pub fn release(&mut self, seq: TaskId) -> Result<u32, KvError> {
        let alloc = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let n = alloc.pages.len() as u32;
        match alloc.residence {
            KvResidence::Device => {
                self.free.extend(alloc.pages);
                self.device_tokens -= alloc.tokens as u64;
            }
            KvResidence::Swapped => {
                self.swapped_tokens -= alloc.tokens as u64;
            }
        }
        Ok(n)
    }

    /// Swap a running sequence out to host memory, freeing its device pages.
    /// Returns the number of tokens moved (for swap-latency accounting).
    pub fn swap_out(&mut self, seq: TaskId) -> Result<u32, KvError> {
        let alloc = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if alloc.residence == KvResidence::Swapped {
            return Err(KvError::Swapped(seq));
        }
        let pages = std::mem::take(&mut alloc.pages);
        self.free.extend(pages);
        alloc.residence = KvResidence::Swapped;
        self.device_tokens -= alloc.tokens as u64;
        self.swapped_tokens += alloc.tokens as u64;
        Ok(alloc.tokens)
    }

    /// Whether a swapped sequence fits back on device (plus one page of
    /// decode headroom).
    pub fn can_swap_in(&self, seq: TaskId) -> bool {
        match self.seqs.get(&seq) {
            Some(a) if a.residence == KvResidence::Swapped => {
                self.pages_for(a.tokens) + 1 <= self.free_pages()
            }
            _ => false,
        }
    }

    /// Swap a sequence back onto the device. Returns tokens moved.
    pub fn swap_in(&mut self, seq: TaskId) -> Result<u32, KvError> {
        if !self.can_swap_in(seq) {
            let free = self.free_pages();
            let need = self
                .seqs
                .get(&seq)
                .map(|a| self.pages_for(a.tokens) + 1)
                .ok_or(KvError::UnknownSeq(seq))?;
            return Err(KvError::OutOfPages { need, free });
        }
        let page_size = self.page_size;
        let alloc = self.seqs.get_mut(&seq).unwrap();
        let need = alloc.tokens.div_ceil(page_size).max(1);
        for _ in 0..need {
            alloc.pages.push(self.free.pop().unwrap());
        }
        alloc.residence = KvResidence::Device;
        self.swapped_tokens -= alloc.tokens as u64;
        self.device_tokens += alloc.tokens as u64;
        Ok(alloc.tokens)
    }

    /// Current token count of a sequence.
    pub fn seq_tokens(&self, seq: TaskId) -> Option<u32> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }

    /// Residence of a sequence.
    pub fn residence(&self, seq: TaskId) -> Option<KvResidence> {
        self.seqs.get(&seq).map(|a| a.residence)
    }

    /// The block table of a device-resident sequence (page ids in order) —
    /// consumed by the PJRT paged-attention path.
    pub fn block_table(&self, seq: TaskId) -> Option<&[PageId]> {
        self.seqs.get(&seq).and_then(|a| {
            if a.residence == KvResidence::Device {
                Some(a.pages.as_slice())
            } else {
                None
            }
        })
    }

    /// Invariant check used by tests/debug builds: every page is either free
    /// or owned by exactly one device-resident sequence.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut seen = vec![false; self.total_pages as usize];
        for &p in &self.free {
            if seen[p as usize] {
                return Err(format!("page {p} double-listed in free"));
            }
            seen[p as usize] = true;
        }
        let mut dev_tokens = 0u64;
        let mut swap_tokens = 0u64;
        for (id, a) in &self.seqs {
            match a.residence {
                KvResidence::Device => {
                    dev_tokens += a.tokens as u64;
                    if (a.pages.len() as u32 * self.page_size) < a.tokens {
                        return Err(format!("{id}: pages don't cover tokens"));
                    }
                    for &p in &a.pages {
                        if seen[p as usize] {
                            return Err(format!("page {p} owned twice"));
                        }
                        seen[p as usize] = true;
                    }
                }
                KvResidence::Swapped => {
                    swap_tokens += a.tokens as u64;
                    if !a.pages.is_empty() {
                        return Err(format!("{id}: swapped but holds pages"));
                    }
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("leaked pages".into());
        }
        if dev_tokens != self.device_tokens {
            return Err(format!("device_tokens {} != {}", self.device_tokens, dev_tokens));
        }
        if swap_tokens != self.swapped_tokens {
            return Err(format!("swapped_tokens {} != {}", self.swapped_tokens, swap_tokens));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tid(i: u32) -> TaskId {
        TaskId { agent: 0, index: i }
    }

    #[test]
    fn allocate_and_release() {
        let mut kv = BlockAllocator::new(10, 16);
        assert_eq!(kv.capacity_tokens(), 160);
        kv.allocate(tid(1), 33).unwrap(); // 3 pages
        assert_eq!(kv.free_pages(), 7);
        assert_eq!(kv.device_tokens(), 33);
        assert_eq!(kv.block_table(tid(1)).unwrap().len(), 3);
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(tid(1)).unwrap(), 3);
        assert_eq!(kv.free_pages(), 10);
        assert_eq!(kv.device_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_new_pages() {
        let mut kv = BlockAllocator::new(3, 4);
        kv.allocate(tid(1), 4).unwrap(); // exactly 1 page
        kv.append_token(tid(1)).unwrap(); // needs 2nd page
        assert_eq!(kv.seq_tokens(tid(1)), Some(5));
        assert_eq!(kv.free_pages(), 1);
        for _ in 0..3 {
            kv.append_token(tid(1)).unwrap(); // fills 2nd page (8 tokens)
        }
        kv.append_token(tid(1)).unwrap(); // 3rd page
        assert_eq!(kv.free_pages(), 0);
        // Pool exhausted at 12 tokens cap.
        for _ in 0..3 {
            kv.append_token(tid(1)).unwrap();
        }
        assert_eq!(kv.append_token(tid(1)), Err(KvError::OutOfPages { need: 1, free: 0 }));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_rule_keeps_headroom() {
        let kv = BlockAllocator::new(4, 16);
        assert!(kv.can_admit(48)); // 3 pages + 1 headroom = 4
        assert!(!kv.can_admit(49)); // would need 4 + 1
    }

    #[test]
    fn swap_out_in_cycle() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 16).unwrap(); // 2 pages
        kv.allocate(tid(2), 8).unwrap(); // 1 page
        let moved = kv.swap_out(tid(1)).unwrap();
        assert_eq!(moved, 16);
        assert_eq!(kv.free_pages(), 3);
        assert_eq!(kv.residence(tid(1)), Some(KvResidence::Swapped));
        assert_eq!(kv.swapped_tokens(), 16);
        assert!(kv.block_table(tid(1)).is_none());
        assert!(!kv.can_append(tid(1)));
        kv.check_invariants().unwrap();

        assert!(kv.can_swap_in(tid(1)));
        let back = kv.swap_in(tid(1)).unwrap();
        assert_eq!(back, 16);
        assert_eq!(kv.residence(tid(1)), Some(KvResidence::Device));
        assert_eq!(kv.swapped_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_requires_space() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 24).unwrap(); // 3 pages
        kv.swap_out(tid(1)).unwrap();
        kv.allocate(tid(2), 24).unwrap(); // takes 3 pages
        assert!(!kv.can_swap_in(tid(1))); // needs 3+1, only 1 free
        assert!(kv.swap_in(tid(1)).is_err());
        kv.release(tid(2)).unwrap();
        assert!(kv.can_swap_in(tid(1)));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_swapped_seq() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 10).unwrap();
        kv.swap_out(tid(1)).unwrap();
        kv.release(tid(1)).unwrap();
        assert_eq!(kv.swapped_tokens(), 0);
        assert_eq!(kv.free_pages(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn errors() {
        let mut kv = BlockAllocator::new(2, 8);
        assert_eq!(kv.release(tid(9)), Err(KvError::UnknownSeq(tid(9))));
        kv.allocate(tid(1), 4).unwrap();
        assert_eq!(kv.allocate(tid(1), 4), Err(KvError::AlreadyAllocated(tid(1))));
        assert!(matches!(kv.allocate(tid(2), 100), Err(KvError::OutOfPages { .. })));
        kv.swap_out(tid(1)).unwrap();
        assert_eq!(kv.swap_out(tid(1)), Err(KvError::Swapped(tid(1))));
        assert_eq!(kv.append_token(tid(1)), Err(KvError::Swapped(tid(1))));
    }

    #[test]
    fn zero_prompt_gets_one_page() {
        let mut kv = BlockAllocator::new(2, 8);
        kv.allocate(tid(1), 0).unwrap();
        assert_eq!(kv.block_table(tid(1)).unwrap().len(), 1);
        kv.check_invariants().unwrap();
    }
}
