//! Chunked prefill — 300 agents at 3× density per workload family (staged /
//! DAG / shared-prefix), four policies, chunk sizes 1024/512/128 under a
//! 2048-token iteration budget, vs the atomic-admission baseline.
//!
//! Beyond the paper: batch *formation* as a fairness lever (FairBatching) —
//! one long prefill admitted atomically stalls every running decode for its
//! whole duration, distorting both tail latency and the service signal the
//! scheduler acts on. Expected shape: decode p99 inter-token latency
//! improves monotonically as the chunk shrinks at fixed budget (atomic is
//! worst), at a bounded avg-JCT cost; every suite completes either way.

use justitia::config::{Config, Policy};
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Chunked prefill: workload x policy x chunk (300 agents, 3x density)");
    let mut out = ResultsFile::new("bench_chunked_prefill.txt");
    let chunks = [1024, 512, 128];
    let rows =
        justitia::experiments::chunked_prefill(&Config::default(), 300, 3.0, &chunks, 2048, 42);
    out.line(justitia::experiments::ChunkedPrefillRow::table_header());
    for r in &rows {
        out.line(r.table_row());
    }
    for w in justitia::experiments::CHUNKED_WORKLOADS {
        let get = |c: u32| {
            rows.iter().find(|r| r.workload == w && r.policy == Policy::Justitia && r.chunk == c)
        };
        if let (Some(off), Some(best)) = (get(0), get(128)) {
            out.line(format!(
                "headline {w} (Justitia): decode ITL p99 {:.1} ms -> {:.1} ms at chunk 128, \
                 avg JCT {:.1}s -> {:.1}s, {} stalls",
                off.decode_itl_p99_ms,
                best.decode_itl_p99_ms,
                off.avg_jct,
                best.avg_jct,
                best.prefill_stalls
            ));
        }
    }
}
