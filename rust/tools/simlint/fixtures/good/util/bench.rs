// Fixture: util/ is exempt by path — wall-clock and env reads here are the
// whole point of a benchmarking module and must NOT be flagged.
use std::collections::HashMap;

pub fn time_it<F: FnOnce()>(f: F) -> std::time::Duration {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed()
}

pub fn workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

pub fn env_override() -> Option<String> {
    std::env::var("BENCH_FILTER").ok()
}

pub fn histogram(xs: &[u64]) -> HashMap<u64, usize> {
    let mut h = HashMap::new();
    for &x in xs {
        *h.entry(x).or_insert(0) += 1;
    }
    // Unordered iteration outside core scope: allowed by path.
    let _ = h.iter().count();
    h
}
