//! Criterion-style micro/macro bench harness (criterion itself is not
//! available offline). Every `cargo bench` target in `rust/benches/` uses
//! this: it warms up, runs timed iterations until a time budget or iteration
//! cap is reached, and reports mean/p50/p90/min/max. Results can also be
//! appended to a JSON-lines file so EXPERIMENTS.md tables are regenerated
//! from machine-readable output.

use crate::util::stats;
use std::time::{Duration, Instant};

/// One benchmark measurement summary (times in nanoseconds).
#[derive(Debug, Clone)]
pub struct Summary {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: u64,
    /// Mean (ns).
    pub mean_ns: f64,
    /// Median (ns).
    pub p50_ns: f64,
    /// P90 (ns).
    pub p90_ns: f64,
    /// Fastest sample (ns).
    pub min_ns: f64,
    /// Slowest sample (ns).
    pub max_ns: f64,
}

impl Summary {
    /// Print the criterion-style one-line summary.
    pub fn report(&self) {
        println!(
            "{:<48} {:>10} iters  mean {:>12}  p50 {:>12}  p90 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p90_ns)
        );
    }
}

/// Human-readable nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with a per-benchmark time budget.
pub struct Bencher {
    warmup: Duration,
    budget: Duration,
    max_iters: u64,
    results: Vec<Summary>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    /// Default harness: 200 ms warmup, 2 s timed budget.
    pub fn new() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Set the timed budget.
    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Set the warmup duration.
    pub fn with_warmup(mut self, warmup: Duration) -> Self {
        self.warmup = warmup;
        self
    }

    /// Cap the iteration count.
    pub fn with_max_iters(mut self, n: u64) -> Self {
        self.max_iters = n;
        self
    }

    /// Time `f` repeatedly; `f` receives the iteration index. Use
    /// `std::hint::black_box` inside `f` to defeat dead-code elimination.
    pub fn bench<F: FnMut(u64)>(&mut self, name: &str, mut f: F) -> &Summary {
        // Warmup.
        let start = Instant::now();
        let mut i = 0u64;
        while start.elapsed() < self.warmup && i < self.max_iters {
            f(i);
            i += 1;
        }
        // Timed samples.
        let mut samples: Vec<f64> = Vec::with_capacity(1024);
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.budget && iters < self.max_iters {
            let t = Instant::now();
            f(iters);
            samples.push(t.elapsed().as_nanos() as f64);
            iters += 1;
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let s = Summary {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples),
            p50_ns: stats::percentile_sorted(&sorted, 50.0),
            p90_ns: stats::percentile_sorted(&sorted, 90.0),
            min_ns: sorted.first().copied().unwrap_or(0.0),
            max_ns: sorted.last().copied().unwrap_or(0.0),
        };
        s.report();
        self.results.push(s);
        self.results.last().unwrap()
    }

    /// All summaries collected so far.
    pub fn results(&self) -> &[Summary] {
        &self.results
    }
}

/// Print a section header, visually matching criterion's grouping.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a table row in the experiment-harness format (pipes-aligned), so the
/// bench binaries emit the same rows the paper's tables/figures report.
pub fn table_row(cols: &[String]) {
    println!("| {} |", cols.join(" | "));
}

/// Write experiment output both to stdout and a results file under
/// `results/` (created on demand). Keeps EXPERIMENTS.md regenerable.
pub struct ResultsFile {
    path: std::path::PathBuf,
    lines: Vec<String>,
}

impl ResultsFile {
    /// Create/overwrite `results/<name>`.
    pub fn new(name: &str) -> Self {
        let dir = std::path::Path::new("results");
        let _ = std::fs::create_dir_all(dir);
        ResultsFile { path: dir.join(name), lines: Vec::new() }
    }

    /// Print a line and record it for the file.
    pub fn line(&mut self, s: impl AsRef<str>) {
        println!("{}", s.as_ref());
        self.lines.push(s.as_ref().to_string());
    }

    /// Record a line without printing it.
    pub fn raw(&mut self, s: impl AsRef<str>) {
        self.lines.push(s.as_ref().to_string());
    }
}

impl Drop for ResultsFile {
    fn drop(&mut self) {
        let body = self.lines.join("\n") + "\n";
        if let Err(e) = std::fs::write(&self.path, body) {
            eprintln!("warn: failed writing {}: {e}", self.path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let mut b = Bencher::new()
            .with_warmup(Duration::from_millis(1))
            .with_budget(Duration::from_millis(20));
        let s = b.bench("noop", |i| {
            std::hint::black_box(i * 2);
        });
        assert!(s.iters > 100);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p90_ns && s.p90_ns <= s.max_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("µs"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with("s"));
    }

    #[test]
    fn max_iters_cap() {
        let mut b = Bencher::new()
            .with_warmup(Duration::from_millis(0))
            .with_budget(Duration::from_secs(10))
            .with_max_iters(50);
        let s = b.bench("capped", |_| {});
        assert!(s.iters <= 50);
    }
}
