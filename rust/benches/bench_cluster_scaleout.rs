//! Cluster scale-out — 300 mixed agents at 3× density replayed through
//! 1/2/4/8 Justitia replicas under each placement policy.
//!
//! Beyond the paper: the single-GPU Justitia guarantee composed at cluster
//! level. Expected shape: avg JCT falls superlinearly while contention
//! dominates (each replica sheds swap pressure as well as queueing);
//! `cluster-vtime` placement should match `least-loaded` on efficiency while
//! keeping the max-min fair-share ratio lowest, and `round-robin` should
//! trail on both once elephants land unevenly.

use justitia::cluster::Placement;
use justitia::config::{Config, Policy};
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Cluster scale-out: replicas x placement (300 agents, 3x density)");
    let mut out = ResultsFile::new("bench_cluster_scaleout.txt");
    let counts = [1usize, 2, 4, 8];
    let rows = justitia::experiments::cluster_scaleout(
        &Config::default(),
        &counts,
        &Placement::ALL,
        Policy::Justitia,
        300,
        3.0,
        42,
    );
    out.line(format!(
        "{:<10} {:<14} {:>9} {:>9} {:>9} {:>10} {:>6}",
        "replicas", "placement", "avgJCT", "p99JCT", "makespan", "maxmin", "done"
    ));
    for r in &rows {
        out.line(format!(
            "{:<10} {:<14} {:>8.1}s {:>8.1}s {:>8.1}s {:>9.2}x {:>6}",
            r.replicas,
            r.placement.name(),
            r.avg_jct,
            r.p99_jct,
            r.makespan,
            r.maxmin_ratio,
            r.completed
        ));
    }

    // Headline: 8-replica cluster-vtime vs single replica.
    let get = |n: usize, p: Placement| {
        rows.iter().find(|r| r.replicas == n && r.placement == p).unwrap()
    };
    let one = get(1, Placement::ClusterVtime);
    let eight = get(8, Placement::ClusterVtime);
    out.line(format!(
        "cluster-vtime 1->8 replicas: avg JCT {:.1}s -> {:.1}s ({:.2}x), p99 {:.1}s -> {:.1}s",
        one.avg_jct,
        eight.avg_jct,
        one.avg_jct / eight.avg_jct.max(1e-9),
        one.p99_jct,
        eight.p99_jct
    ));
    let rr8 = get(8, Placement::RoundRobin);
    out.line(format!(
        "placement at 8 replicas: cluster-vtime maxmin {:.2}x vs round-robin {:.2}x",
        eight.maxmin_ratio, rr8.maxmin_ratio
    ));
}
