# Convenience targets; see README.md for the full quickstart.

.PHONY: artifacts build test test-release bench kick-tires smoke clean

# AOT-compile the tiny JAX+Pallas model to HLO text + weights for the Rust
# PJRT runtime (Layer 2/1 → Layer 3 handoff; needs jax installed).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

# Release-mode tests surface codegen-only issues; CI runs both.
test-release:
	cd rust && cargo test -q --release

kick-tires:
	scripts/kick-tires.sh

# The CI smoke job's mode: small agent counts, ~2 minutes, BENCH_*.json
# artifacts under out/.
smoke:
	scripts/kick-tires.sh --quick

clean:
	cd rust && cargo clean
	rm -rf out results
