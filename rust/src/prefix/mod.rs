//! Radix-tree prefix cache over token sequences with ref-counted,
//! copy-on-write KV page sharing (beyond the paper; cf. SGLang's RadixAttention
//! and vLLM's block-level prefix caching, and Cao et al. 2025 on co-designing
//! prefix locality with fair queuing).
//!
//! Task-parallel agents fan out inferences that open with the same system
//! prompt + accumulated context, and agent *families* re-submit the same
//! preamble across agents. Without sharing, every inference pays KV pages
//! for its own copy of that prefix — inflating both prefill latency and the
//! memory occupancy that Justitia's cost model (paper Eq. 1) charges. This
//! module deduplicates it:
//!
//! * **Token identity.** The simulator has no real text, so prompt content
//!   is derived deterministically: positions inside a task's
//!   [`PrefixGroup`](crate::workload::PrefixGroup) draw from the family's
//!   token stream, the remainder from a per-task stream
//!   ([`prompt_token_ids`]). Equal group ⇒ byte-equal prefix; everything
//!   else never collides at page granularity.
//! * **The tree.** A radix tree at *page* granularity: each node is one full
//!   page (`page_size` tokens) of prompt content plus the [`PageId`] holding
//!   its KV. Children are keyed by their full token chunk, so lookup walks
//!   whole pages; partial tail pages are never cached (they are the pages
//!   decode writes into — the copy-on-write boundary).
//! * **Ownership.** The tree holds one allocator reference per node
//!   ([`BlockAllocator::retain_page`]); every *attached* sequence holds one
//!   more per node on its path. Eviction (LRU over `refcount == 0` leaves)
//!   only ever drops the tree's own reference, so a page vanishes exactly
//!   when its last user lets go — conservation is checked by
//!   [`BlockAllocator::check_invariants_shared`].

use crate::kv::{BlockAllocator, PageId};
use crate::workload::{PrefixGroup, TaskId};
use std::collections::BTreeMap;
use std::collections::HashMap;

const SHARED_SALT: u64 = 0x5a1e_d001_cafe_f00d;
const UNIQUE_SALT: u64 = 0xd1b5_4a32_d192_ed03;

/// SplitMix64 — the statelessly-seedable mixer behind the token streams.
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Token at `pos` of the stream seeded by `seed`.
fn token_at(seed: u64, pos: u32) -> u32 {
    (splitmix(seed ^ ((pos as u64 + 1) << 1)) >> 16) as u32
}

/// Materialize the prompt token ids of one inference: the first
/// `group.tokens` positions come from the family stream (identical for every
/// task of the family), the rest from a task-unique stream.
pub fn prompt_token_ids(task: TaskId, prompt_tokens: u32, group: Option<PrefixGroup>) -> Vec<u32> {
    let unique = splitmix(UNIQUE_SALT ^ (((task.agent as u64) << 32) | task.index as u64));
    let shared = group.map(|g| (splitmix(SHARED_SALT ^ g.id), g.tokens));
    (0..prompt_tokens)
        .map(|i| match shared {
            Some((seed, len)) if i < len => token_at(seed, i),
            _ => token_at(unique, i),
        })
        .collect()
}

/// Result of matching a prompt against the tree.
#[derive(Debug, Clone, Default)]
pub struct PrefixMatch {
    /// Matched tree nodes, root-childmost order (not yet attached).
    pub path: Vec<usize>,
    /// The matched nodes' KV pages, in block-table order.
    pub pages: Vec<PageId>,
    /// Tokens covered (= `pages.len() × page_size`).
    pub tokens: u32,
}

#[derive(Debug, Clone)]
struct Node {
    /// This node's page content (exactly `page_size` tokens).
    tokens: Vec<u32>,
    /// KV page holding that content (tree owns one allocator reference).
    page: PageId,
    /// Children keyed by their full token chunk (radix step = one page).
    children: BTreeMap<Vec<u32>, usize>,
    parent: usize,
    /// Attached sequences at or below... strictly: sequences whose prefix
    /// path includes this node. 0 ⇒ evictable once childless.
    refs: u32,
    /// LRU stamp (logical tick of the last lookup/insert touching it).
    last_use: u64,
}

const ROOT: usize = 0;

/// The radix-tree prefix cache. One per engine replica; owns nothing but
/// tree structure — pages live in the engine's [`BlockAllocator`].
#[derive(Debug, Clone)]
pub struct PrefixCache {
    page_size: u32,
    /// Node arena; slot 0 is the (pageless) root, `None` = tombstone.
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    tick: u64,
}

impl PrefixCache {
    /// Empty cache for pages of `page_size` tokens.
    pub fn new(page_size: u32) -> Self {
        assert!(page_size > 0);
        let root = Node {
            tokens: Vec::new(),
            page: PageId::MAX,
            children: BTreeMap::new(),
            parent: ROOT,
            refs: 0,
            last_use: 0,
        };
        PrefixCache { page_size, nodes: vec![Some(root)], free_slots: Vec::new(), tick: 0 }
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("live node")
    }

    /// Number of pages currently held by the tree. O(1): every tombstoned
    /// slot is recorded in `free_slots`, so live nodes = arena − root −
    /// tombstones (this runs once per engine iteration for the occupancy
    /// gauge).
    pub fn cached_pages(&self) -> usize {
        debug_assert_eq!(
            self.nodes.len() - 1 - self.free_slots.len(),
            self.nodes.iter().skip(1).filter(|n| n.is_some()).count()
        );
        self.nodes.len() - 1 - self.free_slots.len()
    }

    /// One tree-held reference per node page — the `external` argument for
    /// [`BlockAllocator::check_invariants_shared`].
    pub fn page_holds(&self) -> HashMap<PageId, u32> {
        let mut holds: HashMap<PageId, u32> = HashMap::new();
        for n in self.nodes.iter().skip(1).flatten() {
            *holds.entry(n.page).or_insert(0) += 1;
        }
        holds
    }

    /// Walk the tree over `ids`, matching whole pages. Touches matched nodes
    /// for LRU purposes; does not attach.
    pub fn lookup(&mut self, ids: &[u32]) -> PrefixMatch {
        self.tick += 1;
        let tick = self.tick;
        let ps = self.page_size as usize;
        let mut m = PrefixMatch::default();
        let mut cur = ROOT;
        for chunk in ids.chunks_exact(ps) {
            let Some(&child) = self.node(cur).children.get(chunk) else { break };
            self.node_mut(child).last_use = tick;
            m.pages.push(self.node(child).page);
            m.path.push(child);
            cur = child;
        }
        m.tokens = (m.pages.len() * ps) as u32;
        m
    }

    /// Pin every node on `path` on behalf of one sequence (call after
    /// [`lookup`](Self::lookup), before anything else can evict).
    pub fn attach(&mut self, path: &[usize]) {
        for &n in path {
            self.node_mut(n).refs += 1;
        }
    }

    /// Undo [`attach`](Self::attach) for one sequence.
    pub fn detach(&mut self, path: &[usize]) {
        for &n in path {
            let r = &mut self.node_mut(n).refs;
            debug_assert!(*r >= 1, "detach of unattached node");
            *r = r.saturating_sub(1);
        }
    }

    /// Register a freshly-prefilled sequence's full prompt pages and attach
    /// the sequence to the whole chain. `ids` is the complete prompt token
    /// stream, `table` the sequence's block table, and `prior` the path the
    /// sequence already attached at admission (must be a prefix of the walk;
    /// its nodes are not re-attached).
    ///
    /// Where a chunk already exists in the tree (a sibling prefilled it
    /// first), the sequence *adopts* the cached page — its private copy is
    /// released back to the pool ([`BlockAllocator::adopt_page`]) — so
    /// same-iteration fan-out still deduplicates. Where it does not, the
    /// sequence's own page is donated to the tree (tree takes a reference).
    /// Returns the sequence's new full prefix path.
    pub fn insert_and_attach(
        &mut self,
        seq: TaskId,
        ids: &[u32],
        kv: &mut BlockAllocator,
        prior: &[usize],
    ) -> Vec<usize> {
        self.tick += 1;
        let tick = self.tick;
        let ps = self.page_size as usize;
        let full = ids.len() / ps;
        let mut path = Vec::with_capacity(full);
        let mut cur = ROOT;
        for i in 0..full {
            let chunk = &ids[i * ps..(i + 1) * ps];
            let next = match self.node(cur).children.get(chunk) {
                Some(&c) => {
                    // Chain already cached: adopt its page, drop ours.
                    let page = self.node(c).page;
                    kv.adopt_page(seq, i, page).expect("adopt cached page");
                    c
                }
                None => {
                    let page = kv.block_table(seq).expect("seq resident")[i];
                    kv.retain_page(page); // the tree's own reference
                    let node = Node {
                        tokens: chunk.to_vec(),
                        page,
                        children: BTreeMap::new(),
                        parent: cur,
                        refs: 0,
                        last_use: tick,
                    };
                    let slot = match self.free_slots.pop() {
                        Some(s) => {
                            self.nodes[s] = Some(node);
                            s
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    self.node_mut(cur).children.insert(chunk.to_vec(), slot);
                    slot
                }
            };
            self.node_mut(next).last_use = tick;
            path.push(next);
            cur = next;
        }
        debug_assert!(
            path.len() >= prior.len() && path[..prior.len()] == *prior,
            "admission-time match must be a prefix of the prefill-time chain"
        );
        // `prior` nodes already carry this sequence's reference.
        for &n in &path[prior.len()..] {
            self.node_mut(n).refs += 1;
        }
        path
    }

    /// Upper bound on the pages eviction could return to the pool right
    /// now: unpinned nodes whose page the tree is the sole holder of. Used
    /// to decide whether an eviction pass can possibly satisfy a request —
    /// without it, an infeasibly large admission would drain every
    /// reclaimable chain and still block. (Over-approximates: an unpinned
    /// inner node above a pinned descendant is counted but not evictable.)
    pub fn reclaimable_pages(&self, kv: &BlockAllocator) -> u32 {
        self.nodes
            .iter()
            .skip(1)
            .flatten()
            .filter(|n| n.refs == 0 && kv.page_ref(n.page) == 1)
            .count() as u32
    }

    /// Evict LRU unpinned leaves until the allocator has at least
    /// `target_free` free pages or nothing evictable remains. Returns the
    /// number of nodes dropped. Deterministic: ties on the LRU stamp break
    /// toward the lowest arena slot.
    pub fn evict_until(&mut self, kv: &mut BlockAllocator, target_free: u32) -> usize {
        let mut dropped = 0;
        while kv.free_pages() < target_free {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .skip(1)
                .filter_map(|(i, n)| n.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
                .min_by_key(|(i, n)| (n.last_use, *i))
                .map(|(i, _)| i);
            let Some(i) = victim else { break };
            let node = self.nodes[i].take().expect("victim live");
            self.free_slots.push(i);
            self.node_mut(node.parent).children.remove(&node.tokens);
            kv.release_page(node.page);
            dropped += 1;
        }
        dropped
    }

    /// Fractional occupancy charge for a sequence attached along `path`:
    /// each shared page's `page_size` token slots are split evenly across
    /// its current sharers (the attached sequences), so the sum of charges
    /// over all sharers equals the physical occupancy — the
    /// [`SharedMemoryCentric`](crate::cost::CostModel::SharedMemoryCentric)
    /// accounting identity.
    pub fn shared_charge(&self, path: &[usize]) -> f64 {
        path.iter().map(|&n| self.page_size as f64 / self.node(n).refs.max(1) as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tid(i: u32) -> TaskId {
        TaskId { agent: 0, index: i }
    }

    fn g(id: u64, tokens: u32) -> Option<PrefixGroup> {
        Some(PrefixGroup { id, tokens })
    }

    #[test]
    fn token_streams_share_exactly_the_prefix() {
        let a = prompt_token_ids(tid(1), 40, g(7, 24));
        let b = prompt_token_ids(TaskId { agent: 3, index: 0 }, 40, g(7, 24));
        assert_eq!(a[..24], b[..24], "family positions must match");
        assert_ne!(a[24..], b[24..], "task-unique positions must differ");
        let c = prompt_token_ids(tid(1), 40, g(8, 24));
        assert_ne!(a[..24], c[..24], "different families must differ");
        let d = prompt_token_ids(tid(1), 40, None);
        let e = prompt_token_ids(tid(2), 40, None);
        assert_ne!(d, e);
        // Deterministic.
        assert_eq!(a, prompt_token_ids(tid(1), 40, g(7, 24)));
    }

    #[test]
    fn insert_then_lookup_hits_full_pages_only() {
        let mut kv = BlockAllocator::new(16, 4);
        let mut cache = PrefixCache::new(4);
        let ids = prompt_token_ids(tid(1), 10, g(1, 10)); // 2 full pages + 2
        kv.allocate(tid(1), 10).unwrap(); // 3 pages
        let path = cache.insert_and_attach(tid(1), &ids, &mut kv, &[]);
        assert_eq!(path.len(), 2, "only full pages are cached");
        assert_eq!(cache.cached_pages(), 2);

        // A family sibling with a longer prompt matches both pages.
        let ids2 = prompt_token_ids(tid(2), 12, g(1, 10));
        let m = cache.lookup(&ids2);
        assert_eq!(m.pages.len(), 2);
        assert_eq!(m.tokens, 8);
        assert_eq!(m.path, path);
        // A stranger matches nothing.
        let m = cache.lookup(&prompt_token_ids(tid(3), 12, None));
        assert_eq!(m.pages.len(), 0);
        kv.check_invariants_shared(&cache.page_holds()).unwrap();
    }

    #[test]
    fn shared_admission_end_to_end() {
        let mut kv = BlockAllocator::new(8, 4);
        let mut cache = PrefixCache::new(4);
        let ids1 = prompt_token_ids(tid(1), 8, g(5, 8));
        kv.allocate(tid(1), 8).unwrap(); // 2 pages
        let p1 = cache.insert_and_attach(tid(1), &ids1, &mut kv, &[]);

        // Sibling arrives: matches, attaches, shares pages.
        let ids2 = prompt_token_ids(tid(2), 8, g(5, 8));
        let m = cache.lookup(&ids2);
        assert_eq!(m.tokens, 8);
        cache.attach(&m.path);
        kv.share_prefix(tid(2), &m.pages, 8).unwrap();
        assert_eq!(kv.free_pages(), 6, "no fresh pages for a full hit");
        kv.check_invariants_shared(&cache.page_holds()).unwrap();

        // Both leave; tree still pins the chain; then eviction reclaims it.
        cache.detach(&p1);
        kv.release(tid(1)).unwrap();
        cache.detach(&m.path);
        kv.release(tid(2)).unwrap();
        assert_eq!(kv.free_pages(), 6, "tree still holds the chain");
        let dropped = cache.evict_until(&mut kv, 8);
        assert_eq!(dropped, 2);
        assert_eq!(kv.free_pages(), 8);
        assert_eq!(cache.cached_pages(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn attached_nodes_are_not_evictable() {
        let mut kv = BlockAllocator::new(8, 4);
        let mut cache = PrefixCache::new(4);
        let ids = prompt_token_ids(tid(1), 8, g(2, 8));
        kv.allocate(tid(1), 8).unwrap();
        let path = cache.insert_and_attach(tid(1), &ids, &mut kv, &[]);
        assert_eq!(cache.evict_until(&mut kv, 8), 0, "attached chain must be pinned");
        cache.detach(&path);
        // Inner node still has a child ⇒ only the leaf goes first; both go.
        assert_eq!(cache.evict_until(&mut kv, 8), 2);
        kv.release(tid(1)).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn eviction_is_lru() {
        let mut kv = BlockAllocator::new(16, 4);
        let mut cache = PrefixCache::new(4);
        // Two independent single-page chains.
        for (i, fam) in [(1u32, 11u64), (2, 22)] {
            let ids = prompt_token_ids(tid(i), 4, g(fam, 4));
            kv.allocate(tid(i), 4).unwrap();
            let p = cache.insert_and_attach(tid(i), &ids, &mut kv, &[]);
            cache.detach(&p);
            kv.release(tid(i)).unwrap();
        }
        // Touch family 11 so family 22 becomes LRU.
        cache.lookup(&prompt_token_ids(tid(9), 4, g(11, 4)));
        let holds_before = cache.page_holds();
        assert_eq!(holds_before.len(), 2);
        let free_before = kv.free_pages();
        assert_eq!(cache.evict_until(&mut kv, free_before + 1), 1);
        // The surviving node is family 11's (still matched).
        assert_eq!(cache.lookup(&prompt_token_ids(tid(9), 4, g(11, 4))).pages.len(), 1);
        assert_eq!(cache.lookup(&prompt_token_ids(tid(9), 4, g(22, 4))).pages.len(), 0);
    }

    #[test]
    fn sibling_insert_adopts_cached_pages() {
        let mut kv = BlockAllocator::new(8, 4);
        let mut cache = PrefixCache::new(4);
        let ids1 = prompt_token_ids(tid(1), 8, g(9, 8));
        let ids2 = prompt_token_ids(tid(2), 8, g(9, 8));
        // Both admitted before either prefilled (same engine iteration):
        // both hold private pages.
        kv.allocate(tid(1), 8).unwrap();
        kv.allocate(tid(2), 8).unwrap();
        assert_eq!(kv.free_pages(), 4);
        let p1 = cache.insert_and_attach(tid(1), &ids1, &mut kv, &[]);
        // Second insert finds the chain and adopts: its 2 private pages are
        // returned to the pool.
        let p2 = cache.insert_and_attach(tid(2), &ids2, &mut kv, &[]);
        assert_eq!(p1, p2);
        assert_eq!(kv.free_pages(), 6);
        assert_eq!(kv.block_table(tid(1)).unwrap(), kv.block_table(tid(2)).unwrap());
        kv.check_invariants_shared(&cache.page_holds()).unwrap();
        assert!((cache.shared_charge(&p1) - 4.0).abs() < 1e-12, "2 sharers × (4/2 per page)");
    }

    #[test]
    fn reclaimable_counts_only_sole_holder_unpinned_nodes() {
        let mut kv = BlockAllocator::new(8, 4);
        let mut cache = PrefixCache::new(4);
        let ids = prompt_token_ids(tid(1), 8, g(6, 8));
        kv.allocate(tid(1), 8).unwrap();
        let path = cache.insert_and_attach(tid(1), &ids, &mut kv, &[]);
        // Attached: nothing reclaimable.
        assert_eq!(cache.reclaimable_pages(&kv), 0);
        // Detached but the sequence still holds the pages: evicting would
        // free no memory, so still nothing reclaimable.
        cache.detach(&path);
        assert_eq!(cache.reclaimable_pages(&kv), 0);
        // Once the sequence exits, both chain pages are reclaimable.
        kv.release(tid(1)).unwrap();
        assert_eq!(cache.reclaimable_pages(&kv), 2);
        cache.evict_until(&mut kv, 8);
        assert_eq!(cache.reclaimable_pages(&kv), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn shared_charge_splits_across_sharers() {
        let mut kv = BlockAllocator::new(8, 4);
        let mut cache = PrefixCache::new(4);
        let ids = prompt_token_ids(tid(1), 4, g(3, 4));
        kv.allocate(tid(1), 4).unwrap();
        let path = cache.insert_and_attach(tid(1), &ids, &mut kv, &[]);
        assert!((cache.shared_charge(&path) - 4.0).abs() < 1e-12);
        cache.attach(&path); // a second sharer
        assert!((cache.shared_charge(&path) - 2.0).abs() < 1e-12);
        cache.detach(&path);
        assert!((cache.shared_charge(&path) - 4.0).abs() < 1e-12);
    }
}
