//! Command-line argument parsing (clap is unavailable offline).
//!
//! Grammar: `justitia <subcommand> [--flag value] [--switch] [positional...]`.
//! Flags may be given as `--key value` or `--key=value`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token, e.g. `serve` or `experiment`.
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` pairs.
    pub flags: BTreeMap<String, String>,
    /// Boolean `--switch` flags.
    pub switches: Vec<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_switches` lists boolean flags that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_switches: &[&str]) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&stripped) {
                    args.switches.push(stripped.to_string());
                } else if let Some(next) = iter.peek() {
                    if next.starts_with("--") {
                        args.switches.push(stripped.to_string());
                    } else {
                        let v = iter.next().unwrap();
                        args.flags.insert(stripped.to_string(), v);
                    }
                } else {
                    args.switches.push(stripped.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse from the process environment.
    pub fn from_env(known_switches: &[&str]) -> Args {
        Self::parse(std::env::args().skip(1), known_switches)
    }

    /// Value of flag `key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Value of flag `key`, or `default`.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse flag `key` as `u64`, or `default`.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Parse flag `key` as `usize`, or `default`.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Parse flag `key` as `f64`, or `default`.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Whether boolean switch `switch` was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(raw: &[&str]) -> Args {
        Args::parse(raw.iter().map(|s| s.to_string()), &["verbose", "dry-run"])
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--port", "8080", "--scheduler=justitia", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("scheduler"), Some("justitia"));
        assert!(a.has("verbose"));
        assert!(!a.has("dry-run"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["run", "--n", "42", "--rate", "1.5"]);
        assert_eq!(a.get_u64("n", 0), 42);
        assert!((a.get_f64("rate", 0.0) - 1.5).abs() < 1e-12);
        assert_eq!(a.get_u64("missing", 7), 7);
    }

    #[test]
    fn positionals() {
        let a = parse(&["bench", "fig7", "fig8"]);
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig7", "fig8"]);
    }

    #[test]
    fn trailing_unknown_flag_is_switch() {
        let a = parse(&["x", "--flag"]);
        assert!(a.has("flag"));
    }

    #[test]
    fn unknown_flag_followed_by_flag_is_switch() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.has("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
