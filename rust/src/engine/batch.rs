//! Batch formation as a first-class policy (DESIGN.md §15).
//!
//! Chunked prefill (DESIGN.md §10) gave every engine iteration a shared token
//! budget but hard-coded how that budget is split: decodes take one token
//! each, then pending prefill chunks greedily fill whatever is left. That
//! static split is exactly the tension FairBatching (arxiv 2510.14392)
//! targets — prefill admission steals decode headroom and inflates the ITL
//! tail of running agents — and it was measurable here (log-bucket ITL
//! histogram, `beta_mixed`) but not steerable.
//!
//! This module extracts the split into a [`BatchPolicy`]: each iteration the
//! engine shows the policy the batch state ([`BatchObs`]) and receives a
//! prefill token/slot allowance ([`BatchPlan`]). The fair queue still decides
//! *which* prefills get the prefill share — the policy only sizes the share,
//! so fairness ordering and batch sizing stay orthogonal, composable axes.
//!
//! Three implementations:
//!
//! * [`StaticBudget`] — the default. Unbounded allowance: every `min` in the
//!   composition loop is an arithmetic identity, so the engine is
//!   bit-identical to the pre-policy code on both cores
//!   (`prop_batch_policy_identity`).
//! * [`FixedSplit`] — reserve a configured number of tokens for decodes;
//!   prefill may never use more than `budget − reserve`. With reserve 0 this
//!   degenerates to `StaticBudget` (also property-tested).
//! * [`FairBatching`] — a closed loop over SLO pressure: shrink the prefill
//!   share multiplicatively when the windowed p99 ITL of running decodes
//!   breaches the tightest active class SLO, grow it additively when latency
//!   is comfortably inside the SLO *and* TTFT pressure (pending prefill work
//!   or TTFT deadline misses) dominates. A hysteresis band (grow only below
//!   `0.8 × SLO`) plus a cooldown between adjustments prevents the
//!   shrink/grow limit cycle a naive bang-bang controller produces.
//!
//! Policies are only consulted in chunk mode: without a finite budget there
//! is nothing to split, so every policy is inert when `chunked_prefill` is
//! off (the third property in `prop_batch_policy_identity`).

use crate::config::{BatchPolicyKind, Config};

/// Resolved per-iteration batching knobs. Consolidates the tri-state config
/// surface (`chunked_prefill: bool` + two `u32` knobs with `u32::MAX`
/// sentinels previously threaded through engine fields) into one value built
/// once at `Engine::new`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Max prompt tokens one sequence may prefill per iteration
    /// (`u32::MAX` = unchunked atomic admission).
    pub chunk: u32,
    /// Per-iteration token budget shared by decodes and prefill chunks
    /// (`u32::MAX` = unbounded).
    pub budget: u32,
    /// Which [`BatchPolicy`] sizes the prefill share each iteration.
    pub kind: BatchPolicyKind,
    /// Decode reservation for [`BatchPolicyKind::FixedSplit`].
    pub decode_reserve: u32,
}

impl BatchConfig {
    /// Resolve the legacy config surface. `chunked_prefill = false` maps both
    /// knobs to the `u32::MAX` sentinel (the unchunked engine); when enabled
    /// the knobs are clamped to ≥ 1, preserving the documented degenerate
    /// case that `prefill_chunk = u32::MAX` with an unbounded budget is
    /// bit-identical to chunking off.
    pub fn resolve(cfg: &Config) -> Self {
        let (chunk, budget) = if cfg.chunked_prefill {
            (cfg.prefill_chunk.max(1), cfg.max_batched_tokens.max(1))
        } else {
            (u32::MAX, u32::MAX)
        };
        BatchConfig { chunk, budget, kind: cfg.batch_policy, decode_reserve: cfg.decode_reserve }
    }

    /// Is per-iteration budgeting active? False for the classical
    /// whole-prompt admission path.
    pub fn chunk_mode(&self) -> bool {
        self.chunk != u32::MAX || self.budget != u32::MAX
    }
}

/// What a [`BatchPolicy`] sees when the engine composes an iteration.
#[derive(Debug, Clone, Copy)]
pub struct BatchObs {
    /// The configured per-iteration token budget (`BatchConfig::budget`).
    pub total_budget: u32,
    /// Budget remaining after earlier composition bookkeeping (currently the
    /// full budget — decodes are charged inside the loop).
    pub budget: u32,
    /// Running sequences currently in decode (prefill complete).
    pub decoders: u32,
    /// Running sequences still owing prefill work (fresh, swapped-in, or
    /// recompute re-entries at the head of the fair queue).
    pub prefills_pending: u32,
    /// Agents parked in the waiting set (admission-blocked TTFT pressure).
    pub waiting: u64,
    /// Free device KV pages.
    pub kv_free_pages: u64,
}

/// The policy's answer: how much of this iteration goes to prefill.
/// `u32::MAX` means "no cap" for either field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchPlan {
    /// Max prompt tokens this iteration may spend on prefill chunks.
    pub prefill_tokens: u32,
    /// Max distinct sequences that may prefill this iteration.
    pub prefill_seqs: u32,
}

impl BatchPlan {
    /// The unbounded plan: composition reduces to the pre-policy arithmetic.
    pub fn unbounded() -> Self {
        BatchPlan { prefill_tokens: u32::MAX, prefill_seqs: u32::MAX }
    }
}

/// One controller adjustment, exported to the flight recorder so batch-policy
/// decisions join the scheduler pick audit in the Chrome trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchAudit {
    /// Prefill share of the budget after the adjustment (0.1 ..= 1.0).
    pub prefill_share: f64,
    /// The share in tokens at the current budget.
    pub prefill_tokens: u32,
    /// Windowed p99 ITL (ms) that triggered the adjustment.
    pub itl_p99_ms: f64,
    /// True if the share grew (TTFT pressure won), false if it shrank
    /// (ITL breach won).
    pub grew: bool,
}

/// Per-iteration batch composition policy. Implementations must be cheap:
/// `plan` runs once per engine iteration on the hot path (chunk mode only).
///
/// Feedback methods are only invoked when the engine runs with
/// `wants_feedback()` policies in chunk mode, always from code shared by the
/// tick and event cores, so a feedback-free policy adds zero work and the
/// two cores cannot diverge through this trait.
pub trait BatchPolicy: Send {
    /// Size the prefill share for the iteration being composed.
    fn plan(&mut self, obs: &BatchObs) -> BatchPlan;

    /// Display name (trace audit rows, `run` output).
    fn name(&self) -> &'static str;

    /// Does this policy consume latency feedback? Lets the engine skip the
    /// per-iteration SLO bookkeeping for open-loop policies.
    fn wants_feedback(&self) -> bool {
        false
    }

    /// One engine iteration retired with `decoders` running decodes, each
    /// observing `itl_ms` inter-token latency; `min_slo_ms` is the tightest
    /// p99-ITL SLO among those decoders' classes.
    fn on_iteration(&mut self, _itl_ms: f64, _min_slo_ms: f64, _decoders: u32) {}

    /// A sequence produced its first token `ttft_ms` after task-ready,
    /// against a `slo_ms` TTFT deadline.
    fn on_first_token(&mut self, _ttft_ms: f64, _slo_ms: f64) {}

    /// Drain the audit entry for the most recent adjustment, if any. Only
    /// called when tracing is enabled; never affects `plan`.
    fn audit(&mut self) -> Option<BatchAudit> {
        None
    }
}

/// Instantiate the configured policy.
pub fn build(batch: &BatchConfig) -> Box<dyn BatchPolicy> {
    match batch.kind {
        BatchPolicyKind::Static => Box::new(StaticBudget),
        BatchPolicyKind::FixedSplit => Box::new(FixedSplit { reserve: batch.decode_reserve }),
        BatchPolicyKind::FairBatching => Box::new(FairBatching::new()),
    }
}

/// Today's behavior: decodes one token each, prefill fills the rest. The
/// unbounded plan makes every `min` in the composition loop an identity.
pub struct StaticBudget;

impl BatchPolicy for StaticBudget {
    fn plan(&mut self, _obs: &BatchObs) -> BatchPlan {
        BatchPlan::unbounded()
    }

    fn name(&self) -> &'static str {
        "static"
    }
}

/// Reserve `reserve` tokens of the budget for decodes. Prefill chunks may
/// use at most `total_budget − reserve` tokens per iteration; decodes are
/// never capped (a reservation only withholds, it does not schedule).
pub struct FixedSplit {
    /// Tokens withheld from prefill each iteration.
    pub reserve: u32,
}

impl BatchPolicy for FixedSplit {
    fn plan(&mut self, obs: &BatchObs) -> BatchPlan {
        // MAX budget (policy active without chunking) keeps MAX allowance:
        // saturating_sub would otherwise invent a finite cap from nothing.
        if obs.total_budget == u32::MAX {
            return BatchPlan::unbounded();
        }
        BatchPlan {
            prefill_tokens: obs.total_budget.saturating_sub(self.reserve),
            prefill_seqs: u32::MAX,
        }
    }

    fn name(&self) -> &'static str {
        "fixed-split"
    }
}

/// Closed-loop prefill/decode reallocation (FairBatching, arxiv 2510.14392).
///
/// The controller holds a prefill share in `[MIN_SHARE, 1.0]`, starting at
/// 1.0 (= `StaticBudget` until pressure appears):
///
/// * **Shrink** (`share ×= SHRINK`) when the p99 of the last
///   [`ITL_WINDOW`] ITL samples breaches the tightest active SLO — running
///   decodes are visibly suffering from mixed-batch interference.
/// * **Grow** (`share += GROW_STEP`) only when p99 ITL is below
///   `GROW_MARGIN ×` SLO *and* TTFT pressure is live (pending prefill work
///   at plan time, or a TTFT deadline miss since the last adjustment).
///
/// The asymmetric band between `GROW_MARGIN × SLO` and `SLO` is the
/// hysteresis: a share that pushed p99 into the band stays put instead of
/// oscillating. [`COOLDOWN`] iterations must pass between adjustments so
/// each new share is measured before the next move (the ITL window must
/// partially refill under the new split).
pub struct FairBatching {
    /// Current prefill share of the budget.
    share: f64,
    /// Ring of recent per-iteration ITL samples (ms).
    itl_window: [f64; Self::ITL_WINDOW],
    /// Valid samples in `itl_window` (≤ ITL_WINDOW).
    itl_len: usize,
    /// Next write slot in the ring.
    itl_next: usize,
    /// Tightest p99-ITL SLO (ms) seen among recent decoders.
    min_slo_ms: f64,
    /// Feedback events since the last adjustment.
    since_adjust: u32,
    /// TTFT deadline misses since the last adjustment.
    ttft_misses: u32,
    /// Prefill work was pending at the most recent `plan` call.
    prefill_pressure: bool,
    /// Audit entry for the most recent adjustment, drained by the tracer.
    pending_audit: Option<BatchAudit>,
}

impl FairBatching {
    /// ITL ring capacity: enough samples for a stable p99 estimate without
    /// remembering pressure from a regime that has already passed.
    const ITL_WINDOW: usize = 64;
    /// Floor on the prefill share — prefill must never fully starve or TTFT
    /// diverges (and admission, which frees KV for decodes, stalls with it).
    const MIN_SHARE: f64 = 0.1;
    /// Multiplicative shrink on SLO breach (fast backoff).
    const SHRINK: f64 = 0.7;
    /// Additive growth under slack (slow recovery) — the classic AIMD shape.
    const GROW_STEP: f64 = 0.05;
    /// Grow only when p99 ITL is below this fraction of the SLO.
    const GROW_MARGIN: f64 = 0.8;
    /// Minimum feedback events between adjustments.
    const COOLDOWN: u32 = 8;
    /// Minimum ring occupancy before the p99 estimate is trusted.
    const MIN_SAMPLES: usize = 8;

    /// A fresh controller at full prefill share.
    pub fn new() -> Self {
        FairBatching {
            share: 1.0,
            itl_window: [0.0; Self::ITL_WINDOW],
            itl_len: 0,
            itl_next: 0,
            min_slo_ms: f64::INFINITY,
            since_adjust: 0,
            ttft_misses: 0,
            prefill_pressure: false,
            pending_audit: None,
        }
    }

    /// Current prefill share (tests; the engine only sees plans).
    pub fn share(&self) -> f64 {
        self.share
    }

    /// p99 of the current ITL window (sorted copy — 64 elements, off the
    /// per-token path: only runs on feedback events past the cooldown).
    fn itl_p99_ms(&self) -> f64 {
        if self.itl_len == 0 {
            return 0.0;
        }
        let mut v = self.itl_window[..self.itl_len].to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        let idx = ((self.itl_len as f64) * 0.99).ceil() as usize;
        v[idx.clamp(1, self.itl_len) - 1]
    }

    /// Apply the control law after new feedback.
    fn adjust(&mut self) {
        self.since_adjust = self.since_adjust.saturating_add(1);
        if self.since_adjust < Self::COOLDOWN
            || self.itl_len < Self::MIN_SAMPLES
            || !self.min_slo_ms.is_finite()
        {
            return;
        }
        let p99 = self.itl_p99_ms();
        let breach = p99 > self.min_slo_ms;
        let slack = p99 < Self::GROW_MARGIN * self.min_slo_ms;
        let ttft_pressure = self.ttft_misses > 0 || self.prefill_pressure;
        let old = self.share;
        if breach {
            self.share = (self.share * Self::SHRINK).max(Self::MIN_SHARE);
        } else if slack && ttft_pressure {
            self.share = (self.share + Self::GROW_STEP).min(1.0);
        }
        if self.share != old {
            self.since_adjust = 0;
            self.ttft_misses = 0;
            self.pending_audit =
                Some(BatchAudit { prefill_share: self.share, prefill_tokens: 0, itl_p99_ms: p99, grew: self.share > old });
        }
    }
}

impl Default for FairBatching {
    fn default() -> Self {
        Self::new()
    }
}

impl BatchPolicy for FairBatching {
    fn plan(&mut self, obs: &BatchObs) -> BatchPlan {
        self.prefill_pressure = obs.prefills_pending > 0 || obs.waiting > 0;
        if obs.total_budget == u32::MAX {
            return BatchPlan::unbounded();
        }
        let tokens = ((obs.total_budget as f64) * self.share).max(1.0) as u32;
        if let Some(a) = self.pending_audit.as_mut() {
            a.prefill_tokens = tokens;
        }
        BatchPlan { prefill_tokens: tokens, prefill_seqs: u32::MAX }
    }

    fn name(&self) -> &'static str {
        "fairbatching"
    }

    fn wants_feedback(&self) -> bool {
        true
    }

    fn on_iteration(&mut self, itl_ms: f64, min_slo_ms: f64, decoders: u32) {
        if decoders == 0 {
            return;
        }
        self.itl_window[self.itl_next] = itl_ms;
        self.itl_next = (self.itl_next + 1) % Self::ITL_WINDOW;
        self.itl_len = (self.itl_len + 1).min(Self::ITL_WINDOW);
        // Track the tightest SLO currently in play; decays only by restart,
        // which is fine — classes don't leave a suite mid-run.
        if min_slo_ms < self.min_slo_ms {
            self.min_slo_ms = min_slo_ms;
        }
        self.adjust();
    }

    fn on_first_token(&mut self, ttft_ms: f64, slo_ms: f64) {
        if ttft_ms > slo_ms {
            self.ttft_misses = self.ttft_misses.saturating_add(1);
        }
    }

    fn audit(&mut self) -> Option<BatchAudit> {
        self.pending_audit.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(total: u32) -> BatchObs {
        BatchObs {
            total_budget: total,
            budget: total,
            decoders: 4,
            prefills_pending: 2,
            waiting: 3,
            kv_free_pages: 10,
        }
    }

    #[test]
    fn batch_config_resolution_round_trips() {
        // Off: both knobs collapse to the sentinel, chunk mode is false.
        let cfg = Config::default();
        let b = BatchConfig::resolve(&cfg);
        assert_eq!((b.chunk, b.budget), (u32::MAX, u32::MAX));
        assert!(!b.chunk_mode());
        assert_eq!(b.kind, BatchPolicyKind::Static);

        // On: the legacy knobs flow through, clamped to >= 1.
        let cfg = Config {
            chunked_prefill: true,
            prefill_chunk: 128,
            max_batched_tokens: 1024,
            batch_policy: BatchPolicyKind::FixedSplit,
            decode_reserve: 64,
            ..Config::default()
        };
        let b = BatchConfig::resolve(&cfg);
        assert_eq!((b.chunk, b.budget), (128, 1024));
        assert!(b.chunk_mode());
        assert_eq!((b.kind, b.decode_reserve), (BatchPolicyKind::FixedSplit, 64));

        // Degenerate: chunking "on" with MAX knobs stays the sentinel pair
        // (MAX.max(1) == MAX) — the documented bit-identical case.
        let cfg = Config {
            chunked_prefill: true,
            prefill_chunk: u32::MAX,
            max_batched_tokens: u32::MAX,
            ..Config::default()
        };
        let b = BatchConfig::resolve(&cfg);
        assert_eq!((b.chunk, b.budget), (u32::MAX, u32::MAX));
    }

    #[test]
    fn static_budget_is_unbounded() {
        let mut p = StaticBudget;
        for total in [64, 2048, u32::MAX] {
            assert_eq!(p.plan(&obs(total)), BatchPlan::unbounded());
        }
        assert!(!p.wants_feedback());
        assert!(p.audit().is_none());
    }

    #[test]
    fn fixed_split_reserves_decode_tokens() {
        let mut p = FixedSplit { reserve: 256 };
        assert_eq!(p.plan(&obs(2048)).prefill_tokens, 1792);
        // Reserve beyond the budget floors at zero prefill, not underflow.
        assert_eq!(p.plan(&obs(100)).prefill_tokens, 0);
        // Unbounded budget stays unbounded (policy inert without chunking).
        assert_eq!(p.plan(&obs(u32::MAX)), BatchPlan::unbounded());
        // Zero reserve degenerates to the static plan's token count.
        let mut z = FixedSplit { reserve: 0 };
        assert_eq!(z.plan(&obs(2048)).prefill_tokens, 2048);
    }

    #[test]
    fn fairbatching_shrinks_on_itl_breach() {
        let mut p = FairBatching::new();
        p.plan(&obs(2048)); // register prefill pressure
        for _ in 0..64 {
            p.on_iteration(300.0, 150.0, 4); // p99 way over SLO
        }
        assert!(p.share() < 1.0, "share must shrink under sustained breach");
        let a = p.audit().expect("adjustment must leave an audit entry");
        assert!(!a.grew);
        assert!(a.itl_p99_ms > 150.0);
    }

    #[test]
    fn fairbatching_grows_only_under_slack_and_ttft_pressure() {
        let mut p = FairBatching::new();
        p.plan(&obs(2048));
        // Shrink first so there is room to grow.
        for _ in 0..64 {
            p.on_iteration(300.0, 150.0, 4);
        }
        let low = p.share();
        assert!(low < 1.0);
        // Comfortable ITL but NO ttft pressure: a full-share plan with an
        // empty queue clears the pressure bit, so the share must hold
        // (hysteresis: inside the band nothing moves).
        let idle =
            BatchObs { prefills_pending: 0, waiting: 0, ..obs(2048) };
        p.plan(&idle);
        for _ in 0..128 {
            p.on_iteration(100.0, 150.0, 4);
        }
        assert_eq!(p.share(), low, "no growth without TTFT pressure");
        // Now with pressure: misses + pending prefill → additive growth.
        p.plan(&obs(2048));
        p.on_first_token(20_000.0, 10_000.0);
        for _ in 0..256 {
            p.on_iteration(100.0, 150.0, 4);
        }
        assert!(p.share() > low, "slack + TTFT pressure must grow the share");
    }

    #[test]
    fn fairbatching_share_stays_bounded_under_extreme_inputs() {
        let mut p = FairBatching::new();
        p.plan(&obs(2048));
        // Hammer with breaches: share must floor at MIN_SHARE, not 0.
        for _ in 0..10_000 {
            p.on_iteration(1.0e9, 1.0e-9, 8);
            p.on_first_token(1.0e9, 1.0e-9);
        }
        assert!(p.share() >= FairBatching::MIN_SHARE - 1e-12);
        let plan = p.plan(&obs(2048));
        assert!(plan.prefill_tokens >= 1, "prefill never fully starves");
        // Hammer with slack + pressure: share must cap at 1.0.
        let mut p = FairBatching::new();
        for _ in 0..10_000 {
            p.plan(&obs(2048));
            p.on_first_token(1.0e9, 1.0e-9);
            p.on_iteration(1.0e-6, 1.0e9, 8);
        }
        assert!(p.share() <= 1.0 + 1e-12);
        assert!(p.plan(&obs(2048)).prefill_tokens <= 2048);
    }

    #[test]
    fn fairbatching_cooldown_limits_adjustment_rate() {
        let mut p = FairBatching::new();
        p.plan(&obs(2048));
        let mut adjustments = 0u32;
        for _ in 0..640 {
            p.on_iteration(300.0, 150.0, 4);
            if p.audit().is_some() {
                adjustments += 1;
            }
        }
        // 640 feedback events / cooldown 8 = at most 80 moves; the warmup
        // (MIN_SAMPLES) and the MIN_SHARE floor only reduce the count.
        assert!(adjustments >= 1, "sustained breach must adjust at least once");
        assert!(
            adjustments <= 640 / FairBatching::COOLDOWN,
            "cooldown must bound adjustment frequency ({adjustments})"
        );
        // Zero-decoder iterations are not feedback.
        let before = p.share();
        for _ in 0..100 {
            p.on_iteration(1.0e9, 1.0e-9, 0);
        }
        assert_eq!(p.share(), before);
    }

    #[test]
    fn build_matches_kind() {
        for kind in BatchPolicyKind::ALL {
            let b = BatchConfig { chunk: 512, budget: 2048, kind, decode_reserve: 256 };
            assert_eq!(build(&b).name(), kind.name());
        }
    }
}
