//! Property tests for the VTC baseline's own fairness invariant (Sheng et
//! al.): among continuously-backlogged agents, the difference in received
//! service (virtual token counters) stays bounded — VTC approximates
//! instantaneous fair sharing. This pins down the *reference* scheduler the
//! Fig. 8 fair ratios are normalized against.

use justitia::config::Policy;
use justitia::sched::{vtc::service_delta, AgentInfo, Scheduler, TaskInfo};
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::TaskId;

/// A synthetic service trace: n agents, each with a stream of tasks of
/// random size, drained one admission at a time.
#[derive(Debug, Clone)]
struct Trace {
    n_agents: u32,
    /// (agent, prompt, decode) in push order.
    tasks: Vec<(u32, u32, u32)>,
}

struct TraceStrategy;

impl Strategy for TraceStrategy {
    type Value = Trace;

    fn generate(&self, rng: &mut Rng) -> Trace {
        let n_agents = rng.range_u64(2, 6) as u32;
        let n_tasks = rng.range_u64(20, 120) as usize;
        let tasks = (0..n_tasks)
            .map(|_| {
                (
                    rng.below(n_agents as u64) as u32,
                    rng.range_u64(10, 200) as u32,
                    rng.range_u64(5, 100) as u32,
                )
            })
            .collect();
        Trace { n_agents, tasks }
    }

    fn shrink(&self, v: &Trace) -> Vec<Trace> {
        let mut out = Vec::new();
        if v.tasks.len() > 4 {
            out.push(Trace { n_agents: v.n_agents, tasks: v.tasks[..v.tasks.len() / 2].to_vec() });
        }
        out
    }
}

#[test]
fn vtc_counters_stay_balanced_for_backlogged_agents() {
    let cfg = PropConfig { cases: 60, seed: 0x57c, max_shrink_steps: 30 };
    check(&cfg, &TraceStrategy, |trace| {
        let mut s = justitia::sched::vtc::Vtc::new(justitia::cost::CostModel::ComputeCentric);
        for a in 0..trace.n_agents {
            s.on_agent_arrival(&AgentInfo::new(a, 0.0, 0.0), 0.0);
        }
        // Push everything up front: all agents continuously backlogged while
        // they still have tasks.
        let mut remaining = vec![0u32; trace.n_agents as usize];
        for (i, &(a, p, d)) in trace.tasks.iter().enumerate() {
            s.push_task(
                TaskInfo {
                    id: TaskId { agent: a, index: i as u32 },
                    prompt_tokens: p,
                    predicted_decode: d as f64,
                    seq: i as u64,
                },
                0.0,
            );
            remaining[a as usize] += 1;
        }
        let max_task: f64 = trace
            .tasks
            .iter()
            .map(|&(_, p, d)| service_delta(p, d))
            .fold(0.0, f64::max);

        // Serve one task at a time; whenever every agent is still
        // backlogged, counters must not diverge by more than one task's
        // worth of service (the VTC bound).
        while let Some(t) = s.pop_next(0.0) {
            let (_, p, d) = trace.tasks[t.seq as usize];
            s.on_service(t.id.agent, service_delta(p, d));
            remaining[t.id.agent as usize] -= 1;
            if remaining.iter().all(|&r| r > 0) {
                let counters: Vec<f64> = (0..trace.n_agents).map(|a| s.counter(a)).collect();
                let spread = counters.iter().cloned().fold(f64::MIN, f64::max)
                    - counters.iter().cloned().fold(f64::MAX, f64::min);
                if spread > 2.0 * max_task + 1e-9 {
                    return Err(format!(
                        "counter spread {spread:.0} > 2*max_task {max_task:.0}: {counters:?}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn vtc_drains_all_tasks_exactly_once() {
    let cfg = PropConfig { cases: 40, seed: 0x57d, max_shrink_steps: 20 };
    check(&cfg, &TraceStrategy, |trace| {
        let mut s = justitia::sched::vtc::Vtc::new(justitia::cost::CostModel::ComputeCentric);
        for a in 0..trace.n_agents {
            s.on_agent_arrival(&AgentInfo::new(a, 0.0, 0.0), 0.0);
        }
        for (i, &(a, p, d)) in trace.tasks.iter().enumerate() {
            s.push_task(
                TaskInfo {
                    id: TaskId { agent: a, index: i as u32 },
                    prompt_tokens: p,
                    predicted_decode: d as f64,
                    seq: i as u64,
                },
                0.0,
            );
        }
        let mut seen = std::collections::HashSet::new();
        while let Some(t) = s.pop_next(0.0) {
            if !seen.insert(t.seq) {
                return Err(format!("task {} popped twice", t.seq));
            }
            s.on_service(t.id.agent, 1.0);
        }
        if seen.len() != trace.tasks.len() {
            return Err(format!("drained {} of {}", seen.len(), trace.tasks.len()));
        }
        Ok(())
    });
}
