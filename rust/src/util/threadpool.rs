//! A fixed-size thread pool (tokio is unavailable offline).
//!
//! Used by the experiment drivers to run independent simulations (e.g. the
//! 6-scheduler × 3-density Fig. 7 sweep) in parallel, and by the HTTP server
//! to handle connections. Work items are boxed closures over an MPMC channel
//! built from `std::sync::mpsc` plus a mutex-guarded receiver.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Dropping the pool joins all workers after the
/// queued jobs complete.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Create a pool with `n` worker threads (`n >= 1`).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("justitia-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Pool sized to the number of available CPUs.
    pub fn with_cpus() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool alive").send(Box::new(f)).expect("workers alive");
    }

    /// Run `f` over every item of `items` on the pool and collect results in
    /// input order. Blocks until all complete.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker panicked");
            out[i] = Some(r);
        }
        out.into_iter().map(|x| x.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<u32> = pool.map(Vec::<u32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_is_sequential_safe() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
