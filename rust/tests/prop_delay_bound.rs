//! Property tests for Theorem B.1 (the constant delay bound) and core
//! scheduler invariants, using the in-house mini property-testing framework
//! (`justitia::util::prop`) against randomized agent sets.
//!
//! Theorem B.1: under Justitia, every agent completes within a constant time
//! after its GPS completion: `f_j − f̄_j ≤ 2·c_max + C_max / M`, where c_max
//! is the largest single-inference cost, C_max the largest agent cost, and
//! time is measured in units where the saturated server drains M token-time
//! per unit (the unit-time simulator backend: one iteration = one second,
//! rate_scale = 1).
//!
//! The engine adds discretization the fluid proof idealizes away (page
//! granularity, prompt-admission headroom, one-token-per-iteration decode),
//! each costing at most a few c_max/M of extra delay; we check the bound
//! with those terms folded in, and assert the *qualitative* half (delay does
//! not grow with the number of competing agents) in Fig. 9's bench.

use justitia::config::{BackendProfile, Config, Policy};
use justitia::cost::CostModel;
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::sched::gps;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::agent_at;
use justitia::workload::{AgentSpec, Suite};

/// A randomized workload: agents with random arrival, fan-out, and task
/// sizes, scaled to a small pool so contention is real.
#[derive(Clone, Debug)]
struct RandomSuite {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
}

struct SuiteStrategy;

impl Strategy for SuiteStrategy {
    type Value = RandomSuite;

    fn generate(&self, rng: &mut Rng) -> RandomSuite {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 64);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 14) as usize;
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05); // bursty-ish arrivals in iteration time
            let n_stages = rng.range_u64(1, 3) as usize;
            let mut stages = Vec::new();
            for s in 0..n_stages {
                let fan = rng.range_u64(1, 4) as usize;
                let mut tasks = Vec::new();
                for i in 0..fan {
                    // Prompts well under the pool so nothing is unservable.
                    let p = rng.range_u64(2, (m_tokens / 6).max(3)) as u32;
                    let d = rng.range_u64(2, 40) as u32;
                    tasks.push(justitia::workload::test_support::inference(
                        i as u32, s as u32, p, d,
                    ));
                }
                stages.push(tasks);
            }
            agents.push(agent_at(id as u32, t, stages));
        }
        RandomSuite { agents, pages, page_size }
    }

    fn shrink(&self, v: &RandomSuite) -> Vec<RandomSuite> {
        let mut out = Vec::new();
        if v.agents.len() > 2 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
            let mut w = v.clone();
            w.agents.remove(0);
            for (i, a) in w.agents.iter_mut().enumerate() {
                a.id = i as u32;
            }
            out.push(w);
        }
        // Drop the last stage (deepest DAG level) of the biggest agent.
        // These agents are staged, so trimming the top level keeps indices
        // dense and dependencies intact.
        if let Some(big) =
            v.agents.iter().enumerate().max_by_key(|(_, a)| a.n_tasks()).map(|(i, _)| i)
        {
            if v.agents[big].depth() > 1 {
                let mut w = v.clone();
                let last = w.agents[big].tasks.iter().map(|t| t.stage).max().unwrap();
                w.agents[big].tasks.retain(|t| t.stage < last);
                out.push(w);
            }
        }
        out
    }
}

fn run_justitia(rs: &RandomSuite) -> (Engine<SimBackend>, Suite) {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop".into(),
        kv_tokens: rs.pages * rs.page_size as u64,
        page_size: rs.page_size,
        alpha: 1.0, // unit-time backend: 1 iteration == 1 second
        beta_prefill: 0.0,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: None,
        swap_bw_tokens_per_sec: 0.0,
    };
    cfg.max_batch = 1024; // memory-limited, not slot-limited (as in the proof)
    let suite = Suite::new(rs.agents.clone());
    let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
    let model = CostModel::MemoryCentric;
    engine.run_suite(&suite, |a| model.agent_cost(a));
    (engine, suite)
}

#[test]
fn theorem_b1_delay_bound_holds() {
    let cfg = PropConfig { cases: prop_cases(40), seed: 0xb1, max_shrink_steps: 60 };
    check(&cfg, &SuiteStrategy, |rs| {
        let (engine, suite) = run_justitia(rs);
        let m_tokens = (rs.pages * rs.page_size as u64) as f64;
        let model = CostModel::MemoryCentric;

        // GPS reference over the same (agent, arrival, cost) triples.
        let gps_res = gps::run_suite(&suite, model, rs.pages * rs.page_size as u64, 1.0);

        let c_max: f64 = suite
            .agents
            .iter()
            .flat_map(|a| a.tasks())
            .map(|t| model.inference_cost(t.prompt_tokens, t.decode_tokens))
            .fold(0.0, f64::max);
        let cap_max: f64 = suite.agents.iter().map(|a| model.agent_cost(a)).fold(0.0, f64::max);
        // Longest single-inference runtime in iterations (decode dominates).
        let d_max: f64 = suite.agents.iter().map(|a| a.max_decode()).fold(0, u32::max) as f64;

        // Paper bound (time units where the server drains M per second):
        //   f_j − f̄_j ≤ 2·c_max/M + C_max/M   …plus the discretization terms
        // the fluid proof idealizes away: per-inference runtime floors (an
        // inference takes d iterations even on an empty server) and one
        // iteration of slack per stage boundary.
        let stages_max = suite.agents.iter().map(|a| a.depth()).max().unwrap_or(1) as f64;
        let bound =
            2.0 * c_max / m_tokens + cap_max / m_tokens + 2.0 * d_max + stages_max + 2.0;

        for a in &suite.agents {
            let f = engine
                .metrics
                .agent_complete_time(a.id)
                .ok_or_else(|| format!("agent {} not completed", a.id))?;
            let f_gps = gps_res.finish_of(a.id);
            let delay = f - f_gps;
            if delay > bound {
                return Err(format!(
                    "agent {}: f={f:.1} gps={f_gps:.1} delay={delay:.1} > bound={bound:.1} \
                     (c_max={c_max:.0}, C_max={cap_max:.0}, M={m_tokens:.0}, d_max={d_max:.0})",
                    a.id
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn all_agents_complete_and_kv_is_clean() {
    let cfg = PropConfig { cases: prop_cases(30), seed: 0xc1ea, max_shrink_steps: 40 };
    check(&cfg, &SuiteStrategy, |rs| {
        let (engine, suite) = run_justitia(rs);
        if engine.metrics.completed_agents() != suite.len() {
            return Err(format!(
                "{}/{} agents completed",
                engine.metrics.completed_agents(),
                suite.len()
            ));
        }
        engine.kv.check_invariants()?;
        if engine.kv.device_tokens() != 0 {
            return Err("leaked device tokens".into());
        }
        Ok(())
    });
}

#[test]
fn work_conservation_vs_gps_makespan() {
    // The engine (work-conserving, non-preemptive) must not finish the whole
    // batch much later than the GPS makespan.
    let cfg = PropConfig { cases: prop_cases(25), seed: 0x3a4ed, max_shrink_steps: 40 };
    check(&cfg, &SuiteStrategy, |rs| {
        let (engine, suite) = run_justitia(rs);
        let model = CostModel::MemoryCentric;
        let gps_res = gps::run_suite(&suite, model, rs.pages * rs.page_size as u64, 1.0);
        let gps_makespan =
            suite.agents.iter().map(|a| gps_res.finish_of(a.id)).fold(0.0, f64::max);
        let engine_makespan = engine.metrics.engine_time();
        let d_max: f64 = suite.agents.iter().map(|a| a.max_decode()).fold(0, u32::max) as f64;
        let stages: f64 = suite.agents.iter().map(|a| a.depth()).sum::<usize>() as f64;
        // Slack: per-inference runtime floors + stage barriers.
        let slack = 3.0 * d_max + 2.0 * stages + 10.0;
        if engine_makespan > gps_makespan + slack {
            return Err(format!(
                "makespan {engine_makespan:.1} >> GPS {gps_makespan:.1} + slack {slack:.1}"
            ));
        }
        Ok(())
    });
}

/// Honor the env knob while keeping CI fast by default.
fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
