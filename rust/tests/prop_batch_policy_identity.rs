//! Differential tests for the batch-policy seam (ISSUE 9 tentpole): pulling
//! per-iteration batch composition out of `Engine::step()` behind the
//! [`BatchPolicy`] trait must not move a single bit on the default path.
//!
//! Three identities, each across all six schedulers and randomized knob
//! draws ({prefix cache, DAG + dynamic spawning, preemption-auto} × both
//! engine cores):
//!
//! 1. `StaticBudget` with chunked prefill ON replays bit-identically on the
//!    tick loop and the event core — the policy returns an unbounded plan,
//!    so every `min`/`saturating_sub` in composition is an arithmetic
//!    identity and the seam is invisible.
//! 2. `FixedSplit` with `decode_reserve = 0` is bit-identical to
//!    `StaticBudget`: a zero reservation can never bind (the shared
//!    iteration budget is always at most the total the split is taken
//!    from), so the two policies must produce the same schedule.
//! 3. Without chunked prefill there is no token budget to split, so ALL
//!    three policies — including the closed-loop `FairBatching` — are
//!    inert: `plan()` is never consulted and every policy replays the
//!    `StaticBudget` schedule exactly.
//!
//! [`BatchPolicy`]: justitia::engine::batch::BatchPolicy

use justitia::config::{BackendProfile, BatchPolicyKind, Config, Policy, PreemptionMode};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, SpawnSpec, Suite};

const ALL_POLICIES: [Policy; 6] = [
    Policy::Fcfs,
    Policy::Sjf,
    Policy::AgentFcfs,
    Policy::Vtc,
    Policy::Srjf,
    Policy::Justitia,
];

/// A randomized workload plus the knob draws the batch-policy seam must be
/// invisible under.
#[derive(Clone, Debug)]
struct BatchScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    prefix_cache: bool,
    spawn: bool,
    /// `PreemptionMode::Auto` with a bounded host pool (else default Swap).
    preempt_auto: bool,
    host_tokens: Option<u64>,
    swap_bw: f64,
    /// Run on the event core instead of the tick loop.
    event_core: bool,
}

struct BatchStrategy;

impl Strategy for BatchStrategy {
    type Value = BatchScenario;

    fn generate(&self, rng: &mut Rng) -> BatchScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 48);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 7) as usize;
        let spawn = rng.chance(0.5);
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 5) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                // Prompts up to a third of the pool force preemption traffic
                // while every sequence still fits an empty pool; they also
                // span several 16-token chunks, so the budget genuinely
                // splits prefills across iterations.
                let p = rng.range_u64(2, m_tokens / 3) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            let mut a = dag_agent(id as u32, t, tasks);
            if spawn {
                a.spawn = Some(SpawnSpec {
                    prob: 0.6,
                    branch: 2,
                    max_depth: 1,
                    seed: rng.next_u64(),
                });
            }
            agents.push(a);
        }
        BatchScenario {
            agents,
            pages,
            page_size,
            prefix_cache: rng.chance(0.5),
            spawn,
            preempt_auto: rng.chance(0.5),
            host_tokens: match rng.below(3) {
                0 => None,
                1 => Some(m_tokens / 4),
                _ => Some(0),
            },
            swap_bw: if rng.chance(0.5) { 1000.0 } else { 0.0 },
            event_core: rng.chance(0.5),
        }
    }

    fn shrink(&self, v: &BatchScenario) -> Vec<BatchScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        for knob in 0..4 {
            let mut w = v.clone();
            let on = match knob {
                0 => std::mem::replace(&mut w.prefix_cache, false),
                1 => {
                    let on = w.spawn;
                    w.spawn = false;
                    for a in &mut w.agents {
                        a.spawn = None;
                    }
                    on
                }
                2 => std::mem::replace(&mut w.preempt_auto, false),
                _ => std::mem::replace(&mut w.event_core, false),
            };
            if on {
                out.push(w);
            }
        }
        out
    }
}

fn config_for(sc: &BatchScenario, chunked: bool, batch: BatchPolicyKind) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-batch".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: 1e-3,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: sc.host_tokens,
        swap_bw_tokens_per_sec: sc.swap_bw,
    };
    cfg.max_batch = 64;
    cfg.prefix_cache = sc.prefix_cache;
    cfg.event_core = sc.event_core;
    if sc.preempt_auto {
        cfg.preemption = PreemptionMode::Auto;
    }
    if chunked {
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 48;
    }
    cfg.batch_policy = batch;
    if batch == BatchPolicyKind::FixedSplit {
        cfg.decode_reserve = 0;
    }
    cfg
}

fn suite_for(sc: &BatchScenario) -> Suite {
    let mut suite = Suite::new(sc.agents.clone());
    if sc.prefix_cache {
        justitia::workload::trace::annotate_families(&mut suite, 2, 16, 0xfa7e);
    }
    suite
}

/// Everything the engine observably computed, in exact (bit-level) form.
type Trace = (f64, Vec<(u32, f64)>, Vec<(u32, u32, Option<f64>, Option<f64>)>, [u64; 7]);

fn replay(sc: &BatchScenario, policy: Policy, chunked: bool, batch: BatchPolicyKind) -> Trace {
    let cfg = config_for(sc, chunked, batch);
    let suite = suite_for(sc);
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan = engine.run_suite(&suite, |a| model.agent_cost(a));
    let m = &engine.metrics;
    let mut tasks = Vec::new();
    for a in &suite.agents {
        for t in a.tasks.iter().chain(a.expand_spawns().iter()) {
            tasks.push((
                t.id.agent,
                t.id.index,
                m.task_admit_time(t.id),
                m.task_complete_time(t.id),
            ));
        }
    }
    (
        makespan,
        m.jcts(),
        tasks,
        [
            m.iterations(),
            m.swap_out_count(),
            m.recompute_count(),
            m.prefill_tokens_executed(),
            m.prefix_hits(),
            m.spawned_tasks(),
            m.prefill_stalls(),
        ],
    )
}

/// Property 1: `StaticBudget` with chunked prefill ON is bit-identical on
/// the tick loop and the event core — the trait seam never moves a bit on
/// the default policy. (The scenario's `event_core` draw is overridden so
/// every case compares both cores directly.)
#[test]
fn prop_static_budget_identity_across_cores() {
    let cfg = PropConfig { cases: prop_cases(20), seed: 0xba7c_0001, max_shrink_steps: 60 };
    check(&cfg, &BatchStrategy, |sc| {
        for policy in ALL_POLICIES {
            let mut tick_sc = sc.clone();
            tick_sc.event_core = false;
            let mut event_sc = sc.clone();
            event_sc.event_core = true;
            let tick = replay(&tick_sc, policy, true, BatchPolicyKind::Static);
            let event = replay(&event_sc, policy, true, BatchPolicyKind::Static);
            if tick != event {
                return Err(format!(
                    "{policy:?}: StaticBudget diverged across cores \
                     (tick counters {:?} vs event {:?}, makespan {} vs {})",
                    tick.3, event.3, tick.0, event.0
                ));
            }
        }
        Ok(())
    });
}

/// Property 2: `FixedSplit` with a zero decode reservation replays the
/// `StaticBudget` schedule exactly — the reservation arithmetic is a pure
/// no-op at reserve 0, on whichever core the scenario drew.
#[test]
fn prop_fixed_split_zero_reserve_is_static() {
    let cfg = PropConfig { cases: prop_cases(20), seed: 0xba7c_0002, max_shrink_steps: 60 };
    check(&cfg, &BatchStrategy, |sc| {
        for policy in ALL_POLICIES {
            let st = replay(sc, policy, true, BatchPolicyKind::Static);
            let fs = replay(sc, policy, true, BatchPolicyKind::FixedSplit);
            if st != fs {
                return Err(format!(
                    "{policy:?}: FixedSplit(reserve=0) diverged from Static \
                     (static counters {:?} vs fixed-split {:?})",
                    st.3, fs.3
                ));
            }
        }
        Ok(())
    });
}

/// Property 3: without chunked prefill there is no budget to split, so every
/// batch policy — the closed-loop `FairBatching` included — is inert and
/// replays the `StaticBudget` schedule bit-for-bit.
#[test]
fn prop_all_policies_inert_without_chunking() {
    let cfg = PropConfig { cases: prop_cases(15), seed: 0xba7c_0003, max_shrink_steps: 40 };
    check(&cfg, &BatchStrategy, |sc| {
        for policy in ALL_POLICIES {
            let base = replay(sc, policy, false, BatchPolicyKind::Static);
            for batch in [BatchPolicyKind::FixedSplit, BatchPolicyKind::FairBatching] {
                let other = replay(sc, policy, false, batch);
                if base != other {
                    return Err(format!(
                        "{policy:?}: {batch:?} not inert without chunking \
                         (static counters {:?} vs {:?})",
                        base.3, other.3
                    ));
                }
            }
        }
        Ok(())
    });
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
