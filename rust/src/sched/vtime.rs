//! Virtual time V(t) for fair queuing (paper Eq. 2–3).
//!
//! ```text
//! V(0) = 0,     dV/dt = M / N_t                              (Eq. 2)
//! F_j  = V(a_j) + C_j                                        (Eq. 3)
//! ```
//!
//! `M` is the total KV capacity and `N_t` the number of GPS-active agents —
//! agents that have arrived but whose GPS (idealized fair-sharing) service is
//! not yet complete. The classical fair-queuing identity makes this cheap to
//! track: *agent j is GPS-active exactly while V(t) < F_j*, so the active set
//! is a min-heap on F and V(t) is piecewise linear between heap events.
//!
//! This same structure doubles as the exact GPS fluid simulator: inverting
//! the piecewise-linear V gives each agent's GPS completion time f̄_j in real
//! time, which the fairness metrics and the Theorem-B.1 property tests use.
//!
//! Units: costs C_j are KV token-time (token·iterations). `rate_scale`
//! converts to wall seconds: the work-conserving server drains
//! `M × rate_scale` token-time units per second (`rate_scale` = iterations
//! per second). The *order* of {F_j} — all Justitia needs — is invariant to
//! `rate_scale`.

use crate::sched::OrdF64;
use crate::workload::AgentId;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashMap;

/// Virtual clock + GPS-active set.
///
/// Supports online *re-tagging* ([`VirtualClock::retag`], the §4.2
/// misprediction-correction loop): heap entries are lazily invalidated —
/// an entry is live only while it matches the agent's current tag — and the
/// GPS-active population is tracked by an explicit counter so stale entries
/// never distort the fair rate. Without retags the clock behaves exactly as
/// the original (every entry stays live), bit for bit.
#[derive(Debug, Clone)]
pub struct VirtualClock {
    m: f64,
    rate_scale: f64,
    v: f64,
    last_t: f64,
    /// GPS-active agents: min-heap on virtual finish time. May hold stale
    /// entries after a retag; liveness = entry matches `tags` and the agent
    /// has no GPS finish yet.
    active: BinaryHeap<Reverse<(OrdF64, AgentId)>>,
    /// Number of distinct GPS-active agents (arrived, not yet GPS-finished).
    n_active: usize,
    /// Real-time GPS completion, recorded when V crosses F_j.
    gps_finish: HashMap<AgentId, f64>,
    /// Virtual finish tags (F_j), kept for inspection.
    tags: HashMap<AgentId, f64>,
}

impl VirtualClock {
    /// `capacity_tokens` = M; `rate_scale` = iterations per second the
    /// server sustains (use 1.0 when simulating in iteration time).
    pub fn new(capacity_tokens: u64, rate_scale: f64) -> Self {
        assert!(capacity_tokens > 0 && rate_scale > 0.0);
        VirtualClock {
            m: capacity_tokens as f64,
            rate_scale,
            v: 0.0,
            last_t: 0.0,
            active: BinaryHeap::new(),
            n_active: 0,
            gps_finish: HashMap::new(),
            tags: HashMap::new(),
        }
    }

    /// Number of GPS-active agents right now (N_t after advancing to `now`).
    pub fn active_agents(&mut self, now: f64) -> usize {
        self.advance(now);
        self.n_active
    }

    /// Drop heap entries that no longer reflect an agent's live tag (the
    /// agent was retagged, or already GPS-finished).
    fn skim_stale(&mut self) {
        while let Some(&Reverse((OrdF64(f), a))) = self.active.peek() {
            let live =
                !self.gps_finish.contains_key(&a) && self.tags.get(&a).copied() == Some(f);
            if live {
                return;
            }
            self.active.pop();
        }
    }

    /// Current virtual time after advancing to `now`.
    pub fn vt(&mut self, now: f64) -> f64 {
        self.advance(now);
        self.v
    }

    /// Advance V(t) to real time `now`, popping agents whose GPS service
    /// completes on the way (piecewise-linear integration of Eq. 2).
    pub fn advance(&mut self, now: f64) {
        debug_assert!(now + 1e-9 >= self.last_t, "time went backwards: {} < {}", now, self.last_t);
        let now = now.max(self.last_t);
        loop {
            self.skim_stale();
            let n = self.n_active;
            if n == 0 {
                // Idle GPS server: V holds (no active agents to serve).
                self.last_t = now;
                return;
            }
            // dV/dt = (M / N) × rate_scale  [token-time units per second]
            let rate = self.m / n as f64 * self.rate_scale;
            let &Reverse((OrdF64(min_f), min_agent)) = self.active.peek().unwrap();
            let t_finish = self.last_t + (min_f - self.v).max(0.0) / rate;
            if t_finish <= now {
                // Agent min_agent completes in GPS at t_finish. A downward
                // retag can leave min_f below the current V; V itself must
                // stay monotone (it anchors every later arrival's tag), so
                // such agents finish immediately without regressing V.
                self.v = self.v.max(min_f);
                self.last_t = t_finish;
                self.active.pop();
                self.gps_finish.insert(min_agent, t_finish);
                self.n_active -= 1;
            } else {
                self.v += rate * (now - self.last_t);
                self.last_t = now;
                return;
            }
        }
    }

    /// Register an arrival (paper Eq. 3): returns the virtual finish tag
    /// F_j = V(a_j) + C_j, computed once and never updated.
    pub fn on_arrival(&mut self, agent: AgentId, cost: f64, now: f64) -> f64 {
        self.advance(now);
        let f = self.v + cost.max(0.0);
        self.active.push(Reverse((OrdF64(f), agent)));
        self.tags.insert(agent, f);
        self.n_active += 1;
        f
    }

    /// Replace an active agent's virtual finish tag (§4.2 online
    /// correction). The old heap entry becomes stale and is skimmed lazily;
    /// the GPS-active population is unchanged. A no-op once the agent has
    /// already GPS-finished (the correction arrived too late to matter) or
    /// was never registered.
    pub fn retag(&mut self, agent: AgentId, new_f: f64) {
        if self.gps_finish.contains_key(&agent) || !self.tags.contains_key(&agent) {
            return;
        }
        if self.tags.get(&agent).copied() == Some(new_f) {
            return;
        }
        self.tags.insert(agent, new_f);
        self.active.push(Reverse((OrdF64(new_f), agent)));
    }

    /// The virtual finish tag of an agent, if registered.
    pub fn tag(&self, agent: AgentId) -> Option<f64> {
        self.tags.get(&agent).copied()
    }

    /// GPS completion time in real seconds, available once V(t) has been
    /// advanced past F_j. Call `advance(∞-ish)` or `finish_all` first when
    /// draining.
    pub fn gps_finish(&self, agent: AgentId) -> Option<f64> {
        self.gps_finish.get(&agent).copied()
    }

    /// Real-time GPS finish a *hypothetical* agent with service cost `cost`
    /// arriving at `now` would achieve, leaving this clock untouched (the
    /// arrival is simulated on a clone). This is the finish-tag estimate the
    /// cluster dispatcher's placement policies compare across replicas
    /// (`crate::cluster::placement`): the replica minimizing it is the one
    /// an N×M-capacity GPS server would have the agent finish on first.
    ///
    /// `agent` is only a probe label; any id may be passed (a stale GPS
    /// record for that id on the clone is discarded first).
    pub fn hypothetical_gps_finish(&self, agent: AgentId, cost: f64, now: f64) -> f64 {
        let mut sim = self.clone();
        sim.gps_finish.remove(&agent);
        sim.on_arrival(agent, cost, now.max(sim.last_t));
        sim.finish_all();
        sim.gps_finish(agent).expect("probe agent drained")
    }

    /// Drain the active set: advance until every registered agent has a GPS
    /// finish time, and return the final real time.
    pub fn finish_all(&mut self) -> f64 {
        loop {
            self.skim_stale();
            let Some(&Reverse((OrdF64(min_f), _))) = self.active.peek() else { break };
            let n = self.n_active;
            let rate = self.m / n as f64 * self.rate_scale;
            let t = self.last_t + (min_f - self.v).max(0.0) / rate;
            self.advance(t + 1e-12);
        }
        self.last_t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_agent_full_rate() {
        // One agent, cost 100, M=10, scale=1 → GPS serves at 10/s → 10 s.
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 100.0, 0.0);
        vc.finish_all();
        assert!((vc.gps_finish(1).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn two_equal_agents_share() {
        // Two agents arriving together, each cost 100, M=10: each gets 5/s,
        // both complete at t=20.
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 100.0, 0.0);
        vc.on_arrival(2, 100.0, 0.0);
        vc.finish_all();
        assert!((vc.gps_finish(1).unwrap() - 20.0).abs() < 1e-9);
        assert!((vc.gps_finish(2).unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn unequal_agents_short_finishes_first() {
        // Costs 50 and 150, arriving together, M=10. Shared until the short
        // one has consumed 50 (t=10); then the long one runs alone.
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 50.0, 0.0);
        vc.on_arrival(2, 150.0, 0.0);
        vc.finish_all();
        assert!((vc.gps_finish(1).unwrap() - 10.0).abs() < 1e-9);
        // Long agent: 50 served by t=10, remaining 100 at 10/s → t=20.
        assert!((vc.gps_finish(2).unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn late_arrival_does_not_change_existing_order() {
        // Paper §4.3: later arrivals change the fair rate but not the
        // relative completion order among existing agents.
        let mut vc = VirtualClock::new(100, 1.0);
        let f1 = vc.on_arrival(1, 500.0, 0.0);
        let f2 = vc.on_arrival(2, 900.0, 1.0);
        let f3 = vc.on_arrival(3, 50.0, 2.0);
        assert!(f1 < f2);
        // Tags never change after computation.
        assert_eq!(vc.tag(1), Some(f1));
        assert_eq!(vc.tag(2), Some(f2));
        assert_eq!(vc.tag(3), Some(f3));
        vc.finish_all();
        let (g1, g2) = (vc.gps_finish(1).unwrap(), vc.gps_finish(2).unwrap());
        assert!(g1 < g2);
    }

    #[test]
    fn virtual_rate_depends_on_active_count() {
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 1000.0, 0.0);
        vc.on_arrival(2, 1000.0, 0.0);
        // After 1 s with 2 active: V advanced by 10/2 = 5.
        assert!((vc.vt(1.0) - 5.0).abs() < 1e-9);
        // Idle clock holds V.
        let mut idle = VirtualClock::new(10, 1.0);
        assert_eq!(idle.vt(100.0), 0.0);
    }

    #[test]
    fn arrival_during_service_gets_current_v() {
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 100.0, 0.0);
        // At t=2, V = 20 (one active agent, rate 10/s).
        let f2 = vc.on_arrival(2, 30.0, 2.0);
        assert!((f2 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn rate_scale_scales_real_times_not_order() {
        let mut a = VirtualClock::new(10, 1.0);
        let mut b = VirtualClock::new(10, 4.0);
        for (id, c, t) in [(1u32, 80.0, 0.0), (2, 40.0, 0.5), (3, 120.0, 1.0)] {
            a.on_arrival(id, c, t);
            b.on_arrival(id, c, t);
        }
        a.finish_all();
        b.finish_all();
        let order = |vc: &VirtualClock| {
            let mut v: Vec<_> = (1..=3u32).map(|i| (OrdF64(vc.gps_finish(i).unwrap()), i)).collect();
            v.sort();
            v.into_iter().map(|(_, i)| i).collect::<Vec<_>>()
        };
        assert_eq!(order(&a), order(&b));
        assert!(b.gps_finish(3).unwrap() < a.gps_finish(3).unwrap());
    }

    #[test]
    fn hypothetical_finish_is_side_effect_free() {
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 100.0, 0.0);
        // Probe: a 50-cost agent arriving now would share 5/s → finish t=10.
        let est = vc.hypothetical_gps_finish(99, 50.0, 0.0);
        assert!((est - 10.0).abs() < 1e-9);
        // The probe left no trace: agent 1 still finishes alone at t=10.
        vc.finish_all();
        assert!((vc.gps_finish(1).unwrap() - 10.0).abs() < 1e-9);
        assert_eq!(vc.gps_finish(99), None);
    }

    #[test]
    fn hypothetical_finish_sees_existing_load() {
        let empty = VirtualClock::new(10, 1.0);
        let mut busy = VirtualClock::new(10, 1.0);
        busy.on_arrival(1, 500.0, 0.0);
        let on_empty = empty.hypothetical_gps_finish(9, 100.0, 0.0);
        let on_busy = busy.hypothetical_gps_finish(9, 100.0, 0.0);
        assert!(on_empty < on_busy, "{on_empty} vs {on_busy}");
    }

    #[test]
    fn retag_moves_gps_finish() {
        // Two agents, M=10. Agent 2's cost is corrected down from 150 to 50
        // at t=2: it should then finish like a 50-cost agent would.
        let mut a = VirtualClock::new(10, 1.0);
        a.on_arrival(1, 50.0, 0.0);
        a.on_arrival(2, 150.0, 0.0);
        a.advance(2.0); // V = 10
        a.retag(2, a.vt(2.0) - /* served share ≈ */ 10.0 + 50.0);
        a.finish_all();
        let mut b = VirtualClock::new(10, 1.0);
        b.on_arrival(1, 50.0, 0.0);
        b.on_arrival(2, 150.0, 0.0);
        b.finish_all();
        // Corrected agent 2 finishes strictly earlier than uncorrected.
        assert!(a.gps_finish(2).unwrap() < b.gps_finish(2).unwrap());
        // Population accounting stayed sane: both finished exactly once.
        assert_eq!(a.active_agents(1e9), 0);
    }

    #[test]
    fn retag_is_noop_after_finish_or_for_unknown() {
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 10.0, 0.0);
        vc.finish_all();
        let done = vc.gps_finish(1).unwrap();
        vc.retag(1, 9999.0);
        vc.retag(77, 5.0); // never arrived
        vc.finish_all();
        assert_eq!(vc.gps_finish(1), Some(done));
        assert_eq!(vc.gps_finish(77), None);
        assert_eq!(vc.active_agents(1e9), 0);
    }

    #[test]
    fn downward_retag_does_not_regress_virtual_time() {
        // M=10, one active agent with F=1000; V reaches 200 at t=20. A
        // correction down to 150 (< V) must finish the agent immediately
        // WITHOUT pulling V backward — later arrivals anchor on V.
        let mut vc = VirtualClock::new(10, 1.0);
        vc.on_arrival(1, 1000.0, 0.0);
        assert!((vc.vt(20.0) - 200.0).abs() < 1e-9);
        vc.retag(1, 150.0);
        vc.advance(20.0);
        assert_eq!(vc.gps_finish(1), Some(20.0), "retagged-below-V agent finishes now");
        assert!((vc.vt(20.0) - 200.0).abs() < 1e-9, "V must not regress");
        // A later arrival is anchored at the un-regressed V.
        let f2 = vc.on_arrival(2, 50.0, 20.0);
        assert!((f2 - 250.0).abs() < 1e-9);
    }

    #[test]
    fn retag_same_value_changes_nothing() {
        let mut a = VirtualClock::new(10, 1.0);
        let f = a.on_arrival(1, 100.0, 0.0);
        a.retag(1, f);
        a.finish_all();
        assert!((a.gps_finish(1).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn gps_conservation() {
        // Total work / M = makespan when the server is never idle.
        let mut vc = VirtualClock::new(20, 1.0);
        let costs = [300.0, 500.0, 200.0];
        for (i, c) in costs.iter().enumerate() {
            vc.on_arrival(i as u32, *c, 0.0);
        }
        let end = vc.finish_all();
        assert!((end - costs.iter().sum::<f64>() / 20.0).abs() < 1e-9);
    }
}
