//! Task-parallel LLM agent workloads (paper §2.1, §5.1, Appendix A).
//!
//! An *agent* is a DAG of LLM inferences structured as sequential *stages* of
//! parallel *tasks*: stage k+1 is released only when every task of stage k
//! has completed (map→reduce, merge→score→final, plan→execute, ...). The
//! nine agent classes of §5.1 are synthesized by `generator` with
//! per-class, per-stage skew-normal (p, d) token-length distributions
//! (substitution T3 in DESIGN.md).

pub mod classes;
pub mod generator;
pub mod trace;

pub use classes::AgentClass;

/// Identifies an agent within a workload suite.
pub type AgentId = u32;

/// Identifies one inference task: (agent, per-agent task index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId {
    /// Owning agent.
    pub agent: AgentId,
    /// Task index within the agent.
    pub index: u32,
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a{}-t{}", self.agent, self.index)
    }
}

/// Declares that the first `tokens` prompt tokens of an inference are the
/// *same content* as every other inference carrying the same `id` — the
/// shared system-prompt + accumulated-context prefix that task-parallel
/// agents fan out over (and that agent *families* re-submit across agents).
/// The prefix cache ([`crate::prefix`]) derives identical token streams from
/// equal ids, so two inferences share KV pages exactly up to
/// `min(tokens, prompt_tokens)` of both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixGroup {
    /// Content identity of the shared prefix (suite-unique per family).
    pub id: u64,
    /// Length of the shared prefix in tokens.
    pub tokens: u32,
}

/// One LLM inference task. `prompt_tokens`/`decode_tokens` are the ground
/// truth the engine executes; the scheduler only sees predictions.
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceSpec {
    /// Task identity.
    pub id: TaskId,
    /// Stage index within the agent (tasks of stage s+1 wait on stage s).
    pub stage: u32,
    /// Prompt (prefill) token length p.
    pub prompt_tokens: u32,
    /// Decode (output) token length d.
    pub decode_tokens: u32,
    /// Name of the inference kind (e.g. "generate-summary"), Appendix-A style.
    pub kind: &'static str,
    /// Shared-prefix annotation (`None` = fully unique prompt). Inert unless
    /// the prefix cache is enabled.
    pub prefix_group: Option<PrefixGroup>,
}

/// One task-parallel LLM agent.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentSpec {
    /// Agent id (suite-unique).
    pub id: AgentId,
    /// Agent class (template).
    pub class: AgentClass,
    /// Arrival (submission) time in seconds from suite start.
    pub arrival: f64,
    /// Stages of parallel inference tasks, executed stage-by-stage.
    pub stages: Vec<Vec<InferenceSpec>>,
    /// Synthesized user-input text; what the cost predictor sees on arrival.
    pub input_text: String,
}

impl AgentSpec {
    /// Total number of inference tasks.
    pub fn n_tasks(&self) -> usize {
        self.stages.iter().map(|s| s.len()).sum()
    }

    /// Iterate over all inference specs in stage order.
    pub fn tasks(&self) -> impl Iterator<Item = &InferenceSpec> {
        self.stages.iter().flatten()
    }

    /// Maximum single-inference decode length (bounds inference runtime).
    pub fn max_decode(&self) -> u32 {
        self.tasks().map(|t| t.decode_tokens).max().unwrap_or(0)
    }

    /// Total prompt + decode tokens (used by stats / Fig. 13).
    pub fn total_tokens(&self) -> u64 {
        self.tasks().map(|t| (t.prompt_tokens + t.decode_tokens) as u64).sum()
    }

    /// The agent's dominant shared-prefix family, if any task carries one
    /// (the cluster dispatcher's prefix-affinity placement keys on this).
    pub fn prefix_group_id(&self) -> Option<u64> {
        self.tasks().find_map(|t| t.prefix_group.map(|g| g.id))
    }
}

/// A full workload suite: agents sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Suite {
    /// Agents sorted by arrival; ids follow arrival order.
    pub agents: Vec<AgentSpec>,
}

impl Suite {
    /// Sort by arrival and re-index ids to 0..n.
    pub fn new(mut agents: Vec<AgentSpec>) -> Self {
        agents.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        // Re-index so ids follow arrival order (stable, deterministic).
        for (i, a) in agents.iter_mut().enumerate() {
            let new_id = i as AgentId;
            a.id = new_id;
            for stage in &mut a.stages {
                for t in stage {
                    t.id.agent = new_id;
                }
            }
        }
        Suite { agents }
    }

    /// Number of agents.
    pub fn len(&self) -> usize {
        self.agents.len()
    }

    /// Whether the suite has no agents.
    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }
}

/// Test helpers shared by unit/integration/property tests.
pub mod test_support {
    use super::*;

    /// Build a bare inference spec.
    pub fn inference(index: u32, stage: u32, prompt: u32, decode: u32) -> InferenceSpec {
        InferenceSpec {
            id: TaskId { agent: 0, index },
            stage,
            prompt_tokens: prompt,
            decode_tokens: decode,
            kind: "test",
            prefix_group: None,
        }
    }

    /// Build an agent from explicit stages (ids re-labelled consistently).
    pub fn agent_with_stages(stages: Vec<Vec<InferenceSpec>>) -> AgentSpec {
        agent_at(0, 0.0, stages)
    }

    /// Build an agent with explicit id/arrival.
    pub fn agent_at(id: AgentId, arrival: f64, mut stages: Vec<Vec<InferenceSpec>>) -> AgentSpec {
        let mut idx = 0;
        for (s, stage) in stages.iter_mut().enumerate() {
            for t in stage {
                t.id = TaskId { agent: id, index: idx };
                t.stage = s as u32;
                idx += 1;
            }
        }
        AgentSpec {
            id,
            class: AgentClass::EquationVerification,
            arrival,
            stages,
            input_text: String::new(),
        }
    }

    /// A simple single-stage agent with `n` identical parallel tasks.
    pub fn simple_agent(id: AgentId, arrival: f64, n: usize, prompt: u32, decode: u32) -> AgentSpec {
        agent_at(id, arrival, vec![(0..n as u32).map(|i| inference(i, 0, prompt, decode)).collect()])
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn agent_accessors() {
        let a = agent_with_stages(vec![
            vec![inference(0, 0, 10, 5), inference(1, 0, 20, 9)],
            vec![inference(2, 1, 30, 2)],
        ]);
        assert_eq!(a.n_tasks(), 3);
        assert_eq!(a.max_decode(), 9);
        assert_eq!(a.total_tokens(), 10 + 5 + 20 + 9 + 30 + 2);
        assert_eq!(a.tasks().count(), 3);
    }

    #[test]
    fn suite_sorts_and_reindexes() {
        let a = simple_agent(7, 5.0, 1, 10, 10);
        let b = simple_agent(3, 1.0, 2, 10, 10);
        let suite = Suite::new(vec![a, b]);
        assert_eq!(suite.len(), 2);
        assert!(suite.agents[0].arrival < suite.agents[1].arrival);
        assert_eq!(suite.agents[0].id, 0);
        assert_eq!(suite.agents[1].id, 1);
        for (i, agent) in suite.agents.iter().enumerate() {
            for t in agent.tasks() {
                assert_eq!(t.id.agent, i as AgentId);
            }
        }
    }

    #[test]
    fn task_id_display() {
        let t = TaskId { agent: 3, index: 11 };
        assert_eq!(t.to_string(), "a3-t11");
    }

    #[test]
    fn prefix_group_id_finds_first_annotation() {
        let mut a = agent_with_stages(vec![vec![inference(0, 0, 10, 5), inference(1, 0, 10, 5)]]);
        assert_eq!(a.prefix_group_id(), None);
        a.stages[0][1].prefix_group = Some(PrefixGroup { id: 7, tokens: 64 });
        assert_eq!(a.prefix_group_id(), Some(7));
    }
}
