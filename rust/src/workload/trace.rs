//! Arrival traces and suite building (§5.1 Workloads; substitution T2).
//!
//! The paper replays the Mooncake production trace's request arrival times,
//! stretched to 6/9/18-minute submission windows for 3×/2×/1× density. That
//! trace is not available offline; we generate a bursty Gamma-renewal arrival
//! process (shape k < 1 ⇒ CV > 1, matching the burstiness production LLM
//! traces exhibit) normalized to the same windows, and sample classes with
//! the 72/26/2 small/medium/large mix.

use crate::util::json::{obj, Json};
use crate::util::rng::Rng;
use crate::workload::classes::SizeBucket;
use crate::workload::generator::Generator;
use crate::workload::{AgentClass, AgentSpec, Suite};
use anyhow::{Context, Result};
use std::path::Path;

/// Gamma-renewal arrival process: inter-arrival ~ Gamma(shape, scale). The
/// shape < 1 gives coefficient of variation 1/sqrt(shape) > 1 ("bursty").
pub const ARRIVAL_GAMMA_SHAPE: f64 = 0.5; // CV ≈ 1.41, production-like

/// Generate `n` arrival offsets inside `[0, window_secs]`, sorted.
pub fn arrivals(rng: &mut Rng, n: usize, window_secs: f64) -> Vec<f64> {
    if n == 0 {
        return Vec::new();
    }
    // Draw n bursty gaps, then renormalize the cumulative sum to the window
    // (exactly what "replay a trace stretched to the window" does).
    let gaps: Vec<f64> = (0..n).map(|_| rng.gamma(ARRIVAL_GAMMA_SHAPE, 1.0)).collect();
    let mut cum: Vec<f64> = Vec::with_capacity(n);
    let mut s = 0.0;
    for g in &gaps {
        s += g;
        cum.push(s);
    }
    let total = s.max(1e-9);
    cum.iter().map(|c| c / total * window_secs).collect()
}

/// Sample an agent class with the paper's 72/26/2 size mix, uniform within
/// the bucket.
pub fn sample_class(rng: &mut Rng, class_mix: &[f64; 3]) -> AgentClass {
    let bucket = match rng.categorical(class_mix) {
        0 => SizeBucket::Small,
        1 => SizeBucket::Medium,
        _ => SizeBucket::Large,
    };
    let classes = AgentClass::in_bucket(bucket);
    *rng.choose(&classes)
}

/// Build the full §5.1 workload suite. When the config's shared-prefix knobs
/// are set (`prefix_fanout ≥ 2` and `prefix_tokens > 0`), the suite is
/// additionally partitioned into *agent families*: consecutive agents (in
/// arrival order) are grouped `prefix_fanout` at a time and every inference
/// of a family is annotated with the same [`PrefixGroup`](crate::workload::PrefixGroup)
/// — modeling fleets of agents re-submitting the same long system prompt +
/// context. The annotation is inert unless the engine's prefix cache is on,
/// so the default (0/0) suite is bit-identical to the unannotated one.
pub fn build_suite(cfg: &crate::config::WorkloadConfig) -> Suite {
    let mut rng = Rng::with_stream(cfg.seed, 0x7ace);
    let mut gen = Generator::new(cfg.seed ^ 0xabcd_ef01);
    let times = arrivals(&mut rng, cfg.n_agents, cfg.window_secs);
    let agents = times
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            let class = sample_class(&mut rng, &cfg.class_mix);
            gen.agent(class, i as u32, t)
        })
        .collect();
    let mut suite = Suite::new(agents);
    if cfg.prefix_fanout >= 2 && cfg.prefix_tokens > 0 {
        annotate_families(&mut suite, cfg.prefix_fanout, cfg.prefix_tokens, cfg.seed);
    }
    suite
}

/// Stamp shared-prefix family annotations onto an existing suite: agents
/// `[k·fanout, (k+1)·fanout)` in arrival order form family `k`, all sharing
/// one `prefix_tokens`-long prompt prefix (clamped per task to its own
/// prompt length by the cache).
pub fn annotate_families(suite: &mut Suite, fanout: usize, prefix_tokens: u32, seed: u64) {
    for (i, a) in suite.agents.iter_mut().enumerate() {
        // Family ids are salted with the seed so two suites never alias.
        let group = crate::workload::PrefixGroup {
            id: seed.rotate_left(24) ^ ((i / fanout) as u64),
            tokens: prefix_tokens,
        };
        for stage in &mut a.stages {
            for t in stage {
                t.prefix_group = Some(group);
            }
        }
    }
}

/// Serialize a suite to JSON (tasks only — input text elided by default to
/// keep trace files small; pass `with_text` to keep it for predictor work).
pub fn suite_to_json(suite: &Suite, with_text: bool) -> Json {
    let agents: Vec<Json> = suite
        .agents
        .iter()
        .map(|a| {
            let stages: Vec<Json> = a
                .stages
                .iter()
                .map(|st| {
                    Json::Arr(
                        st.iter()
                            .map(|t| {
                                let mut o = obj([
                                    ("p", t.prompt_tokens.into()),
                                    ("d", t.decode_tokens.into()),
                                    ("kind", t.kind.into()),
                                ]);
                                if let Some(g) = t.prefix_group {
                                    if let Json::Obj(map) = &mut o {
                                        // Hex string: u64 ids survive the
                                        // f64-backed number representation.
                                        map.insert("pg".into(), Json::Str(format!("{:x}", g.id)));
                                        map.insert("pt".into(), Json::Num(g.tokens as f64));
                                    }
                                }
                                o
                            })
                            .collect(),
                    )
                })
                .collect();
            let mut fields = vec![
                ("class".to_string(), Json::Str(a.class.short_name().into())),
                ("arrival".to_string(), Json::Num(a.arrival)),
                ("stages".to_string(), Json::Arr(stages)),
            ];
            if with_text {
                fields.push(("input".to_string(), Json::Str(a.input_text.clone())));
            }
            Json::Obj(fields.into_iter().collect())
        })
        .collect();
    obj([("agents", Json::Arr(agents))])
}

/// Parse a suite back from JSON (kind strings are interned to the class
/// template's stage kinds when they match, else "replay").
pub fn suite_from_json(v: &Json) -> Result<Suite> {
    let mut agents = Vec::new();
    for (i, a) in v.get("agents").as_arr().context("agents")?.iter().enumerate() {
        let class = AgentClass::by_short_name(a.get("class").as_str().context("class")?)
            .context("unknown class")?;
        let arrival = a.get("arrival").as_f64().context("arrival")?;
        let template = class.template();
        let mut stages = Vec::new();
        let mut index = 0u32;
        for (s, st) in a.get("stages").as_arr().context("stages")?.iter().enumerate() {
            let kind = template.stages.get(s).map(|t| t.kind).unwrap_or("replay");
            let mut tasks = Vec::new();
            for t in st.as_arr().context("stage")? {
                let prefix_group = match (t.get("pg").as_str(), t.get("pt").as_u64()) {
                    (Some(hex), Some(tokens)) => Some(crate::workload::PrefixGroup {
                        id: u64::from_str_radix(hex, 16).context("pg")?,
                        tokens: tokens as u32,
                    }),
                    (None, None) => None,
                    _ => anyhow::bail!(
                        "agent {i}: task has a partial prefix-group annotation \
                         (both \"pg\" and \"pt\" are required)"
                    ),
                };
                tasks.push(crate::workload::InferenceSpec {
                    id: crate::workload::TaskId { agent: i as u32, index },
                    stage: s as u32,
                    prompt_tokens: t.get("p").as_u64().context("p")? as u32,
                    decode_tokens: t.get("d").as_u64().context("d")? as u32,
                    kind,
                    prefix_group,
                });
                index += 1;
            }
            stages.push(tasks);
        }
        agents.push(AgentSpec {
            id: i as u32,
            class,
            arrival,
            stages,
            input_text: a.get("input").as_str().unwrap_or("").to_string(),
        });
    }
    Ok(Suite::new(agents))
}

/// Write a suite trace file.
pub fn save_suite(suite: &Suite, path: &Path, with_text: bool) -> Result<()> {
    std::fs::write(path, suite_to_json(suite, with_text).pretty())
        .with_context(|| format!("write {}", path.display()))
}

/// Load a suite trace file.
pub fn load_suite(path: &Path) -> Result<Suite> {
    let text = std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    suite_from_json(&v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadConfig;

    #[test]
    fn arrivals_sorted_within_window() {
        let mut rng = Rng::new(3);
        let ts = arrivals(&mut rng, 200, 360.0);
        assert_eq!(ts.len(), 200);
        for w in ts.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(*ts.last().unwrap() <= 360.0 + 1e-9);
        assert!(ts[0] >= 0.0);
    }

    #[test]
    fn arrivals_are_bursty() {
        // CV of inter-arrival gaps should exceed 1 (Gamma shape 0.5 ⇒ ~1.4).
        let mut rng = Rng::new(5);
        let ts = arrivals(&mut rng, 2000, 1000.0);
        let gaps: Vec<f64> = ts.windows(2).map(|w| w[1] - w[0]).collect();
        let m = crate::util::stats::mean(&gaps);
        let s = crate::util::stats::std_dev(&gaps);
        assert!(s / m > 1.15, "cv={}", s / m);
    }

    #[test]
    fn class_mix_matches_72_26_2() {
        let mut rng = Rng::new(7);
        let mix = [0.72, 0.26, 0.02];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            let c = sample_class(&mut rng, &mix);
            counts[match c.size_bucket() {
                SizeBucket::Small => 0,
                SizeBucket::Medium => 1,
                SizeBucket::Large => 2,
            }] += 1;
        }
        assert!((counts[0] as f64 / 2e4 - 0.72).abs() < 0.02);
        assert!((counts[1] as f64 / 2e4 - 0.26).abs() < 0.02);
        assert!((counts[2] as f64 / 2e4 - 0.02).abs() < 0.01);
    }

    #[test]
    fn build_suite_deterministic() {
        let cfg = WorkloadConfig { n_agents: 40, window_secs: 120.0, ..Default::default() };
        let s1 = build_suite(&cfg);
        let s2 = build_suite(&cfg);
        assert_eq!(s1.agents, s2.agents);
        assert_eq!(s1.len(), 40);
        let cfg2 = WorkloadConfig { seed: 43, ..cfg };
        let s3 = build_suite(&cfg2);
        assert_ne!(s1.agents, s3.agents);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = WorkloadConfig {
            n_agents: 12,
            window_secs: 60.0,
            prefix_fanout: 3,
            prefix_tokens: 256,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        let j = suite_to_json(&suite, true);
        let back = suite_from_json(&j).unwrap();
        assert_eq!(back.len(), suite.len());
        for (a, b) in suite.agents.iter().zip(back.agents.iter()) {
            assert_eq!(a.class, b.class);
            assert!((a.arrival - b.arrival).abs() < 1e-9);
            assert_eq!(a.n_tasks(), b.n_tasks());
            assert_eq!(a.input_text, b.input_text);
            for (x, y) in a.tasks().zip(b.tasks()) {
                assert_eq!((x.prompt_tokens, x.decode_tokens), (y.prompt_tokens, y.decode_tokens));
                assert_eq!(x.prefix_group, y.prefix_group);
            }
        }
    }

    #[test]
    fn shared_prefix_families_group_consecutive_agents() {
        let cfg = WorkloadConfig {
            n_agents: 10,
            window_secs: 60.0,
            prefix_fanout: 4,
            prefix_tokens: 512,
            ..Default::default()
        };
        let suite = build_suite(&cfg);
        let gid = |i: usize| suite.agents[i].prefix_group_id().unwrap();
        // Agents 0..4 share one family, 4..8 another, 8..10 the tail family.
        assert_eq!(gid(0), gid(3));
        assert_ne!(gid(3), gid(4));
        assert_eq!(gid(4), gid(7));
        assert_eq!(gid(8), gid(9));
        // Every task carries the annotation with the configured length.
        for a in &suite.agents {
            for t in a.tasks() {
                assert_eq!(t.prefix_group.unwrap().tokens, 512);
            }
        }
        // Default knobs leave the suite unannotated (and otherwise equal).
        let plain = build_suite(&WorkloadConfig {
            n_agents: 10,
            window_secs: 60.0,
            ..Default::default()
        });
        assert!(plain.agents.iter().all(|a| a.prefix_group_id().is_none()));
        for (a, b) in suite.agents.iter().zip(plain.agents.iter()) {
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.n_tasks(), b.n_tasks());
        }
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("justitia-tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("suite.json");
        let cfg = WorkloadConfig { n_agents: 5, window_secs: 30.0, ..Default::default() };
        let suite = build_suite(&cfg);
        save_suite(&suite, &path, false).unwrap();
        let back = load_suite(&path).unwrap();
        assert_eq!(back.len(), 5);
    }
}
