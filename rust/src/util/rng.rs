//! Deterministic pseudo-random number generation and distributions.
//!
//! The image has no `rand` crate, so this module provides the PRNG substrate
//! used across the workload generator, the predictor trainer and the
//! simulator. The generator is PCG-XSH-RR 64/32 ("pcg32") with a 64-bit
//! state/stream, which is small, fast and statistically solid for simulation
//! purposes. All experiment code takes explicit seeds so every figure in
//! EXPERIMENTS.md is exactly reproducible.

/// PCG-XSH-RR 64/32 pseudo-random number generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed produce independent sequences.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed on the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator; used to give each agent /
    /// experiment repetition its own stream.
    pub fn fork(&mut self, salt: u64) -> Rng {
        let seed = self.next_u64() ^ salt.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng::with_stream(seed, salt | 1)
    }

    /// Next raw 32-bit output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output (two pcg32 draws).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform usize in [0, n).
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (polar rejection).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Skew-normal draw (Azzalini) with location `xi`, scale `omega`, shape
    /// `alpha`. Appendix A of the paper fits per-stage token lengths with
    /// skewed Gaussians; the workload generator samples from these.
    pub fn skew_normal(&mut self, xi: f64, omega: f64, alpha: f64) -> f64 {
        // Sample via the conditioning representation:
        //   u0, v ~ N(0,1) iid; delta = alpha/sqrt(1+alpha^2)
        //   u1 = delta*u0 + sqrt(1-delta^2)*v;  z = u1 if u0 >= 0 else -u1...
        // Standard construction: z = delta*|u0| + sqrt(1-delta^2)*v.
        let delta = alpha / (1.0 + alpha * alpha).sqrt();
        let u0 = self.normal();
        let v = self.normal();
        let z = delta * u0.abs() + (1.0 - delta * delta).sqrt() * v;
        xi + omega * z
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Gamma(shape k, scale theta) via Marsaglia-Tsang (k >= 1) with the
    /// boost trick for k < 1. Used for the bursty (CV > 1 via hyper-/
    /// hypo-exponential mixtures) arrival process that stands in for the
    /// Mooncake trace (substitution T2 in DESIGN.md).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            let u = self.f64().max(1e-12);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3 * theta;
            }
        }
    }

    /// Log-normal with underlying normal(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Categorical draw: returns an index with probability proportional to
    /// `weights[i]`.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below_usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(7);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn skew_normal_is_skewed() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.skew_normal(0.0, 1.0, 6.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        // alpha=6 -> delta ~ 0.986, E[Z] = delta*sqrt(2/pi) ~ 0.787
        assert!((mean - 0.787).abs() < 0.02, "mean={mean}");
        // Positive skew: median < mean.
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        assert!(sorted[n / 2] < mean);
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(13);
        let (k, theta) = (2.5, 1.7);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            s += r.gamma(k, theta);
        }
        let mean = s / n as f64;
        assert!((mean - k * theta).abs() < 0.06, "mean={mean}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let w = [0.72, 0.26, 0.02];
        let mut counts = [0u32; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[0] as f64 / 1e5 - 0.72).abs() < 0.01);
        assert!((counts[1] as f64 / 1e5 - 0.26).abs() < 0.01);
        assert!((counts[2] as f64 / 1e5 - 0.02).abs() < 0.005);
    }

    #[test]
    fn forked_streams_diverge() {
        let mut r = Rng::new(23);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
