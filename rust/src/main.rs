//! `justitia` CLI: serve agents, run experiments, generate workloads,
//! train predictors.
//!
//! ```text
//! justitia serve        [--artifacts DIR] [--policy P] [--port N] [--replicas R] [--placement PL]
//! justitia run          [--policy P] [--backend B] [--agents N] [--density D] [--seed S]
//! justitia cluster      [--replicas R] [--placement PL] [--agents N] [--density D] [--seed S]
//! justitia experiment   <fig3|fig7|...|fig13|table1|prefix_sharing|dag_agents|chunked_prefill|
//!                        fairbatching|preemption|trace_demo|elasticity|all> [--agents N] [--seed S]
//! justitia gen-workload [--agents N] [--density D] [--seed S] --out FILE
//! justitia train-predictor [--samples N] [--seed S]
//! justitia gps          [--agents N] [--density D] [--seed S]   (GPS reference dump)
//! ```

use anyhow::{bail, Result};
use justitia::cli::Args;
use justitia::cluster::Placement;
use justitia::config::{BackendProfile, BatchPolicyKind, Config, Policy};
use justitia::cost::CostModel;
use justitia::experiments as exp;
use justitia::util::bench::{fmt_ns, ResultsFile};
use justitia::util::json::Json;
use justitia::workload::trace;

fn main() {
    let args = Args::from_env(&[
        "predict",
        "verbose",
        "with-text",
        "occupancy",
        "prefix-cache",
        "dag",
        "online-correction",
        "chunked-prefill",
        "event-core",
        "trace",
    ]);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(args),
        Some("run") => cmd_run(args),
        Some("cluster") => cmd_cluster(args),
        Some("experiment") => cmd_experiment(args),
        Some("gen-workload") => cmd_gen_workload(args),
        Some("train-predictor") => cmd_train_predictor(args),
        Some("gps") => cmd_gps(args),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try `justitia help`)"),
    }
}

fn print_help() {
    println!(
        "justitia — fair and efficient scheduling of task-parallel LLM agents\n\n\
         USAGE:\n  justitia <serve|run|cluster|experiment|gen-workload|train-predictor|gps> [flags]\n\n\
         SUBCOMMANDS:\n\
           serve            HTTP front-end over the PJRT model (POST /agents)\n\
           run              run one policy over a generated suite (simulator)\n\
           cluster          multi-replica scale-out experiment (replicas x placement)\n\
           experiment       regenerate a paper figure/table (fig3..fig13, table1,\n\
                            prefix_sharing, dag_agents, chunked_prefill, fairbatching,\n\
                            preemption, trace_demo, elasticity, all)\n\
           gen-workload     write a workload trace JSON\n\
           train-predictor  train + evaluate the per-class MLP predictor\n\
           gps              dump the GPS fluid reference for a suite\n\n\
         COMMON FLAGS:\n\
           --policy fcfs|sjf|parrot|vtc|srjf|justitia|justitia-c\n\
           --backend llama7b-a100|llama13b-4v100|qwen32b-h800|tiny-cpu\n\
           --replicas N   --placement round-robin|least-loaded|cluster-vtime|prefix-affinity\n\
           --agents N   --density 1|2|3   --seed S   --lambda L   --predict\n\
           --prefix-cache   --prefix-fanout F   --prefix-tokens T\n\
           --dag   --spawn-prob P   --branch B   --online-correction\n\
           --chunked-prefill   --prefill-chunk C   --max-batched-tokens T\n\
           --batch-policy static|fixed-split|fairbatching   --decode-reserve T\n\
           --preemption swap|recompute|auto   --victim youngest|most-pages|\n\
                        cheapest-remaining|pamper-aware\n\
           --host-mem-pages N   --swap-bw TOKENS_PER_SEC\n\
           --failures DSL (replica churn schedule, e.g. crash@40:1,drain@60:0,join@90;\n\
                           empty = immortal pool, bit-identical to pre-elasticity runs)\n\
           --autoscale DSL (queue-depth autoscaler, e.g. every=30,up=8,down=1,min=1,max=8)\n\
           --event-core   (event-driven engine core; bit-identical, faster)\n\
           --trace        (flight recorder + Chrome/Perfetto export; default off)\n\
           --trace-sample N   (sample the time series every N iterations; default 8)\n\
           --trace-cap N      (ring-buffer capacity per stream; default 65536)"
    );
}

fn config_from(args: &Args) -> Result<Config> {
    let base = match args.get("config") {
        Some(path) => Config::from_json_file(std::path::Path::new(path))?,
        None => Config::default(),
    };
    base.apply_args(args)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let suite = trace::build_suite(&cfg.workload);
    println!(
        "workload: {} agents over {:.0}s on {} (M={} tokens), policy {}",
        suite.len(),
        cfg.workload.window_secs,
        cfg.backend.name,
        cfg.backend.kv_tokens,
        cfg.policy.name()
    );
    let t0 = std::time::Instant::now();
    let trained = if cfg.use_predictor {
        let (pred, report) =
            justitia::predictor::train_per_class(CostModel::MemoryCentric, 100, 20, cfg.workload.seed);
        println!(
            "predictor: rel_error {:.1}%, infer {:.2} ms, trained in {:.1}s",
            report.rel_error * 100.0,
            report.infer_ms,
            report.train_secs
        );
        Some(pred)
    } else {
        None
    };
    let source = match &trained {
        Some(pred) => exp::CostSource::Model(pred),
        None if cfg.noise_lambda > 1.0 => {
            exp::CostSource::Noisy { lambda: cfg.noise_lambda, seed: cfg.workload.seed }
        }
        None => exp::CostSource::Oracle,
    };
    let (metrics, trace_rec) = exp::run_policy_traced(&cfg, &suite, cfg.policy, &source);
    println!(
        "completed {}/{} agents | avg JCT {:.1}s | P90 JCT {:.1}s | engine time {:.1}s | \
         iterations {} | swaps {} | sched delay mean {} (host wall {:.2}s)",
        metrics.completed_agents(),
        suite.len(),
        metrics.avg_jct(),
        metrics.p90_jct(),
        metrics.engine_time(),
        metrics.iterations(),
        metrics.swap_out_count(),
        fmt_ns(metrics.sched_latency_ms() * 1e6),
        t0.elapsed().as_secs_f64()
    );
    if metrics.ttft_samples() > 0 {
        println!(
            "ttft: mean {:.1} ms, p99 {:.1} ms over {} first tokens",
            metrics.ttft_mean() * 1e3,
            metrics.ttft_percentile(99.0) * 1e3,
            metrics.ttft_samples()
        );
    }
    let class_deadlines = metrics.class_deadlines();
    if !class_deadlines.is_empty() {
        let per: Vec<String> = class_deadlines
            .iter()
            .map(|(c, d)| format!("{} {:.1}%", c.short_name(), d.miss_rate() * 100.0))
            .collect();
        println!(
            "slo deadlines: miss rate {:.1}% overall [{}]",
            metrics.deadline_miss_rate() * 100.0,
            per.join(", ")
        );
    }
    if cfg.prefix_cache {
        println!(
            "prefix cache: hit rate {:.1}% ({}/{}), {} prefill tokens saved, peak {} pages",
            metrics.prefix_hit_rate() * 100.0,
            metrics.prefix_hits(),
            metrics.prefix_lookups(),
            metrics.prefill_tokens_saved(),
            metrics.cache_pages_peak()
        );
    }
    if cfg.workload.dag {
        println!("dag workload: {} tasks spawned dynamically", metrics.spawned_tasks());
    }
    if cfg.chunked_prefill {
        println!(
            "chunked prefill: chunk {} / budget {} tokens, decode ITL mean {:.1} ms \
             p99 {:.1} ms, {} prefill stalls",
            cfg.prefill_chunk,
            cfg.max_batched_tokens,
            metrics.decode_itl_mean() * 1e3,
            metrics.decode_itl_percentile(99.0) * 1e3,
            metrics.prefill_stalls()
        );
        let reserve = match cfg.batch_policy {
            BatchPolicyKind::FixedSplit => format!(" (decode reserve {} tokens)", cfg.decode_reserve),
            _ => String::new(),
        };
        println!("batch policy: {}{reserve}", cfg.batch_policy.name());
    }
    if cfg.online_correction {
        println!(
            "online correction: {} events, mean rel error {:.1}%",
            metrics.correction_samples(),
            metrics.correction_error_mean() * 100.0
        );
    }
    if metrics.recompute_count() > 0 || cfg.backend.host_kv_tokens.is_some() {
        println!(
            "preemption: mode {} / victim {}, host {} tokens, {} recomputes \
             ({} tokens re-prefilled)",
            cfg.preemption.name(),
            cfg.victim.name(),
            cfg.backend
                .host_kv_tokens
                .map(|t| t.to_string())
                .unwrap_or_else(|| "inf".into()),
            metrics.recompute_count(),
            metrics.recomputed_tokens()
        );
    }
    if let Some(rec) = trace_rec {
        std::fs::create_dir_all("results")?;
        let json = justitia::trace::chrome_trace(&[(0, cfg.policy.name(), &rec)]);
        std::fs::write("results/TRACE_run.json", json.dump())?;
        println!(
            "trace: {} events ({} dropped), {} samples, {} picks -> results/TRACE_run.json",
            rec.event_count(),
            rec.dropped_events(),
            rec.sample_count(),
            rec.pick_count()
        );
    }
    Ok(())
}

/// The cluster scale-out experiment (`justitia cluster`).
///
/// By default sweeps 1→8 replicas × every placement policy; `--replicas`
/// and/or `--placement` restrict the sweep to one value each, so
/// `justitia cluster --replicas 4 --placement cluster-vtime` runs exactly
/// one configuration end to end.
fn cmd_cluster(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let n = args.get_usize("agents", 300);
    let density = args.get_f64("density", 3.0);
    let seed = cfg.workload.seed;
    let counts: Vec<usize> = match args.get("replicas") {
        Some(_) => vec![cfg.cluster.replicas],
        None => vec![1, 2, 4, 8],
    };
    let placements: Vec<Placement> = match args.get("placement") {
        Some(_) => vec![cfg.cluster.placement],
        None => Placement::ALL.to_vec(),
    };

    let mut out = ResultsFile::new("cluster.txt");
    out.line(format!(
        "=== Cluster scale-out: {} agents at {density}x density on {}, policy {} ===",
        n,
        cfg.backend.name,
        cfg.policy.name()
    ));
    out.line(format!(
        "{:<10} {:<14} {:>9} {:>9} {:>9} {:>10} {:>6}",
        "replicas", "placement", "avgJCT", "p99JCT", "makespan", "maxmin", "done"
    ));
    let t0 = std::time::Instant::now();
    let rows = exp::cluster_scaleout(&cfg, &counts, &placements, cfg.policy, n, density, seed);
    for r in &rows {
        out.line(format!(
            "{:<10} {:<14} {:>8.1}s {:>8.1}s {:>8.1}s {:>9.2}x {:>6}",
            r.replicas,
            r.placement.name(),
            r.avg_jct,
            r.p99_jct,
            r.makespan,
            r.maxmin_ratio,
            r.completed
        ));
    }
    if !cfg.failures.is_empty() {
        out.line(format!("churn schedule: [{}]", cfg.failures.to_dsl()));
        for r in &rows {
            if r.replicas_lost > 0 {
                out.line(format!(
                    "churn {}x {}: {} replicas lost, {} agents recovered, {} KV tokens rescheduled",
                    r.replicas,
                    r.placement.name(),
                    r.replicas_lost,
                    r.recovered_agents,
                    r.rescheduled_tokens
                ));
            }
        }
    }
    if counts.len() > 1 {
        let base = rows.iter().find(|r| r.replicas == counts[0]);
        let last = rows.iter().rev().find(|r| r.replicas == *counts.last().unwrap());
        if let (Some(b), Some(l)) = (base, last) {
            out.line(format!(
                "scale-out {}x replicas: avg JCT {:.1}s -> {:.1}s ({:.2}x)",
                l.replicas / b.replicas.max(1),
                b.avg_jct,
                l.avg_jct,
                b.avg_jct / l.avg_jct.max(1e-9)
            ));
        }
    }
    out.line(format!("(host wall {:.2}s)", t0.elapsed().as_secs_f64()));
    Ok(())
}

fn cmd_gen_workload(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let out = args.get("out").unwrap_or("workload.json");
    let suite = trace::build_suite(&cfg.workload);
    trace::save_suite(&suite, std::path::Path::new(out), args.has("with-text"))?;
    println!("wrote {} agents to {out}", suite.len());
    Ok(())
}

fn cmd_train_predictor(args: &Args) -> Result<()> {
    let samples = args.get_usize("samples", 100);
    let seed = args.get_u64("seed", 42);
    println!("training per-class MLP predictors ({samples} samples/class)…");
    let (_, mlp) = justitia::predictor::train_per_class(CostModel::MemoryCentric, samples, 30, seed);
    println!(
        "MLP      : rel_error {:.1}%  infer {:.2} ms  train {:.1}s",
        mlp.rel_error * 100.0,
        mlp.infer_ms,
        mlp.train_secs
    );
    println!("training shared (S3-style) baseline…");
    let (_, s3) = justitia::predictor::s3::train_shared(CostModel::MemoryCentric, samples, 30, seed);
    println!(
        "Shared   : rel_error {:.1}%  infer {:.2} ms  train {:.1}s",
        s3.rel_error * 100.0,
        s3.infer_ms,
        s3.train_secs
    );
    Ok(())
}

fn cmd_gps(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let suite = trace::build_suite(&cfg.workload);
    let scale = exp::rate_scale(&cfg);
    let gps =
        justitia::sched::gps::run_suite(&suite, CostModel::MemoryCentric, cfg.backend.kv_tokens, scale);
    println!("agent  class  arrival  gps_finish  gps_jct");
    for a in &suite.agents {
        println!(
            "{:>5}  {:>5}  {:>7.1}  {:>10.1}  {:>7.1}",
            a.id,
            a.class.short_name(),
            a.arrival,
            gps.finish_of(a.id),
            gps.jct(a.id, a.arrival)
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let port: u16 = args.get_u64("port", 8080) as u16;
    let policy = Policy::by_name(args.get_or("policy", "justitia"))?;
    let replicas: usize = match args.get("replicas") {
        Some(s) => {
            let r = s.parse().map_err(|e| anyhow::anyhow!("--replicas: {e}"))?;
            if r < 1 {
                bail!("--replicas must be >= 1");
            }
            r
        }
        None => 1,
    };
    let placement = Placement::by_name(args.get_or("placement", "cluster-vtime"))?;
    let trace = args
        .has("trace")
        .then(|| (args.get_u64("trace-sample", 8) as u32, args.get_usize("trace-cap", 65536)));
    justitia::server::http::serve(
        std::path::Path::new(artifacts),
        port,
        policy,
        replicas,
        placement,
        args.has("predict"),
        trace,
    )
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let seed = args.get_u64("seed", 42);
    let n = args.get_usize("agents", 300);
    let run_all = which == "all";

    if run_all || which == "fig3" {
        let mut out = ResultsFile::new("fig3.txt");
        out.line("=== Fig. 3: selective pampering vs instantaneous fair sharing (2 DM agents) ===");
        let r = exp::fig3(seed);
        for (name, jcts, avg) in &r.rows {
            out.line(format!(
                "{name:<10} JCTs: {:?}  avg {avg:.1}s",
                jcts.iter().map(|j| (j * 10.0).round() / 10.0).collect::<Vec<_>>()
            ));
        }
        for (name, tl) in &r.timelines {
            let peak = tl.iter().map(|(_, v)| *v).max().unwrap_or(0);
            out.line(format!("{name:<10} occupancy samples: {}, peak {} tokens", tl.len(), peak));
        }
    }
    if run_all || which == "fig7" {
        let mut out = ResultsFile::new("fig7.txt");
        out.line("=== Fig. 7: JCT across backends × schedulers × densities ===");
        let backends = [
            BackendProfile::llama7b_a100(),
            BackendProfile::llama13b_4v100(),
            BackendProfile::qwen32b_h800(),
        ];
        let rows = exp::fig7(&backends, &[1.0, 2.0, 3.0], n, seed);
        out.line(format!(
            "{:<16} {:>7} {:<10} {:>9} {:>9} {:>6}",
            "backend", "density", "policy", "avgJCT", "p90JCT", "done"
        ));
        for r in rows {
            out.line(format!(
                "{:<16} {:>6}x {:<10} {:>8.1}s {:>8.1}s {:>6}",
                r.backend,
                r.density,
                r.policy.name(),
                r.avg_jct,
                r.p90_jct,
                r.completed
            ));
        }
    }
    if run_all || which == "fig8" {
        let mut out = ResultsFile::new("fig8.txt");
        out.line("=== Fig. 8: CDF of finish-time fair ratios (vs VTC), 3x density ===");
        let r = exp::fig8(n, 3.0, seed);
        for (p, frac, worst, avg_delay) in &r.summaries {
            out.line(format!(
                "{:<10} not-delayed {:>5.1}%  worst-delay {:>6.1}%  avg-delay-of-delayed {:>5.1}%",
                p.name(),
                frac * 100.0,
                worst,
                avg_delay
            ));
        }
        for (p, rs) in &r.ratios {
            let q = |x: f64| justitia::util::stats::percentile_sorted(rs, x);
            out.line(format!(
                "{:<10} ratio p10 {:.2}  p50 {:.2}  p90 {:.2}  p99 {:.2}  max {:.2}",
                p.name(),
                q(10.0),
                q(50.0),
                q(90.0),
                q(99.0),
                rs.last().copied().unwrap_or(0.0)
            ));
        }
    }
    if run_all || which == "fig9" {
        let mut out = ResultsFile::new("fig9.txt");
        out.line("=== Fig. 9: elephant JCT vs number of mice (SRJF vs Justitia) ===");
        let rows = exp::fig9(&[0, 10, 20, 40, 80, 160], seed);
        out.line(format!("{:>6} {:>12} {:>12}", "mice", "SRJF", "Justitia"));
        let mut by_n: std::collections::BTreeMap<usize, (f64, f64)> = Default::default();
        for r in rows {
            let e = by_n.entry(r.n_mice).or_default();
            match r.policy {
                Policy::Srjf => e.0 = r.elephant_jct,
                Policy::Justitia => e.1 = r.elephant_jct,
                _ => {}
            }
        }
        for (mice, (srjf, just)) in by_n {
            out.line(format!("{mice:>6} {srjf:>11.1}s {just:>11.1}s"));
        }
    }
    if run_all || which == "fig10" {
        let mut out = ResultsFile::new("fig10.txt");
        out.line("=== Fig. 10: robustness to prediction error (lambda scaling) ===");
        let rows = exp::fig10(&[1.0, 1.5, 2.0, 3.0], n, 2.0, seed);
        let base = rows[0].avg_jct;
        for r in &rows {
            out.line(format!(
                "lambda {:>3.1}x  avg JCT {:>7.1}s ({:+.1}%)  p90 {:>7.1}s",
                r.lambda,
                r.avg_jct,
                (r.avg_jct / base - 1.0) * 100.0,
                r.p90_jct
            ));
        }
    }
    if run_all || which == "fig11" {
        let mut out = ResultsFile::new("fig11.txt");
        out.line("=== Fig. 11: memory-centric vs compute-centric cost modeling ===");
        let rows = exp::fig11(n, 2.0, seed);
        for r in &rows {
            out.line(format!(
                "{:<11} avg JCT {:>7.1}s  p90 {:>7.1}s",
                r.policy.name(),
                r.avg_jct,
                r.p90_jct
            ));
        }
        if rows.len() == 2 {
            out.line(format!(
                "degradation from compute-centric cost: avg {:+.1}%, p90 {:+.1}%",
                (rows[1].avg_jct / rows[0].avg_jct - 1.0) * 100.0,
                (rows[1].p90_jct / rows[0].p90_jct - 1.0) * 100.0
            ));
        }
    }
    if run_all || which == "fig12" {
        let mut out = ResultsFile::new("fig12.txt");
        out.line("=== Fig. 12: scheduling delay vs arrival rate ===");
        let rows = exp::fig12(&[1.0, 2.0, 4.0, 8.0, 16.0], n.min(200), seed);
        for r in &rows {
            out.line(format!(
                "rate {:>5.1}/s  mean {:>8}  max {:>8}  ({} decisions)",
                r.arrival_rate,
                fmt_ns(r.mean_delay_ms * 1e6),
                fmt_ns(r.max_delay_ms * 1e6),
                r.decisions
            ));
        }
    }
    if run_all || which == "fig13" {
        let mut out = ResultsFile::new("fig13.txt");
        out.line("=== Fig. 13: per-stage demand stability over 100 trial runs ===");
        for d in exp::fig13(seed) {
            out.line(format!(
                "{} / {}: prompt range {:?} hist {:?}",
                d.class.short_name(),
                d.kind,
                d.prompt_range,
                d.prompt_hist
            ));
            out.line(format!(
                "{} / {}: decode range {:?} hist {:?}",
                d.class.short_name(),
                d.kind,
                d.decode_range,
                d.decode_hist
            ));
        }
    }
    if run_all || which == "prefix_sharing" {
        let mut out = ResultsFile::new("prefix_sharing.txt");
        out.line("=== Prefix sharing: radix-tree KV dedup, cache off vs on ===");
        let fanout = args.get_usize("prefix-fanout", 4);
        let prefix_tokens = args.get_u64("prefix-tokens", 512) as u32;
        let rows = exp::prefix_sharing(&Config::default(), n, 3.0, fanout, prefix_tokens, seed);
        out.line(format!(
            "workload: {n} agents, families of {fanout}, {prefix_tokens}-token shared prefix"
        ));
        out.line(format!(
            "{:<8} {:>8} {:>12} {:>12} {:>9} {:>9} {:>9} {:>8} {:>6}",
            "cache", "hit%", "prefill-run", "saved", "peak-pg", "avgJCT", "p99JCT", "maxmin", "done"
        ));
        for r in &rows {
            out.line(format!(
                "{:<8} {:>7.1}% {:>12} {:>12} {:>9} {:>8.1}s {:>8.1}s {:>7.2}x {:>6}",
                if r.cache_enabled { "on" } else { "off" },
                r.hit_rate * 100.0,
                r.prefill_tokens_executed,
                r.prefill_tokens_saved,
                r.cache_pages_peak,
                r.avg_jct,
                r.p99_jct,
                r.maxmin_ratio,
                r.completed
            ));
        }
        if rows.len() == 2 {
            out.line(format!(
                "sharing: {:.1}% of prefill tokens skipped, avg JCT {:+.1}%",
                100.0 * rows[1].prefill_tokens_saved as f64
                    / (rows[1].prefill_tokens_saved + rows[1].prefill_tokens_executed).max(1)
                        as f64,
                (rows[1].avg_jct / rows[0].avg_jct.max(1e-9) - 1.0) * 100.0
            ));
        }
        // Machine-readable copy for kick-tires / EXPERIMENTS.md tooling.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("cache", Json::Bool(r.cache_enabled)),
                        ("hit_rate", Json::Num(r.hit_rate)),
                        ("prefix_hits", Json::Num(r.prefix_hits as f64)),
                        ("prefill_tokens_executed", Json::Num(r.prefill_tokens_executed as f64)),
                        ("prefill_tokens_saved", Json::Num(r.prefill_tokens_saved as f64)),
                        ("cache_pages_peak", Json::Num(r.cache_pages_peak as f64)),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("ttft_mean_ms", Json::Num(r.ttft_mean_ms)),
                        ("ttft_p99_ms", Json::Num(r.ttft_p99_ms)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("completed", Json::Num(r.completed as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/prefix_sharing.json", json.pretty())?;
        out.line("(wrote results/prefix_sharing.json)".to_string());
    }
    if run_all || which == "dag_agents" {
        let mut out = ResultsFile::new("dag_agents.txt");
        out.line("=== DAG agents: workflow shapes, dynamic spawning, online correction ===");
        let spawn_prob = args.get_f64("spawn-prob", 0.3);
        let branch = args.get_u64("branch", 3) as u32;
        let lambda = args.get_f64("lambda", 2.0);
        let rows =
            exp::dag_agents(&Config::default(), n, 3.0, spawn_prob, branch, lambda, seed);
        out.line(format!(
            "workload: {n} agents at 3x density, spawn-prob {spawn_prob}, branch {branch}, \
             noise lambda {lambda}x"
        ));
        out.line(exp::DagAgentsRow::table_header());
        for r in &rows {
            out.line(r.table_row());
        }
        // Machine-readable copy for kick-tires / EXPERIMENTS.md tooling.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("shape", Json::Str(r.shape.name().into())),
                        ("correction", Json::Bool(r.correction)),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("ttft_mean_ms", Json::Num(r.ttft_mean_ms)),
                        ("ttft_p99_ms", Json::Num(r.ttft_p99_ms)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("spawned_tasks", Json::Num(r.spawned_tasks as f64)),
                        ("correction_error", Json::Num(r.correction_error)),
                        ("correction_events", Json::Num(r.correction_events as f64)),
                        ("serial_frac", Json::Num(r.serial_frac)),
                        ("completed", Json::Num(r.completed as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/dag_agents.json", json.pretty())?;
        out.line("(wrote results/dag_agents.json)".to_string());
    }
    if run_all || which == "chunked_prefill" {
        let mut out = ResultsFile::new("chunked_prefill.txt");
        out.line("=== Chunked prefill: token-budget batch formation, chunk x budget sweep ===");
        let budget = args.get_u64("max-batched-tokens", 2048) as u32;
        let chunks: Vec<u32> = match args.get("prefill-chunk") {
            Some(c) => vec![c.parse().map_err(|e| anyhow::anyhow!("--prefill-chunk: {e}"))?],
            None => vec![1024, 512, 128],
        };
        let rows = exp::chunked_prefill(&Config::default(), n, 3.0, &chunks, budget, seed);
        out.line(format!(
            "workload: {n} agents at 3x density; chunks {chunks:?} under a {budget}-token \
             iteration budget (chunk `off` = atomic admission)"
        ));
        out.line(exp::ChunkedPrefillRow::table_header());
        for r in &rows {
            out.line(r.table_row());
        }
        for w in exp::CHUNKED_WORKLOADS {
            let get = |c: u32| {
                rows.iter().find(|r| {
                    r.workload == w && r.policy == Policy::Justitia && r.chunk == c
                })
            };
            if let (Some(off), Some(best)) = (get(0), get(*chunks.last().unwrap())) {
                out.line(format!(
                    "headline {w} (Justitia): decode ITL p99 {:.1} ms -> {:.1} ms at chunk {}, \
                     avg JCT {:.1}s -> {:.1}s",
                    off.decode_itl_p99_ms,
                    best.decode_itl_p99_ms,
                    best.chunk,
                    off.avg_jct,
                    best.avg_jct
                ));
            }
        }
        // Machine-readable copy for kick-tires / CI smoke artifacts.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("workload", Json::Str(r.workload.into())),
                        ("policy", Json::Str(r.policy.name().into())),
                        ("chunk", Json::Num(r.chunk as f64)),
                        ("budget", Json::Num(r.budget as f64)),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("decode_itl_p99_ms", Json::Num(r.decode_itl_p99_ms)),
                        ("decode_itl_mean_ms", Json::Num(r.decode_itl_mean_ms)),
                        ("ttft_mean_ms", Json::Num(r.ttft_mean_ms)),
                        ("ttft_p99_ms", Json::Num(r.ttft_p99_ms)),
                        ("deadline_miss_rate", Json::Num(r.deadline_miss_rate)),
                        ("prefill_stalls", Json::Num(r.prefill_stalls as f64)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("completed", Json::Num(r.completed as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/chunked_prefill.json", json.pretty())?;
        out.line("(wrote results/chunked_prefill.json)".to_string());
    }
    if run_all || which == "fairbatching" {
        let mut out = ResultsFile::new("fairbatching.txt");
        out.line("=== FairBatching: batch-policy sweep (closed-loop prefill/decode split) ===");
        let rows = exp::fairbatching(&Config::default(), n, 3.0, seed);
        out.line(format!(
            "workload: {n} agents at 3x density; chunked prefill on everywhere \
             (chunk 512 / budget 2048); beta_mixed 2e-6 prices prefill/decode \
             interference on every arm (stock profiles keep it 0)"
        ));
        out.line(exp::FairBatchingRow::table_header());
        for r in &rows {
            out.line(r.table_row());
        }
        for w in exp::FAIRBATCH_WORKLOADS {
            let get = |b: BatchPolicyKind| {
                rows.iter().find(|r| {
                    r.workload == w && r.policy == Policy::Justitia && r.batch == b
                })
            };
            if let (Some(st), Some(fb)) =
                (get(BatchPolicyKind::Static), get(BatchPolicyKind::FairBatching))
            {
                out.line(format!(
                    "headline {w} (Justitia): decode ITL p99 {:.1} ms -> {:.1} ms, \
                     ttft p99 {:.0} ms -> {:.0} ms, deadline miss {:.1}% -> {:.1}%",
                    st.decode_itl_p99_ms,
                    fb.decode_itl_p99_ms,
                    st.ttft_p99_ms,
                    fb.ttft_p99_ms,
                    st.deadline_miss_rate * 100.0,
                    fb.deadline_miss_rate * 100.0
                ));
            }
        }
        // Machine-readable copy for kick-tires / CI smoke artifacts.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("workload", Json::Str(r.workload.into())),
                        ("policy", Json::Str(r.policy.name().into())),
                        ("batch_policy", Json::Str(r.batch.name().into())),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("decode_itl_p99_ms", Json::Num(r.decode_itl_p99_ms)),
                        ("decode_itl_mean_ms", Json::Num(r.decode_itl_mean_ms)),
                        ("ttft_mean_ms", Json::Num(r.ttft_mean_ms)),
                        ("ttft_p99_ms", Json::Num(r.ttft_p99_ms)),
                        ("deadline_miss_rate", Json::Num(r.deadline_miss_rate)),
                        ("prefill_stalls", Json::Num(r.prefill_stalls as f64)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("completed", Json::Num(r.completed as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/fairbatching.json", json.pretty())?;
        out.line("(wrote results/fairbatching.json)".to_string());
    }
    if run_all || which == "preemption" {
        let mut out = ResultsFile::new("preemption.txt");
        out.line("=== Preemption: bounded host memory, swap vs recompute, victim policies ===");
        let rows = exp::preemption(&Config::default(), n, 3.0, seed);
        out.line(format!(
            "workload: {n} agents at 3x density; host tiers {{inf, M/8}}, swap bw {} tokens/s \
             on every arm (stock profiles keep bw 0)",
            exp::PREEMPT_SWAP_BW
        ));
        out.line(exp::PreemptionRow::table_header());
        for r in &rows {
            out.line(r.table_row());
        }
        for w in exp::PREEMPT_WORKLOADS {
            let get = |mode: &str, victim: &str| {
                rows.iter().find(|r| {
                    r.workload == w
                        && r.host_pages > 0
                        && r.mode.name() == mode
                        && r.victim.name() == victim
                })
            };
            if let (Some(swap), Some(auto)) = (get("swap", "youngest"), get("auto", "pamper-aware"))
            {
                out.line(format!(
                    "headline {w} (host M/8): p99 JCT {:.1}s (swap+youngest) -> {:.1}s \
                     (auto+pamper-aware), {} recomputes / {} wasted tokens",
                    swap.p99_jct, auto.p99_jct, auto.recomputes, auto.recomputed_tokens
                ));
            }
        }
        // Machine-readable copy for kick-tires / CI smoke artifacts.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("workload", Json::Str(r.workload.into())),
                        ("host_pages", Json::Num(r.host_pages as f64)),
                        ("mode", Json::Str(r.mode.name().into())),
                        ("victim", Json::Str(r.victim.name().into())),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("ttft_mean_ms", Json::Num(r.ttft_mean_ms)),
                        ("ttft_p99_ms", Json::Num(r.ttft_p99_ms)),
                        ("swap_outs", Json::Num(r.swap_outs as f64)),
                        ("recomputes", Json::Num(r.recomputes as f64)),
                        ("recomputed_tokens", Json::Num(r.recomputed_tokens as f64)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("completed", Json::Num(r.completed as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/preemption.json", json.pretty())?;
        out.line("(wrote results/preemption.json)".to_string());
    }
    if run_all || which == "trace_demo" {
        let mut out = ResultsFile::new("trace_demo.txt");
        out.line("=== Trace demo: Fig. 9 starvation scenario with the flight recorder on ===");
        let n_mice = args.get_usize("mice", 40);
        let stride = args.get_u64("trace-sample", 4) as u32;
        let arms = exp::trace_starvation(n_mice, stride, seed);
        for a in &arms {
            out.line(format!(
                "{:<10} elephant JCT {:>7.1}s | {} events ({} dropped), {} samples, {} picks",
                a.label,
                a.elephant_jct,
                a.recorder.event_count(),
                a.recorder.dropped_events(),
                a.recorder.sample_count(),
                a.recorder.pick_count()
            ));
        }
        let parts: Vec<(u32, &str, &justitia::trace::TraceRecorder)> = arms
            .iter()
            .enumerate()
            .map(|(i, a)| (i as u32, a.label, &a.recorder))
            .collect();
        let json = justitia::trace::chrome_trace(&parts);
        std::fs::write("results/TRACE_starvation.json", json.dump())?;
        out.line(
            "(wrote results/TRACE_starvation.json — load in Perfetto/chrome://tracing; \
             see EXPERIMENTS.md \"How to read a trace\")"
                .to_string(),
        );
    }
    if run_all || which == "elasticity" {
        let mut out = ResultsFile::new("elasticity.txt");
        out.line("=== Elasticity: replica churn (crash/drain/join) vs an oracle dispatcher ===");
        let replicas = args.get_usize("replicas", 3).max(3);
        let rows = exp::elasticity(&Config::default(), n, 3.0, replicas, seed);
        out.line(format!(
            "workload: {n} agents at 3x density on {replicas} Justitia replicas; churn times \
             are fractions of the arrival window; `oracle` rows know the schedule at t=0"
        ));
        out.line(format!(
            "{:<13} {:<7} {:>9} {:>9} {:>9} {:>8} {:>5} {:>5} {:>6} {:>12}",
            "scenario",
            "mode",
            "avgJCT",
            "p99JCT",
            "makespan",
            "maxmin",
            "done",
            "lost",
            "recov",
            "resched-tok"
        ));
        for r in &rows {
            out.line(format!(
                "{:<13} {:<7} {:>8.1}s {:>8.1}s {:>8.1}s {:>7.2}x {:>5} {:>5} {:>6} {:>12}",
                r.scenario,
                if r.oracle { "oracle" } else { "churn" },
                r.avg_jct,
                r.p99_jct,
                r.makespan,
                r.maxmin_ratio,
                r.completed,
                r.replicas_lost,
                r.recovered_agents,
                r.rescheduled_tokens
            ));
        }
        // Headline: what blind recovery costs vs announced failures.
        for sc in ["drain-1", "crash-1", "crash-2+join"] {
            let churn = rows.iter().find(|r| r.scenario == sc && !r.oracle);
            let orac = rows.iter().find(|r| r.scenario == sc && r.oracle);
            if let (Some(c), Some(o)) = (churn, orac) {
                out.line(format!(
                    "degradation {sc}: avg JCT {:+.1}% vs oracle, p99 {:+.1}%, \
                     maxmin {:.2}x -> {:.2}x",
                    100.0 * (c.avg_jct / o.avg_jct.max(1e-9) - 1.0),
                    100.0 * (c.p99_jct / o.p99_jct.max(1e-9) - 1.0),
                    o.maxmin_ratio,
                    c.maxmin_ratio
                ));
            }
        }
        // Machine-readable copy for kick-tires / CI smoke artifacts.
        let json = Json::Arr(
            rows.iter()
                .map(|r| {
                    justitia::util::json::obj([
                        ("scenario", Json::Str(r.scenario.into())),
                        ("oracle", Json::Bool(r.oracle)),
                        ("avg_jct", Json::Num(r.avg_jct)),
                        ("p99_jct", Json::Num(r.p99_jct)),
                        ("makespan", Json::Num(r.makespan)),
                        ("maxmin_ratio", Json::Num(r.maxmin_ratio)),
                        ("completed", Json::Num(r.completed as f64)),
                        ("replicas_lost", Json::Num(r.replicas_lost as f64)),
                        ("recovered_agents", Json::Num(r.recovered_agents as f64)),
                        ("rescheduled_tokens", Json::Num(r.rescheduled_tokens as f64)),
                    ])
                })
                .collect(),
        );
        std::fs::write("results/elasticity.json", json.pretty())?;
        out.line("(wrote results/elasticity.json)".to_string());
    }
    if run_all || which == "table1" {
        let mut out = ResultsFile::new("table1.txt");
        out.line("=== Table 1: MLP vs shared-model (Distillbert-style) prediction ===");
        let rows = exp::table1(n.min(150), 2.0, 100, seed);
        out.line(format!(
            "{:<32} {:>10} {:>10} {:>9} {:>9}",
            "model", "rel-err", "infer", "avgJCT", "train"
        ));
        for r in &rows {
            out.line(format!(
                "{:<32} {:>9.1}% {:>7.2}ms {:>8.1}s {:>8.1}s",
                r.model, r.rel_error_pct, r.infer_ms, r.avg_jct, r.train_secs
            ));
        }
        out.line("(paper Distillbert reference: 452% rel-err, 55.7 ms, ~2 h train)");
    }
    Ok(())
}
