//! A flat slot arena with dense `u32` ids (DESIGN.md §12).
//!
//! The event core keeps bulk state — pending arrivals today; sequence and
//! KV-page records as the tick-era hash maps retire — in flat vectors
//! indexed by dense ids instead of `HashMap`s keyed by sparse ids: one
//! bounds-checked index replaces a hash + probe on the hot path, iteration
//! is cache-linear, and freed slots are recycled LIFO so the arena's
//! footprint tracks the *live* population, not the total ever inserted.
//!
//! Determinism note: slot ids are assigned by a free-list pop (LIFO) falling
//! back to append, a pure function of the insert/remove call sequence — two
//! identical replays hand out identical ids.

/// A flat arena of `T` slots with LIFO slot reuse.
#[derive(Debug, Clone)]
pub struct Arena<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T> Default for Arena<T> {
    fn default() -> Self {
        Arena { slots: Vec::new(), free: Vec::new(), live: 0 }
    }
}

impl<T> Arena<T> {
    /// An empty arena.
    pub fn new() -> Self {
        Arena::default()
    }

    /// An empty arena with room for `n` slots before reallocating.
    pub fn with_capacity(n: usize) -> Self {
        Arena { slots: Vec::with_capacity(n), free: Vec::new(), live: 0 }
    }

    /// Insert a value, returning its dense slot id.
    pub fn insert(&mut self, value: T) -> u32 {
        self.live += 1;
        match self.free.pop() {
            Some(id) => {
                debug_assert!(self.slots[id as usize].is_none(), "free list corrupt");
                self.slots[id as usize] = Some(value);
                id
            }
            None => {
                let id = u32::try_from(self.slots.len()).expect("arena overflow");
                self.slots.push(Some(value));
                id
            }
        }
    }

    /// The value in `slot`, if live.
    pub fn get(&self, slot: u32) -> Option<&T> {
        self.slots.get(slot as usize).and_then(|s| s.as_ref())
    }

    /// Mutable access to the value in `slot`, if live.
    pub fn get_mut(&mut self, slot: u32) -> Option<&mut T> {
        self.slots.get_mut(slot as usize).and_then(|s| s.as_mut())
    }

    /// Remove and return the value in `slot`; the slot is recycled by the
    /// next insert. Returns `None` if the slot was already free.
    pub fn remove(&mut self, slot: u32) -> Option<T> {
        let v = self.slots.get_mut(slot as usize).and_then(|s| s.take());
        if v.is_some() {
            self.live -= 1;
            self.free.push(slot);
        }
        v
    }

    /// Number of live values.
    pub fn len(&self) -> usize {
        self.live
    }

    /// Whether no values are live.
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Iterate `(slot, &value)` over live slots in ascending slot order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove() {
        let mut a = Arena::new();
        let x = a.insert("x");
        let y = a.insert("y");
        assert_eq!((x, y), (0, 1));
        assert_eq!(a.get(x), Some(&"x"));
        assert_eq!(a.len(), 2);
        assert_eq!(a.remove(x), Some("x"));
        assert_eq!(a.get(x), None);
        assert_eq!(a.remove(x), None, "double remove is inert");
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn slots_recycle_lifo() {
        let mut a = Arena::with_capacity(4);
        let ids: Vec<u32> = (0..4).map(|i| a.insert(i)).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        a.remove(1);
        a.remove(3);
        // LIFO reuse: last freed slot hands out first.
        assert_eq!(a.insert(30), 3);
        assert_eq!(a.insert(10), 1);
        assert_eq!(a.insert(40), 4);
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn get_mut_and_iter() {
        let mut a = Arena::new();
        for i in 0..5 {
            a.insert(i * 10);
        }
        a.remove(2);
        *a.get_mut(4).unwrap() += 1;
        let live: Vec<(u32, i32)> = a.iter().map(|(s, &v)| (s, v)).collect();
        assert_eq!(live, vec![(0, 0), (1, 10), (3, 30), (4, 41)]);
        assert!(a.get_mut(2).is_none());
    }

    #[test]
    fn empty_arena() {
        let a: Arena<u8> = Arena::new();
        assert!(a.is_empty());
        assert_eq!(a.iter().count(), 0);
        assert!(a.get(0).is_none());
    }
}
