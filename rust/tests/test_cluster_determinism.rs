//! End-to-end cluster determinism (ISSUE 1 acceptance):
//!
//! * with ONE replica, every placement policy reproduces the single-engine
//!   Justitia run bit for bit (identical JCT vectors on the same seed);
//! * multi-replica runs are exactly reproducible (same seed → same JCTs and
//!   same assignments), complete every agent, and leave every replica's KV
//!   pool clean.

use justitia::cluster::{ClusterDispatcher, Placement};
use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::engine::exec::SimBackend;
use justitia::experiments::{build_sim_cluster, rate_scale, run_policy_oracle};
use justitia::workload::trace;
use justitia::workload::Suite;

fn cfg_with(n_agents: usize, density: f64, seed: u64, replicas: usize, p: Placement) -> Config {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
    cfg.cluster.replicas = replicas;
    cfg.cluster.placement = p;
    cfg
}

fn run_cluster(cfg: &Config, suite: &Suite) -> ClusterDispatcher<SimBackend> {
    // Same oracle basis as run_policy_oracle: expanded (spawn-inclusive)
    // ground truth — identical to plain agent_cost for spawn-free suites.
    let costs = justitia::cost::oracle_costs(false, suite, CostModel::MemoryCentric);
    let mut cluster = build_sim_cluster(cfg, Policy::Justitia);
    cluster.run_suite(suite, |a| costs[&a.id]);
    cluster
}

#[test]
fn one_replica_is_bit_identical_to_single_engine_for_every_placement() {
    for seed in [42u64, 7, 1234] {
        let cfg = cfg_with(100, 3.0, seed, 1, Placement::ClusterVtime);
        let suite = trace::build_suite(&cfg.workload);
        let single = run_policy_oracle(&cfg, &suite, Policy::Justitia);
        let want = single.jcts();
        assert_eq!(want.len(), 100, "seed {seed}: single run incomplete");

        for p in Placement::ALL {
            let cfg = cfg_with(100, 3.0, seed, 1, p);
            let cluster = run_cluster(&cfg, &suite);
            let got = cluster.merged_metrics().jcts();
            // Bit-identical: exact f64 equality, not approximate.
            assert_eq!(got, want, "seed {seed}: placement {p:?} diverged with 1 replica");
            assert_eq!(cluster.assignment_counts(), vec![100]);
        }
    }
}

#[test]
fn multi_replica_runs_are_reproducible_and_complete() {
    for p in Placement::ALL {
        let cfg = cfg_with(150, 3.0, 42, 4, p);
        let suite = trace::build_suite(&cfg.workload);
        let a = run_cluster(&cfg, &suite);
        let b = run_cluster(&cfg, &suite);
        let (ma, mb) = (a.merged_metrics(), b.merged_metrics());
        assert_eq!(ma.completed_agents(), 150, "{p:?} dropped agents");
        assert_eq!(ma.jcts(), mb.jcts(), "{p:?} not reproducible");
        assert_eq!(a.assignment_counts(), b.assignment_counts());
        // Every replica drained its pool completely.
        for r in 0..a.n_replicas() {
            a.replica(r).kv.check_invariants().unwrap();
            assert_eq!(a.replica(r).kv.device_tokens(), 0, "{p:?} replica {r} leaked KV");
        }
    }
}

#[test]
fn scale_out_helps_and_cluster_vtime_beats_round_robin_on_fairness() {
    let model = CostModel::MemoryCentric;
    let avg = |replicas: usize, p: Placement| {
        let cfg = cfg_with(150, 3.0, 42, replicas, p);
        let suite = trace::build_suite(&cfg.workload);
        run_cluster(&cfg, &suite).merged_metrics().avg_jct()
    };
    let one = avg(1, Placement::ClusterVtime);
    let four = avg(4, Placement::ClusterVtime);
    assert!(four < one, "scale-out regressed: 1 replica {one:.1}s vs 4 replicas {four:.1}s");

    // Fairness: worst-over-best slowdown vs the cluster-wide GPS reference.
    let maxmin = |p: Placement| {
        let cfg = cfg_with(150, 3.0, 42, 4, p);
        let suite = trace::build_suite(&cfg.workload);
        let cluster = run_cluster(&cfg, &suite);
        let m = cluster.merged_metrics();
        let gps = justitia::sched::gps::run_suite(
            &suite,
            model,
            cfg.backend.kv_tokens * 4,
            rate_scale(&cfg),
        );
        let slows: Vec<f64> = suite
            .agents
            .iter()
            .map(|a| m.jct(a.id).unwrap() / gps.jct(a.id, a.arrival).max(1e-9))
            .collect();
        let max = slows.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = slows.iter().cloned().fold(f64::INFINITY, f64::min);
        max / min
    };
    let (vtime, rr) = (maxmin(Placement::ClusterVtime), maxmin(Placement::RoundRobin));
    assert!(
        vtime <= rr * 1.10,
        "cluster-vtime maxmin {vtime:.2} should not be worse than round-robin {rr:.2}"
    );
}

#[test]
fn prefix_cache_disabled_replay_is_bit_identical_to_baseline() {
    // ISSUE 2 acceptance: with the prefix cache disabled (the config
    // default), single-replica trace replay must be bit-identical to the
    // pre-cache engine. Two equivalences pin that down:
    //   1. a shared-prefix-annotated suite replayed with the cache off
    //      equals the same suite with every annotation stripped (the new
    //      workload metadata is inert), and
    //   2. the default suite replayed through the default config equals the
    //      cluster path at one replica for every placement policy,
    //      including the new prefix-affinity.
    let mut cfg = cfg_with(100, 3.0, 42, 1, Placement::PrefixAffinity);
    cfg.workload.prefix_fanout = 4;
    cfg.workload.prefix_tokens = 512;
    assert!(!cfg.prefix_cache, "prefix cache must default to off");
    let annotated = trace::build_suite(&cfg.workload);
    assert!(annotated.agents.iter().all(|a| a.prefix_group_id().is_some()));
    let mut stripped = annotated.clone();
    for a in &mut stripped.agents {
        for t in &mut a.tasks {
            t.prefix_group = None;
        }
    }
    let m_annotated = run_policy_oracle(&cfg, &annotated, Policy::Justitia);
    let m_stripped = run_policy_oracle(&cfg, &stripped, Policy::Justitia);
    assert_eq!(
        m_annotated.jcts(),
        m_stripped.jcts(),
        "prefix annotations must be inert while the cache is off"
    );
    assert_eq!(m_annotated.prefix_lookups(), 0);
    assert_eq!(m_annotated.prefill_tokens_saved(), 0);

    // One replica + prefix-affinity placement degenerates to the single
    // engine bit for bit, like every other placement.
    let cluster = run_cluster(&cfg, &annotated);
    assert_eq!(cluster.merged_metrics().jcts(), m_annotated.jcts());
}

#[test]
fn dag_suite_cluster_runs_are_reproducible_and_one_replica_matches_single() {
    // ISSUE 3 acceptance, DAG edition: a DAG workload (mixed shapes +
    // dynamic spawning) through the cluster path must be exactly
    // reproducible for every placement, and one replica must reproduce the
    // single-engine run bit for bit — spawned-task counts included.
    let mut cfg = cfg_with(60, 3.0, 42, 1, Placement::ClusterVtime);
    cfg.workload = cfg.workload.clone().with_dag(0.3, 3);
    let suite = trace::build_suite(&cfg.workload);
    assert!(suite.agents.iter().all(|a| a.spawn.is_some()));

    let single = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    let want = single.jcts();
    assert_eq!(want.len(), 60, "single DAG run incomplete");
    assert!(single.spawned_tasks() > 0, "spawn-prob 0.3 over 60 agents must spawn");

    for p in Placement::ALL {
        let mut cfg1 = cfg_with(60, 3.0, 42, 1, p);
        cfg1.workload = cfg1.workload.clone().with_dag(0.3, 3);
        let cluster = run_cluster(&cfg1, &suite);
        let got = cluster.merged_metrics();
        assert_eq!(got.jcts(), want, "{p:?} diverged on the DAG suite with 1 replica");
        assert_eq!(got.spawned_tasks(), single.spawned_tasks(), "{p:?} spawn counts");
    }

    // Multi-replica: reproducible, complete, and spawn counts match the
    // static expansion (placement cannot change what spawns).
    let expected_spawns: u64 =
        suite.agents.iter().map(|a| a.expand_spawns().len() as u64).sum();
    for p in Placement::ALL {
        let mut cfg4 = cfg_with(60, 3.0, 42, 4, p);
        cfg4.workload = cfg4.workload.clone().with_dag(0.3, 3);
        let a = run_cluster(&cfg4, &suite);
        let b = run_cluster(&cfg4, &suite);
        let (ma, mb) = (a.merged_metrics(), b.merged_metrics());
        assert_eq!(ma.completed_agents(), 60, "{p:?} dropped DAG agents");
        assert_eq!(ma.jcts(), mb.jcts(), "{p:?} DAG run not reproducible");
        assert_eq!(ma.spawned_tasks(), expected_spawns, "{p:?} spawned set drifted");
        for r in 0..a.n_replicas() {
            a.replica(r).kv.check_invariants().unwrap();
            assert_eq!(a.replica(r).kv.device_tokens(), 0, "{p:?} replica {r} leaked KV");
        }
    }
}

#[test]
fn online_path_agrees_with_replay_on_completions() {
    // Drive the same agents through the online submit/step path; every agent
    // must complete and land on exactly one replica.
    let cfg = cfg_with(30, 3.0, 9, 3, Placement::ClusterVtime);
    let suite = trace::build_suite(&cfg.workload);
    let model = CostModel::MemoryCentric;
    let mut cluster = build_sim_cluster(&cfg, Policy::Justitia);
    for a in &suite.agents {
        cluster.submit(a.clone(), model.agent_cost(a));
    }
    let mut guard = 0u64;
    while cluster.has_work() {
        cluster.step();
        guard += 1;
        assert!(guard < 2_000_000, "runaway online drain");
    }
    let m = cluster.merged_metrics();
    assert_eq!(m.completed_agents(), 30);
    for a in &suite.agents {
        assert!(cluster.replica_of(a.id).is_some());
        assert!(cluster.agent_complete_time(a.id).is_some());
    }
    assert_eq!(cluster.assignment_counts().iter().sum::<usize>(), 30);
}
