//! Fig. 13 (Appendix A) — per-stage prompt/decode length distributions over
//! 100 trial runs: MRS generate-summary and FV generate-queries, 10 buckets
//! each with skew-normal shape.

use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 13: agent-specific demand stability (100 trial runs)");
    let mut out = ResultsFile::new("bench_fig13.txt");
    for d in justitia::experiments::fig13(42) {
        out.line(format!("--- {} / {} ---", d.class.short_name(), d.kind));
        out.line(format!(
            "prompt  range [{}, {}]  histogram {:?}",
            d.prompt_range.0, d.prompt_range.1, d.prompt_hist
        ));
        out.line(format!(
            "decode  range [{}, {}]  histogram {:?}",
            d.decode_range.0, d.decode_range.1, d.decode_hist
        ));
        let total: usize = d.prompt_hist.iter().sum();
        let peak = d.prompt_hist.iter().max().copied().unwrap_or(0);
        out.line(format!(
            "prompt concentration: peak bucket holds {:.0}% of {} samples",
            peak as f64 / total as f64 * 100.0,
            total
        ));
    }
    out.line("(paper: FV generate-queries prompts cluster at 360-380 tokens)".to_string());
}
