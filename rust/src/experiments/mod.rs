//! The experiment harness: one function per paper table/figure.
//!
//! Each function regenerates the corresponding rows/series from scratch
//! (workload generation → engine runs → metrics) and returns structured
//! results; the bench binaries (`rust/benches/bench_*`) and the CLI
//! (`justitia experiment <id>`) print them. DESIGN.md §6 maps experiment ids
//! to modules; EXPERIMENTS.md records paper-vs-measured.

use crate::cluster::{ClusterDispatcher, FailureSchedule, Placement};
use crate::config::{BatchPolicyKind, Config, Policy, PreemptionMode, VictimPolicy, WorkloadConfig};
use crate::cost::CostModel;
use crate::engine::exec::SimBackend;
use crate::engine::Engine;
use crate::metrics::{fair_ratios, fairness_summary, RunMetrics};
use crate::predictor::{oracle::NoisyOracle, Predictor};
use crate::sched::cost_model_for;
use crate::trace::TraceRecorder;
use crate::util::threadpool::ThreadPool;
use crate::workload::{AgentClass, AgentId, Suite};

/// How the scheduler learns agent costs.
pub enum CostSource<'a> {
    /// Ground truth under the policy's cost model.
    Oracle,
    /// Ground truth × log-uniform noise in [1/λ, λ] (Fig. 10).
    Noisy { lambda: f64, seed: u64 },
    /// A trained predictor (Table 1 / predictor-in-the-loop runs).
    Model(&'a dyn Predictor),
}

/// Iterations/second scale used to map KV token-time into GPS real time for
/// Justitia's virtual clock. Priority order is invariant to it; only GPS
/// diagnostics depend on it, so a fixed nominal decode rate suffices.
pub fn rate_scale(cfg: &Config) -> f64 {
    let b = (cfg.max_batch / 2).max(1);
    1.0 / (cfg.backend.alpha + cfg.backend.beta_decode * b as f64)
}

/// Run one policy over a suite on the calibrated simulator backend.
///
/// With `cfg.prefix_cache` on and a memory-centric policy, oracle costs are
/// the suite-wide *deduplicated* token-time ([`crate::cost::shared_agent_costs`]):
/// the engine delivers deduplicated physical service, so feeding the
/// scheduler undeduplicated costs would skew its finish tags. Without the
/// cache (or without prefix annotations) the map is identical to plain
/// Eq. 1 costs, so the default path is unchanged bit for bit.
pub fn run_policy(cfg: &Config, suite: &Suite, policy: Policy, source: &CostSource) -> RunMetrics {
    run_policy_traced(cfg, suite, policy, source).0
}

/// [`run_policy`], but also hand back the engine's flight recorder when
/// `cfg.trace` is on (`None` otherwise — the recorder is never allocated on
/// the off path, see DESIGN.md §13). The CLI uses this to write
/// `results/TRACE_run.json`; everything metric-only goes through
/// [`run_policy`].
pub fn run_policy_traced(
    cfg: &Config,
    suite: &Suite,
    policy: Policy,
    source: &CostSource,
) -> (RunMetrics, Option<TraceRecorder>) {
    let model = cost_model_for(policy);
    // A trained-model run is a predictor run end to end: the engine derives
    // per-task scheduler tags from the agent-level prediction too (the
    // ISSUE 5 predictor bugfix), whatever `cfg.use_predictor` says.
    let mut cfg = cfg.clone();
    cfg.use_predictor = cfg.use_predictor || matches!(source, CostSource::Model(_));
    let cfg = &cfg;
    let sched = crate::sched::build(policy, cfg.backend.kv_tokens, rate_scale(cfg));
    let mut engine = Engine::new(cfg, sched, SimBackend::new(&cfg.backend));
    let mut noisy = match source {
        CostSource::Noisy { lambda, seed } => Some(NoisyOracle::new(model, *lambda, *seed)),
        _ => None,
    };
    let oracle = crate::cost::oracle_costs(cfg.prefix_cache, suite, model);
    engine.run_suite(suite, |a| match source {
        CostSource::Oracle => oracle[&a.id],
        CostSource::Noisy { .. } => noisy.as_mut().unwrap().cost(a),
        CostSource::Model(p) => p.predict(a.class, &a.input_text),
    });
    let trace = engine.take_trace();
    (std::mem::take(&mut engine.metrics), trace)
}

/// Convenience: oracle-cost run.
pub fn run_policy_oracle(cfg: &Config, suite: &Suite, policy: Policy) -> RunMetrics {
    run_policy(cfg, suite, policy, &CostSource::Oracle)
}

/// Max-min fair-share ratio vs a GPS fluid reference: each completed
/// agent's slowdown is its JCT over its GPS JCT; the ratio of the worst to
/// the best slowdown measures how evenly contention is paid (1.0 = perfectly
/// even; the empty/degenerate case reports 1.0). Shared by the cluster
/// scale-out, prefix-sharing and DAG-agents experiments.
pub fn maxmin_vs_gps(suite: &Suite, m: &RunMetrics, gps: &crate::sched::gps::GpsResult) -> f64 {
    let mut worst = f64::NEG_INFINITY;
    let mut best = f64::INFINITY;
    for a in &suite.agents {
        if let Some(jct) = m.jct(a.id) {
            let slowdown = jct / gps.jct(a.id, a.arrival).max(1e-9);
            worst = worst.max(slowdown);
            best = best.min(slowdown);
        }
    }
    if best.is_finite() && best > 0.0 {
        worst / best
    } else {
        1.0
    }
}

// ---------------------------------------------------------------------------
// Fig. 3 — selective pampering vs instantaneous fair sharing (2 DM agents)
// ---------------------------------------------------------------------------

/// Fig. 3 outcome: per-policy JCTs and KV-occupancy timelines.
pub struct Fig3Result {
    /// (policy label, per-agent JCTs, avg JCT).
    pub rows: Vec<(String, Vec<f64>, f64)>,
    /// KV-occupancy timelines: (label, samples of (t, device_tokens)).
    pub timelines: Vec<(String, Vec<(f64, u64)>)>,
}

/// Two DocMerging agents submitted simultaneously to the llama7b-a100
/// profile (M = 459 blocks), under VTC (instantaneous fair sharing) vs
/// Justitia (pampering in fair order).
pub fn fig3(seed: u64) -> Fig3Result {
    let cfg = Config::default();
    let mut gen = crate::workload::generator::Generator::new(seed);
    let a = gen.agent(AgentClass::DocumentMerging, 0, 0.0);
    let b = gen.agent(AgentClass::DocumentMerging, 1, 0.0);
    let suite = Suite::new(vec![a, b]);

    let mut rows = Vec::new();
    let mut timelines = Vec::new();
    for policy in [Policy::Vtc, Policy::Justitia] {
        let model = cost_model_for(policy);
        let sched = crate::sched::build(policy, cfg.backend.kv_tokens, rate_scale(&cfg));
        let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
        engine.record_occupancy = true;
        engine.run_suite(&suite, |a| model.agent_cost(a));
        let jcts: Vec<f64> = (0..2).map(|i| engine.metrics.jct(i).unwrap()).collect();
        let avg = crate::util::stats::mean(&jcts);
        rows.push((policy.name().to_string(), jcts, avg));
        timelines.push((
            policy.name().to_string(),
            engine.metrics.kv_samples.iter().map(|s| (s.t, s.device_tokens)).collect(),
        ));
    }
    Fig3Result { rows, timelines }
}

// ---------------------------------------------------------------------------
// Fig. 7 — avg/P90 JCT, backends × schedulers × densities
// ---------------------------------------------------------------------------

/// One (backend, density, policy) cell of the Fig. 7 sweep.
pub struct Fig7Row {
    /// Backend profile name.
    pub backend: String,
    /// Workload density multiplier.
    pub density: f64,
    /// Scheduling policy.
    pub policy: Policy,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P90 JCT (s).
    pub p90_jct: f64,
    /// Completed agents.
    pub completed: usize,
}

/// The §5.2 efficiency sweep. `n_agents` is scaled down from 300 for test
/// use; benches use the full size.
pub fn fig7(
    backends: &[crate::config::BackendProfile],
    densities: &[f64],
    n_agents: usize,
    seed: u64,
) -> Vec<Fig7Row> {
    // Parallelize across (backend, density, policy) — all independent.
    let mut jobs = Vec::new();
    for backend in backends {
        for &density in densities {
            for policy in Policy::all_paper_baselines() {
                jobs.push((backend.clone(), density, policy));
            }
        }
    }
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(backend, density, policy)| {
        let mut cfg = Config::default();
        cfg.backend = backend.clone();
        cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
        let suite = crate::workload::trace::build_suite(&cfg.workload);
        let m = run_policy_oracle(&cfg, &suite, policy);
        Fig7Row {
            backend: backend.name.clone(),
            density,
            policy,
            avg_jct: m.avg_jct(),
            p90_jct: m.p90_jct(),
            completed: m.completed_agents(),
        }
    })
}

// ---------------------------------------------------------------------------
// Fig. 8 — CDF of finish-time fair ratios at 3× density
// ---------------------------------------------------------------------------

/// Fig. 8 outcome: fair-ratio distributions and summaries per policy.
pub struct Fig8Result {
    /// (policy, sorted ratios) — ratio = JCT / JCT_under_VTC per agent.
    pub ratios: Vec<(Policy, Vec<f64>)>,
    /// (policy, frac not delayed, worst delay %, avg delay % of delayed).
    pub summaries: Vec<(Policy, f64, f64, f64)>,
}

/// The fairness experiment: finish-time ratios vs the VTC baseline run.
pub fn fig8(n_agents: usize, density: f64, seed: u64) -> Fig8Result {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
    let suite = crate::workload::trace::build_suite(&cfg.workload);
    let baseline = run_policy_oracle(&cfg, &suite, Policy::Vtc);

    let policies = [Policy::Fcfs, Policy::Sjf, Policy::AgentFcfs, Policy::Srjf, Policy::Justitia];
    let pool = ThreadPool::with_cpus();
    let cfg2 = cfg.clone();
    let suite2 = suite.clone();
    let runs = pool.map(policies.to_vec(), move |p| (p, run_policy_oracle(&cfg2, &suite2, p)));

    let mut ratios = Vec::new();
    let mut summaries = Vec::new();
    for (p, m) in runs {
        let r = fair_ratios(&m, &baseline);
        let s = fairness_summary(&r);
        summaries.push((p, s.frac_not_delayed, s.worst_delay_pct, s.avg_delay_pct_of_delayed));
        let mut rs: Vec<f64> = r.into_iter().map(|(_, x)| x).collect();
        rs.sort_by(|a, b| a.total_cmp(b));
        ratios.push((p, rs));
    }
    Fig8Result { ratios, summaries }
}

// ---------------------------------------------------------------------------
// Fig. 9 — starvation: elephant (MRS) + stream of mice
// ---------------------------------------------------------------------------

/// One (mice count, policy) cell of the Fig. 9 starvation study.
pub struct Fig9Row {
    /// Mice agents in the stream.
    pub n_mice: usize,
    /// Scheduling policy.
    pub policy: Policy,
    /// The elephant's JCT (s).
    pub elephant_jct: f64,
}

/// One MRS elephant at t=0, then `n_mice` small agents (KBQAV/CC/ALFWI)
/// arriving as a sustained stream. The paper submits one mouse per second,
/// which saturates its A100 testbed; on the calibrated simulator the same
/// *utilization* needs ~4 mice/s (EXPERIMENTS.md §Calibration) — the
/// starvation mechanism is identical.
pub const FIG9_MICE_PER_SEC: f64 = 1.5;

/// The Fig. 9 workload: one MRS elephant at t=0 plus a sustained stream of
/// `n_mice` small agents, on a config whose batch slots are the second
/// contended resource (vLLM max_num_seqs, scaled like M — §Calibration).
/// Shared by [`fig9`] and [`trace_starvation`] so the starvation trace demo
/// replays exactly the paper's scenario.
pub fn fig9_suite(n_mice: usize, seed: u64) -> (Config, Suite) {
    let mut cfg = Config::default();
    cfg.max_batch = 8;
    let mut gen = crate::workload::generator::Generator::new(seed);
    let mut agents = vec![gen.agent(AgentClass::MapReduceSummarization, 0, 0.0)];
    let mice_classes =
        [AgentClass::KbqaVerification, AgentClass::CodeChecking, AgentClass::AlfworldInteraction];
    let mut rng = crate::util::rng::Rng::with_stream(seed, 0x91ce);
    for i in 0..n_mice {
        let class = *rng.choose(&mice_classes);
        agents.push(gen.agent(class, (i + 1) as u32, 1.0 + i as f64 / FIG9_MICE_PER_SEC));
    }
    (cfg, Suite::new(agents))
}

/// The starvation study: elephant JCT per mice count, SRJF vs Justitia.
pub fn fig9(mice_counts: &[usize], seed: u64) -> Vec<Fig9Row> {
    let mut jobs = Vec::new();
    for &n in mice_counts {
        for policy in [Policy::Srjf, Policy::Justitia] {
            jobs.push((n, policy));
        }
    }
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(n_mice, policy)| {
        let (cfg, suite) = fig9_suite(n_mice, seed);
        // After Suite::new re-sorting, the elephant is still agent 0 (t=0).
        let m = run_policy_oracle(&cfg, &suite, policy);
        Fig9Row { n_mice, policy, elephant_jct: m.jct(0).unwrap() }
    })
}

/// One traced arm of the starvation demo: the policy label, its elephant
/// JCT, and the full flight recorder for the run.
pub struct TraceStarvationArm {
    /// Policy label ("srjf" / "justitia") — also the Perfetto process name.
    pub label: &'static str,
    /// The elephant's JCT under this policy (s).
    pub elephant_jct: f64,
    /// The run's flight recorder (events, samples, pick audit).
    pub recorder: TraceRecorder,
}

/// The worked starvation example behind EXPERIMENTS.md "how to read a
/// trace": the Fig. 9 elephant+mice suite replayed under SRJF and Justitia
/// with the flight recorder on. SRJF's track shows the elephant parked in
/// the waiting row with its virtual-time lag climbing; Justitia's shows the
/// pampered pick (audit log) driving it to completion. The CLI exports the
/// two recorders side by side as `results/TRACE_starvation.json`.
pub fn trace_starvation(n_mice: usize, sample_stride: u32, seed: u64) -> Vec<TraceStarvationArm> {
    [(Policy::Srjf, "srjf"), (Policy::Justitia, "justitia")]
        .into_iter()
        .map(|(policy, label)| {
            let (mut cfg, suite) = fig9_suite(n_mice, seed);
            cfg.trace = true;
            cfg.trace_sample = sample_stride;
            let (m, recorder) = run_policy_traced(&cfg, &suite, policy, &CostSource::Oracle);
            TraceStarvationArm {
                label,
                elephant_jct: m.jct(0).unwrap_or(0.0),
                recorder: recorder.expect("cfg.trace was set"),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Fig. 10 — robustness to prediction error
// ---------------------------------------------------------------------------

/// One λ row of the Fig. 10 robustness sweep.
pub struct Fig10Row {
    /// Noise scale λ.
    pub lambda: f64,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P90 JCT (s).
    pub p90_jct: f64,
}

/// Justitia under log-uniform cost noise (Fig. 10).
pub fn fig10(lambdas: &[f64], n_agents: usize, density: f64, seed: u64) -> Vec<Fig10Row> {
    let pool = ThreadPool::with_cpus();
    pool.map(lambdas.to_vec(), move |lambda| {
        let mut cfg = Config::default();
        cfg.workload =
            WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
        let suite = crate::workload::trace::build_suite(&cfg.workload);
        let m = run_policy(
            &cfg,
            &suite,
            Policy::Justitia,
            &CostSource::Noisy { lambda, seed: seed ^ 0xf16 },
        );
        Fig10Row { lambda, avg_jct: m.avg_jct(), p90_jct: m.p90_jct() }
    })
}

// ---------------------------------------------------------------------------
// Fig. 11 — cost-model ablation: Justitia vs Justitia/C
// ---------------------------------------------------------------------------

/// One row of the Fig. 11 cost-model ablation.
pub struct Fig11Row {
    /// Justitia or Justitia/C.
    pub policy: Policy,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P90 JCT (s).
    pub p90_jct: f64,
}

/// Memory- vs compute-centric cost modeling (Fig. 11).
pub fn fig11(n_agents: usize, density: f64, seed: u64) -> Vec<Fig11Row> {
    let pool = ThreadPool::with_cpus();
    pool.map(
        vec![Policy::Justitia, Policy::JustitiaComputeCost],
        move |policy| {
            let mut cfg = Config::default();
            cfg.workload =
                WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
            let suite = crate::workload::trace::build_suite(&cfg.workload);
            let m = run_policy_oracle(&cfg, &suite, policy);
            Fig11Row { policy, avg_jct: m.avg_jct(), p90_jct: m.p90_jct() }
        },
    )
}

// ---------------------------------------------------------------------------
// Fig. 12 — scheduling overhead vs arrival rate
// ---------------------------------------------------------------------------

/// One arrival-rate row of the Fig. 12 overhead study.
pub struct Fig12Row {
    /// Agent arrivals per second.
    pub arrival_rate: f64,
    /// Mean scheduling decision latency (ms).
    pub mean_delay_ms: f64,
    /// Max scheduling decision latency (ms).
    pub max_delay_ms: f64,
    /// Decision points measured.
    pub decisions: u64,
}

/// Host-side scheduling decision latency under increasing arrival rates.
pub fn fig12(rates_per_sec: &[f64], n_agents: usize, seed: u64) -> Vec<Fig12Row> {
    rates_per_sec
        .iter()
        .map(|&rate| {
            let mut cfg = Config::default();
            cfg.workload = WorkloadConfig {
                n_agents,
                window_secs: n_agents as f64 / rate,
                seed,
                ..Default::default()
            };
            let suite = crate::workload::trace::build_suite(&cfg.workload);
            let m = run_policy_oracle(&cfg, &suite, Policy::Justitia);
            Fig12Row {
                arrival_rate: rate,
                mean_delay_ms: m.sched_latency_ms(),
                max_delay_ms: m.sched_latency_max_ms(),
                decisions: m.sched_decisions(),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Fig. 13 — demand stability (Appendix A)
// ---------------------------------------------------------------------------

/// One (class, inference kind) distribution of the Fig. 13 stability study.
pub struct Fig13Dist {
    /// Agent class.
    pub class: AgentClass,
    /// Inference kind within the class template.
    pub kind: &'static str,
    /// 10-bucket histogram of token lengths over 100 trial runs + range.
    pub prompt_hist: Vec<usize>,
    /// Observed prompt-length range.
    pub prompt_range: (u32, u32),
    /// 10-bucket decode-length histogram.
    pub decode_hist: Vec<usize>,
    /// Observed decode-length range.
    pub decode_range: (u32, u32),
}

/// Per-stage demand stability over 100 trial runs (Appendix A).
pub fn fig13(seed: u64) -> Vec<Fig13Dist> {
    let targets = [
        (AgentClass::MapReduceSummarization, "generate-summary"),
        (AgentClass::FactVerification, "generate-queries"),
    ];
    targets
        .iter()
        .map(|&(class, kind)| {
            let mut gen = crate::workload::generator::Generator::new(seed);
            let mut prompts = Vec::new();
            let mut decodes = Vec::new();
            for i in 0..100 {
                let a = gen.agent(class, i, 0.0);
                for t in a.tasks().filter(|t| t.kind == kind) {
                    prompts.push(t.prompt_tokens as f64);
                    decodes.push(t.decode_tokens as f64);
                }
            }
            let pr = (
                prompts.iter().cloned().fold(f64::MAX, f64::min) as u32,
                prompts.iter().cloned().fold(0.0f64, f64::max) as u32,
            );
            let dr = (
                decodes.iter().cloned().fold(f64::MAX, f64::min) as u32,
                decodes.iter().cloned().fold(0.0f64, f64::max) as u32,
            );
            Fig13Dist {
                class,
                kind,
                prompt_hist: crate::util::stats::histogram(&prompts, pr.0 as f64, pr.1 as f64 + 1.0, 10),
                prompt_range: pr,
                decode_hist: crate::util::stats::histogram(&decodes, dr.0 as f64, dr.1 as f64 + 1.0, 10),
                decode_range: dr,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Cluster scale-out — replicas × placement policies (beyond the paper:
// cluster-level Justitia fair queuing; see DESIGN.md §5 and ROADMAP.md)
// ---------------------------------------------------------------------------

/// Build `cfg.cluster.replicas` simulator replicas running `policy` and wrap
/// them in a [`ClusterDispatcher`] under `cfg.cluster.placement`.
pub fn build_sim_cluster(cfg: &Config, policy: Policy) -> ClusterDispatcher<SimBackend> {
    let n = cfg.cluster.replicas.max(1);
    let replicas = (0..n)
        .map(|_| {
            let sched = crate::sched::build(policy, cfg.backend.kv_tokens, rate_scale(cfg));
            Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
        })
        .collect();
    ClusterDispatcher::new(replicas, cfg.cluster.placement, cfg.backend.kv_tokens, rate_scale(cfg))
}

/// One (replica count, placement) configuration's results.
pub struct ClusterRow {
    /// Number of engine replicas.
    pub replicas: usize,
    /// Placement policy routing agents to replicas.
    pub placement: Placement,
    /// Per-replica scheduling policy.
    pub policy: Policy,
    /// Average JCT across all agents (s).
    pub avg_jct: f64,
    /// P99 JCT (s) — the scale-out tail metric.
    pub p99_jct: f64,
    /// Max-min fair-share ratio: each agent's slowdown vs the idealized
    /// cluster-wide GPS reference (capacity N×M), max divided by min. 1.0
    /// means slowdown is spread perfectly evenly; large values mean some
    /// agents absorb the whole contention penalty.
    pub maxmin_ratio: f64,
    /// Agents that completed (must equal the suite size).
    pub completed: usize,
    /// Cluster makespan (s): the slowest replica's engine time.
    pub makespan: f64,
    /// Replica crashes suffered (0 on immortal-pool runs).
    pub replicas_lost: u64,
    /// Agents salvaged off crashed replicas through the recompute fold.
    pub recovered_agents: u64,
    /// KV tokens (device + host) destroyed by crashes and re-derived on the
    /// recovery replicas.
    pub rescheduled_tokens: u64,
}

/// The cluster scale-out experiment: one §5.1 suite replayed through
/// 1..=N-replica clusters under each placement policy. Reports JCT
/// efficiency (avg/p99) and cluster-level fairness (max-min fair-share
/// ratio against the N×M GPS fluid reference).
///
/// `base` supplies the backend profile / batch limits (its workload and
/// cluster knobs are overridden per job).
pub fn cluster_scaleout(
    base: &Config,
    replica_counts: &[usize],
    placements: &[Placement],
    policy: Policy,
    n_agents: usize,
    density: f64,
    seed: u64,
) -> Vec<ClusterRow> {
    let mut jobs = Vec::new();
    for &n_r in replica_counts {
        for &placement in placements {
            jobs.push((n_r, placement));
        }
    }
    let base = base.clone();
    // Two levels of parallelism share one core budget: many small jobs run
    // concurrently on the outer pool with serial replicas; a single huge job
    // (the 1M-agent scale-out smoke) instead gives ALL cores to its replica
    // simulations via `run_suite_parallel` — the merged metrics are
    // byte-identical either way (see cluster::run_suite_parallel).
    let inner_threads = if jobs.len() == 1 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        1
    };
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(n_r, placement)| {
        let mut cfg = base.clone();
        // Keep the base workload's shape knobs (class mix, shared-prefix
        // families) and override only size/seed/density.
        cfg.workload.n_agents = n_agents;
        cfg.workload.seed = seed;
        cfg.workload = cfg.workload.clone().with_density(density);
        cfg.cluster = crate::config::ClusterConfig { replicas: n_r, placement };
        // Past ~200k agents the synthesized prompt text dominates memory and
        // nothing below reads it (costs come from the oracle): use the lean
        // suite, which is identical except for empty `input_text`.
        let suite = if n_agents >= 200_000 {
            crate::workload::trace::build_suite_lean(&cfg.workload)
        } else {
            crate::workload::trace::build_suite(&cfg.workload)
        };
        let model = cost_model_for(policy);
        let mut cluster = build_sim_cluster(&cfg, policy);
        // Same dedup-aware oracle rule as `run_policy`: with the prefix
        // cache on, scheduler tags and the GPS yardstick both use the
        // deduplicated cost base. Note this is the workload's *intrinsic*
        // deduplicated demand (ideal colocation): one common basis keeps
        // maxmin_ratio comparable across placements, at the price of
        // overstating slowdowns for placements that scatter families and
        // therefore realize less physical sharing.
        let oracle = crate::cost::oracle_costs(cfg.prefix_cache, &suite, model);
        let makespan = if cfg.failures.is_empty() {
            cluster.run_suite_parallel(&suite, |a| oracle[&a.id], inner_threads)
        } else {
            // Churn run: online submit+step driving with crash recovery.
            // Crash replacements and pool growth get fresh engines built
            // exactly like the originals.
            let schedule = cfg.failures.clone();
            let spawn_cfg = cfg.clone();
            cluster.run_suite_churn(&suite, |a| oracle[&a.id], &schedule, || {
                let sched = crate::sched::build(
                    policy,
                    spawn_cfg.backend.kv_tokens,
                    rate_scale(&spawn_cfg),
                );
                Engine::new(&spawn_cfg, sched, SimBackend::new(&spawn_cfg.backend))
            })
        };
        let m = cluster.merged_metrics();

        // Fairness yardstick: the whole cluster as ONE GPS server of
        // capacity N×M. slowdown_j = JCT_j / GPS-JCT_j; the ratio of the
        // worst to the best slowdown measures how evenly contention is paid.
        let triples: Vec<(crate::workload::AgentId, f64, f64)> =
            suite.agents.iter().map(|a| (a.id, a.arrival, oracle[&a.id])).collect();
        let gps = crate::sched::gps::run(
            &triples,
            cfg.backend.kv_tokens * n_r as u64,
            rate_scale(&cfg),
        );
        let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
        ClusterRow {
            replicas: n_r,
            placement,
            policy,
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            maxmin_ratio,
            completed: m.completed_agents(),
            makespan,
            replicas_lost: m.replicas_lost(),
            recovered_agents: m.recovered_agents(),
            rescheduled_tokens: m.rescheduled_tokens(),
        }
    })
}

// ---------------------------------------------------------------------------
// Elasticity under churn — crash/drain/join with recompute-path recovery vs
// an oracle dispatcher that knows the failure schedule (DESIGN.md §14)
// ---------------------------------------------------------------------------

/// One (scenario, dispatcher) row of the elasticity experiment.
pub struct ElasticityRow {
    /// Scenario label ("immortal", "drain-1", "crash-1", "crash-2+join").
    pub scenario: &'static str,
    /// True for the oracle dispatcher (schedule known in advance: doomed
    /// replicas take no placements, nothing needs recovery).
    pub oracle: bool,
    /// Average JCT across all agents (s).
    pub avg_jct: f64,
    /// P99 JCT (s).
    pub p99_jct: f64,
    /// Max-min fair-share ratio vs the N×M GPS fluid reference.
    pub maxmin_ratio: f64,
    /// Agents completed (conservation demands the full suite).
    pub completed: usize,
    /// Cluster makespan (s).
    pub makespan: f64,
    /// Replica crashes suffered.
    pub replicas_lost: u64,
    /// Agents salvaged off crashed replicas.
    pub recovered_agents: u64,
    /// KV tokens destroyed by crashes and re-derived elsewhere.
    pub rescheduled_tokens: u64,
}

/// The elasticity experiment: one suite replayed through an N-replica
/// Justitia cluster under increasing churn, each non-trivial schedule run
/// twice — *reactively* (failures strike unannounced; in-flight agents fold
/// their generated tokens into fresh prompts and re-place on the survivors)
/// and through the *oracle* dispatcher ([`ClusterDispatcher::run_suite_churn_oracle`])
/// that knew the schedule at t=0. The JCT/fairness gap between each pair is
/// the price of blind recovery; the gap to the immortal baseline is the
/// price of churn itself. Churn times are fractions of the arrival window so
/// failures always strike mid-run regardless of suite size.
pub fn elasticity(
    base: &Config,
    n_agents: usize,
    density: f64,
    replicas: usize,
    seed: u64,
) -> Vec<ElasticityRow> {
    let replicas = replicas.max(3);
    let mut cfg = base.clone();
    cfg.workload.n_agents = n_agents;
    cfg.workload.seed = seed;
    cfg.workload = cfg.workload.clone().with_density(density);
    cfg.cluster =
        crate::config::ClusterConfig { replicas, placement: Placement::ClusterVtime };
    let w = cfg.workload.window_secs;
    let schedules: Vec<(&'static str, FailureSchedule)> = vec![
        ("immortal", FailureSchedule::none()),
        ("drain-1", FailureSchedule::parse(&format!("drain@{}:1", 0.25 * w)).unwrap()),
        ("crash-1", FailureSchedule::parse(&format!("crash@{}:1", 0.25 * w)).unwrap()),
        (
            "crash-2+join",
            FailureSchedule::parse(&format!(
                "crash@{}:1,crash@{}:2,join@{}",
                0.2 * w,
                0.4 * w,
                0.5 * w
            ))
            .unwrap(),
        ),
    ];
    let mut jobs: Vec<(&'static str, FailureSchedule, bool)> = Vec::new();
    for (name, s) in schedules {
        let trivial = s.is_empty();
        jobs.push((name, s.clone(), false));
        if !trivial {
            jobs.push((name, s, true));
        }
    }
    let policy = Policy::Justitia;
    let suite = crate::workload::trace::build_suite(&cfg.workload);
    let model = cost_model_for(policy);
    let costs = crate::cost::oracle_costs(cfg.prefix_cache, &suite, model);
    // One shared yardstick for every scenario: the immortal N×M GPS fluid.
    // Degradation numbers then isolate what churn does to the *real* system
    // while the ideal it is judged against stays fixed.
    let triples: Vec<(crate::workload::AgentId, f64, f64)> =
        suite.agents.iter().map(|a| (a.id, a.arrival, costs[&a.id])).collect();
    let gps = crate::sched::gps::run(
        &triples,
        cfg.backend.kv_tokens * replicas as u64,
        rate_scale(&cfg),
    );
    let suite = std::sync::Arc::new(suite);
    let costs = std::sync::Arc::new(costs);
    let gps = std::sync::Arc::new(gps);
    let cfg = std::sync::Arc::new(cfg);
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(scenario, schedule, oracle)| {
        let cfg = std::sync::Arc::clone(&cfg);
        let mut cluster = build_sim_cluster(&cfg, policy);
        let spawn_cfg = std::sync::Arc::clone(&cfg);
        let spawn = move || {
            let sched =
                crate::sched::build(policy, spawn_cfg.backend.kv_tokens, rate_scale(&spawn_cfg));
            Engine::new(&spawn_cfg, sched, SimBackend::new(&spawn_cfg.backend))
        };
        let makespan = if oracle {
            cluster.run_suite_churn_oracle(&suite, |a| costs[&a.id], &schedule, spawn)
        } else {
            cluster.run_suite_churn(&suite, |a| costs[&a.id], &schedule, spawn)
        };
        let m = cluster.merged_metrics();
        ElasticityRow {
            scenario,
            oracle,
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            maxmin_ratio: maxmin_vs_gps(&suite, &m, &gps),
            completed: m.completed_agents(),
            makespan,
            replicas_lost: m.replicas_lost(),
            recovered_agents: m.recovered_agents(),
            rescheduled_tokens: m.rescheduled_tokens(),
        }
    })
}

// ---------------------------------------------------------------------------
// Prefix sharing — radix-tree KV dedup on a shared-prefix workload (beyond
// the paper: fairness when the fairly-shared resource is deduplicated; see
// DESIGN.md §8 and the ROADMAP scenario axis)
// ---------------------------------------------------------------------------

/// One (cache on/off) row of the prefix-sharing experiment.
pub struct PrefixSharingRow {
    /// Whether the radix-tree prefix cache was enabled for this run.
    pub cache_enabled: bool,
    /// Fraction of admissions that hit at least one cached page.
    pub hit_rate: f64,
    /// Admissions that hit the cache.
    pub prefix_hits: u64,
    /// Prompt tokens actually prefilled.
    pub prefill_tokens_executed: u64,
    /// Prompt tokens skipped via cached prefixes.
    pub prefill_tokens_saved: u64,
    /// Peak pages held by the cache.
    pub cache_pages_peak: u64,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P99 JCT (s).
    pub p99_jct: f64,
    /// Mean time-to-first-token (ms), anchored at task-ready time.
    pub ttft_mean_ms: f64,
    /// P99 time-to-first-token (ms).
    pub ttft_p99_ms: f64,
    /// Max-min fair-share ratio vs the GPS fluid reference (costs deduped
    /// when the cache is on, plain Eq. 1 when off — the yardstick matches
    /// what the scheduler itself was told).
    pub maxmin_ratio: f64,
    /// Agents completed (must equal the suite size).
    pub completed: usize,
}

/// The prefix-sharing sweep: one shared-prefix family workload
/// (`prefix_fanout` agents per family, `prefix_tokens`-long common prompt
/// prefix) replayed through a single Justitia replica with the radix-tree
/// cache off, then on. Reports hit rate, prefill tokens saved, avg/p99 JCT,
/// and the max-min fair-share ratio vs GPS under each regime.
pub fn prefix_sharing(
    base: &Config,
    n_agents: usize,
    density: f64,
    prefix_fanout: usize,
    prefix_tokens: u32,
    seed: u64,
) -> Vec<PrefixSharingRow> {
    [false, true]
        .into_iter()
        .map(|cache| {
            let mut cfg = base.clone();
            // Preserve the base workload's shape knobs (class mix) like
            // `cluster_scaleout`; override size/seed/density/families.
            cfg.workload.n_agents = n_agents;
            cfg.workload.seed = seed;
            cfg.workload = cfg
                .workload
                .clone()
                .with_density(density)
                .with_shared_prefix(prefix_fanout, prefix_tokens);
            cfg.prefix_cache = cache;
            let suite = crate::workload::trace::build_suite(&cfg.workload);
            // Predicted costs: suite-wide deduped token-time when sharing is
            // on, plain Eq. 1 when off. The GPS yardstick below uses the
            // same basis, so Justitia's virtual finish tags and the fluid
            // reference stay mutually truthful.
            let costs: std::collections::HashMap<AgentId, f64> =
                crate::cost::oracle_costs(cache, &suite, CostModel::MemoryCentric);
            let sched =
                crate::sched::build(Policy::Justitia, cfg.backend.kv_tokens, rate_scale(&cfg));
            let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
            engine.run_suite(&suite, |a| costs[&a.id]);
            let m = std::mem::take(&mut engine.metrics);

            let triples: Vec<(AgentId, f64, f64)> =
                suite.agents.iter().map(|a| (a.id, a.arrival, costs[&a.id])).collect();
            let gps = crate::sched::gps::run(&triples, cfg.backend.kv_tokens, rate_scale(&cfg));
            let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
            PrefixSharingRow {
                cache_enabled: cache,
                hit_rate: m.prefix_hit_rate(),
                prefix_hits: m.prefix_hits(),
                prefill_tokens_executed: m.prefill_tokens_executed(),
                prefill_tokens_saved: m.prefill_tokens_saved(),
                cache_pages_peak: m.cache_pages_peak(),
                avg_jct: m.avg_jct(),
                p99_jct: m.p99_jct(),
                ttft_mean_ms: m.ttft_mean() * 1e3,
                ttft_p99_ms: m.ttft_percentile(99.0) * 1e3,
                maxmin_ratio,
                completed: m.completed_agents(),
            }
        })
        .collect()
}

// ---------------------------------------------------------------------------
// DAG agents — workflow shapes, dynamic spawning, online cost correction
// (beyond the paper's staged agents: DESIGN.md §9; fairness under DAG
// workloads per "Fairness in Serving Large Language Models" and
// "Locality-aware Fair Scheduling in LLM Serving")
// ---------------------------------------------------------------------------

/// One (shape, correction on/off) row of the DAG-agents experiment.
pub struct DagAgentsRow {
    /// DAG shape family every agent in the suite uses.
    pub shape: crate::workload::DagShape,
    /// Whether the §4.2 online misprediction-correction loop ran.
    pub correction: bool,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P99 JCT (s).
    pub p99_jct: f64,
    /// Mean time-to-first-token (ms), anchored at task-ready time.
    pub ttft_mean_ms: f64,
    /// P99 time-to-first-token (ms).
    pub ttft_p99_ms: f64,
    /// Max-min fair-share ratio vs the GPS fluid reference priced at the
    /// expanded (spawn-inclusive) ground-truth costs.
    pub maxmin_ratio: f64,
    /// Tasks dynamically spawned over the run (identical across the
    /// correction on/off pair — spawning is a pure function of the suite).
    pub spawned_tasks: u64,
    /// Mean relative error of the corrected cost estimate vs ground truth
    /// (0 when correction is off: no estimates are revised).
    pub correction_error: f64,
    /// Correction events recorded.
    pub correction_events: u64,
    /// Mean critical-path fraction: per agent, the remaining-DAG signal
    /// [`crate::sched::AgentInfo::critical_path`] over the agent's total
    /// static cost — 1.0 for pipelines (fully serial), well below 1 for
    /// map-reduce (parallel maps dominate). Characterizes how much of the
    /// shape's work a scheduler can actually overlap.
    pub serial_frac: f64,
    /// Agents completed (must equal the suite size).
    pub completed: usize,
}

impl DagAgentsRow {
    /// Fixed-width report header (one source for the CLI and the bench
    /// binary, so their outputs cannot drift).
    pub fn table_header() -> String {
        format!(
            "{:<11} {:<11} {:>9} {:>9} {:>8} {:>8} {:>9} {:>11} {:>6}",
            "shape", "correction", "avgJCT", "p99JCT", "maxmin", "serial", "spawned", "corr-err",
            "done"
        )
    }

    /// One fixed-width report row matching [`DagAgentsRow::table_header`].
    pub fn table_row(&self) -> String {
        format!(
            "{:<11} {:<11} {:>8.1}s {:>8.1}s {:>7.2}x {:>8.2} {:>9} {:>10.1}% {:>6}",
            self.shape.name(),
            if self.correction { "on" } else { "off" },
            self.avg_jct,
            self.p99_jct,
            self.maxmin_ratio,
            self.serial_frac,
            self.spawned_tasks,
            self.correction_error * 100.0,
            self.completed
        )
    }
}

/// The DAG-agents experiment: one suite per workflow shape (map-reduce,
/// tree, pipeline — all agents forced to that shape), replayed through a
/// single Justitia replica with §4.2 online correction off, then on.
///
/// Predictions are deliberately imperfect on two axes: the noisy oracle
/// scales the arrival-visible cost by U_log[1/λ, λ] (Fig. 10 style), and
/// dynamically spawned tasks are invisible at arrival altogether. The
/// correction loop must claw both back; the GPS yardstick is priced at the
/// expanded ground truth either way, so the max-min ratio measures how much
/// of the misprediction each regime lets leak into unfairness.
pub fn dag_agents(
    base: &Config,
    n_agents: usize,
    density: f64,
    spawn_prob: f64,
    branch: u32,
    lambda: f64,
    seed: u64,
) -> Vec<DagAgentsRow> {
    let mut jobs = Vec::new();
    for shape in crate::workload::DagShape::ALL {
        for correction in [false, true] {
            jobs.push((shape, correction));
        }
    }
    let base = base.clone();
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(shape, correction)| {
        let mut cfg = base.clone();
        cfg.workload.n_agents = n_agents;
        cfg.workload.seed = seed;
        cfg.workload = cfg.workload.clone().with_density(density).with_dag(spawn_prob, branch);
        cfg.online_correction = correction;
        let suite = crate::workload::trace::build_dag_suite(&cfg.workload, shape);

        let sched =
            crate::sched::build(Policy::Justitia, cfg.backend.kv_tokens, rate_scale(&cfg));
        let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
        let mut noisy = NoisyOracle::new(CostModel::MemoryCentric, lambda, seed ^ 0xda6);
        engine.run_suite(&suite, |a| noisy.cost(a));
        let m = std::mem::take(&mut engine.metrics);

        // GPS yardstick at the expanded ground truth (run_suite prices
        // spawned work — the single pricing site for all experiments).
        let gps = crate::sched::gps::run_suite(
            &suite,
            CostModel::MemoryCentric,
            cfg.backend.kv_tokens,
            rate_scale(&cfg),
        );
        let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
        let serial_frac = suite
            .agents
            .iter()
            .map(|a| {
                crate::cost::critical_path_cost(CostModel::MemoryCentric, a)
                    / CostModel::MemoryCentric.agent_cost(a).max(1e-9)
            })
            .sum::<f64>()
            / suite.len().max(1) as f64;
        DagAgentsRow {
            shape,
            correction,
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            ttft_mean_ms: m.ttft_mean() * 1e3,
            ttft_p99_ms: m.ttft_percentile(99.0) * 1e3,
            maxmin_ratio,
            spawned_tasks: m.spawned_tasks(),
            correction_error: m.correction_error_mean(),
            correction_events: m.correction_samples(),
            serial_frac,
            completed: m.completed_agents(),
        }
    })
}

// ---------------------------------------------------------------------------
// Chunked prefill — token-budget batch formation (beyond the paper: Sarathi-
// style chunking; FairBatching observes that how prefill and decode tokens
// share an iteration is itself a fairness lever; DESIGN.md §10)
// ---------------------------------------------------------------------------

/// Workload families the chunked-prefill sweep replays: the §5.1 staged
/// suite, map-reduce DAG agents with dynamic spawning, and shared-prefix
/// families with the radix-tree cache on.
pub const CHUNKED_WORKLOADS: [&str; 3] = ["staged", "dag", "prefix"];

/// The policies the chunked-prefill sweep compares (the fairness-relevant
/// subset: fair queuing, token counters, SRJF pampering, and plain FCFS).
pub const CHUNKED_POLICIES: [Policy; 4] =
    [Policy::Fcfs, Policy::Vtc, Policy::Srjf, Policy::Justitia];

/// One (workload, policy, chunk) cell of the chunked-prefill experiment.
pub struct ChunkedPrefillRow {
    /// Workload family (see [`CHUNKED_WORKLOADS`]).
    pub workload: &'static str,
    /// Scheduling policy.
    pub policy: Policy,
    /// Prefill chunk size in tokens (0 = chunking off, atomic admission).
    pub chunk: u32,
    /// Per-iteration token budget (0 when chunking is off).
    pub budget: u32,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P99 JCT (s).
    pub p99_jct: f64,
    /// P99 decode inter-token latency (ms) — the headline tail metric: the
    /// gap a decoding agent sees while someone else's prompt prefills.
    pub decode_itl_p99_ms: f64,
    /// Mean decode inter-token latency (ms).
    pub decode_itl_mean_ms: f64,
    /// Mean time-to-first-token (ms), anchored at task-ready time.
    pub ttft_mean_ms: f64,
    /// P99 time-to-first-token (ms).
    pub ttft_p99_ms: f64,
    /// Fraction of judged TTFT/ITL deadlines missed against the per-class
    /// SLO targets (`AgentClass::ttft_slo_ms` / `itl_p99_slo_ms`).
    pub deadline_miss_rate: f64,
    /// Prefill-pending sequences denied a chunk by the budget or a KV page
    /// shortage, summed over iterations.
    pub prefill_stalls: u64,
    /// Max-min fair-share ratio vs the GPS fluid reference (costs on the
    /// policy's model; deduped when the prefix cache is on).
    pub maxmin_ratio: f64,
    /// Agents completed (must equal the suite size).
    pub completed: usize,
}

impl ChunkedPrefillRow {
    /// Fixed-width report header (one source for the CLI and the bench
    /// binary, so their outputs cannot drift).
    pub fn table_header() -> String {
        format!(
            "{:<8} {:<10} {:>6} {:>7} {:>9} {:>9} {:>10} {:>10} {:>7} {:>7} {:>5}",
            "workload", "policy", "chunk", "budget", "avgJCT", "p99JCT", "itl-p99", "itl-mean",
            "stalls", "maxmin", "done"
        )
    }

    /// One fixed-width report row matching [`ChunkedPrefillRow::table_header`].
    pub fn table_row(&self) -> String {
        format!(
            "{:<8} {:<10} {:>6} {:>7} {:>8.1}s {:>8.1}s {:>8.1}ms {:>8.1}ms {:>7} {:>6.2}x {:>5}",
            self.workload,
            self.policy.name(),
            if self.chunk == 0 { "off".to_string() } else { self.chunk.to_string() },
            if self.chunk == 0 { "-".to_string() } else { self.budget.to_string() },
            self.avg_jct,
            self.p99_jct,
            self.decode_itl_p99_ms,
            self.decode_itl_mean_ms,
            self.prefill_stalls,
            self.maxmin_ratio,
            self.completed
        )
    }
}

/// The chunked-prefill sweep: each workload family × policy replayed with
/// atomic admission (chunk 0), then with every chunk size in `chunks` under
/// the fixed per-iteration token `budget`.
///
/// All arms — including the atomic baseline — run with a small mixed-batch
/// interference coefficient (`beta_mixed`), so the decode latency a long
/// prefill inflicts on concurrent decodes is priced identically everywhere;
/// the stock profiles keep `beta_mixed = 0` so nothing outside this
/// experiment changes. Expected shape: decode p99 inter-token latency
/// improves as the chunk shrinks at fixed budget (atomic is worst), at a
/// modest JCT cost from spreading prefills over more iterations.
pub fn chunked_prefill(
    base: &Config,
    n_agents: usize,
    density: f64,
    chunks: &[u32],
    budget: u32,
    seed: u64,
) -> Vec<ChunkedPrefillRow> {
    let mut jobs = Vec::new();
    for workload in CHUNKED_WORKLOADS {
        for policy in CHUNKED_POLICIES {
            jobs.push((workload, policy, 0u32)); // atomic-admission baseline
            for &c in chunks {
                jobs.push((workload, policy, c));
            }
        }
    }
    let base = base.clone();
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(workload, policy, chunk)| {
        let mut cfg = base.clone();
        cfg.workload.n_agents = n_agents;
        cfg.workload.seed = seed;
        cfg.workload = cfg.workload.clone().with_density(density);
        // Price prefill/decode interference on every arm of the sweep (the
        // built-in profiles carry 0 to keep pre-chunking runs unchanged).
        cfg.backend.beta_mixed = 1.0e-7;
        match workload {
            "dag" => cfg.workload = cfg.workload.clone().with_dag(0.2, 2),
            "prefix" => {
                cfg.workload = cfg.workload.clone().with_shared_prefix(4, 512);
                cfg.prefix_cache = true;
            }
            _ => {}
        }
        if chunk > 0 {
            cfg.chunked_prefill = true;
            cfg.prefill_chunk = chunk;
            cfg.max_batched_tokens = budget;
        }
        let suite = if workload == "dag" {
            crate::workload::trace::build_dag_suite(
                &cfg.workload,
                crate::workload::DagShape::MapReduce,
            )
        } else {
            crate::workload::trace::build_suite(&cfg.workload)
        };
        let model = cost_model_for(policy);
        let oracle = crate::cost::oracle_costs(cfg.prefix_cache, &suite, model);
        let m = run_policy_oracle(&cfg, &suite, policy);

        let triples: Vec<(AgentId, f64, f64)> =
            suite.agents.iter().map(|a| (a.id, a.arrival, oracle[&a.id])).collect();
        let gps = crate::sched::gps::run(&triples, cfg.backend.kv_tokens, rate_scale(&cfg));
        let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
        ChunkedPrefillRow {
            workload,
            policy,
            chunk,
            budget: if chunk > 0 { budget } else { 0 },
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            decode_itl_p99_ms: m.decode_itl_percentile(99.0) * 1e3,
            decode_itl_mean_ms: m.decode_itl_mean() * 1e3,
            ttft_mean_ms: m.ttft_mean() * 1e3,
            ttft_p99_ms: m.ttft_percentile(99.0) * 1e3,
            deadline_miss_rate: m.deadline_miss_rate(),
            prefill_stalls: m.prefill_stalls(),
            maxmin_ratio,
            completed: m.completed_agents(),
        }
    })
}

// ---------------------------------------------------------------------------
// Batch-policy (FairBatching) — closed-loop prefill/decode budget split
// (beyond the paper: FairBatching's SLO-pressure-driven reallocation layered
// on top of the fair queue; the queue still picks *which* prefills run, the
// policy decides *how many tokens* they may take; DESIGN.md §15)
// ---------------------------------------------------------------------------

/// Workload families the fairbatching sweep replays (same trio as the
/// chunked-prefill sweep).
pub const FAIRBATCH_WORKLOADS: [&str; 3] = ["staged", "dag", "prefix"];

/// The scheduling policies the fairbatching sweep crosses with each batch
/// policy: plain FCFS, token counters, and Justitia's fair queue — the
/// point being that the batch-composition lever is orthogonal to all three.
pub const FAIRBATCH_POLICIES: [Policy; 3] = [Policy::Fcfs, Policy::Vtc, Policy::Justitia];

/// One (workload, scheduler, batch policy) cell of the fairbatching sweep.
pub struct FairBatchingRow {
    /// Workload family (see [`FAIRBATCH_WORKLOADS`]).
    pub workload: &'static str,
    /// Scheduling policy (which prefills the fair queue admits).
    pub policy: Policy,
    /// Batch-composition policy (how many prefill tokens they may take).
    pub batch: BatchPolicyKind,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P99 JCT (s).
    pub p99_jct: f64,
    /// P99 decode inter-token latency (ms) — the acceptance metric:
    /// FairBatching must beat StaticBudget here at equal-or-better TTFT.
    pub decode_itl_p99_ms: f64,
    /// Mean decode inter-token latency (ms).
    pub decode_itl_mean_ms: f64,
    /// Mean time-to-first-token (ms), anchored at task-ready time.
    pub ttft_mean_ms: f64,
    /// P99 time-to-first-token (ms).
    pub ttft_p99_ms: f64,
    /// Fraction of judged TTFT/ITL deadlines missed against the per-class
    /// SLO targets.
    pub deadline_miss_rate: f64,
    /// Prefill-pending sequences denied a chunk, summed over iterations.
    pub prefill_stalls: u64,
    /// Max-min fair-share ratio vs the GPS fluid reference.
    pub maxmin_ratio: f64,
    /// Agents completed (must equal the suite size).
    pub completed: usize,
}

impl FairBatchingRow {
    /// Fixed-width report header (one source for the CLI and the bench
    /// binary, so their outputs cannot drift).
    pub fn table_header() -> String {
        format!(
            "{:<8} {:<10} {:<12} {:>9} {:>9} {:>10} {:>10} {:>9} {:>9} {:>7} {:>7} {:>6} {:>5}",
            "workload", "policy", "batch", "avgJCT", "p99JCT", "itl-p99", "itl-mean", "ttft-avg",
            "ttft-p99", "miss", "stalls", "maxmin", "done"
        )
    }

    /// One fixed-width report row matching [`FairBatchingRow::table_header`].
    pub fn table_row(&self) -> String {
        format!(
            "{:<8} {:<10} {:<12} {:>8.1}s {:>8.1}s {:>8.1}ms {:>8.1}ms {:>7.0}ms {:>7.0}ms {:>6.1}% {:>7} {:>5.2}x {:>5}",
            self.workload,
            self.policy.name(),
            self.batch.name(),
            self.avg_jct,
            self.p99_jct,
            self.decode_itl_p99_ms,
            self.decode_itl_mean_ms,
            self.ttft_mean_ms,
            self.ttft_p99_ms,
            self.deadline_miss_rate * 100.0,
            self.prefill_stalls,
            self.maxmin_ratio,
            self.completed
        )
    }
}

/// The fairbatching sweep: {staged, DAG, shared-prefix} × {FCFS, VTC,
/// Justitia} × {static, fixed-split, fairbatching}, all with chunked
/// prefill on (chunk 512, budget 2048) and a mixed-batch interference
/// coefficient strong enough that throttling prefill genuinely buys decode
/// tail latency — the FairBatching win-win regime. The stock profiles keep
/// `beta_mixed = 0`, so nothing outside this sweep changes.
///
/// Expected shape: `fairbatching` shrinks its prefill share when decode p99
/// inter-token latency breaches the per-class SLO and grows it back only
/// under TTFT pressure, so it beats `static` on decode p99 ITL at
/// equal-or-better TTFT on congested cells; `fixed-split` lands in between
/// (a blunt always-on reservation pays TTFT for its decode headroom).
pub fn fairbatching(
    base: &Config,
    n_agents: usize,
    density: f64,
    seed: u64,
) -> Vec<FairBatchingRow> {
    let mut jobs = Vec::new();
    for workload in FAIRBATCH_WORKLOADS {
        for policy in FAIRBATCH_POLICIES {
            for batch in BatchPolicyKind::ALL {
                jobs.push((workload, policy, batch));
            }
        }
    }
    fairbatching_cells(base, n_agents, density, seed, jobs)
}

/// Run an explicit subset of the fairbatching grid — each job is
/// `(workload, scheduler, batch policy)`. The full sweep ([`fairbatching`])
/// delegates here; tests run just the cells they assert on (the grid is 27
/// full simulator runs — bench territory).
pub fn fairbatching_cells(
    base: &Config,
    n_agents: usize,
    density: f64,
    seed: u64,
    jobs: Vec<(&'static str, Policy, BatchPolicyKind)>,
) -> Vec<FairBatchingRow> {
    let base = base.clone();
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(workload, policy, batch)| {
        let mut cfg = base.clone();
        cfg.workload.n_agents = n_agents;
        cfg.workload.seed = seed;
        cfg.workload = cfg.workload.clone().with_density(density);
        // Chunked prefill on everywhere — the batch policy only has a lever
        // when iterations carry a token budget to split.
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 512;
        cfg.max_batched_tokens = 2048;
        cfg.batch_policy = batch;
        // Price prefill/decode interference steeply (20x the chunked-prefill
        // sweep): every prefill token in a mixed batch slows the decodes
        // sharing the iteration, so throttling prefill under ITL pressure is
        // a genuine win, not a pure TTFT tax.
        cfg.backend.beta_mixed = 2.0e-6;
        match workload {
            "dag" => cfg.workload = cfg.workload.clone().with_dag(0.2, 2),
            "prefix" => {
                cfg.workload = cfg.workload.clone().with_shared_prefix(4, 512);
                cfg.prefix_cache = true;
            }
            _ => {}
        }
        let suite = if workload == "dag" {
            crate::workload::trace::build_dag_suite(
                &cfg.workload,
                crate::workload::DagShape::MapReduce,
            )
        } else {
            crate::workload::trace::build_suite(&cfg.workload)
        };
        let model = cost_model_for(policy);
        let oracle = crate::cost::oracle_costs(cfg.prefix_cache, &suite, model);
        let m = run_policy_oracle(&cfg, &suite, policy);

        let triples: Vec<(AgentId, f64, f64)> =
            suite.agents.iter().map(|a| (a.id, a.arrival, oracle[&a.id])).collect();
        let gps = crate::sched::gps::run(&triples, cfg.backend.kv_tokens, rate_scale(&cfg));
        let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
        FairBatchingRow {
            workload,
            policy,
            batch,
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            decode_itl_p99_ms: m.decode_itl_percentile(99.0) * 1e3,
            decode_itl_mean_ms: m.decode_itl_mean() * 1e3,
            ttft_mean_ms: m.ttft_mean() * 1e3,
            ttft_p99_ms: m.ttft_percentile(99.0) * 1e3,
            deadline_miss_rate: m.deadline_miss_rate(),
            prefill_stalls: m.prefill_stalls(),
            maxmin_ratio,
            completed: m.completed_agents(),
        }
    })
}

// ---------------------------------------------------------------------------
// Preemption — bounded host memory, swap vs recompute, victim policies
// (beyond the paper: vLLM's swap-vs-recompute preemption priced under a
// finite host tier and PCIe bandwidth; Sarathi-Serve shows why the choice
// must be priced, not free; DESIGN.md §11)
// ---------------------------------------------------------------------------

/// Workload families the preemption sweep replays (same trio as the
/// chunked-prefill sweep: the §5.1 staged suite, map-reduce DAG agents with
/// dynamic spawning, and shared-prefix families with the cache on).
pub const PREEMPT_WORKLOADS: [&str; 3] = ["staged", "dag", "prefix"];

/// Host↔device swap bandwidth the sweep models (tokens/s): a contended
/// PCIe link slow enough that recompute genuinely competes with swapping on
/// the stock `beta_prefill` coefficients.
pub const PREEMPT_SWAP_BW: f64 = 3.0e4;

/// One (workload, host tier, mode, victim) cell of the preemption sweep.
pub struct PreemptionRow {
    /// Workload family (see [`PREEMPT_WORKLOADS`]).
    pub workload: &'static str,
    /// Host swap-pool size in pages (0 = unbounded — the classical tier).
    pub host_pages: u64,
    /// Preemption mode.
    pub mode: PreemptionMode,
    /// Victim-ranking policy.
    pub victim: VictimPolicy,
    /// Average JCT (s).
    pub avg_jct: f64,
    /// P99 JCT (s) — the acceptance metric: `Auto`+`PamperAware` must beat
    /// `Swap`+`Youngest` under a host pool sized below peak swap demand.
    pub p99_jct: f64,
    /// Mean time-to-first-token (ms), anchored at task-ready time.
    pub ttft_mean_ms: f64,
    /// P99 time-to-first-token (ms).
    pub ttft_p99_ms: f64,
    /// Swap-out preemptions performed.
    pub swap_outs: u64,
    /// Recompute preemptions performed.
    pub recomputes: u64,
    /// KV tokens discarded for recompute (the wasted-token gauge).
    pub recomputed_tokens: u64,
    /// Max-min fair-share ratio vs the GPS fluid reference.
    pub maxmin_ratio: f64,
    /// Agents completed (must equal the suite size).
    pub completed: usize,
}

impl PreemptionRow {
    /// Fixed-width report header (one source for the CLI and the bench
    /// binary, so their outputs cannot drift).
    pub fn table_header() -> String {
        format!(
            "{:<8} {:>9} {:<10} {:<18} {:>9} {:>9} {:>7} {:>7} {:>10} {:>7} {:>5}",
            "workload", "host-pg", "mode", "victim", "avgJCT", "p99JCT", "swaps", "recomp",
            "wasted-tok", "maxmin", "done"
        )
    }

    /// One fixed-width report row matching [`PreemptionRow::table_header`].
    pub fn table_row(&self) -> String {
        format!(
            "{:<8} {:>9} {:<10} {:<18} {:>8.1}s {:>8.1}s {:>7} {:>7} {:>10} {:>6.2}x {:>5}",
            self.workload,
            if self.host_pages == 0 { "inf".to_string() } else { self.host_pages.to_string() },
            self.mode.name(),
            self.victim.name(),
            self.avg_jct,
            self.p99_jct,
            self.swap_outs,
            self.recomputes,
            self.recomputed_tokens,
            self.maxmin_ratio,
            self.completed
        )
    }
}

/// The preemption sweep: each workload family replayed through a single
/// Justitia replica under {unbounded host, host = M/8} × every
/// [`PreemptionMode`] × every [`VictimPolicy`], with swap traffic
/// serialized behind [`PREEMPT_SWAP_BW`] on every arm (the stock profiles
/// keep bandwidth 0, so nothing outside this sweep changes).
///
/// Expected shape: with the M/8 host tier the Swap arms stall behind the
/// serialized PCIe link and forced-recompute fallbacks, while `Auto` skips
/// the round trips whose refill is cheaper — so `Auto`+`PamperAware` beats
/// `Swap`+`Youngest` on p99 JCT under host pressure (the ISSUE 5
/// acceptance headline).
pub fn preemption(
    base: &Config,
    n_agents: usize,
    density: f64,
    seed: u64,
) -> Vec<PreemptionRow> {
    let mut jobs = Vec::new();
    for workload in PREEMPT_WORKLOADS {
        for host_div in [0u64, 8] {
            for mode in [PreemptionMode::Swap, PreemptionMode::Recompute, PreemptionMode::Auto] {
                for victim in VictimPolicy::ALL {
                    jobs.push((workload, host_div, mode, victim));
                }
            }
        }
    }
    preemption_cells(base, n_agents, density, seed, jobs)
}

/// Run an explicit subset of the preemption grid — each job is
/// `(workload, host_div, mode, victim)` with `host_div = 0` meaning an
/// unbounded host tier and `host_div = d` a pool of `M/d` tokens. The full
/// sweep ([`preemption`]) delegates here; tests run just the cells they
/// assert on (the grid is 72 full simulator runs — bench territory).
pub fn preemption_cells(
    base: &Config,
    n_agents: usize,
    density: f64,
    seed: u64,
    jobs: Vec<(&'static str, u64, PreemptionMode, VictimPolicy)>,
) -> Vec<PreemptionRow> {
    let base = base.clone();
    let pool = ThreadPool::with_cpus();
    pool.map(jobs, move |(workload, host_div, mode, victim)| {
        let mut cfg = base.clone();
        cfg.workload.n_agents = n_agents;
        cfg.workload.seed = seed;
        cfg.workload = cfg.workload.clone().with_density(density);
        cfg.backend.swap_bw_tokens_per_sec = PREEMPT_SWAP_BW;
        let host_tokens = if host_div == 0 { None } else { Some(cfg.backend.kv_tokens / host_div) };
        cfg.backend.host_kv_tokens = host_tokens;
        cfg.preemption = mode;
        cfg.victim = victim;
        match workload {
            "dag" => cfg.workload = cfg.workload.clone().with_dag(0.2, 2),
            "prefix" => {
                cfg.workload = cfg.workload.clone().with_shared_prefix(4, 512);
                cfg.prefix_cache = true;
            }
            _ => {}
        }
        let suite = if workload == "dag" {
            crate::workload::trace::build_dag_suite(
                &cfg.workload,
                crate::workload::DagShape::MapReduce,
            )
        } else {
            crate::workload::trace::build_suite(&cfg.workload)
        };
        let model = cost_model_for(Policy::Justitia);
        let oracle = crate::cost::oracle_costs(cfg.prefix_cache, &suite, model);
        let m = run_policy_oracle(&cfg, &suite, Policy::Justitia);

        let triples: Vec<(AgentId, f64, f64)> =
            suite.agents.iter().map(|a| (a.id, a.arrival, oracle[&a.id])).collect();
        let gps = crate::sched::gps::run(&triples, cfg.backend.kv_tokens, rate_scale(&cfg));
        let maxmin_ratio = maxmin_vs_gps(&suite, &m, &gps);
        PreemptionRow {
            workload,
            host_pages: host_tokens.map(|t| t / cfg.backend.page_size as u64).unwrap_or(0),
            mode,
            victim,
            avg_jct: m.avg_jct(),
            p99_jct: m.p99_jct(),
            ttft_mean_ms: m.ttft_mean() * 1e3,
            ttft_p99_ms: m.ttft_percentile(99.0) * 1e3,
            swap_outs: m.swap_out_count(),
            recomputes: m.recompute_count(),
            recomputed_tokens: m.recomputed_tokens(),
            maxmin_ratio,
            completed: m.completed_agents(),
        }
    })
}

// ---------------------------------------------------------------------------
// Table 1 — MLP vs shared-model (Distillbert-style) prediction
// ---------------------------------------------------------------------------

/// One predictor row of Table 1.
pub struct Table1Row {
    /// Predictor label.
    pub model: String,
    /// Mean relative error (%).
    pub rel_error_pct: f64,
    /// Mean per-prediction latency (ms).
    pub infer_ms: f64,
    /// Average JCT with this predictor in the loop (s).
    pub avg_jct: f64,
    /// Training wall time (s).
    pub train_secs: f64,
}

/// Table 1: per-class MLP vs shared (S³-style) cost prediction.
pub fn table1(n_agents: usize, density: f64, samples_per_class: usize, seed: u64) -> Vec<Table1Row> {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(density);
    let suite = crate::workload::trace::build_suite(&cfg.workload);

    let (mlp_pred, mlp_report) =
        crate::predictor::train_per_class(CostModel::MemoryCentric, samples_per_class, 30, seed);
    let (s3_pred, s3_report) =
        crate::predictor::s3::train_shared(CostModel::MemoryCentric, samples_per_class, 30, seed);

    let m_mlp = run_policy(&cfg, &suite, Policy::Justitia, &CostSource::Model(&mlp_pred));
    let m_s3 = run_policy(&cfg, &suite, Policy::Justitia, &CostSource::Model(&s3_pred));

    vec![
        Table1Row {
            model: "MLP (per-class)".into(),
            rel_error_pct: mlp_report.rel_error * 100.0,
            infer_ms: mlp_report.infer_ms,
            avg_jct: m_mlp.avg_jct(),
            train_secs: mlp_report.train_secs,
        },
        Table1Row {
            model: "Shared (S3/Distillbert-style)".into(),
            rel_error_pct: s3_report.rel_error * 100.0,
            infer_ms: s3_report.infer_ms,
            avg_jct: m_s3.avg_jct(),
            train_secs: s3_report.train_secs,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_pampering_beats_fair_sharing_without_delaying() {
        let r = fig3(5);
        let (vtc, just) = (&r.rows[0], &r.rows[1]);
        assert_eq!(vtc.0, "VTC");
        assert_eq!(just.0, "Justitia");
        // Average JCT improves…
        assert!(just.2 < vtc.2, "justitia {} vs vtc {}", just.2, vtc.2);
        // …and no agent is delayed beyond tolerance (the paper's own
        // worst-case bound in Fig. 8 is 26%; Fig. 3's demo shows none —
        // low-parallelism tail stages cost a few % here).
        for (j, v) in just.1.iter().zip(&vtc.1) {
            assert!(j <= &(v * 1.10), "agent delayed: {j} vs {v}");
        }
        assert!(!r.timelines[0].1.is_empty());
    }

    #[test]
    fn fig7_full_scale_ordering() {
        // The full 300-agent suite at 3× density (the sim runs it in tens of
        // milliseconds): the §5.2 headline shape must hold.
        let rows = fig7(&[crate::config::BackendProfile::llama7b_a100()], &[3.0], 300, 42);
        assert_eq!(rows.len(), 6);
        let get = |p: Policy| rows.iter().find(|r| r.policy == p).unwrap().avg_jct;
        // Justitia ≪ VTC (paper: −57.5%), ≪ Parrot (−61.1%), ≈ SRJF.
        assert!(get(Policy::Justitia) < 0.6 * get(Policy::Vtc), "justitia must beat VTC by a wide margin");
        assert!(get(Policy::Justitia) < 0.6 * get(Policy::AgentFcfs), "justitia must beat Parrot");
        assert!(get(Policy::Justitia) < get(Policy::Fcfs), "justitia must beat vLLM-FCFS");
        let (j, s) = (get(Policy::Justitia), get(Policy::Srjf));
        assert!((j - s).abs() / s < 0.25, "justitia {j} should track SRJF {s}");
        for r in &rows {
            assert_eq!(r.completed, 300, "{:?} dropped agents", r.policy);
        }
    }

    #[test]
    fn fig9_justitia_bounded_srjf_grows() {
        // A sustained mice stream: SRJF keeps starving the elephant while
        // mice arrive (JCT grows with the stream length); Justitia's delay
        // plateaus once V(t) passes the elephant's virtual finish tag.
        let rows = fig9(&[0, 150], 13);
        let jct = |p: Policy, n: usize| {
            rows.iter().find(|r| r.policy == p && r.n_mice == n).unwrap().elephant_jct
        };
        let srjf_growth = jct(Policy::Srjf, 150) / jct(Policy::Srjf, 0);
        let just_growth = jct(Policy::Justitia, 150) / jct(Policy::Justitia, 0);
        assert!(
            srjf_growth > 1.5 * just_growth,
            "srjf growth {srjf_growth} should far exceed justitia {just_growth}"
        );
    }

    #[test]
    fn trace_starvation_records_both_arms() {
        let arms = trace_starvation(12, 4, 13);
        assert_eq!(arms.len(), 2);
        assert_eq!(arms[0].label, "srjf");
        assert_eq!(arms[1].label, "justitia");
        for arm in &arms {
            assert!(arm.elephant_jct > 0.0, "{}: elephant never finished", arm.label);
            assert!(arm.recorder.event_count() > 0, "{}: no events", arm.label);
            assert!(arm.recorder.sample_count() > 0, "{}: no samples", arm.label);
            // 13 agents arrive and complete on every arm.
            let count = |k: &str| {
                arm.recorder.events().filter(|e| e.kind.name() == k).count()
            };
            assert_eq!(count("arrival"), 13, "{}", arm.label);
            assert_eq!(count("complete"), 13, "{}", arm.label);
        }
        // Justitia's audit log must show the pick stream (SRJF records picks
        // too, just without virtual-time tags).
        assert!(arms[1].recorder.pick_count() > 0);
        assert!(arms[1].recorder.picks().any(|p| p.winner_tag.is_some()));
        // The exported pair loads as one Chrome trace with two processes.
        let parts: Vec<(u32, &str, &crate::trace::TraceRecorder)> = arms
            .iter()
            .enumerate()
            .map(|(i, a)| (i as u32, a.label, &a.recorder))
            .collect();
        let json = crate::trace::chrome_trace(&parts);
        let events = json.get("traceEvents").as_arr().unwrap();
        assert!(events.len() > 26, "trace too small: {}", events.len());
    }

    #[test]
    fn experiment_rows_report_ttft() {
        let rows = chunked_prefill(&Config::default(), 24, 3.0, &[512], 2048, 42);
        for r in &rows {
            assert!(
                r.ttft_mean_ms > 0.0 && r.ttft_p99_ms >= r.ttft_mean_ms * 0.5,
                "{} {:?} chunk {}: ttft mean {} p99 {}",
                r.workload,
                r.policy,
                r.chunk,
                r.ttft_mean_ms,
                r.ttft_p99_ms
            );
            // Satellite 6: every experiment row carries a deadline-miss rate
            // judged against the per-class SLO targets.
            assert!(
                (0.0..=1.0).contains(&r.deadline_miss_rate),
                "{} {:?} chunk {}: miss rate {} out of range",
                r.workload,
                r.policy,
                r.chunk,
                r.deadline_miss_rate
            );
        }
    }

    #[test]
    fn cluster_one_replica_matches_single_engine() {
        // The scale-out experiment at N=1 must agree with run_policy_oracle
        // to the last bit, for every placement policy.
        let mut cfg = Config::default();
        cfg.workload = WorkloadConfig { n_agents: 40, seed: 21, ..Default::default() }
            .with_density(3.0);
        let suite = crate::workload::trace::build_suite(&cfg.workload);
        let single = run_policy_oracle(&cfg, &suite, Policy::Justitia);
        let rows = cluster_scaleout(&cfg, &[1], &Placement::ALL, Policy::Justitia, 40, 3.0, 21);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert_eq!(r.completed, 40, "{:?}", r.placement);
            assert_eq!(r.avg_jct, single.avg_jct(), "{:?} avg JCT diverged", r.placement);
            assert_eq!(r.p99_jct, single.p99_jct(), "{:?} p99 JCT diverged", r.placement);
        }
    }

    #[test]
    fn cluster_scaleout_shrinks_jct_and_stays_fair() {
        let rows = cluster_scaleout(
            &Config::default(),
            &[1, 4],
            &[Placement::ClusterVtime],
            Policy::Justitia,
            120,
            3.0,
            42,
        );
        let get = |n: usize| rows.iter().find(|r| r.replicas == n).unwrap();
        assert!(
            get(4).avg_jct < get(1).avg_jct,
            "4 replicas ({:.1}s) should beat 1 ({:.1}s)",
            get(4).avg_jct,
            get(1).avg_jct
        );
        for r in &rows {
            assert_eq!(r.completed, 120);
            assert!(r.maxmin_ratio >= 1.0, "ratio {} must be >= 1", r.maxmin_ratio);
            assert!(r.makespan > 0.0);
        }
    }

    #[test]
    fn prefix_sharing_saves_prefill_and_stays_fair() {
        let rows = prefix_sharing(&Config::default(), 60, 3.0, 4, 512, 42);
        assert_eq!(rows.len(), 2);
        let (off, on) = (&rows[0], &rows[1]);
        assert!(!off.cache_enabled && on.cache_enabled);
        assert_eq!(off.completed, 60);
        assert_eq!(on.completed, 60);
        // Cache off: no lookups, nothing saved.
        assert_eq!(off.prefix_hits, 0);
        assert_eq!(off.prefill_tokens_saved, 0);
        // Cache on: hits, savings, and strictly less prefill executed.
        assert!(on.hit_rate > 0.0, "hit rate must be positive");
        assert!(on.prefill_tokens_saved > 0);
        assert!(
            on.prefill_tokens_executed < off.prefill_tokens_executed,
            "sharing must execute strictly fewer prefill tokens ({} vs {})",
            on.prefill_tokens_executed,
            off.prefill_tokens_executed
        );
        assert!(on.cache_pages_peak > 0);
        // Fairness: dedup must not widen the slowdown spread vs GPS (small
        // tolerance for iteration-granularity noise on tiny agents).
        assert!(
            on.maxmin_ratio <= off.maxmin_ratio * 1.10,
            "max-min ratio regressed: {} (on) vs {} (off)",
            on.maxmin_ratio,
            off.maxmin_ratio
        );
    }

    #[test]
    fn dag_agents_covers_shapes_and_correction_helps_estimates() {
        let rows = dag_agents(&Config::default(), 40, 3.0, 0.3, 3, 2.0, 42);
        assert_eq!(rows.len(), 6, "3 shapes x correction off/on");
        for shape in crate::workload::DagShape::ALL {
            let pair: Vec<&DagAgentsRow> =
                rows.iter().filter(|r| r.shape == shape).collect();
            assert_eq!(pair.len(), 2);
            let off = pair.iter().find(|r| !r.correction).unwrap();
            let on = pair.iter().find(|r| r.correction).unwrap();
            assert_eq!(off.completed, 40, "{shape:?} dropped agents (off)");
            assert_eq!(on.completed, 40, "{shape:?} dropped agents (on)");
            // Spawning is a pure function of the suite: identical either way.
            assert!(on.spawned_tasks > 0, "{shape:?} spawned nothing");
            assert_eq!(on.spawned_tasks, off.spawned_tasks);
            // Correction off records nothing; on records and stays sane.
            assert_eq!(off.correction_events, 0);
            assert!(on.correction_events > 0);
            assert!(on.correction_error.is_finite() && on.correction_error >= 0.0);
            assert!(on.maxmin_ratio >= 1.0 && off.maxmin_ratio >= 1.0);
            assert!(on.avg_jct > 0.0 && on.p99_jct >= on.avg_jct * 0.5);
        }
        // The remaining-DAG signal separates the shapes: pipelines are
        // fully serial, map-reduce is dominated by its parallel maps.
        let frac = |s: crate::workload::DagShape| {
            rows.iter().find(|r| r.shape == s).unwrap().serial_frac
        };
        assert!((frac(crate::workload::DagShape::Pipeline) - 1.0).abs() < 1e-9);
        assert!(frac(crate::workload::DagShape::MapReduce) < 0.9);
        assert!(frac(crate::workload::DagShape::Tree) < frac(crate::workload::DagShape::Pipeline));
    }

    #[test]
    fn chunked_prefill_improves_decode_tail_latency() {
        let rows = chunked_prefill(&Config::default(), 60, 3.0, &[512, 128], 2048, 42);
        // 3 workloads × 4 policies × (off + 2 chunk sizes).
        assert_eq!(rows.len(), 3 * 4 * 3);
        for r in &rows {
            assert_eq!(
                r.completed, 60,
                "{} {:?} chunk {} dropped agents",
                r.workload, r.policy, r.chunk
            );
            assert!(r.decode_itl_p99_ms > 0.0 && r.maxmin_ratio >= 1.0);
            // Chunking off records no stalls (pending prefills always run
            // whole); the counter is meaningful only when chunking is on.
            if r.chunk == 0 {
                assert_eq!(r.prefill_stalls, 0, "{} {:?}", r.workload, r.policy);
            }
        }
        // Headline (acceptance): at a fixed budget, decode p99 inter-token
        // latency improves as the chunk shrinks — atomic admission is
        // strictly worst, and the smaller chunk is no worse than the larger
        // (equal only within histogram bucket resolution).
        let itl = |w: &str, p: Policy, c: u32| {
            rows.iter()
                .find(|r| r.workload == w && r.policy == p && r.chunk == c)
                .unwrap()
                .decode_itl_p99_ms
        };
        for w in CHUNKED_WORKLOADS {
            for p in CHUNKED_POLICIES {
                let (off, c512, c128) = (itl(w, p, 0), itl(w, p, 512), itl(w, p, 128));
                assert!(c128 < off, "{w}/{p:?}: chunk 128 {c128} !< atomic {off}");
                assert!(c512 <= off, "{w}/{p:?}: chunk 512 {c512} !<= atomic {off}");
                assert!(c128 <= c512, "{w}/{p:?}: chunk 128 {c128} !<= chunk 512 {c512}");
            }
        }
    }

    #[test]
    fn fairbatching_improves_itl_tail_at_equal_ttft() {
        // The acceptance cells only: Static vs FairBatching on every
        // (workload, scheduler) pair — 18 runs; the 27-cell grid including
        // fixed-split is bench/kick-tires territory.
        let mut jobs = Vec::new();
        for w in FAIRBATCH_WORKLOADS {
            for p in FAIRBATCH_POLICIES {
                jobs.push((w, p, BatchPolicyKind::Static));
                jobs.push((w, p, BatchPolicyKind::FairBatching));
            }
        }
        let n = jobs.len();
        let rows = fairbatching_cells(&Config::default(), 60, 3.0, 42, jobs);
        assert_eq!(rows.len(), n);
        for r in &rows {
            assert_eq!(
                r.completed, 60,
                "{} {:?} {:?} dropped agents",
                r.workload, r.policy, r.batch
            );
            assert!(r.decode_itl_p99_ms > 0.0 && r.maxmin_ratio >= 1.0);
            assert!(
                (0.0..=1.0).contains(&r.deadline_miss_rate),
                "{} {:?} {:?}: miss rate {} out of range",
                r.workload,
                r.policy,
                r.batch,
                r.deadline_miss_rate
            );
        }
        // Acceptance headline: on at least one cell the closed loop shrinks
        // decode p99 inter-token latency without paying for it in TTFT p99
        // (tiny tolerance for histogram bucket resolution).
        let get = |w: &str, p: Policy, b: BatchPolicyKind| {
            rows.iter().find(|r| r.workload == w && r.policy == p && r.batch == b).unwrap()
        };
        let win_win = FAIRBATCH_WORKLOADS.iter().any(|&w| {
            FAIRBATCH_POLICIES.iter().any(|&p| {
                let st = get(w, p, BatchPolicyKind::Static);
                let fb = get(w, p, BatchPolicyKind::FairBatching);
                fb.decode_itl_p99_ms < st.decode_itl_p99_ms
                    && fb.ttft_p99_ms <= st.ttft_p99_ms * 1.001
            })
        });
        assert!(
            win_win,
            "no cell where FairBatching beats Static on decode p99 ITL at \
             equal-or-better TTFT p99"
        );
    }

    #[test]
    fn preemption_auto_pampering_beats_swap_youngest_under_host_pressure() {
        // Full 300-agent scale: at 3× density the suite offers ~1.7× the
        // KV drain capacity (EXPERIMENTS.md §Calibration), so preemption
        // pressure — and the M/8 host-pool squeeze — is guaranteed; smaller
        // suites at the same window are under-loaded and swap-free. Only
        // the cells the assertions below read are run (the full 72-cell
        // grid is bench/kick-tires territory).
        use PreemptionMode::{Auto, Recompute, Swap};
        use VictimPolicy::{PamperAware, Youngest};
        let mut jobs = vec![("staged", 0u64, Recompute, Youngest)];
        for w in PREEMPT_WORKLOADS {
            jobs.push((w, 0, Swap, Youngest));
            jobs.push((w, 8, Swap, Youngest));
            jobs.push((w, 8, Auto, PamperAware));
        }
        let n = jobs.len();
        let rows = preemption_cells(&Config::default(), 300, 3.0, 42, jobs);
        assert_eq!(rows.len(), n);
        let get = |w: &str, host0: bool, m: PreemptionMode, v: VictimPolicy| {
            rows.iter()
                .find(|r| {
                    r.workload == w && (r.host_pages == 0) == host0 && r.mode == m && r.victim == v
                })
                .unwrap()
        };
        for r in &rows {
            assert_eq!(
                r.completed, 300,
                "{} host={} {:?}/{:?} dropped agents",
                r.workload, r.host_pages, r.mode, r.victim
            );
            assert!(r.maxmin_ratio >= 1.0);
            // Recompute mode never swaps; unbounded-host Swap never drops.
            if r.mode == Recompute {
                assert_eq!(r.swap_outs, 0, "{}: recompute mode swapped", r.workload);
            }
            if r.mode == Swap && r.host_pages == 0 {
                assert_eq!(r.recomputes, 0, "{}: unbounded swap recomputed", r.workload);
            }
            // The wasted-token gauge moves exactly when drops happen.
            assert_eq!(r.recomputes > 0, r.recomputed_tokens > 0);
        }
        // Memory pressure is real: the classical arm actually preempts, and
        // pure recompute mode genuinely drops KV.
        assert!(
            get("staged", true, Swap, Youngest).swap_outs > 0,
            "3x density must trigger preemptions"
        );
        assert!(get("staged", true, Recompute, Youngest).recomputes > 0);
        // Acceptance headline: under a host pool sized below peak swap
        // demand (M/8), Auto + PamperAware beats Swap + Youngest on p99 JCT.
        let swap = get("staged", false, Swap, Youngest);
        let auto = get("staged", false, Auto, PamperAware);
        assert!(
            auto.p99_jct < swap.p99_jct,
            "staged: Auto+PamperAware p99 {:.1}s must beat Swap+Youngest {:.1}s",
            auto.p99_jct,
            swap.p99_jct
        );
        // The other workload families must not regress beyond noise.
        for w in ["dag", "prefix"] {
            let swap = get(w, false, Swap, Youngest);
            let auto = get(w, false, Auto, PamperAware);
            assert!(
                auto.p99_jct <= swap.p99_jct * 1.05,
                "{w}: Auto+PamperAware p99 {:.1}s vs Swap+Youngest {:.1}s",
                auto.p99_jct,
                swap.p99_jct
            );
        }
    }

    #[test]
    fn fig10_noise_degrades_gracefully() {
        let rows = fig10(&[1.0, 3.0], 30, 2.0, 17);
        let inflation = rows[1].avg_jct / rows[0].avg_jct;
        assert!(inflation < 1.6, "λ=3 inflation {inflation} too large");
    }

    #[test]
    fn fig12_overhead_small() {
        let rows = fig12(&[2.0, 8.0], 30, 19);
        for r in &rows {
            assert!(r.mean_delay_ms < 10.0, "mean sched delay {} ms", r.mean_delay_ms);
            assert!(r.decisions > 0);
        }
    }

    #[test]
    fn fig13_has_two_distributions() {
        let dists = fig13(23);
        assert_eq!(dists.len(), 2);
        for d in &dists {
            assert_eq!(d.prompt_hist.iter().sum::<usize>(), d.decode_hist.iter().sum::<usize>());
            assert!(d.prompt_range.1 > d.prompt_range.0);
        }
        // FV generate-queries: tight prompt range (Appendix A: 340–390).
        let fv = &dists[1];
        assert!(fv.prompt_range.0 >= 340 && fv.prompt_range.1 <= 390, "{:?}", fv.prompt_range);
    }
}
