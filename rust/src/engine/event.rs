//! The event/calendar-queue core of the simulator (DESIGN.md §12).
//!
//! A discrete-event simulator advances time by *popping the next event*,
//! not by scanning every sequence every tick. This module provides the
//! calendar: a binary-heap [`EventQueue`] of timestamped [`Event`]s with a
//! deterministic total order — events pop in global time order, and ties
//! break by insertion sequence (FIFO within one timestamp), so a replay is
//! reproducible bit for bit regardless of how the heap happened to
//! rebalance.
//!
//! Event taxonomy (DESIGN.md §12): the queue carries the *exogenous*
//! events — agent [`EventKind::Admission`] arrivals, whose timestamps are
//! known when the trace is loaded. The *endogenous* events (chunk-complete,
//! decode-batch-complete, swap-done, recompute-ready, spawn) are emitted by
//! the engine at iteration boundaries as [`EngineEvent`]s into the
//! scheduler's [`on_event`](crate::sched::Scheduler::on_event) hook instead
//! of being enqueued ahead of time: under continuous batching their
//! timestamps are a function of batch composition (the backend prices the
//! whole iteration at once), so a pre-queued endogenous event would have to
//! be speculatively invalidated whenever the batch changed — the classical
//! event-cancellation problem. Emitting them at the boundary keeps the
//! calendar monotone and the determinism argument trivial.

use crate::workload::TaskId;
use std::collections::BinaryHeap;

/// What a queued calendar event does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// An agent arrival: `slot` indexes the pending-arrival
    /// [`Arena`](super::arena::Arena) holding the spec to submit.
    Admission { slot: u32 },
}

/// A timestamped calendar entry. Ordering is `(time, seq)` ascending — the
/// queue assigns `seq` at push, so equal-time events fire in insertion
/// order (FIFO), which is exactly the legacy tick loop's suite order.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Fire time (engine seconds).
    pub time: f64,
    /// Insertion sequence number — the deterministic tie-break.
    pub seq: u64,
    /// Payload.
    pub kind: EventKind,
}

// `BinaryHeap` is a max-heap; reverse the comparison so the *smallest*
// (time, seq) pops first. `total_cmp` gives a total order over every f64
// (NaN included), so `Ord` is honest and the heap can never misbehave on
// exotic timestamps.
impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time).is_eq() && self.seq == other.seq
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: earliest time first, then lowest seq (FIFO at one time).
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The calendar: a binary heap of [`Event`]s popping in deterministic
/// `(time, insertion seq)` order.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    /// An empty calendar.
    pub fn new() -> Self {
        EventQueue::default()
    }

    /// Queue `kind` to fire at `time`. Assigns the insertion sequence
    /// number that breaks same-time ties FIFO.
    pub fn push(&mut self, time: f64, kind: EventKind) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time, seq, kind });
    }

    /// The earliest event without removing it.
    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek()
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether the calendar is empty.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// An endogenous engine event, emitted at iteration boundaries into the
/// scheduler's [`on_event`](crate::sched::Scheduler::on_event) hook (the
/// event-hook replacement for per-tick polling; see the module docs for why
/// these are not queue-borne). Every variant fires *after* the engine state
/// change it describes, at the engine clock passed alongside.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineEvent {
    /// A task was admitted from the waiting queue into the running batch.
    Admission { task: TaskId },
    /// A sequence's prefill advanced by `tokens` this iteration (the chunk
    /// that completed; the full uncached prompt when chunking is off).
    ChunkComplete { task: TaskId, tokens: u32 },
    /// One engine iteration retired: `decoders` sequences appended a token
    /// and `prefills` sequences ran prefill work.
    DecodeBatchComplete { decoders: usize, prefills: usize },
    /// A swapped-out sequence finished swapping back onto the device.
    SwapDone { task: TaskId },
    /// A recompute-preempted sequence re-entered the running batch.
    RecomputeReady { task: TaskId },
    /// A completed task dynamically spawned a child task.
    Spawn { task: TaskId },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        for (i, t) in [5.0, 1.0, 3.0, 0.5, 4.0].iter().enumerate() {
            q.push(*t, EventKind::Admission { slot: i as u32 });
        }
        let order: Vec<f64> = std::iter::from_fn(|| q.pop()).map(|e| e.time).collect();
        assert_eq!(order, vec![0.5, 1.0, 3.0, 4.0, 5.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn equal_times_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for slot in 0..100u32 {
            q.push(7.25, EventKind::Admission { slot });
        }
        let slots: Vec<u32> = std::iter::from_fn(|| q.pop())
            .map(|e| match e.kind {
                EventKind::Admission { slot } => slot,
            })
            .collect();
        assert_eq!(slots, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(2.0, EventKind::Admission { slot: 0 });
        q.push(1.0, EventKind::Admission { slot: 1 });
        assert_eq!(q.pop().unwrap().time, 1.0);
        q.push(0.5, EventKind::Admission { slot: 2 });
        q.push(2.0, EventKind::Admission { slot: 3 });
        assert_eq!(q.pop().unwrap().time, 0.5);
        // The two time-2.0 events fire in push order despite the pops
        // between them.
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!((a.time, b.time), (2.0, 2.0));
        assert!(a.seq < b.seq);
        match (a.kind, b.kind) {
            (EventKind::Admission { slot: x }, EventKind::Admission { slot: y }) => {
                assert_eq!((x, y), (0, 3));
            }
        }
    }

    #[test]
    fn len_and_peek() {
        let mut q = EventQueue::new();
        assert_eq!(q.len(), 0);
        assert!(q.peek().is_none());
        q.push(1.5, EventKind::Admission { slot: 9 });
        assert_eq!(q.len(), 1);
        assert_eq!(q.peek().unwrap().time, 1.5);
        assert_eq!(q.len(), 1, "peek must not consume");
    }
}
