//! Multi-Layer Perceptron regressor (paper §4.2): "a simple structure…
//! efficiently trained even with limited historical data; minimal
//! computational resources to make predictions".
//!
//! Dense layers with ReLU activations (linear output), trained by mini-batch
//! SGD with momentum on MSE + L2 regularization — exactly the setup the
//! paper describes ("gradient descent with Mean Squared Error (with L2
//! regularization)"). f32 throughout; no BLAS needed at these sizes.

use crate::util::rng::Rng;

/// One dense layer.
#[derive(Debug, Clone)]
struct Layer {
    w: Vec<f32>, // out × in, row-major
    b: Vec<f32>,
    n_in: usize,
    n_out: usize,
    // SGD momentum buffers.
    vw: Vec<f32>,
    vb: Vec<f32>,
}

impl Layer {
    fn new(n_in: usize, n_out: usize, rng: &mut Rng) -> Self {
        // He initialization for ReLU nets.
        let scale = (2.0 / n_in as f64).sqrt();
        let w = (0..n_in * n_out).map(|_| (rng.normal() * scale) as f32).collect();
        Layer {
            w,
            b: vec![0.0; n_out],
            n_in,
            n_out,
            vw: vec![0.0; n_in * n_out],
            vb: vec![0.0; n_out],
        }
    }

    fn forward(&self, x: &[f32], out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.n_out, 0.0);
        for o in 0..self.n_out {
            let row = &self.w[o * self.n_in..(o + 1) * self.n_in];
            let mut acc = self.b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[o] = acc;
        }
    }
}

/// Training hyperparameters.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// SGD epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f64,
    /// L2 regularization weight.
    pub l2: f64,
    /// Mini-batch size.
    pub batch: usize,
    /// Shuffle seed.
    pub seed: u64,
}

/// The MLP: `sizes = [in, h1, h2, out]` gives the paper's 4-layer shape.
#[derive(Debug, Clone)]
pub struct Mlp {
    layers: Vec<Layer>,
}

const MOMENTUM: f32 = 0.9;

impl Mlp {
    /// Randomly-initialized MLP; `sizes = [in, .., out]`.
    pub fn new(sizes: &[usize], seed: u64) -> Self {
        assert!(sizes.len() >= 2);
        let mut rng = Rng::with_stream(seed, 0x31337);
        let layers = sizes.windows(2).map(|w| Layer::new(w[0], w[1], &mut rng)).collect();
        Mlp { layers }
    }

    /// Total trainable parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward pass (ReLU between layers, linear output).
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        let mut cur = x.to_vec();
        let mut next = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            layer.forward(&cur, &mut next);
            if i + 1 < self.layers.len() {
                for v in &mut next {
                    *v = v.max(0.0);
                }
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur
    }

    /// Train on (xs, ys) with scalar targets. Returns final epoch MSE.
    pub fn train(&mut self, xs: &[Vec<f32>], ys: &[f32], cfg: &TrainConfig) -> f64 {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Rng::with_stream(cfg.seed, 0x7ea1);
        let mut last_mse = f64::INFINITY;

        // Per-layer activation buffers (pre-ReLU saved for backprop).
        let n_layers = self.layers.len();
        for _epoch in 0..cfg.epochs {
            rng.shuffle(&mut order);
            let mut sq_sum = 0.0f64;
            for chunk in order.chunks(cfg.batch.max(1)) {
                // Zero-init gradient accumulators.
                let mut gw: Vec<Vec<f32>> =
                    self.layers.iter().map(|l| vec![0.0; l.w.len()]).collect();
                let mut gb: Vec<Vec<f32>> =
                    self.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();

                for &i in chunk {
                    // Forward, saving activations.
                    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n_layers + 1);
                    acts.push(xs[i].clone());
                    for (li, layer) in self.layers.iter().enumerate() {
                        let mut out = Vec::new();
                        layer.forward(acts.last().unwrap(), &mut out);
                        if li + 1 < n_layers {
                            for v in &mut out {
                                *v = v.max(0.0);
                            }
                        }
                        acts.push(out);
                    }
                    let pred = acts.last().unwrap()[0];
                    let err = pred - ys[i];
                    sq_sum += (err * err) as f64;

                    // Backward.
                    let mut delta = vec![2.0 * err]; // dMSE/dpred
                    for li in (0..n_layers).rev() {
                        let layer = &self.layers[li];
                        let input = &acts[li];
                        // Accumulate grads for this layer.
                        for o in 0..layer.n_out {
                            let d = delta[o];
                            if d == 0.0 {
                                continue;
                            }
                            gb[li][o] += d;
                            let grow = &mut gw[li][o * layer.n_in..(o + 1) * layer.n_in];
                            for (g, &x) in grow.iter_mut().zip(input) {
                                *g += d * x;
                            }
                        }
                        if li == 0 {
                            break;
                        }
                        // Propagate delta to previous layer through W and the
                        // ReLU mask of that layer's (post-activation) output.
                        let mut prev = vec![0.0f32; layer.n_in];
                        for o in 0..layer.n_out {
                            let d = delta[o];
                            if d == 0.0 {
                                continue;
                            }
                            let row = &layer.w[o * layer.n_in..(o + 1) * layer.n_in];
                            for (p, &w) in prev.iter_mut().zip(row) {
                                *p += d * w;
                            }
                        }
                        // ReLU derivative: act[li] is post-ReLU of layer li-1.
                        for (p, &a) in prev.iter_mut().zip(&acts[li][..]) {
                            if a <= 0.0 {
                                *p = 0.0;
                            }
                        }
                        delta = prev;
                    }
                }

                // SGD + momentum + L2 step.
                let scale = (cfg.lr / chunk.len() as f64) as f32;
                let l2 = cfg.l2 as f32;
                for (li, layer) in self.layers.iter_mut().enumerate() {
                    for (j, w) in layer.w.iter_mut().enumerate() {
                        let g = gw[li][j] + l2 * *w;
                        layer.vw[j] = MOMENTUM * layer.vw[j] - scale * g;
                        *w += layer.vw[j];
                    }
                    for (j, b) in layer.b.iter_mut().enumerate() {
                        layer.vb[j] = MOMENTUM * layer.vb[j] - scale * gb[li][j];
                        *b += layer.vb[j];
                    }
                }
            }
            last_mse = sq_sum / n as f64;
        }
        last_mse
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_params() {
        let m = Mlp::new(&[8, 16, 8, 1], 1);
        assert_eq!(m.forward(&vec![0.5; 8]).len(), 1);
        assert_eq!(m.n_params(), 8 * 16 + 16 + 16 * 8 + 8 + 8 * 1 + 1);
    }

    #[test]
    fn learns_linear_function() {
        // y = 2*x0 - x1 + 0.5
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f32>> = (0..200)
            .map(|_| vec![rng.f64() as f32, rng.f64() as f32])
            .collect();
        let ys: Vec<f32> = xs.iter().map(|x| 2.0 * x[0] - x[1] + 0.5).collect();
        let mut m = Mlp::new(&[2, 16, 8, 1], 3);
        let mse = m.train(&xs, &ys, &TrainConfig { epochs: 400, lr: 1e-2, l2: 1e-6, batch: 16, seed: 4 });
        assert!(mse < 1e-3, "mse={mse}");
        let p = m.forward(&[0.5, 0.5])[0];
        assert!((p - 1.0).abs() < 0.1, "pred={p}");
    }

    #[test]
    fn learns_nonlinear_function() {
        // y = |x0 - 0.5| needs the hidden nonlinearity.
        let mut rng = Rng::new(5);
        let xs: Vec<Vec<f32>> = (0..300).map(|_| vec![rng.f64() as f32]).collect();
        let ys: Vec<f32> = xs.iter().map(|x| (x[0] - 0.5).abs()).collect();
        let mut m = Mlp::new(&[1, 24, 12, 1], 6);
        let mse = m.train(&xs, &ys, &TrainConfig { epochs: 600, lr: 1e-2, l2: 0.0, batch: 16, seed: 7 });
        assert!(mse < 2e-3, "mse={mse}");
    }

    #[test]
    fn l2_shrinks_weights() {
        let xs: Vec<Vec<f32>> = (0..50).map(|i| vec![(i as f32) / 50.0]).collect();
        let ys: Vec<f32> = xs.iter().map(|x| x[0]).collect();
        let strong = {
            let mut m = Mlp::new(&[1, 8, 4, 1], 9);
            m.train(&xs, &ys, &TrainConfig { epochs: 200, lr: 1e-2, l2: 0.5, batch: 8, seed: 9 });
            m
        };
        let weak = {
            let mut m = Mlp::new(&[1, 8, 4, 1], 9);
            m.train(&xs, &ys, &TrainConfig { epochs: 200, lr: 1e-2, l2: 0.0, batch: 8, seed: 9 });
            m
        };
        let norm = |m: &Mlp| -> f64 {
            m.layers.iter().flat_map(|l| l.w.iter()).map(|w| (*w as f64).powi(2)).sum()
        };
        assert!(norm(&strong) < norm(&weak));
    }

    #[test]
    fn deterministic_training() {
        let xs: Vec<Vec<f32>> = (0..20).map(|i| vec![i as f32 / 20.0]).collect();
        let ys: Vec<f32> = xs.iter().map(|x| x[0] * 3.0).collect();
        let cfg = TrainConfig { epochs: 50, lr: 1e-2, l2: 1e-5, batch: 4, seed: 11 };
        let mut a = Mlp::new(&[1, 8, 4, 1], 12);
        let mut b = Mlp::new(&[1, 8, 4, 1], 12);
        a.train(&xs, &ys, &cfg);
        b.train(&xs, &ys, &cfg);
        assert_eq!(a.forward(&[0.3]), b.forward(&[0.3]));
    }
}
