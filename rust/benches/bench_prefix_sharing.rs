//! Prefix sharing — 300 shared-prefix-family agents at 3× density through a
//! Justitia replica with the radix-tree KV cache off vs on.
//!
//! Beyond the paper: when fan-out inferences and agent families re-submit
//! the same system prompt + context, dedup shrinks both prefill work and
//! the memory-centric cost base Justitia charges. Expected shape: positive
//! hit rate, a large fraction of prefill tokens skipped, avg/p99 JCT no
//! worse (usually better under contention), and a max-min fair-share ratio
//! vs GPS no worse than the no-sharing run.

use justitia::config::Config;
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Prefix sharing: radix-tree KV dedup off vs on (300 agents, 3x density)");
    let mut out = ResultsFile::new("bench_prefix_sharing.txt");
    let rows = justitia::experiments::prefix_sharing(&Config::default(), 300, 3.0, 4, 512, 42);
    out.line(format!(
        "{:<8} {:>8} {:>13} {:>13} {:>9} {:>9} {:>9} {:>8} {:>6}",
        "cache", "hit%", "prefill-run", "saved", "peak-pg", "avgJCT", "p99JCT", "maxmin", "done"
    ));
    for r in &rows {
        out.line(format!(
            "{:<8} {:>7.1}% {:>13} {:>13} {:>9} {:>8.1}s {:>8.1}s {:>7.2}x {:>6}",
            if r.cache_enabled { "on" } else { "off" },
            r.hit_rate * 100.0,
            r.prefill_tokens_executed,
            r.prefill_tokens_saved,
            r.cache_pages_peak,
            r.avg_jct,
            r.p99_jct,
            r.maxmin_ratio,
            r.completed
        ));
    }
    if let [off, on] = &rows[..] {
        let total = on.prefill_tokens_saved + on.prefill_tokens_executed;
        out.line(format!(
            "headline: {:.1}% of prefill tokens deduplicated, avg JCT {:.1}s -> {:.1}s, \
             maxmin {:.2}x -> {:.2}x",
            100.0 * on.prefill_tokens_saved as f64 / total.max(1) as f64,
            off.avg_jct,
            on.avg_jct,
            off.maxmin_ratio,
            on.maxmin_ratio
        ));
    }
}
