// Fixture: determinism-clean core module. Nothing here may be flagged.
use std::collections::{BTreeMap, HashMap};

pub struct Engine {
    // Ordered map: iteration is deterministic, no annotation needed.
    agents: BTreeMap<u32, u64>,
    // Hash map is fine as long as access stays keyed.
    cache: HashMap<u32, u64>,
    names: Vec<String>,
}

impl Engine {
    pub fn total(&self) -> u64 {
        let mut sum = 0;
        for (_, v) in &self.agents {
            sum += v;
        }
        sum
    }

    // Keyed access into a hash map: not iteration, not flagged.
    pub fn lookup(&self, id: u32) -> Option<u64> {
        self.cache.get(&id).copied()
    }

    // Vec iteration: ordered, not flagged even though the method names match.
    pub fn all_names(&self) -> Vec<String> {
        self.names.iter().cloned().collect()
    }

    // Hash iteration folded through a commutative reduction, justified
    // by an own-line annotation covering the next code line.
    pub fn cache_total(&self) -> u64 {
        // simlint::allow(unordered-iter): commutative sum, order-independent
        self.cache.values().sum()
    }

    // Same-line annotation form.
    pub fn cache_len_hint(&self) -> usize {
        self.cache.keys().count() // simlint::allow(unordered-iter): count only, order-free
    }
}
