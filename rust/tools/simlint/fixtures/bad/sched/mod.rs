// Fixture: NaN-unsafe ordering and bad annotations. All flagged.

// R3: `.partial_cmp(..).unwrap()` in a sort key.
pub fn pick(keys: &mut Vec<(u32, f64)>) {
    keys.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
}

// Annotation with an empty justification: itself a violation.
pub fn pick_min(keys: &[(u32, f64)]) -> Option<u32> {
    keys.iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap()) // simlint::allow(nan-order)
        .map(|(id, _)| *id)
}

// R2: unseeded RNG and ambient environment reads in core.
pub fn jitter() -> u64 {
    let _ = std::env::var("SEED");
    let mut rng = thread_rng();
    rng.next_u64()
}

// Stale annotation: suppresses nothing on the next code line.
// simlint::allow(unordered-iter): nothing unordered here
pub fn noop() {}
