//! Preemption subsystem — 300 agents at 3× density per workload family
//! (staged / DAG / shared-prefix), host tiers {∞, M/8} × preemption modes
//! {swap, recompute, auto} × all four victim policies, swap traffic
//! serialized behind a contended PCIe link (DESIGN.md §11).
//!
//! Beyond the paper: the engine's memory hierarchy is finite — swaps land in
//! a bounded host pool over a real link, so swap-vs-recompute is a priced
//! choice (vLLM preemption modes; Sarathi-Serve on why stalls must be
//! priced). Expected shape: under the M/8 host tier, `auto`+`pamper-aware`
//! beats `swap`+`youngest` on p99 JCT — the swap arms stall behind the
//! serialized transfers and forced-recompute fallbacks, while auto skips
//! every round trip whose cached-prefix-adjusted refill is cheaper.

use justitia::config::{Config, PreemptionMode, VictimPolicy};
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Preemption: workload x host tier x mode x victim (300 agents, 3x density)");
    let mut out = ResultsFile::new("bench_preemption.txt");
    let rows = justitia::experiments::preemption(&Config::default(), 300, 3.0, 42);
    out.line(justitia::experiments::PreemptionRow::table_header());
    for r in &rows {
        out.line(r.table_row());
    }
    for w in justitia::experiments::PREEMPT_WORKLOADS {
        let get = |m: PreemptionMode, v: VictimPolicy| {
            rows.iter().find(|r| r.workload == w && r.host_pages > 0 && r.mode == m && r.victim == v)
        };
        if let (Some(swap), Some(auto)) = (
            get(PreemptionMode::Swap, VictimPolicy::Youngest),
            get(PreemptionMode::Auto, VictimPolicy::PamperAware),
        ) {
            out.line(format!(
                "headline {w} (host M/8): p99 JCT {:.1}s (swap+youngest) -> {:.1}s \
                 (auto+pamper-aware); {} -> {} swaps, {} recomputes ({} tokens re-prefilled)",
                swap.p99_jct,
                auto.p99_jct,
                swap.swap_outs,
                auto.swap_outs,
                auto.recomputes,
                auto.recomputed_tokens
            ));
        }
    }
}
