//! Model-checks the assumption `test_parallel_replica_determinism` relies
//! on but never exercises adversarially: `ThreadPool::map()` returns
//! results in *input* order no matter what order the workers *complete* in.
//!
//! Loom can't model-check this pool (std `mpsc` isn't loom-instrumented and
//! the crate builds with zero dependencies), so the schedule space is
//! driven explicitly instead: with 4 items resident on 4 workers, a
//! condvar turnstile forces the items to complete in each of the 4! = 24
//! possible orders, which covers every completion-order interleaving the
//! reinstall loop `out[i] = Some(r)` can observe for 4 in-flight results.
//! CI additionally runs this file under ThreadSanitizer (ci.yml `tsan`
//! job) to check the same code for data races rather than orderings.

use justitia::util::threadpool::ThreadPool;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// All permutations of `0..n` in lexicographic order (deterministic).
fn permutations(n: usize) -> Vec<Vec<usize>> {
    if n == 0 {
        return vec![vec![]];
    }
    let mut out = Vec::new();
    for rest in permutations(n - 1) {
        for slot in 0..=rest.len() {
            let mut p = rest.clone();
            p.insert(slot, n - 1);
            out.push(p);
        }
    }
    out.sort();
    out
}

/// Run `n` items on `n` workers, forcing completion order `perm`
/// (`perm[k]` = the item that completes k-th), and return `map()`'s output.
fn forced_order_map(n: usize, perm: &[usize]) -> Vec<usize> {
    // rank[item] = position in the forced completion order.
    let mut rank = vec![0usize; n];
    for (k, &item) in perm.iter().enumerate() {
        rank[item] = k;
    }
    let turnstile = Arc::new((Mutex::new(0usize), Condvar::new()));
    let pool = ThreadPool::new(n);
    let items: Vec<(usize, usize)> = (0..n).map(|i| (i, rank[i])).collect();
    let ts = Arc::clone(&turnstile);
    pool.map(items, move |(i, my_rank)| {
        let (lock, cv) = &*ts;
        let mut turn = lock.lock().unwrap();
        // Every item occupies its own worker, so all n closures reach this
        // wait concurrently; release them strictly in rank order.
        while *turn != my_rank {
            let (t, timeout) = cv
                .wait_timeout(turn, Duration::from_secs(30))
                .expect("turnstile poisoned");
            turn = t;
            assert!(!timeout.timed_out(), "turnstile deadlock: item {i} rank {my_rank}");
        }
        *turn += 1;
        cv.notify_all();
        // The result encodes the item id; map() must slot it at index i
        // regardless of when it was produced.
        i * 100 + 7
    })
}

#[test]
fn map_order_preserved_under_all_24_completion_orders() {
    let expected: Vec<usize> = (0..4).map(|i| i * 100 + 7).collect();
    let perms = permutations(4);
    assert_eq!(perms.len(), 24);
    for perm in perms {
        let out = forced_order_map(4, &perm);
        assert_eq!(out, expected, "input order broken under completion order {perm:?}");
    }
}

#[test]
fn map_order_preserved_under_reverse_completion_stress() {
    // 8 workers, 8 resident items forced to complete in exact reverse
    // order — the adversarial extreme — repeated to catch flaky reinstalls.
    let n = 8;
    let reverse: Vec<usize> = (0..n).rev().collect();
    let expected: Vec<usize> = (0..n).map(|i| i * 100 + 7).collect();
    for _ in 0..20 {
        assert_eq!(forced_order_map(n, &reverse), expected);
    }
}

#[test]
fn map_results_invariant_in_worker_count() {
    // The same workload must produce the same output vector whatever the
    // pool width — including width 1 (fully sequential) and widths where
    // items queue behind one another.
    let items: Vec<u64> = (0..200).collect();
    let expected: Vec<u64> = items.iter().map(|&x| x.wrapping_mul(x) ^ 0x5a).collect();
    for workers in [1, 2, 3, 5, 8, 16] {
        let pool = ThreadPool::new(workers);
        let out = pool.map(items.clone(), |x| x.wrapping_mul(x) ^ 0x5a);
        assert_eq!(out, expected, "workers = {workers}");
    }
}

#[test]
fn map_heavy_contention_many_more_items_than_workers() {
    // Items vastly outnumber workers, with unequal per-item work so fast
    // items routinely finish before slow earlier ones.
    let pool = ThreadPool::new(4);
    let items: Vec<u32> = (0..500).collect();
    let out = pool.map(items, |x| {
        // Unequal deterministic work: later items spin less.
        let spins = (500 - x) as u64 * 37;
        let mut acc = x as u64;
        for i in 0..spins {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        (x, acc)
    });
    for (i, (x, _)) in out.iter().enumerate() {
        assert_eq!(*x, i as u32, "slot {i} holds item {x}");
    }
}
