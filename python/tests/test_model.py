"""L2 correctness: the paged-KV transformer.

decode (Pallas kernel path) must match decode_ref (pure-jnp oracle path);
prefill-then-decode must be consistent with prefilling the longer prompt
(teacher forcing); the paged pool must be written exactly at the block-table
slots and nowhere else (except the trash page).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = M.ModelConfig(n_pages=16, max_pages_per_seq=4, max_prefill=32)
    w = M.init_weights(cfg, seed=7)
    wl = [jnp.asarray(x) for x in M.weights_as_list(cfg, w)]
    return cfg, wl


def prefill_tokens(cfg, ids):
    t = np.zeros(cfg.max_prefill, np.int32)
    t[: len(ids)] = ids
    return jnp.asarray(t)


class TestWeights:
    def test_weight_names_sorted_and_complete(self):
        cfg = M.ModelConfig()
        names = M.weight_names(cfg)
        assert names == sorted(names)
        assert len(names) == 3 + 6 * cfg.n_layers
        w = M.init_weights(cfg, 0)
        assert set(w) == set(names)

    def test_init_deterministic(self):
        cfg = M.ModelConfig()
        a = M.init_weights(cfg, 3)
        b = M.init_weights(cfg, 3)
        for n in a:
            np.testing.assert_array_equal(a[n], b[n])
        c = M.init_weights(cfg, 4)
        assert any(not np.array_equal(a[n], c[n]) for n in a)


class TestPrefill:
    def test_pool_written_only_at_block_table_slots(self, setup):
        cfg, wl = setup
        kp, vp = M.empty_pools(cfg)
        bt = jnp.asarray([3, 7, 1, 2], jnp.int32)
        toks = prefill_tokens(cfg, np.arange(10) + 5)
        _, kp, vp = M.prefill(cfg, wl, toks, jnp.int32(10), bt, kp, vp)
        kp_np = np.asarray(kp)
        # 10 tokens → page 3 full? page_size=16 → all 10 in page 3.
        assert np.abs(kp_np[:, 3, :10]).sum() > 0
        assert np.abs(kp_np[:, 3, 10:]).sum() == 0
        # Other real pages untouched.
        untouched = [p for p in range(cfg.n_pages) if p != 3]
        assert np.abs(kp_np[:, untouched]).sum() == 0

    def test_padding_goes_to_trash_page(self, setup):
        cfg, wl = setup
        kp, vp = M.empty_pools(cfg)
        bt = jnp.asarray([0, 1, 2, 3], jnp.int32)
        toks = prefill_tokens(cfg, [9, 8, 7])
        _, kp, vp = M.prefill(cfg, wl, toks, jnp.int32(3), bt, kp, vp)
        kp_np = np.asarray(kp)
        # Trash page absorbed the padding writes.
        assert np.abs(kp_np[:, cfg.trash_page]).sum() > 0
        # Real page 0 has exactly 3 token slots written.
        assert np.abs(kp_np[:, 0, :3]).sum() > 0
        assert np.abs(kp_np[:, 0, 3:]).sum() == 0

    def test_logits_invariant_to_padding_content(self, setup):
        cfg, wl = setup
        bt = jnp.asarray([0, 1, 2, 3], jnp.int32)
        ids = [4, 5, 6, 7, 8]
        kp, vp = M.empty_pools(cfg)
        l1, _, _ = M.prefill(cfg, wl, prefill_tokens(cfg, ids), jnp.int32(5), bt, kp, vp)
        t2 = np.full(cfg.max_prefill, 999, np.int32)
        t2[:5] = ids
        kp, vp = M.empty_pools(cfg)
        l2, _, _ = M.prefill(cfg, wl, jnp.asarray(t2), jnp.int32(5), bt, kp, vp)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5, atol=1e-5)


class TestDecode:
    def test_kernel_path_matches_ref_path(self, setup):
        cfg, wl = setup
        kp, vp = M.empty_pools(cfg)
        bt1 = jnp.asarray([0, 1, 2, 3], jnp.int32)
        bt2 = jnp.asarray([4, 5, 6, 7], jnp.int32)
        _, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, np.arange(12) + 3), jnp.int32(12), bt1, kp, vp)
        _, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, np.arange(5) + 50), jnp.int32(5), bt2, kp, vp)
        bts = jnp.stack([bt1, bt2])
        toks = jnp.asarray([11, 22], jnp.int32)
        pos = jnp.asarray([12, 5], jnp.int32)
        l_kernel, kp1, vp1 = M.decode(cfg, wl, toks, pos, bts, kp, vp)
        l_ref, kp2, vp2 = M.decode_ref(cfg, wl, toks, pos, bts, kp, vp)
        np.testing.assert_allclose(np.asarray(l_kernel), np.asarray(l_ref), rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(kp1), np.asarray(kp2), rtol=1e-6, atol=1e-6)

    def test_prefill_decode_teacher_forcing(self, setup):
        cfg, wl = setup
        bt = jnp.asarray([8, 9, 10, 11], jnp.int32)
        ids = list(np.arange(9) + 17)
        # Path A: prefill 9 tokens then decode token X at position 9.
        kp, vp = M.empty_pools(cfg)
        _, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, ids), jnp.int32(9), bt, kp, vp)
        lx, _, _ = M.decode(
            cfg, wl, jnp.asarray([77], jnp.int32), jnp.asarray([9], jnp.int32), bt[None, :], kp, vp
        )
        # Path B: prefill all 10 tokens at once.
        kp, vp = M.empty_pools(cfg)
        ly, _, _ = M.prefill(cfg, wl, prefill_tokens(cfg, ids + [77]), jnp.int32(10), bt, kp, vp)
        np.testing.assert_allclose(np.asarray(lx[0]), np.asarray(ly), rtol=3e-3, atol=3e-3)

    def test_multi_step_greedy_decode_deterministic(self, setup):
        cfg, wl = setup
        bt = jnp.asarray([12, 13, 14, 15], jnp.int32)

        def run():
            kp, vp = M.empty_pools(cfg)
            lg, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, [5, 6, 7]), jnp.int32(3), bt, kp, vp)
            toks = [int(np.argmax(lg))]
            for step in range(6):
                l, kp2, vp2 = M.decode(
                    cfg,
                    wl,
                    jnp.asarray([toks[-1]], jnp.int32),
                    jnp.asarray([3 + step], jnp.int32),
                    bt[None, :],
                    kp,
                    vp,
                )
                kp, vp = kp2, vp2
                toks.append(int(np.argmax(l[0])))
            return toks

        assert run() == run()

    def test_batched_decode_independent_of_batch_composition(self, setup):
        # A sequence decoded alone must produce the same logits as when
        # batched with an unrelated sequence (paging isolation).
        cfg, wl = setup
        kp, vp = M.empty_pools(cfg)
        bt1 = jnp.asarray([0, 1, 2, 3], jnp.int32)
        bt2 = jnp.asarray([4, 5, 6, 7], jnp.int32)
        _, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, [3, 4, 5]), jnp.int32(3), bt1, kp, vp)
        _, kp, vp = M.prefill(cfg, wl, prefill_tokens(cfg, [30, 40]), jnp.int32(2), bt2, kp, vp)
        l_solo, _, _ = M.decode(
            cfg, wl, jnp.asarray([9], jnp.int32), jnp.asarray([3], jnp.int32), bt1[None, :], kp, vp
        )
        l_batch, _, _ = M.decode(
            cfg,
            wl,
            jnp.asarray([9, 19], jnp.int32),
            jnp.asarray([3, 2], jnp.int32),
            jnp.stack([bt1, bt2]),
            kp,
            vp,
        )
        np.testing.assert_allclose(np.asarray(l_solo[0]), np.asarray(l_batch[0]), rtol=2e-4, atol=2e-4)
