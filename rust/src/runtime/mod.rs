//! PJRT runtime: load the AOT HLO artifacts and run the transformer from
//! the Layer-3 hot path.
//!
//! `python/compile/aot.py` lowers prefill + decode (with the Pallas
//! paged-attention kernel inlined) to HLO **text**, writes seeded weights to
//! `weights.jtt`, and records shapes in `model_config.json`. This module:
//!
//! * parses the manifest ([`ModelManifest`]),
//! * loads weights as `xla::Literal`s in sorted-name order (the shared
//!   parameter convention),
//! * compiles each HLO text via `PjRtClient::cpu()` once,
//! * exposes [`PjrtModel`] (prefill / decode calls) and [`PjrtBackend`]
//!   (an [`crate::engine::exec::ExecBackend`] so the serving engine runs the
//!   real model exactly the way it runs the simulator).
//!
//! Python never executes at serving time — the binary is self-contained
//! once `make artifacts` has produced the files.
//!
//! The xla-rs bindings need a local XLA toolchain, so the real model is
//! gated behind the `pjrt` cargo feature. Without it, [`PjrtModel::load`]
//! returns an explanatory error and the rest of the crate (simulator,
//! schedulers, experiments, HTTP parsing) is unaffected.

pub mod backend;

pub use backend::PjrtBackend;

use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use crate::util::tensor_file::{self, DType};
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `model_config.json`.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    /// Vocabulary size.
    pub vocab: usize,
    /// Residual width.
    pub d_model: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// KV pages in the pool (excluding the trash page).
    pub n_pages: usize,
    /// Tokens per KV page.
    pub page_size: usize,
    /// Longest block table a sequence may hold.
    pub max_pages_per_seq: usize,
    /// Longest prompt the prefill executable accepts.
    pub max_prefill: usize,
    /// Weight tensor names in sorted (parameter) order.
    pub weight_names: Vec<String>,
    /// Compiled decode batch sizes.
    pub decode_batches: Vec<usize>,
    /// Artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl ModelManifest {
    /// Parse `model_config.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("model_config.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let m = v.get("model");
        let get = |k: &str| -> Result<usize> {
            m.get(k).as_u64().map(|x| x as usize).with_context(|| format!("model.{k}"))
        };
        Ok(ModelManifest {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_heads: get("n_heads")?,
            d_head: get("d_head")?,
            n_layers: get("n_layers")?,
            n_pages: get("n_pages")?,
            page_size: get("page_size")?,
            max_pages_per_seq: get("max_pages_per_seq")?,
            max_prefill: get("max_prefill")?,
            weight_names: v
                .get("weight_names")
                .as_arr()
                .context("weight_names")?
                .iter()
                .map(|j| j.as_str().map(String::from).context("weight name"))
                .collect::<Result<_>>()?,
            decode_batches: v
                .get("decode_batches")
                .as_arr()
                .context("decode_batches")?
                .iter()
                .map(|j| j.as_u64().map(|x| x as usize).context("batch"))
                .collect::<Result<_>>()?,
            dir: dir.to_path_buf(),
        })
    }

    /// Pool element count: [L, P+1, page, H, D].
    pub fn pool_len(&self) -> usize {
        self.n_layers * (self.n_pages + 1) * self.page_size * self.n_heads * self.d_head
    }

    /// Pool dims `[L, P+1, page, H, D]`.
    pub fn pool_dims(&self) -> [usize; 5] {
        [self.n_layers, self.n_pages + 1, self.page_size, self.n_heads, self.d_head]
    }

    /// Elements in one (layer, page) slab of a pool.
    pub fn page_elems(&self) -> usize {
        self.page_size * self.n_heads * self.d_head
    }

    /// Flat offset of (layer, page) in a pool.
    pub fn page_offset(&self, layer: usize, page: u32) -> usize {
        (layer * (self.n_pages + 1) + page as usize) * self.page_elems()
    }

    /// The trash-page index (padding writes land there).
    pub fn trash_page(&self) -> u32 {
        self.n_pages as u32
    }
}

/// A loaded-and-compiled model: weights + executables + host-side pools.
#[cfg(feature = "pjrt")]
pub struct PjrtModel {
    /// Parsed model shapes and artifact paths.
    pub manifest: ModelManifest,
    client: xla::PjRtClient,
    prefill_exe: xla::PjRtLoadedExecutable,
    /// (batch, executable), ascending batch.
    decode_exes: Vec<(usize, xla::PjRtLoadedExecutable)>,
    /// Weights live on the PJRT device, uploaded ONCE at load time and
    /// passed by reference to every execution (`execute_b`) — cloning the
    /// ~3 MB of weight literals per call dominated the serving hot path
    /// before this (EXPERIMENTS.md §Perf).
    weights: Vec<xla::PjRtBuffer>,
    /// Host-resident paged pools (the CPU PJRT "device" memory is host
    /// memory; the pools round-trip through each execution).
    pub k_pool: Vec<f32>,
    /// Host-resident paged V pool (the CPU plugin's device memory is host
    /// memory; pools round-trip through each execution).
    pub v_pool: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl PjrtModel {
    /// Load everything from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = ModelManifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;

        // Weights in sorted-name order (BTreeMap iteration == sorted),
        // uploaded to the device once.
        let tensors = tensor_file::read_jtt(&dir.join("weights.jtt"))?;
        let mut weights = Vec::with_capacity(manifest.weight_names.len());
        for name in &manifest.weight_names {
            let t = tensors.get(name).with_context(|| format!("weight {name} missing"))?;
            if t.dtype != DType::F32 {
                bail!("weight {name}: expected f32");
            }
            let shape = if t.shape.is_empty() { vec![1usize; 0] } else { t.shape.clone() };
            weights.push(client.buffer_from_host_buffer(&t.data_f32, &shape, None)?);
        }

        let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let prefill_exe = compile("prefill.hlo.txt")?;
        let mut decode_exes = Vec::new();
        for &b in &manifest.decode_batches {
            decode_exes.push((b, compile(&format!("decode_b{b}.hlo.txt"))?));
        }
        decode_exes.sort_by_key(|(b, _)| *b);

        let pool_len = manifest.pool_len();
        Ok(PjrtModel {
            manifest,
            client,
            prefill_exe,
            decode_exes,
            weights,
            k_pool: vec![0.0; pool_len],
            v_pool: vec![0.0; pool_len],
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest compiled decode batch >= n.
    pub fn decode_batch_for(&self, n: usize) -> Result<usize> {
        self.decode_exes
            .iter()
            .map(|(b, _)| *b)
            .find(|&b| b >= n)
            .with_context(|| format!("no decode variant fits batch {n}"))
    }

    /// Largest compiled decode batch.
    pub fn max_decode_batch(&self) -> usize {
        self.decode_exes.last().map(|(b, _)| *b).unwrap_or(1)
    }

    /// Run prefill for one sequence. `tokens` are the prompt ids (<=
    /// max_prefill), `block_table` the engine page ids. Returns the argmax
    /// next token; pools are updated in place.
    pub fn prefill(&mut self, tokens: &[u32], block_table: &[u32]) -> Result<u32> {
        let m = &self.manifest;
        if tokens.is_empty() || tokens.len() > m.max_prefill {
            bail!("prompt length {} not in 1..={}", tokens.len(), m.max_prefill);
        }
        let mut padded = vec![0i32; m.max_prefill];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = (t % m.vocab as u32) as i32;
        }
        let mut bt = vec![m.trash_page() as i32; m.max_pages_per_seq];
        for (i, &p) in block_table.iter().take(m.max_pages_per_seq).enumerate() {
            bt[i] = p as i32;
        }
        let (max_prefill, maxp) = (m.max_prefill, m.max_pages_per_seq);
        let seq_len = [tokens.len() as i32];
        let pool_dims: Vec<usize> = self.manifest.pool_dims().to_vec();

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.weights.len() + 5);
        args.extend(self.weights.iter());
        let state = [
            self.client.buffer_from_host_buffer(&padded, &[max_prefill], None)?,
            self.client.buffer_from_host_buffer(&seq_len, &[], None)?,
            self.client.buffer_from_host_buffer(&bt, &[maxp], None)?,
            self.client.buffer_from_host_buffer(&self.k_pool, &pool_dims, None)?,
            self.client.buffer_from_host_buffer(&self.v_pool, &pool_dims, None)?,
        ];
        args.extend(state.iter());

        let result = self.prefill_exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (logits, kp, vp) = result.to_tuple3()?;
        let logits: Vec<f32> = logits.to_vec()?;
        kp.copy_raw_to(&mut self.k_pool)?;
        vp.copy_raw_to(&mut self.v_pool)?;
        Ok(argmax(&logits))
    }

    /// Run one decode step for `n` sequences (n <= max batch). Each entry is
    /// (last_token, position, block_table). Returns argmax next tokens.
    pub fn decode(&mut self, seqs: &[(u32, u32, Vec<u32>)]) -> Result<Vec<u32>> {
        let n = seqs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let b = self.decode_batch_for(n)?;
        let m = &self.manifest;
        let maxp = m.max_pages_per_seq;
        let trash = m.trash_page() as i32;

        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut tables = vec![trash; b * maxp];
        for (i, (tok, pos, bt)) in seqs.iter().enumerate() {
            tokens[i] = (*tok % m.vocab as u32) as i32;
            positions[i] = *pos as i32;
            for (j, &p) in bt.iter().take(maxp).enumerate() {
                tables[i * maxp + j] = p as i32;
            }
        }
        // Padding lanes write token 0 at position 0 into the trash page.
        let vocab = m.vocab;
        let pool_dims: Vec<usize> = self.manifest.pool_dims().to_vec();

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(self.weights.len() + 5);
        args.extend(self.weights.iter());
        let state = [
            self.client.buffer_from_host_buffer(&tokens, &[b], None)?,
            self.client.buffer_from_host_buffer(&positions, &[b], None)?,
            self.client.buffer_from_host_buffer(&tables, &[b, maxp], None)?,
            self.client.buffer_from_host_buffer(&self.k_pool, &pool_dims, None)?,
            self.client.buffer_from_host_buffer(&self.v_pool, &pool_dims, None)?,
        ];
        args.extend(state.iter());

        let exe = &self.decode_exes.iter().find(|(bb, _)| *bb == b).unwrap().1;
        let result = exe.execute_b(&args)?[0][0].to_literal_sync()?;
        let (logits, kp, vp) = result.to_tuple3()?;
        let logits: Vec<f32> = logits.to_vec()?;
        kp.copy_raw_to(&mut self.k_pool)?;
        vp.copy_raw_to(&mut self.v_pool)?;

        Ok((0..n).map(|i| argmax(&logits[i * vocab..(i + 1) * vocab])).collect())
    }

    /// Elements in one (layer, page) slab of a pool.
    pub fn page_elems(&self) -> usize {
        self.manifest.page_elems()
    }

    /// Flat offset of (layer, page) in a pool.
    pub fn page_offset(&self, layer: usize, page: u32) -> usize {
        self.manifest.page_offset(layer, page)
    }
}

#[cfg(feature = "pjrt")]
fn argmax(xs: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as u32
}

/// Stub model used when the crate is built WITHOUT the `pjrt` feature: the
/// API surface of the real [`PjrtModel`] with `load` (and every execution
/// entry point) returning an explanatory error. Keeps the server, examples
/// and integration tests compiling on images without an XLA toolchain; the
/// artifact-gated tests skip themselves at runtime.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtModel {
    /// Parsed `model_config.json` shapes.
    pub manifest: ModelManifest,
    /// Host-resident paged K pool (unused in the stub).
    pub k_pool: Vec<f32>,
    /// Host-resident paged V pool (unused in the stub).
    pub v_pool: Vec<f32>,
}

#[cfg(not(feature = "pjrt"))]
impl PjrtModel {
    /// Always fails: the binary was built without PJRT support.
    pub fn load(_dir: &Path) -> Result<Self> {
        bail!(
            "this binary was built without the `pjrt` feature; \
             rebuild with `cargo build --features pjrt` (requires the xla-rs \
             toolchain) to serve the real model, or use the simulator paths \
             (`justitia run` / `justitia cluster` / `justitia experiment`)"
        )
    }

    /// Stub platform label.
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Smallest compiled decode batch >= n (from the manifest).
    pub fn decode_batch_for(&self, n: usize) -> Result<usize> {
        self.manifest
            .decode_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .with_context(|| format!("no decode variant fits batch {n}"))
    }

    /// Largest decode batch the manifest declares.
    pub fn max_decode_batch(&self) -> usize {
        self.manifest.decode_batches.iter().copied().max().unwrap_or(1)
    }

    /// Always fails in the stub.
    pub fn prefill(&mut self, _tokens: &[u32], _block_table: &[u32]) -> Result<u32> {
        bail!("pjrt feature disabled")
    }

    /// Always fails in the stub.
    pub fn decode(&mut self, _seqs: &[(u32, u32, Vec<u32>)]) -> Result<Vec<u32>> {
        bail!("pjrt feature disabled")
    }

    /// Elements in one (layer, page) slab of a pool.
    pub fn page_elems(&self) -> usize {
        self.manifest.page_elems()
    }

    /// Flat offset of (layer, page) in a pool.
    pub fn page_offset(&self, layer: usize, page: u32) -> usize {
        self.manifest.page_offset(layer, page)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0); // first wins ties
    }

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(ModelManifest::load(Path::new("/nonexistent-artifacts")).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_explains_missing_feature() {
        let err = PjrtModel::load(Path::new("/nonexistent-artifacts")).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
