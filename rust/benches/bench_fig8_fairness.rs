//! Fig. 8 — CDF of finish-time fair ratios (per-agent JCT normalized by its
//! JCT under VTC) at 3× density.
//!
//! Paper: 92% of agents complete under Justitia no later than under VTC;
//! worst-case delay 26%.

use justitia::util::bench::{section, ResultsFile};
use justitia::util::stats;

fn main() {
    section("Fig. 8: CDF of finish-time fair ratios vs VTC (3x density)");
    let mut out = ResultsFile::new("bench_fig8.txt");
    let r = justitia::experiments::fig8(300, 3.0, 42);
    out.line(format!(
        "{:<10} {:>12} {:>12} {:>18}",
        "policy", "not-delayed", "worst-delay", "avg-delay(delayed)"
    ));
    for (p, frac, worst, avg) in &r.summaries {
        out.line(format!(
            "{:<10} {:>11.1}% {:>11.1}% {:>17.1}%",
            p.name(),
            frac * 100.0,
            worst,
            avg
        ));
    }
    out.line(String::new());
    out.line("CDF series (ratio at cumulative probability):".to_string());
    out.line(format!(
        "{:<10} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7}",
        "policy", "p10", "p25", "p50", "p75", "p90", "p99"
    ));
    for (p, rs) in &r.ratios {
        let q = |x: f64| stats::percentile_sorted(rs, x);
        out.line(format!(
            "{:<10} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>7.2}",
            p.name(),
            q(10.0),
            q(25.0),
            q(50.0),
            q(75.0),
            q(90.0),
            q(99.0)
        ));
    }
    out.line("(paper: Justitia 92% not delayed, worst 26%; SRJF decent median, starved tail)".to_string());
}
