//! Inference-level Shortest-Job-First with predicted durations — the
//! vLLM-SJF baseline (paper baseline (b), after Shahout et al. 2025).
//! Near-optimal mean latency at the inference level; starves long requests.

use crate::config::Policy;
use crate::sched::{AgentInfo, OrdF64, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Inference-level SJF scheduler state.
pub struct Sjf {
    /// Min-heap on (predicted duration, submission seq).
    heap: BinaryHeap<Reverse<(OrdF64, u64, TaskKey)>>,
    tasks: HashMap<TaskKey, TaskInfo>,
    agent_pred: HashMap<AgentId, f64>,
}

type TaskKey = (u32, u32);

fn key(t: &TaskInfo) -> TaskKey {
    (t.id.agent, t.id.index)
}

impl Sjf {
    /// Empty scheduler.
    pub fn new() -> Self {
        Sjf { heap: BinaryHeap::new(), tasks: HashMap::new(), agent_pred: HashMap::new() }
    }

    /// Predicted inference duration: dominated by decode length (one token
    /// per iteration), plus a prefill term.
    fn duration(t: &TaskInfo) -> f64 {
        t.predicted_decode + t.prompt_tokens as f64 / 256.0
    }
}

impl Default for Sjf {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Sjf {
    fn policy(&self) -> Policy {
        Policy::Sjf
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, _now: f64) {
        self.agent_pred.insert(info.id, info.cost);
    }

    fn push_task(&mut self, task: TaskInfo, _now: f64) {
        self.heap.push(Reverse((OrdF64(Self::duration(&task)), task.seq, key(&task))));
        self.tasks.insert(key(&task), task);
    }

    fn pop_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let Reverse((_, _, k)) = self.heap.pop()?;
        self.tasks.remove(&k)
    }

    fn peek_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let &Reverse((_, _, k)) = self.heap.peek()?;
        self.tasks.get(&k).copied()
    }

    fn waiting_len(&self) -> usize {
        self.heap.len()
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        // Preempt the agent with the largest predicted total first.
        self.agent_pred.get(&agent).copied().unwrap_or(f64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn task(agent: u32, index: u32, seq: u64, decode: f64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 100, predicted_decode: decode, seq }
    }

    #[test]
    fn shortest_first() {
        let mut s = Sjf::new();
        s.push_task(task(1, 0, 0, 300.0), 0.0);
        s.push_task(task(2, 0, 1, 20.0), 0.0);
        s.push_task(task(3, 0, 2, 80.0), 0.0);
        let order: Vec<u32> = (0..3).map(|_| s.pop_next(0.0).unwrap().id.agent).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn ties_break_by_seq() {
        let mut s = Sjf::new();
        s.push_task(task(1, 0, 5, 50.0), 0.0);
        s.push_task(task(2, 0, 3, 50.0), 0.0);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 2);
    }

    #[test]
    fn starvation_shape() {
        // A stream of short tasks starves the long one — the failure mode
        // Fig. 9 demonstrates (for SRJF at the agent level).
        let mut s = Sjf::new();
        s.push_task(task(99, 0, 0, 1000.0), 0.0);
        for i in 0..20 {
            s.push_task(task(i, 0, (i + 1) as u64, 10.0), 0.0);
        }
        for _ in 0..20 {
            assert_ne!(s.pop_next(0.0).unwrap().id.agent, 99);
        }
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 99);
    }
}
