//! Property test for the event calendar queue (ISSUE 6): pops come out
//! globally time-ordered, and equal-time events pop in insertion order
//! (stable tie-break on the monotone sequence number) — for EVERY insertion
//! permutation of the same multiset of timestamps. This is the determinism
//! foundation of the event core: replaying the same pushes always drains
//! the same schedule.

use justitia::engine::event::{EventKind, EventQueue};
use justitia::util::prop::{check, Config as PropConfig, Strategy, U64Range, VecOf};
use justitia::util::rng::Rng;

/// Timestamps drawn from a tiny lattice (multiples of 0.5) so ties are
/// frequent, not incidental.
fn times_of(raw: &[u64]) -> Vec<f64> {
    raw.iter().map(|&x| x as f64 * 0.5).collect()
}

/// Drain the queue after pushing `times` in the given order; return the
/// popped `(time, slot)` pairs, where `slot` is the push position.
fn drain_after_pushing(times: &[f64]) -> Vec<(f64, u32)> {
    let mut q = EventQueue::new();
    for (i, &t) in times.iter().enumerate() {
        q.push(t, EventKind::Admission { slot: i as u32 });
    }
    assert_eq!(q.len(), times.len());
    let mut out = Vec::with_capacity(times.len());
    while let Some(ev) = q.pop() {
        let EventKind::Admission { slot } = ev.kind;
        out.push((ev.time, slot));
    }
    out
}

/// The specification: a STABLE sort of the pushed events by time. Slots are
/// push positions, so stability = "ties pop in insertion order".
fn stable_reference(times: &[f64]) -> Vec<(f64, u32)> {
    let mut want: Vec<(f64, u32)> = times.iter().enumerate().map(|(i, &t)| (t, i as u32)).collect();
    want.sort_by(|a, b| a.0.total_cmp(&b.0)); // sort_by is stable
    want
}

#[test]
fn prop_pops_are_time_ordered_and_ties_are_insertion_stable() {
    let cases = std::env::var("JUSTITIA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let cfg = PropConfig { cases, seed: 0xca1e_da12, max_shrink_steps: 200 };
    let strat = VecOf { inner: U64Range { lo: 0, hi: 9 }, min_len: 1, max_len: 40 };
    check(&cfg, &strat, |raw| {
        let times = times_of(raw);
        let got = drain_after_pushing(&times);
        let want = stable_reference(&times);
        if got != want {
            return Err(format!("pop order {got:?} != stable sort {want:?}"));
        }
        Ok(())
    });
}

/// Permutation invariance of the *guarantee* (not the schedule): under any
/// insertion permutation, pops are still globally time-ordered with ties in
/// that permutation's own insertion order — i.e. the stable-sort spec holds
/// for every ordering of the same timestamp multiset.
#[derive(Clone, Debug)]
struct PermutedDraw {
    raw: Vec<u64>,
    shuffle_seed: u64,
}

struct PermutedStrategy;

impl Strategy for PermutedStrategy {
    type Value = PermutedDraw;
    fn generate(&self, rng: &mut Rng) -> PermutedDraw {
        let len = rng.range_u64(2, 30) as usize;
        PermutedDraw {
            raw: (0..len).map(|_| rng.range_u64(0, 6)).collect(),
            shuffle_seed: rng.next_u64(),
        }
    }
    fn shrink(&self, v: &PermutedDraw) -> Vec<PermutedDraw> {
        let mut out = Vec::new();
        if v.raw.len() > 2 {
            let mut w = v.clone();
            w.raw.pop();
            out.push(w);
        }
        out
    }
}

#[test]
fn prop_every_insertion_permutation_satisfies_the_stable_spec() {
    let cases = std::env::var("JUSTITIA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let cfg = PropConfig { cases, seed: 0x5eed_e7e2, max_shrink_steps: 100 };
    check(&cfg, &PermutedStrategy, |draw| {
        let base = times_of(&draw.raw);
        let mut shuffler = Rng::new(draw.shuffle_seed);
        let mut permutations = vec![base.clone()];
        let mut rev = base.clone();
        rev.reverse();
        permutations.push(rev);
        for _ in 0..3 {
            let mut p = base.clone();
            shuffler.shuffle(&mut p);
            permutations.push(p);
        }
        for perm in &permutations {
            let got = drain_after_pushing(perm);
            let want = stable_reference(perm);
            if got != want {
                return Err(format!(
                    "permutation {perm:?}: pop order {got:?} != stable sort {want:?}"
                ));
            }
            // Global time order, stated directly as well.
            for w in got.windows(2) {
                if w[1].0 < w[0].0 {
                    return Err(format!("time went backwards: {:?} then {:?}", w[0], w[1]));
                }
            }
        }
        Ok(())
    });
}

/// Interleaved push/pop keeps the invariant for what remains in the queue:
/// after any prefix of pushes, popping k events yields the k stably-least.
#[test]
fn prop_interleaved_pops_return_the_stably_least_prefix() {
    let cases = std::env::var("JUSTITIA_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let cfg = PropConfig { cases, seed: 0x1a7e_9001, max_shrink_steps: 100 };
    let strat = VecOf { inner: U64Range { lo: 0, hi: 7 }, min_len: 4, max_len: 24 };
    check(&cfg, &strat, |raw| {
        let times = times_of(raw);
        let half = times.len() / 2;
        let mut q = EventQueue::new();
        for (i, &t) in times[..half].iter().enumerate() {
            q.push(t, EventKind::Admission { slot: i as u32 });
        }
        // Model the queue contents as (time, seq) pairs; seq == push index
        // because pushes here are the only source of sequence numbers.
        let mut model: Vec<(f64, u32)> =
            times[..half].iter().enumerate().map(|(i, &t)| (t, i as u32)).collect();
        model.sort_by(|a, b| a.0.total_cmp(&b.0));
        for _ in 0..half / 2 {
            let ev = q.pop().expect("model says non-empty");
            let EventKind::Admission { slot } = ev.kind;
            let want = model.remove(0);
            if (ev.time, slot) != want {
                return Err(format!("mid-stream pop {:?} != {:?}", (ev.time, slot), want));
            }
        }
        for (i, &t) in times[half..].iter().enumerate() {
            q.push(t, EventKind::Admission { slot: (half + i) as u32 });
            model.push((t, (half + i) as u32));
        }
        model.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        while let Some(ev) = q.pop() {
            let EventKind::Admission { slot } = ev.kind;
            let want = model.remove(0);
            if (ev.time, slot) != want {
                return Err(format!("drain pop {:?} != {:?}", (ev.time, slot), want));
            }
        }
        if !model.is_empty() {
            return Err(format!("queue drained early; model still has {model:?}"));
        }
        Ok(())
    });
}
