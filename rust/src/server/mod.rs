//! Request front-end: a minimal HTTP/1.1 server exposing the serving engine
//! (the image has no web-framework crates; the parser lives in [`http`]).

pub mod http;
