//! Descriptive statistics used by the metrics layer and the bench harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, `q` in [0, 100]. Sorts a copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = (q / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median shorthand.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Empirical CDF evaluated at `n_points` evenly spaced quantiles; returns
/// `(value, cumulative_probability)` pairs, ready to plot (Fig. 8).
pub fn cdf_points(xs: &[f64], n_points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || n_points == 0 {
        return Vec::new();
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    let mut out = Vec::with_capacity(n_points);
    for i in 0..n_points {
        let p = (i + 1) as f64 / n_points as f64;
        let idx = ((p * n as f64).ceil() as usize).clamp(1, n) - 1;
        out.push((v[idx], p));
    }
    out
}

/// Fraction of samples with value <= `x`.
pub fn cdf_at(xs: &[f64], x: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

/// Fixed-width histogram over [lo, hi] with `bins` buckets; out-of-range
/// samples clamp into the first/last bucket. Returns per-bucket counts.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut counts = vec![0usize; bins.max(1)];
    if xs.is_empty() || hi <= lo {
        return counts;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = (((x - lo) / w).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        counts[idx] += 1;
    }
    counts
}

/// Online mean/variance accumulator (Welford). Used on hot paths where we do
/// not want to buffer every sample (e.g. scheduling-delay tracking, Fig. 12).
#[derive(Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    fn default() -> Self {
        Self::new()
    }
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add a sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Population variance (0.0 below two samples).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample (0.0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample (0.0 when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Combine another accumulator into this one (Chan et al. parallel
    /// update) — used to merge per-replica metrics into cluster totals.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (na, nb) = (self.n as f64, other.n as f64);
        let delta = other.mean - self.mean;
        let n = na + nb;
        self.m2 += other.m2 + delta * delta * na * nb / n;
        self.mean += delta * nb / n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.n += other.n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert!(cdf_points(&[], 10).is_empty());
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn percentile_single_sample() {
        assert_eq!(percentile(&[3.25], 90.0), 3.25);
    }

    #[test]
    fn cdf_monotone() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        let pts = cdf_points(&xs, 5);
        assert_eq!(pts.len(), 5);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert_eq!(pts.last().unwrap().0, 5.0);
    }

    #[test]
    fn cdf_at_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((cdf_at(&xs, 2.5) - 0.5).abs() < 1e-12);
        assert_eq!(cdf_at(&xs, 0.0), 0.0);
        assert_eq!(cdf_at(&xs, 10.0), 1.0);
    }

    #[test]
    fn welford_merge_matches_sequential() {
        let xs: Vec<f64> = (0..40).map(|i| (i as f64) * 0.7 - 3.0).collect();
        let mut all = Welford::new();
        xs.iter().for_each(|&x| all.push(x));
        let mut a = Welford::new();
        let mut b = Welford::new();
        xs[..15].iter().for_each(|&x| a.push(x));
        xs[15..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        // Merging an empty accumulator is a no-op in both directions.
        let mut e = Welford::new();
        e.merge(&all);
        assert!((e.mean() - all.mean()).abs() < 1e-12);
        all.merge(&Welford::new());
        assert_eq!(all.count(), 40);
    }

    #[test]
    fn histogram_clamps() {
        let xs = [-1.0, 0.1, 0.5, 0.9, 2.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        // -1.0 clamps into bucket 0; 0.5 lands on the boundary → bucket 1;
        // 2.0 clamps into bucket 1.
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
        assert_eq!(w.count(), 8);
    }
}
