//! Table 1 — per-class MLP vs shared-model (Distillbert-style) prediction:
//! relative error, inference overhead, end-to-end JCT under Justitia, and
//! training time (2× workload density).
//!
//! Paper: MLP 53.0% err / 2.16 ms / 151.1 s JCT / ~1 min train;
//! Distillbert 452% / 55.7 ms / 366.7 s / ~2 h.

use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Table 1: MLP vs shared-model prediction (2x density)");
    let mut out = ResultsFile::new("bench_table1.txt");
    let rows = justitia::experiments::table1(300, 2.0, 100, 42);
    out.line(format!(
        "{:<32} {:>9} {:>10} {:>9} {:>9}",
        "model", "rel-err", "infer", "avgJCT", "train"
    ));
    for r in &rows {
        out.line(format!(
            "{:<32} {:>8.1}% {:>8.2}ms {:>8.1}s {:>8.1}s",
            r.model, r.rel_error_pct, r.infer_ms, r.avg_jct, r.train_secs
        ));
    }
    out.line("(paper: MLP 53.0% / 2.16 ms / 151.1 s / ~1 min; Distillbert 452% / 55.7 ms / 366.7 s / ~2 h)".to_string());
}
