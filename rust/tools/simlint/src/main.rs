//! simlint CLI: `cargo run -p simlint [-- --root <src> --manifest <file>]`.
//!
//! Exits 0 when the tree has zero unannotated violations, 1 otherwise
//! (stale annotations warn but do not fail the gate; an `--strict-stale`
//! flag upgrades them). Defaults resolve relative to this crate's own
//! manifest dir, so the bare invocation from anywhere in the workspace
//! lints `rust/src` against the committed knob manifest.

use simlint::{run, Options};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut manifest: Option<PathBuf> = None;
    let mut skip_manifest = false;
    let mut strict_stale = false;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--manifest" => manifest = args.next().map(PathBuf::from),
            "--no-manifest" => skip_manifest = true,
            "--strict-stale" => strict_stale = true,
            "--help" | "-h" => {
                println!(
                    "simlint — determinism-contract lint (DESIGN.md §16)\n\n\
                     USAGE: simlint [--root DIR] [--manifest FILE | --no-manifest] [--strict-stale]\n\n\
                     Rules: unordered-iter, ambient-nondet, nan-order, knob-default.\n\
                     Suppress a site with `// simlint::allow(<rule>): <justification>`."
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("simlint: unknown argument `{other}` (try --help)");
                return ExitCode::FAILURE;
            }
        }
    }

    let tool_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let root = root.unwrap_or_else(|| tool_dir.join("../../src"));
    let manifest = if skip_manifest {
        None
    } else {
        Some(manifest.unwrap_or_else(|| tool_dir.join("knob_defaults.manifest")))
    };

    let report = match run(&Options { root, manifest }) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simlint: {e}");
            return ExitCode::FAILURE;
        }
    };

    for d in &report.violations {
        println!("{}", d.render());
    }
    for d in &report.stale {
        println!("{} (warning)", d.render());
    }
    println!("{}", report.summary());

    if report.violations.is_empty() && (!strict_stale || report.stale.is_empty()) {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
