// Fixture: knob defaults in lockstep with the manifest.
pub struct Config {
    pub fairness: bool,
    pub max_batch: u32,
    pub backend: Backend,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            fairness: false,
            max_batch: 64,
            backend: Backend::default(),
        }
    }
}
