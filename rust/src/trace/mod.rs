//! Flight-recorder tracing, fairness telemetry, and the scheduler decision
//! audit log (DESIGN.md §13).
//!
//! Three bounded artifacts, all recorded on the engine clock (never wall
//! time, so both engine cores emit identical streams by construction):
//!
//! 1. A ring-buffer **flight recorder** of structured lifecycle events
//!    ([`TraceEvent`]): arrival → admission/blocked → prefill chunk →
//!    decode batch → preempt{swap, recompute} → spawn → complete.
//! 2. A **per-iteration sampler** ([`IterSample`], every `sample_stride`-th
//!    iteration): batch occupancy, token-budget utilization, KV gauges,
//!    queue depths, per-agent virtual-time lag, and the realized-vs-GPS max
//!    service gap — the paper's fairness bound rendered as a live signal.
//! 3. A **scheduler decision audit log** ([`PickDecision`], one per
//!    head-of-line admission): winning tag, runner-up tag, pamper status —
//!    so "why did Justitia starve client 3 at t=41s?" is answerable from
//!    the artifact.
//!
//! Everything is bounded: each stream is a ring of at most `cap` entries
//! with a drop counter, so a week-long server run costs O(cap) memory. The
//! [`chrome_trace`] exporter renders recorders (one per replica) as Chrome
//! trace-event / Perfetto JSON: one process track per replica, one thread
//! row per agent, counter tracks for the sampled series.

use crate::util::json::{obj, Json};
use crate::workload::AgentId;
use std::collections::VecDeque;

/// Sentinel agent id for engine-level rows (decode-batch summaries): never
/// assigned to a real agent (`Suite` re-indexing starts at 0 and the
/// cluster dispatcher also reserves `AgentId::MAX` as its GPS probe).
pub const ENGINE_ROW: AgentId = AgentId::MAX;

/// What happened, with event-specific payload. Variant order follows the
/// lifecycle: arrival → admission/blocked → prefill → decode → preemption →
/// re-entry → spawn → completion.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEventKind {
    /// Agent submitted (scheduler saw `on_agent_arrival`).
    Arrival,
    /// Task admitted into the running batch (KV acquired).
    Admitted,
    /// Head-of-line task failed KV admission; the queue is now gated.
    Blocked,
    /// A prefill chunk of `tokens` prompt tokens ran this iteration.
    PrefillChunk {
        /// Prompt tokens prefilled for this sequence this iteration.
        tokens: u32,
    },
    /// A decode batch of `seqs` sequences retired (engine row, emitted on
    /// sampled iterations only — see DESIGN.md §13 overhead model).
    DecodeBatch {
        /// Decoding sequences in the retired batch.
        seqs: u32,
    },
    /// The sequence emitted its first output token (TTFT edge).
    FirstToken,
    /// Preempted: KV swapped out to the host pool.
    PreemptSwap,
    /// Preempted: KV discarded for recompute.
    PreemptRecompute {
        /// KV tokens discarded (all must be re-prefilled at re-entry).
        dropped_tokens: u64,
    },
    /// Swapped-out sequence re-entered the running batch.
    SwapIn,
    /// Recompute-preempted sequence re-entered as a fresh prefill.
    RecomputeReady,
    /// Task completion spawned this child task (DAG workloads).
    Spawn,
    /// Task finished decoding and released its KV.
    TaskComplete,
    /// All tasks of the agent finished.
    Complete,
    /// This replica crashed: device+host KV lost, in-flight agents recovered
    /// (engine row; churn runs only, DESIGN.md §14).
    ReplicaCrash,
    /// This replica began a graceful drain: no new placements (engine row).
    ReplicaDrain,
    /// This replica (re)joined the pool (engine row).
    ReplicaJoin,
    /// A crash-recovered agent was re-placed on this replica with its
    /// generated tokens folded into the prompt (agent row).
    Recovered,
}

impl TraceEventKind {
    /// Stable lowercase name (JSON export, Perfetto event names).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEventKind::Arrival => "arrival",
            TraceEventKind::Admitted => "admitted",
            TraceEventKind::Blocked => "blocked",
            TraceEventKind::PrefillChunk { .. } => "prefill_chunk",
            TraceEventKind::DecodeBatch { .. } => "decode_batch",
            TraceEventKind::FirstToken => "first_token",
            TraceEventKind::PreemptSwap => "preempt_swap",
            TraceEventKind::PreemptRecompute { .. } => "preempt_recompute",
            TraceEventKind::SwapIn => "swap_in",
            TraceEventKind::RecomputeReady => "recompute_ready",
            TraceEventKind::Spawn => "spawn",
            TraceEventKind::TaskComplete => "task_complete",
            TraceEventKind::Complete => "complete",
            TraceEventKind::ReplicaCrash => "replica_crash",
            TraceEventKind::ReplicaDrain => "replica_drain",
            TraceEventKind::ReplicaJoin => "replica_join",
            TraceEventKind::Recovered => "recovered",
        }
    }
}

/// One flight-recorder entry: a lifecycle event stamped with the engine
/// clock.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Engine time (s).
    pub t: f64,
    /// Owning agent ([`ENGINE_ROW`] for engine-level events).
    pub agent: AgentId,
    /// Task index within the agent, when the event is task-scoped.
    pub task: Option<u32>,
    /// What happened.
    pub kind: TraceEventKind,
}

/// One per-iteration telemetry sample (every `sample_stride`-th iteration).
#[derive(Debug, Clone, PartialEq)]
pub struct IterSample {
    /// Engine time (s) at the end of the sampled iteration.
    pub t: f64,
    /// Iteration ordinal (1-based, as counted by the metrics).
    pub iteration: u64,
    /// Sequences in the iteration's batch (prefills + decoders).
    pub batch_seqs: u32,
    /// Tokens the batch ran (prefill tokens + one per decoder).
    pub batch_tokens: u64,
    /// `batch_tokens / max_batched_tokens` (0 when chunking is off — the
    /// budget is unbounded there, so utilization is undefined).
    pub token_budget_util: f64,
    /// Device KV pages free.
    pub kv_free_pages: u64,
    /// KV tokens swapped to host.
    pub kv_swapped_tokens: u64,
    /// Host swap-pool slots still free (`u64::MAX` = unbounded pool).
    pub kv_host_free_tokens: u64,
    /// Tasks waiting in the scheduler.
    pub waiting: u64,
    /// Running sequences.
    pub running: u64,
    /// Swapped-out sequences awaiting swap-in.
    pub swapped_q: u64,
    /// Recompute-preempted sequences awaiting re-entry.
    pub recompute_q: u64,
    /// Per-active-agent virtual-time lag `V(t) − F_j` (sorted by agent id;
    /// positive ⇒ GPS would already have finished the agent, i.e. the real
    /// system is behind the fluid yardstick for it). Empty for schedulers
    /// without a virtual clock.
    pub vt_lags: Vec<(AgentId, f64)>,
    /// `max(0, max_j V(t) − F_j)` over active agents — the realized-vs-GPS
    /// service gap the paper's fairness bound caps.
    pub max_service_gap: f64,
}

/// One scheduler decision audit entry: why this head-of-line task won.
#[derive(Debug, Clone, PartialEq)]
pub struct PickDecision {
    /// Engine time (s) of the admission decision.
    pub t: f64,
    /// The winning agent.
    pub agent: AgentId,
    /// The winning task's index within the agent.
    pub task_index: u32,
    /// The winner's virtual finish tag F_j (`None` for tag-free policies).
    pub winner_tag: Option<f64>,
    /// The best losing agent, when the scheduler can name one.
    pub runner_up: Option<AgentId>,
    /// The runner-up's virtual finish tag.
    pub runner_up_tag: Option<f64>,
    /// Whether this pick continues saturated consecutive service of the
    /// winning agent (selective pampering: more of its tasks still wait).
    pub pampered: bool,
}

/// One batch-policy controller adjustment (DESIGN.md §15): the engine
/// records these alongside [`PickDecision`]s so the Chrome trace shows *why*
/// the prefill share moved next to *which* prefills then won it — one
/// audit schema across both decision kinds.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchDecision {
    /// Engine time (s) of the iteration that applied the new share.
    pub t: f64,
    /// The policy that moved (`BatchPolicy::name`).
    pub policy: &'static str,
    /// Prefill share of the token budget after the adjustment.
    pub prefill_share: f64,
    /// The share in tokens at the current budget.
    pub prefill_tokens: u32,
    /// Windowed p99 ITL (ms) that triggered the move.
    pub itl_p99_ms: f64,
    /// True = the share grew (TTFT pressure), false = shrank (ITL breach).
    pub grew: bool,
}

/// The explanation a [`Scheduler`](crate::sched::Scheduler) returns for a
/// head-of-line pick (see `Scheduler::explain_pick`). Split from
/// [`PickDecision`] so schedulers need not know the engine clock or task
/// identity — the engine fills those in. Batch-policy audit entries
/// ([`BatchDecision`]) deliberately mirror this typed-struct shape so both
/// decision streams export through the same instant-event schema.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PickExplanation {
    /// The winner's virtual finish tag, if the policy keeps one.
    pub winner_tag: Option<f64>,
    /// The best losing agent, if the policy can name one.
    pub runner_up: Option<AgentId>,
    /// The runner-up's tag.
    pub runner_up_tag: Option<f64>,
    /// Whether the pick continues saturated service of the winning agent.
    pub pampered: bool,
}

/// Bounded flight recorder + sampler + audit log for one engine.
///
/// All three streams are rings: when `cap` is reached the oldest entry is
/// dropped and the matching drop counter incremented, so the artifact
/// always says how much history it lost. Equality compares full recorded
/// state (streams + drop counters) — the trace-identity property test
/// compares recorders across engine cores directly.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecorder {
    cap: usize,
    sample_stride: u32,
    /// Iterations seen so far (sampling phase counter).
    iter_count: u64,
    events: VecDeque<TraceEvent>,
    dropped_events: u64,
    samples: VecDeque<IterSample>,
    dropped_samples: u64,
    picks: VecDeque<PickDecision>,
    dropped_picks: u64,
    batches: VecDeque<BatchDecision>,
    dropped_batches: u64,
}

impl TraceRecorder {
    /// Recorder with ring capacity `cap` (entries per stream) sampling every
    /// `sample_stride`-th iteration. Both are clamped to at least 1.
    pub fn new(cap: usize, sample_stride: u32) -> Self {
        TraceRecorder {
            cap: cap.max(1),
            sample_stride: sample_stride.max(1),
            iter_count: 0,
            events: VecDeque::new(),
            dropped_events: 0,
            samples: VecDeque::new(),
            dropped_samples: 0,
            picks: VecDeque::new(),
            dropped_picks: 0,
            batches: VecDeque::new(),
            dropped_batches: 0,
        }
    }

    /// Record a lifecycle event.
    pub fn push(&mut self, t: f64, agent: AgentId, task: Option<u32>, kind: TraceEventKind) {
        if self.events.len() >= self.cap {
            self.events.pop_front();
            self.dropped_events += 1;
        }
        self.events.push_back(TraceEvent { t, agent, task, kind });
    }

    /// Count one engine iteration; `true` when this iteration should be
    /// sampled (every `sample_stride`-th, starting with the first).
    pub fn tick_iteration(&mut self) -> bool {
        let due = self.iter_count % self.sample_stride as u64 == 0;
        self.iter_count += 1;
        due
    }

    /// Record a telemetry sample.
    pub fn push_sample(&mut self, sample: IterSample) {
        if self.samples.len() >= self.cap {
            self.samples.pop_front();
            self.dropped_samples += 1;
        }
        self.samples.push_back(sample);
    }

    /// Record a scheduler decision audit entry.
    pub fn push_pick(&mut self, pick: PickDecision) {
        if self.picks.len() >= self.cap {
            self.picks.pop_front();
            self.dropped_picks += 1;
        }
        self.picks.push_back(pick);
    }

    /// Record a batch-policy adjustment audit entry.
    pub fn push_batch(&mut self, decision: BatchDecision) {
        if self.batches.len() >= self.cap {
            self.batches.pop_front();
            self.dropped_batches += 1;
        }
        self.batches.push_back(decision);
    }

    /// Ring capacity per stream.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Sampling stride (iterations per sample).
    pub fn sample_stride(&self) -> u32 {
        self.sample_stride
    }

    /// Retained lifecycle events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter()
    }

    /// Retained telemetry samples, oldest first.
    pub fn samples(&self) -> impl Iterator<Item = &IterSample> {
        self.samples.iter()
    }

    /// Retained audit entries, oldest first.
    pub fn picks(&self) -> impl Iterator<Item = &PickDecision> {
        self.picks.iter()
    }

    /// Lifecycle events evicted by the ring.
    pub fn dropped_events(&self) -> u64 {
        self.dropped_events
    }

    /// Samples evicted by the ring.
    pub fn dropped_samples(&self) -> u64 {
        self.dropped_samples
    }

    /// Audit entries evicted by the ring.
    pub fn dropped_picks(&self) -> u64 {
        self.dropped_picks
    }

    /// Retained event count (≤ `cap`).
    pub fn event_count(&self) -> usize {
        self.events.len()
    }

    /// Retained sample count (≤ `cap`).
    pub fn sample_count(&self) -> usize {
        self.samples.len()
    }

    /// Retained audit-entry count (≤ `cap`).
    pub fn pick_count(&self) -> usize {
        self.picks.len()
    }

    /// Retained batch-policy adjustments, oldest first.
    pub fn batch_decisions(&self) -> impl Iterator<Item = &BatchDecision> {
        self.batches.iter()
    }

    /// Batch-policy adjustments evicted by the ring.
    pub fn dropped_batches(&self) -> u64 {
        self.dropped_batches
    }

    /// Retained batch-adjustment count (≤ `cap`).
    pub fn batch_count(&self) -> usize {
        self.batches.len()
    }
}

/// Seconds → Chrome trace-event microseconds.
fn us(t: f64) -> Json {
    Json::Num(t * 1e6)
}

fn event_args(kind: &TraceEventKind) -> Json {
    match kind {
        TraceEventKind::PrefillChunk { tokens } => {
            obj([("tokens", Json::Num(*tokens as f64))])
        }
        TraceEventKind::DecodeBatch { seqs } => obj([("seqs", Json::Num(*seqs as f64))]),
        TraceEventKind::PreemptRecompute { dropped_tokens } => {
            obj([("dropped_tokens", Json::Num(*dropped_tokens as f64))])
        }
        _ => obj([]),
    }
}

fn instant(name: &str, pid: u32, tid: AgentId, t: f64, args: Json) -> Json {
    obj([
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("t".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", us(t)),
        ("args", args),
    ])
}

fn counter(name: &str, pid: u32, t: f64, args: Json) -> Json {
    obj([
        ("name", Json::Str(name.into())),
        ("ph", Json::Str("C".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("ts", us(t)),
        ("args", args),
    ])
}

fn metadata(name: &str, pid: u32, tid: Option<AgentId>, label: String) -> Json {
    let mut fields = vec![
        ("name".to_string(), Json::Str(name.into())),
        ("ph".to_string(), Json::Str("M".into())),
        ("pid".to_string(), Json::Num(pid as f64)),
        ("args".to_string(), obj([("name", Json::Str(label))])),
    ];
    if let Some(tid) = tid {
        fields.push(("tid".to_string(), Json::Num(tid as f64)));
    }
    Json::Obj(fields.into_iter().collect())
}

/// Render recorders as Chrome trace-event / Perfetto JSON.
///
/// `parts` is one `(pid, label, recorder)` per track — a replica in cluster
/// runs, a policy in side-by-side experiment dumps. Each part becomes a
/// process with one thread row per agent (plus an `engine` row for
/// batch-level events), `i`-phase instants for lifecycle events and
/// scheduler picks, `X`-phase spans covering each agent's arrival→complete
/// lifetime, and `C`-phase counter tracks for the sampled series.
/// Timestamps are engine seconds scaled to microseconds. The result loads
/// directly in `chrome://tracing` / [ui.perfetto.dev](https://ui.perfetto.dev).
pub fn chrome_trace(parts: &[(u32, &str, &TraceRecorder)]) -> Json {
    let mut out: Vec<Json> = Vec::new();
    for &(pid, label, rec) in parts {
        out.push(metadata("process_name", pid, None, label.to_string()));
        // Agent rows, discovered from the retained events in first-seen
        // order; spans need each agent's first and last timestamp.
        let mut order: Vec<AgentId> = Vec::new();
        let mut bounds: std::collections::HashMap<AgentId, (f64, f64)> =
            std::collections::HashMap::new();
        for e in rec.events() {
            bounds
                .entry(e.agent)
                .and_modify(|(lo, hi)| {
                    *lo = lo.min(e.t);
                    *hi = hi.max(e.t);
                })
                .or_insert_with(|| {
                    order.push(e.agent);
                    (e.t, e.t)
                });
        }
        for &agent in &order {
            let label = if agent == ENGINE_ROW {
                "engine".to_string()
            } else {
                format!("agent {agent}")
            };
            out.push(metadata("thread_name", pid, Some(agent), label.clone()));
            let (lo, hi) = bounds[&agent];
            if agent != ENGINE_ROW && hi > lo {
                out.push(obj([
                    ("name", Json::Str(label)),
                    ("cat", Json::Str("agent".into())),
                    ("ph", Json::Str("X".into())),
                    ("pid", Json::Num(pid as f64)),
                    ("tid", Json::Num(agent as f64)),
                    ("ts", us(lo)),
                    ("dur", Json::Num((hi - lo) * 1e6)),
                    ("args", obj([])),
                ]));
            }
        }
        for e in rec.events() {
            out.push(instant(e.kind.name(), pid, e.agent, e.t, event_args(&e.kind)));
        }
        for p in rec.picks() {
            let mut args = vec![
                ("pampered".to_string(), Json::Bool(p.pampered)),
                ("task_index".to_string(), Json::Num(p.task_index as f64)),
            ];
            if let Some(w) = p.winner_tag {
                args.push(("winner_tag".to_string(), Json::Num(w)));
            }
            if let Some(r) = p.runner_up {
                args.push(("runner_up".to_string(), Json::Num(r as f64)));
            }
            if let Some(rt) = p.runner_up_tag {
                args.push(("runner_up_tag".to_string(), Json::Num(rt)));
            }
            out.push(instant("pick", pid, p.agent, p.t, Json::Obj(args.into_iter().collect())));
        }
        for b in rec.batch_decisions() {
            // Batch-policy adjustments land on the engine row (they size the
            // whole iteration, not one agent) with the pick-style instant
            // schema.
            out.push(instant(
                "batch_policy",
                pid,
                ENGINE_ROW,
                b.t,
                obj([
                    ("policy", Json::Str(b.policy.into())),
                    ("prefill_share", Json::Num(b.prefill_share)),
                    ("prefill_tokens", Json::Num(b.prefill_tokens as f64)),
                    ("itl_p99_ms", Json::Num(b.itl_p99_ms)),
                    ("grew", Json::Bool(b.grew)),
                ]),
            ));
        }
        for s in rec.samples() {
            out.push(counter(
                "batch",
                pid,
                s.t,
                obj([
                    ("seqs", Json::Num(s.batch_seqs as f64)),
                    ("tokens", Json::Num(s.batch_tokens as f64)),
                    ("budget_util", Json::Num(s.token_budget_util)),
                ]),
            ));
            out.push(counter(
                "kv",
                pid,
                s.t,
                obj([
                    ("free_pages", Json::Num(s.kv_free_pages as f64)),
                    ("swapped_tokens", Json::Num(s.kv_swapped_tokens as f64)),
                    (
                        "host_free_tokens",
                        // Unbounded pools would render as 1.8e19 and flatten
                        // every other counter; Perfetto has no "infinity".
                        Json::Num(if s.kv_host_free_tokens == u64::MAX {
                            -1.0
                        } else {
                            s.kv_host_free_tokens as f64
                        }),
                    ),
                ]),
            ));
            out.push(counter(
                "queues",
                pid,
                s.t,
                obj([
                    ("waiting", Json::Num(s.waiting as f64)),
                    ("running", Json::Num(s.running as f64)),
                    ("swapped", Json::Num(s.swapped_q as f64)),
                    ("recompute", Json::Num(s.recompute_q as f64)),
                ]),
            ));
            let mut fairness = vec![(
                "max_service_gap".to_string(),
                Json::Num(s.max_service_gap),
            )];
            for &(client, lag) in &s.vt_lags {
                fairness.push((format!("vt_lag_{client}"), Json::Num(lag)));
            }
            out.push(counter("fairness", pid, s.t, Json::Obj(fairness.into_iter().collect())));
        }
        out.push(metadata(
            "process_labels",
            pid,
            None,
            format!(
                "dropped: {} events, {} samples, {} picks, {} batch decisions",
                rec.dropped_events(),
                rec.dropped_samples(),
                rec.dropped_picks(),
                rec.dropped_batches()
            ),
        ));
    }
    obj([("traceEvents", Json::Arr(out)), ("displayTimeUnit", Json::Str("ms".into()))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = TraceRecorder::new(2, 1);
        r.push(0.0, 1, None, TraceEventKind::Arrival);
        r.push(1.0, 2, None, TraceEventKind::Arrival);
        r.push(2.0, 3, None, TraceEventKind::Arrival);
        assert_eq!(r.event_count(), 2);
        assert_eq!(r.dropped_events(), 1);
        let agents: Vec<AgentId> = r.events().map(|e| e.agent).collect();
        assert_eq!(agents, vec![2, 3], "oldest entry evicted first");
    }

    #[test]
    fn stride_samples_first_then_every_nth() {
        let mut r = TraceRecorder::new(16, 4);
        let due: Vec<bool> = (0..9).map(|_| r.tick_iteration()).collect();
        assert_eq!(
            due,
            vec![true, false, false, false, true, false, false, false, true]
        );
    }

    #[test]
    fn stride_zero_clamps_to_one() {
        let mut r = TraceRecorder::new(0, 0);
        assert_eq!(r.cap(), 1);
        assert_eq!(r.sample_stride(), 1);
        assert!(r.tick_iteration());
        assert!(r.tick_iteration());
    }

    fn sample(t: f64) -> IterSample {
        IterSample {
            t,
            iteration: 1,
            batch_seqs: 2,
            batch_tokens: 34,
            token_budget_util: 0.5,
            kv_free_pages: 7,
            kv_swapped_tokens: 0,
            kv_host_free_tokens: u64::MAX,
            waiting: 3,
            running: 2,
            swapped_q: 0,
            recompute_q: 0,
            vt_lags: vec![(0, -1.0), (1, 2.0)],
            max_service_gap: 2.0,
        }
    }

    #[test]
    fn export_shape_is_chrome_trace() {
        let mut r = TraceRecorder::new(64, 1);
        r.push(0.0, 0, Some(0), TraceEventKind::Arrival);
        r.push(0.5, 0, Some(0), TraceEventKind::Admitted);
        r.push(1.0, 0, Some(0), TraceEventKind::PrefillChunk { tokens: 16 });
        r.push(2.0, 0, None, TraceEventKind::Complete);
        r.push(1.5, ENGINE_ROW, None, TraceEventKind::DecodeBatch { seqs: 3 });
        r.push_sample(sample(1.5));
        r.push_pick(PickDecision {
            t: 0.5,
            agent: 0,
            task_index: 0,
            winner_tag: Some(10.0),
            runner_up: Some(1),
            runner_up_tag: Some(12.0),
            pampered: true,
        });
        r.push_batch(BatchDecision {
            t: 1.25,
            policy: "fairbatching",
            prefill_share: 0.7,
            prefill_tokens: 1433,
            itl_p99_ms: 180.0,
            grew: false,
        });
        let json = chrome_trace(&[(0, "replica 0", &r)]);
        assert_eq!(json.get("displayTimeUnit").as_str(), Some("ms"));
        let events = json.get("traceEvents").as_arr().unwrap();
        // Reparse of the dump round-trips (the artifact is valid JSON).
        let reparsed = Json::parse(&json.dump()).unwrap();
        assert_eq!(&reparsed, &json);
        let phase = |ph: &str| {
            events.iter().filter(|e| e.get("ph").as_str() == Some(ph)).count()
        };
        assert!(phase("M") >= 3, "process + thread metadata");
        assert_eq!(phase("X"), 1, "one agent lifetime span");
        assert_eq!(phase("C"), 4, "batch/kv/queues/fairness counters");
        assert_eq!(phase("i"), 7, "five lifecycle instants + pick + batch_policy");
        // Batch-policy adjustments ride the engine row with the pick schema.
        let bp = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("batch_policy"))
            .unwrap();
        assert_eq!(bp.get("tid").as_f64(), Some(ENGINE_ROW as f64));
        assert_eq!(bp.get("args").get("policy").as_str(), Some("fairbatching"));
        assert_eq!(bp.get("args").get("prefill_share").as_f64(), Some(0.7));
        assert_eq!(bp.get("args").get("grew").as_bool(), Some(false));
        // The agent span covers arrival → complete in microseconds.
        let span = events.iter().find(|e| e.get("ph").as_str() == Some("X")).unwrap();
        assert_eq!(span.get("ts").as_f64(), Some(0.0));
        assert_eq!(span.get("dur").as_f64(), Some(2e6));
        // Unbounded host pool renders as -1, not u64::MAX.
        let kv = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("kv"))
            .unwrap();
        assert_eq!(kv.get("args").get("host_free_tokens").as_f64(), Some(-1.0));
        // Per-client virtual-time lags ride on the fairness counter.
        let fairness = events
            .iter()
            .find(|e| e.get("name").as_str() == Some("fairness"))
            .unwrap();
        assert_eq!(fairness.get("args").get("vt_lag_0").as_f64(), Some(-1.0));
        assert_eq!(fairness.get("args").get("vt_lag_1").as_f64(), Some(2.0));
    }

    #[test]
    fn recorder_equality_detects_divergence() {
        let mut a = TraceRecorder::new(8, 2);
        let mut b = TraceRecorder::new(8, 2);
        a.push(0.0, 1, Some(0), TraceEventKind::Admitted);
        b.push(0.0, 1, Some(0), TraceEventKind::Admitted);
        assert_eq!(a, b);
        b.push(1.0, 1, Some(0), TraceEventKind::FirstToken);
        assert_ne!(a, b);
        // The batch-decision ring participates in recorder equality too
        // (the trace-identity property compares recorders wholesale).
        let mut c = TraceRecorder::new(8, 2);
        let mut d = TraceRecorder::new(8, 2);
        c.push_batch(BatchDecision {
            t: 0.0,
            policy: "fairbatching",
            prefill_share: 0.5,
            prefill_tokens: 1024,
            itl_p99_ms: 200.0,
            grew: true,
        });
        assert_ne!(c, d);
        d.push_batch(c.batch_decisions().next().unwrap().clone());
        assert_eq!(c, d);
    }
}
