//! Fig. 9 — starvation avoidance: one MRS "elephant" + a sustained stream
//! of small "mice" agents (KBQAV/CC/ALFWI).
//!
//! Paper: elephant JCT grows without bound with the number of mice under
//! SRJF; bounded (flat) under Justitia.

use justitia::config::Policy;
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 9: elephant JCT vs number of mice (SRJF vs Justitia)");
    let mut out = ResultsFile::new("bench_fig9.txt");
    let counts = [0usize, 25, 50, 100, 200, 400, 800];
    let rows = justitia::experiments::fig9(&counts, 42);
    out.line(format!("{:>6} {:>12} {:>12}", "mice", "SRJF", "Justitia"));
    let jct = |p: Policy, n: usize| {
        rows.iter().find(|r| r.policy == p && r.n_mice == n).unwrap().elephant_jct
    };
    for &n in &counts {
        out.line(format!("{:>6} {:>11.1}s {:>11.1}s", n, jct(Policy::Srjf, n), jct(Policy::Justitia, n)));
    }
    out.line(format!(
        "SRJF grows {:.1}x from 0 to {} mice; Justitia {:.1}x (bounded — Thm B.1)",
        jct(Policy::Srjf, 800) / jct(Policy::Srjf, 0),
        800,
        jct(Policy::Justitia, 800) / jct(Policy::Justitia, 0)
    ));
}
