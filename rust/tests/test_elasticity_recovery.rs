//! Elasticity/recovery acceptance tests (DESIGN.md §14):
//!
//! * **Bit-identity gate** — a zero-event [`FailureSchedule`] must make
//!   `run_suite_churn` byte-identical to `run_suite_parallel` across all six
//!   schedulers: the churn subsystem is invisible until a schedule is
//!   non-empty.
//! * **Crash + rejoin** — losing a replica mid-run completes every agent,
//!   with average JCT no better than the immortal baseline (a crash destroys
//!   real work; recovery can only pay, never profit).
//! * **Drain** — graceful departure strands no agent and loses no KV.
//! * **Family re-homing** — a shared-prefix family whose `PrefixAffinity`
//!   home replica crashes re-homes on a surviving replica instead of
//!   following a dangling slot (the satellite bug fix:
//!   `Placer::on_replica_down` purges `family_home` entries).

use justitia::cluster::{ClusterDispatcher, FailureSchedule, Placement};
use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::workload::trace;

const POLICIES: [Policy; 6] =
    [Policy::Fcfs, Policy::Sjf, Policy::AgentFcfs, Policy::Vtc, Policy::Srjf, Policy::Justitia];

fn engine_for(cfg: &Config, policy: Policy) -> Engine<SimBackend> {
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
}

fn cluster_for(cfg: &Config, n: usize, policy: Policy, p: Placement) -> ClusterDispatcher<SimBackend> {
    let replicas = (0..n).map(|_| engine_for(cfg, policy)).collect();
    ClusterDispatcher::new(replicas, p, cfg.backend.kv_tokens, 1.0)
}

fn suite_of(n: usize, seed: u64) -> justitia::workload::Suite {
    let wl = WorkloadConfig { n_agents: n, seed, ..Default::default() }.with_density(3.0);
    trace::build_suite(&wl)
}

/// Everything a run observably produced, for byte-identity comparison.
fn fingerprint(m: &justitia::metrics::RunMetrics) -> (Vec<(u32, f64)>, usize, u64, u64, u64) {
    (m.jcts(), m.completed_agents(), m.iterations(), m.swap_out_count(), m.prefill_tokens_executed())
}

#[test]
fn zero_event_schedule_is_byte_identical_across_all_schedulers() {
    let cfg = Config::default();
    let suite = suite_of(40, 17);
    let model = CostModel::MemoryCentric;
    for policy in POLICIES {
        let mut base = cluster_for(&cfg, 3, policy, Placement::ClusterVtime);
        base.run_suite_parallel(&suite, |a| model.agent_cost(a), 2);
        let mut churn = cluster_for(&cfg, 3, policy, Placement::ClusterVtime);
        churn.run_suite_churn(&suite, |a| model.agent_cost(a), &FailureSchedule::none(), || {
            engine_for(&cfg, policy)
        });
        assert_eq!(
            fingerprint(&base.merged_metrics()),
            fingerprint(&churn.merged_metrics()),
            "{policy:?}: empty FailureSchedule must not perturb the immortal path"
        );
        assert_eq!(churn.churn_counters(), (0, 0, 0));
    }
}

#[test]
fn crash_and_rejoin_completes_all_and_never_beats_immortal() {
    let cfg = Config::default();
    let suite = suite_of(60, 5);
    let model = CostModel::MemoryCentric;
    for policy in [Policy::Justitia, Policy::Vtc, Policy::Fcfs] {
        let mut immortal = cluster_for(&cfg, 2, policy, Placement::ClusterVtime);
        immortal.run_suite(&suite, |a| model.agent_cost(a));
        let baseline = immortal.merged_metrics().avg_jct();

        let schedule = FailureSchedule::parse("crash@6:1,join@12").unwrap();
        let mut churn = cluster_for(&cfg, 2, policy, Placement::ClusterVtime);
        churn.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || {
            engine_for(&cfg, policy)
        });
        let m = churn.merged_metrics();
        assert_eq!(m.completed_agents(), 60, "{policy:?}: crash+rejoin lost agents");
        assert_eq!(m.replicas_lost(), 1);
        assert!(
            m.avg_jct() >= baseline - 1e-6,
            "{policy:?}: churn run (avg JCT {:.3}s) cannot beat the immortal pool \
             ({baseline:.3}s) — a crash destroys real work",
            m.avg_jct()
        );
    }
}

#[test]
fn drain_never_strands_an_agent() {
    let cfg = Config::default();
    let suite = suite_of(50, 23);
    let model = CostModel::MemoryCentric;
    for p in Placement::ALL {
        let schedule = FailureSchedule::parse("drain@5:1").unwrap();
        let mut c = cluster_for(&cfg, 3, Policy::Justitia, p);
        c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || {
            engine_for(&cfg, Policy::Justitia)
        });
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 50, "{p:?}: drain stranded agents");
        assert_eq!(c.churn_counters(), (0, 0, 0), "{p:?}: graceful drain must lose nothing");
    }
}

/// The satellite bug fix: with `PrefixAffinity`, a family's cached home
/// replica must be invalidated when that replica leaves the pool. Before the
/// fix, `family_home` kept routing the family to the dead slot.
#[test]
fn prefix_family_rehomes_after_home_replica_crashes() {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents: 24, seed: 9, ..Default::default() }
        .with_density(3.0)
        .with_shared_prefix(4, 256);
    cfg.prefix_cache = true;
    let suite = trace::build_suite(&cfg.workload);
    let model = CostModel::MemoryCentric;
    // Crash every replica but 0 early: whatever homes families had, any
    // member arriving afterwards must land on a surviving (eligible) slot.
    let schedule = FailureSchedule::parse("crash@2:1,crash@2:2").unwrap();
    let mut c = cluster_for(&cfg, 3, Policy::Justitia, Placement::PrefixAffinity);
    c.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || {
        engine_for(&cfg, Policy::Justitia)
    });
    let m = c.merged_metrics();
    assert_eq!(m.completed_agents(), 24, "family members must not follow a dead home");
    for a in &suite.agents {
        if a.arrival > 2.0 {
            assert_eq!(
                c.replica_of(a.id),
                Some(0),
                "agent {} (arrival {:.1}s) was routed to a crashed replica",
                a.id,
                a.arrival
            );
        }
    }
}

/// Virtual-time carry-over: a recovered agent's scheduler tag is its
/// original prediction scaled to the remaining work, so pampering decisions
/// survive migration. Indirect check: with Justitia, a crash must not
/// invert fairness catastrophically — the max-min spread under churn stays
/// within a small factor of the immortal run's.
#[test]
fn recovery_preserves_fairness_order_of_magnitude() {
    let cfg = Config::default();
    let suite = suite_of(60, 5);
    let model = CostModel::MemoryCentric;
    let spread = |m: &justitia::metrics::RunMetrics| {
        let jcts = m.jcts();
        let max = jcts.iter().map(|(_, j)| *j).fold(0.0f64, f64::max);
        let min = jcts.iter().map(|(_, j)| *j).fold(f64::INFINITY, f64::min);
        max / min.max(1e-9)
    };
    let mut immortal = cluster_for(&cfg, 2, Policy::Justitia, Placement::ClusterVtime);
    immortal.run_suite(&suite, |a| model.agent_cost(a));
    let base = spread(&immortal.merged_metrics());

    let schedule = FailureSchedule::parse("crash@6:1,join@12").unwrap();
    let mut churn = cluster_for(&cfg, 2, Policy::Justitia, Placement::ClusterVtime);
    churn.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || {
        engine_for(&cfg, Policy::Justitia)
    });
    let after = spread(&churn.merged_metrics());
    assert!(
        after < base * 10.0 + 10.0,
        "crash recovery blew up the JCT spread: {base:.2}x -> {after:.2}x"
    );
}
