//! PJRT runtime hot-path bench (§Perf L3/runtime): per-call prefill and
//! decode-step latency of the AOT-compiled model, the serving engine's
//! inner loop cost when driving the real backend.
//!
//! Skipped when artifacts are absent.

use justitia::runtime::PjrtModel;
use justitia::util::bench::{section, Bencher};
use std::hint::black_box;
use std::time::Duration;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("model_config.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let mut model = PjrtModel::load(dir).expect("load artifacts");
    section("PJRT runtime hot path");
    let mut b = Bencher::new().with_budget(Duration::from_secs(3));

    b.bench("prefill (1 seq, 24 tokens)", |i| {
        let toks: Vec<u32> = (0..24).map(|k| 3 + ((i + k) % 1000) as u32).collect();
        black_box(model.prefill(&toks, &[0, 1]).unwrap());
    });

    for n in [1usize, 4, 8] {
        // Pre-prefill n sequences at disjoint pages.
        for s in 0..n {
            let toks: Vec<u32> = (0..16).map(|k| 3 + (s * 31 + k) as u32).collect();
            model.prefill(&toks, &[(2 * s) as u32 + 4, (2 * s) as u32 + 5]).unwrap();
        }
        let seqs: Vec<(u32, u32, Vec<u32>)> = (0..n)
            .map(|s| (7 + s as u32, 16, vec![(2 * s) as u32 + 4, (2 * s) as u32 + 5]))
            .collect();
        b.bench(&format!("decode step (batch {n})"), |_| {
            black_box(model.decode(&seqs).unwrap());
        });
    }
}
