//! Paged KV-cache block management (the vLLM substrate, paper §2/§4.1).
//!
//! GPU KV memory is divided into fixed-size pages ("blocks" in vLLM terms) of
//! `page_size` tokens. Each running sequence holds a block table — an ordered
//! list of page ids covering its prompt + generated tokens. The allocator
//! tracks free pages, per-sequence tables, and the swap area (CPU memory) for
//! preempted sequences. This is the resource whose contention the whole paper
//! is about: the scheduler's `M` is `total_pages * page_size` token slots.
//!
//! Pages are **ref-counted**: a page may be shared by several sequences (and
//! by the radix-tree prefix cache, [`crate::prefix`]) when their prompts
//! begin with the same token content. [`BlockAllocator::share_prefix`]
//! admits a sequence on top of existing pages, [`BlockAllocator::cow_split`]
//! gives a sequence a private copy of a shared page before it is written
//! (copy-on-write), and [`BlockAllocator::retain_page`] /
//! [`BlockAllocator::release_page`] let an external cache pin pages beyond
//! any sequence's lifetime. A page returns to the free pool only when its
//! refcount reaches zero. With no sharing in play every page has refcount 1
//! and the allocator behaves exactly like the classical single-owner one.
//!
//! The free pool is a min-heap on page id, so allocation order is a pure
//! function of the operation sequence — release interleaving cannot perturb
//! which pages are handed out next (deterministic trace replay).

use crate::workload::TaskId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Page id within the device pool.
pub type PageId = u32;

/// Where a sequence's KV currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidence {
    /// Resident in the device pool.
    Device,
    /// Stashed in host memory.
    Swapped,
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    pages: Vec<PageId>,
    tokens: u32,
    residence: KvResidence,
}

/// Errors from the allocator.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum KvError {
    /// The pool cannot supply the requested pages.
    #[error("out of KV pages (need {need}, free {free})")]
    OutOfPages { need: u32, free: u32 },
    /// No allocation exists for this sequence.
    #[error("unknown sequence {0}")]
    UnknownSeq(TaskId),
    /// The sequence already holds pages.
    #[error("sequence {0} already allocated")]
    AlreadyAllocated(TaskId),
    /// The operation needs a device-resident sequence.
    #[error("sequence {0} is swapped out")]
    Swapped(TaskId),
    /// The bounded host (CPU) swap pool cannot take the sequence — the
    /// engine must recompute-preempt instead (DESIGN.md §11).
    #[error("host KV pool full (need {need} tokens, free {free})")]
    HostFull {
        /// Tokens the swap-out would move to host.
        need: u32,
        /// Host token slots still free.
        free: u64,
    },
}

/// The paged KV-cache allocator.
#[derive(Debug, Clone)]
pub struct BlockAllocator {
    page_size: u32,
    total_pages: u32,
    /// Free pages, min-heap on id: allocation always hands out the lowest
    /// free page id, independent of release interleaving.
    free: BinaryHeap<Reverse<PageId>>,
    /// Refcount per page; 0 ⇔ the page is in `free`.
    refs: Vec<u32>,
    seqs: HashMap<TaskId, SeqAlloc>,
    /// Token slots occupied on device (for occupancy accounting / Fig. 3).
    /// Logical tokens: shared pages count once per *sharing sequence*.
    device_tokens: u64,
    swapped_tokens: u64,
    /// Host (CPU) swap-pool capacity in token slots; `u64::MAX` models the
    /// classical unbounded host tier (the default — every pre-subsystem
    /// code path is unchanged).
    host_capacity_tokens: u64,
}

impl BlockAllocator {
    /// Allocator over `total_pages` pages of `page_size` tokens.
    pub fn new(total_pages: u32, page_size: u32) -> Self {
        assert!(page_size > 0 && total_pages > 0);
        BlockAllocator {
            page_size,
            total_pages,
            free: (0..total_pages).map(Reverse).collect(),
            refs: vec![0; total_pages as usize],
            seqs: HashMap::new(),
            device_tokens: 0,
            swapped_tokens: 0,
            host_capacity_tokens: u64::MAX,
        }
    }

    /// Bound the host (CPU) swap pool to `tokens` slots. Swap-outs beyond it
    /// fail with [`KvError::HostFull`]; the engine then recompute-preempts.
    pub fn set_host_capacity(&mut self, tokens: u64) {
        self.host_capacity_tokens = tokens;
    }

    /// The host swap-pool capacity (`u64::MAX` = unbounded).
    pub fn host_capacity_tokens(&self) -> u64 {
        self.host_capacity_tokens
    }

    /// Host token slots still free for swap-outs.
    pub fn host_free_tokens(&self) -> u64 {
        self.host_capacity_tokens.saturating_sub(self.swapped_tokens)
    }

    /// Whether a device-resident sequence fits in the host swap pool.
    pub fn can_swap_out(&self, seq: TaskId) -> bool {
        match self.seqs.get(&seq) {
            Some(a) if a.residence == KvResidence::Device => {
                a.tokens as u64 <= self.host_free_tokens()
            }
            _ => false,
        }
    }

    /// Tokens per page.
    pub fn page_size(&self) -> u32 {
        self.page_size
    }

    /// Total pool pages.
    pub fn total_pages(&self) -> u32 {
        self.total_pages
    }

    /// Token capacity M (paper's total KV cache space, per-token units).
    pub fn capacity_tokens(&self) -> u64 {
        self.total_pages as u64 * self.page_size as u64
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> u32 {
        self.free.len() as u32
    }

    /// Pages needed to hold `tokens`.
    pub fn pages_for(&self, tokens: u32) -> u32 {
        tokens.div_ceil(self.page_size)
    }

    /// Tokens currently resident on device (running sequences; logical,
    /// i.e. shared pages count once per sharer).
    pub fn device_tokens(&self) -> u64 {
        self.device_tokens
    }

    /// Tokens currently swapped to host.
    pub fn swapped_tokens(&self) -> u64 {
        self.swapped_tokens
    }

    /// Current refcount of a page (0 = free).
    pub fn page_ref(&self, page: PageId) -> u32 {
        self.refs[page as usize]
    }

    /// Pop the lowest free page id and mark it owned (refcount 1).
    fn take_free(&mut self) -> Option<PageId> {
        let Reverse(p) = self.free.pop()?;
        debug_assert_eq!(self.refs[p as usize], 0);
        self.refs[p as usize] = 1;
        Some(p)
    }

    /// Add a reference to a live page (prefix-cache pinning / sharing).
    /// Panics if the page is free: retaining an unowned page would corrupt
    /// the pool.
    pub fn retain_page(&mut self, page: PageId) {
        assert!(self.refs[page as usize] >= 1, "retain of free page {page}");
        self.refs[page as usize] += 1;
    }

    /// Drop a reference to a live page; the page returns to the free pool
    /// when its refcount reaches zero.
    pub fn release_page(&mut self, page: PageId) {
        let r = &mut self.refs[page as usize];
        assert!(*r >= 1, "release of free page {page}");
        *r -= 1;
        if *r == 0 {
            self.free.push(Reverse(page));
        }
    }

    /// Whether a new sequence with `prompt_tokens` can be admitted now.
    /// vLLM admits when the prompt fits plus one page of headroom for the
    /// first decode step.
    pub fn can_admit(&self, prompt_tokens: u32) -> bool {
        self.pages_for(prompt_tokens) + 1 <= self.free_pages()
    }

    /// Fresh pages (including the one-page decode headroom) a new sequence
    /// needs beyond `cached_pages` supplied by the prefix cache — the single
    /// source of the admission page arithmetic (used by both the engine's
    /// eviction gate and [`can_admit_with_prefix`](Self::can_admit_with_prefix)).
    pub fn fresh_pages_needed(&self, prompt_tokens: u32, cached_pages: u32) -> u32 {
        self.pages_for(prompt_tokens).max(1).saturating_sub(cached_pages) + 1
    }

    /// Like [`can_admit`](Self::can_admit), but with the first
    /// `cached_pages` pages supplied by the prefix cache (shared, no fresh
    /// allocation needed).
    pub fn can_admit_with_prefix(&self, prompt_tokens: u32, cached_pages: u32) -> bool {
        self.fresh_pages_needed(prompt_tokens, cached_pages) <= self.free_pages()
    }

    /// Allocate pages for a newly-admitted sequence's prompt.
    pub fn allocate(&mut self, seq: TaskId, prompt_tokens: u32) -> Result<(), KvError> {
        self.share_prefix(seq, &[], prompt_tokens)
    }

    /// Admit a sequence whose prompt begins with `shared` — existing live
    /// pages (typically full prefix-cache pages) that the new sequence
    /// attaches to (refcount +1 each) instead of re-allocating; the rest of
    /// the prompt gets fresh private pages. With `shared` empty this is
    /// exactly [`allocate`](Self::allocate).
    pub fn share_prefix(
        &mut self,
        seq: TaskId,
        shared: &[PageId],
        prompt_tokens: u32,
    ) -> Result<(), KvError> {
        if self.seqs.contains_key(&seq) {
            return Err(KvError::AlreadyAllocated(seq));
        }
        let total = self.pages_for(prompt_tokens).max(1);
        debug_assert!(
            shared.len() as u32 <= total,
            "shared pages ({}) exceed prompt pages ({total})",
            shared.len()
        );
        let fresh = total - (shared.len() as u32).min(total);
        if fresh > self.free_pages() {
            return Err(KvError::OutOfPages { need: fresh, free: self.free_pages() });
        }
        let mut pages = Vec::with_capacity(total as usize);
        for &p in shared {
            self.retain_page(p);
            pages.push(p);
        }
        for _ in 0..fresh {
            pages.push(self.take_free().expect("free checked"));
        }
        self.device_tokens += prompt_tokens as u64;
        self.seqs.insert(seq, SeqAlloc { pages, tokens: prompt_tokens, residence: KvResidence::Device });
        Ok(())
    }

    /// Replace the page at `page_idx` of `seq`'s block table with an
    /// existing live `page` holding identical content (the inverse of
    /// [`cow_split`](Self::cow_split)): the sequence takes a reference on
    /// `page` and drops its own copy, returning it to the pool if it was the
    /// last holder. Used by the prefix cache when a just-prefilled sequence
    /// discovers a sibling already cached the same chunk. No-op when the
    /// table already holds `page`.
    pub fn adopt_page(&mut self, seq: TaskId, page_idx: usize, page: PageId) -> Result<(), KvError> {
        let alloc = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if alloc.residence != KvResidence::Device {
            return Err(KvError::Swapped(seq));
        }
        assert!(page_idx < alloc.pages.len(), "adopt_page index out of range");
        let old = alloc.pages[page_idx];
        if old == page {
            return Ok(());
        }
        self.retain_page(page);
        self.seqs.get_mut(&seq).expect("checked").pages[page_idx] = page;
        self.release_page(old);
        Ok(())
    }

    /// Give `seq` a private copy of the page at `page_idx` in its block
    /// table (copy-on-write). No-op returning the existing page when it is
    /// already private. Fails with `OutOfPages` when no page is free for the
    /// copy.
    pub fn cow_split(&mut self, seq: TaskId, page_idx: usize) -> Result<PageId, KvError> {
        let alloc = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if alloc.residence != KvResidence::Device {
            return Err(KvError::Swapped(seq));
        }
        assert!(page_idx < alloc.pages.len(), "cow_split index out of range");
        let old = alloc.pages[page_idx];
        if self.refs[old as usize] <= 1 {
            return Ok(old); // already private
        }
        let new = self.take_free().ok_or(KvError::OutOfPages { need: 1, free: 0 })?;
        self.refs[old as usize] -= 1; // was > 1, cannot reach 0
        self.seqs.get_mut(&seq).expect("checked").pages[page_idx] = new;
        Ok(new)
    }

    /// Extend a running sequence by one generated token; may allocate a new
    /// page, and copy-on-writes the tail page first if it is shared. Returns
    /// Err(OutOfPages) when the pool is exhausted — the engine then preempts
    /// (swaps out) some sequence.
    pub fn append_token(&mut self, seq: TaskId) -> Result<(), KvError> {
        let (cap, tokens, tail_idx) = {
            let alloc = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
            if alloc.residence != KvResidence::Device {
                return Err(KvError::Swapped(seq));
            }
            (alloc.pages.len() as u32 * self.page_size, alloc.tokens, alloc.pages.len().wrapping_sub(1))
        };
        if tokens + 1 > cap {
            let p = self.take_free().ok_or(KvError::OutOfPages { need: 1, free: 0 })?;
            self.seqs.get_mut(&seq).expect("checked").pages.push(p);
        } else {
            // Writing into the current tail page: make it private first.
            self.cow_split(seq, tail_idx)?;
        }
        let alloc = self.seqs.get_mut(&seq).expect("checked");
        alloc.tokens += 1;
        self.device_tokens += 1;
        Ok(())
    }

    /// Fresh pages required to grow `seq` by `n` tokens right now: new table
    /// pages plus a copy-on-write page when the partially-filled tail is
    /// shared. 0 when the tokens fit in pages the sequence already owns
    /// privately (or for an unknown / swapped sequence, where
    /// [`extend_tokens`](Self::extend_tokens) fails before allocating).
    pub fn extend_need(&self, seq: TaskId, n: u32) -> u32 {
        let Some(a) = self.seqs.get(&seq) else { return 0 };
        if a.residence != KvResidence::Device || n == 0 {
            return 0;
        }
        let cap = a.pages.len() as u32 * self.page_size;
        let fresh = self.pages_for(a.tokens + n).saturating_sub(a.pages.len() as u32);
        let writes_tail = a.tokens < cap;
        let tail_shared =
            writes_tail && a.pages.last().map(|&p| self.refs[p as usize] > 1).unwrap_or(false);
        fresh + u32::from(tail_shared)
    }

    /// Grow a device-resident sequence by `n` prompt tokens, allocating
    /// fresh pages (and copy-on-write-splitting a shared, partially-filled
    /// tail page) as needed — the chunked-prefill path acquires KV chunk by
    /// chunk through this instead of allocating whole prompts at admission.
    /// All-or-nothing: on `OutOfPages` no page moves and no token is added.
    pub fn extend_tokens(&mut self, seq: TaskId, n: u32) -> Result<(), KvError> {
        if n == 0 {
            return Ok(());
        }
        let (tokens, n_pages, cap) = {
            let a = self.seqs.get(&seq).ok_or(KvError::UnknownSeq(seq))?;
            if a.residence != KvResidence::Device {
                return Err(KvError::Swapped(seq));
            }
            (a.tokens, a.pages.len() as u32, a.pages.len() as u32 * self.page_size)
        };
        let need = self.extend_need(seq, n);
        if need > self.free_pages() {
            return Err(KvError::OutOfPages { need, free: self.free_pages() });
        }
        if tokens < cap {
            // Writing into the current tail page: make it private first.
            self.cow_split(seq, n_pages as usize - 1)?;
        }
        let fresh = self.pages_for(tokens + n).saturating_sub(n_pages);
        for _ in 0..fresh {
            let p = self.take_free().expect("need checked against free");
            self.seqs.get_mut(&seq).expect("checked").pages.push(p);
        }
        let a = self.seqs.get_mut(&seq).expect("checked");
        a.tokens += n;
        self.device_tokens += n as u64;
        Ok(())
    }

    /// Whether `append_token` would succeed without side effects.
    pub fn can_append(&self, seq: TaskId) -> bool {
        match self.seqs.get(&seq) {
            Some(a) if a.residence == KvResidence::Device => {
                let room_in_tail = a.tokens + 1 <= a.pages.len() as u32 * self.page_size;
                let tail_private =
                    a.pages.last().map(|&p| self.refs[p as usize] <= 1).unwrap_or(false);
                (room_in_tail && tail_private) || !self.free.is_empty()
            }
            _ => false,
        }
    }

    /// Free all pages of a finished sequence (shared pages survive while
    /// other holders remain). Returns the number of table pages dropped.
    pub fn release(&mut self, seq: TaskId) -> Result<u32, KvError> {
        let alloc = self.seqs.remove(&seq).ok_or(KvError::UnknownSeq(seq))?;
        let n = alloc.pages.len() as u32;
        match alloc.residence {
            KvResidence::Device => {
                for p in alloc.pages {
                    self.release_page(p);
                }
                self.device_tokens -= alloc.tokens as u64;
            }
            KvResidence::Swapped => {
                self.swapped_tokens -= alloc.tokens as u64;
            }
        }
        Ok(n)
    }

    /// Swap a running sequence out to host memory, dropping its device page
    /// references. Returns the number of tokens moved (for swap-latency
    /// accounting).
    pub fn swap_out(&mut self, seq: TaskId) -> Result<u32, KvError> {
        let host_free = self.host_free_tokens();
        let alloc = self.seqs.get_mut(&seq).ok_or(KvError::UnknownSeq(seq))?;
        if alloc.residence == KvResidence::Swapped {
            return Err(KvError::Swapped(seq));
        }
        if alloc.tokens as u64 > host_free {
            return Err(KvError::HostFull { need: alloc.tokens, free: host_free });
        }
        let pages = std::mem::take(&mut alloc.pages);
        alloc.residence = KvResidence::Swapped;
        let tokens = alloc.tokens;
        for p in pages {
            self.release_page(p);
        }
        self.device_tokens -= tokens as u64;
        self.swapped_tokens += tokens as u64;
        Ok(tokens)
    }

    /// Release a device-resident sequence's allocation entirely — the
    /// recompute-preemption path (DESIGN.md §11): unlike
    /// [`swap_out`](Self::swap_out) nothing moves to host; the KV is
    /// discarded and re-built by a fresh prefill at re-entry. The engine's
    /// prefilled cursor resets accordingly. Pages shared with other holders
    /// (sibling sequences, the prefix cache) survive via their remaining
    /// references, so a cached shared prefix stays resident for the refill
    /// to match against. Returns the tokens dropped (the wasted-work gauge).
    pub fn drop_for_recompute(&mut self, seq: TaskId) -> Result<u32, KvError> {
        match self.residence(seq) {
            None => return Err(KvError::UnknownSeq(seq)),
            Some(KvResidence::Swapped) => return Err(KvError::Swapped(seq)),
            Some(KvResidence::Device) => {}
        }
        let alloc = self.seqs.remove(&seq).expect("residence checked");
        for p in alloc.pages {
            self.release_page(p);
        }
        self.device_tokens -= alloc.tokens as u64;
        Ok(alloc.tokens)
    }

    /// Whether a swapped sequence fits back on device (plus one page of
    /// decode headroom).
    pub fn can_swap_in(&self, seq: TaskId) -> bool {
        match self.seqs.get(&seq) {
            Some(a) if a.residence == KvResidence::Swapped => {
                self.pages_for(a.tokens) + 1 <= self.free_pages()
            }
            _ => false,
        }
    }

    /// Swap a sequence back onto the device (fresh private pages; any prefix
    /// sharing it had is rebuilt only for *new* sequences, not restored).
    /// Returns tokens moved.
    pub fn swap_in(&mut self, seq: TaskId) -> Result<u32, KvError> {
        if !self.can_swap_in(seq) {
            let free = self.free_pages();
            let need = self
                .seqs
                .get(&seq)
                .map(|a| self.pages_for(a.tokens) + 1)
                .ok_or(KvError::UnknownSeq(seq))?;
            return Err(KvError::OutOfPages { need, free });
        }
        let page_size = self.page_size;
        let need = {
            let alloc = self.seqs.get(&seq).expect("checked");
            alloc.tokens.div_ceil(page_size).max(1)
        };
        let mut fresh = Vec::with_capacity(need as usize);
        for _ in 0..need {
            fresh.push(self.take_free().expect("can_swap_in checked"));
        }
        let alloc = self.seqs.get_mut(&seq).expect("checked");
        alloc.pages = fresh;
        alloc.residence = KvResidence::Device;
        self.swapped_tokens -= alloc.tokens as u64;
        self.device_tokens += alloc.tokens as u64;
        Ok(alloc.tokens)
    }

    /// Current token count of a sequence.
    pub fn seq_tokens(&self, seq: TaskId) -> Option<u32> {
        self.seqs.get(&seq).map(|a| a.tokens)
    }

    /// Residence of a sequence.
    pub fn residence(&self, seq: TaskId) -> Option<KvResidence> {
        self.seqs.get(&seq).map(|a| a.residence)
    }

    /// The block table of a device-resident sequence (page ids in order) —
    /// consumed by the PJRT paged-attention path.
    pub fn block_table(&self, seq: TaskId) -> Option<&[PageId]> {
        self.seqs.get(&seq).and_then(|a| {
            if a.residence == KvResidence::Device {
                Some(a.pages.as_slice())
            } else {
                None
            }
        })
    }

    /// Invariant check used by tests/debug builds, assuming no external
    /// (prefix-cache) page holders: every page is either free or referenced
    /// exactly as many times as sequences hold it.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.check_invariants_shared(&HashMap::new())
    }

    /// Full invariant check with external holders declared: `external[p]`
    /// references to page `p` are held outside any sequence table (by the
    /// prefix cache). Verifies conservation (free + in-use = total), exact
    /// refcount accounting, and token bookkeeping.
    pub fn check_invariants_shared(&self, external: &HashMap<PageId, u32>) -> Result<(), String> {
        let mut holders = vec![0u32; self.total_pages as usize];
        let mut in_free = vec![false; self.total_pages as usize];
        for &Reverse(p) in self.free.iter() {
            if in_free[p as usize] {
                return Err(format!("page {p} double-listed in free"));
            }
            in_free[p as usize] = true;
            if self.refs[p as usize] != 0 {
                return Err(format!("free page {p} has refcount {}", self.refs[p as usize]));
            }
        }
        let mut dev_tokens = 0u64;
        let mut swap_tokens = 0u64;
        // simlint::allow(unordered-iter): invariant check accumulates commutatively; first-error text is diagnostic-only
        for (id, a) in &self.seqs {
            match a.residence {
                KvResidence::Device => {
                    dev_tokens += a.tokens as u64;
                    if (a.pages.len() as u32 * self.page_size) < a.tokens {
                        return Err(format!("{id}: pages don't cover tokens"));
                    }
                    for &p in &a.pages {
                        holders[p as usize] += 1;
                    }
                }
                KvResidence::Swapped => {
                    swap_tokens += a.tokens as u64;
                    if !a.pages.is_empty() {
                        return Err(format!("{id}: swapped but holds pages"));
                    }
                }
            }
        }
        for p in 0..self.total_pages {
            let want = holders[p as usize] + external.get(&p).copied().unwrap_or(0);
            let got = self.refs[p as usize];
            if got != want {
                return Err(format!("page {p}: refcount {got} != holders {want}"));
            }
            if (got == 0) != in_free[p as usize] {
                return Err(format!("page {p}: refcount {got} vs free-list {}", in_free[p as usize]));
            }
        }
        // Conservation: free + in-use partitions the pool.
        let in_use = self.refs.iter().filter(|&&r| r > 0).count() as u32;
        if self.free_pages() + in_use != self.total_pages {
            return Err(format!(
                "conservation violated: {} free + {} in-use != {} total",
                self.free_pages(),
                in_use,
                self.total_pages
            ));
        }
        if dev_tokens != self.device_tokens {
            return Err(format!("device_tokens {} != {}", self.device_tokens, dev_tokens));
        }
        if swap_tokens != self.swapped_tokens {
            return Err(format!("swapped_tokens {} != {}", self.swapped_tokens, swap_tokens));
        }
        if self.swapped_tokens > self.host_capacity_tokens {
            return Err(format!(
                "host pool overrun: {} swapped tokens > capacity {}",
                self.swapped_tokens, self.host_capacity_tokens
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tid(i: u32) -> TaskId {
        TaskId { agent: 0, index: i }
    }

    #[test]
    fn allocate_and_release() {
        let mut kv = BlockAllocator::new(10, 16);
        assert_eq!(kv.capacity_tokens(), 160);
        kv.allocate(tid(1), 33).unwrap(); // 3 pages
        assert_eq!(kv.free_pages(), 7);
        assert_eq!(kv.device_tokens(), 33);
        assert_eq!(kv.block_table(tid(1)).unwrap().len(), 3);
        kv.check_invariants().unwrap();
        assert_eq!(kv.release(tid(1)).unwrap(), 3);
        assert_eq!(kv.free_pages(), 10);
        assert_eq!(kv.device_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_new_pages() {
        let mut kv = BlockAllocator::new(3, 4);
        kv.allocate(tid(1), 4).unwrap(); // exactly 1 page
        kv.append_token(tid(1)).unwrap(); // needs 2nd page
        assert_eq!(kv.seq_tokens(tid(1)), Some(5));
        assert_eq!(kv.free_pages(), 1);
        for _ in 0..3 {
            kv.append_token(tid(1)).unwrap(); // fills 2nd page (8 tokens)
        }
        kv.append_token(tid(1)).unwrap(); // 3rd page
        assert_eq!(kv.free_pages(), 0);
        // Pool exhausted at 12 tokens cap.
        for _ in 0..3 {
            kv.append_token(tid(1)).unwrap();
        }
        assert_eq!(kv.append_token(tid(1)), Err(KvError::OutOfPages { need: 1, free: 0 }));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admission_rule_keeps_headroom() {
        let kv = BlockAllocator::new(4, 16);
        assert!(kv.can_admit(48)); // 3 pages + 1 headroom = 4
        assert!(!kv.can_admit(49)); // would need 4 + 1
        // With 3 cached pages the 49-token prompt needs only 1 fresh + 1.
        assert!(kv.can_admit_with_prefix(49, 3));
        assert!(!kv.can_admit_with_prefix(64, 0));
    }

    #[test]
    fn swap_out_in_cycle() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 16).unwrap(); // 2 pages
        kv.allocate(tid(2), 8).unwrap(); // 1 page
        let moved = kv.swap_out(tid(1)).unwrap();
        assert_eq!(moved, 16);
        assert_eq!(kv.free_pages(), 3);
        assert_eq!(kv.residence(tid(1)), Some(KvResidence::Swapped));
        assert_eq!(kv.swapped_tokens(), 16);
        assert!(kv.block_table(tid(1)).is_none());
        assert!(!kv.can_append(tid(1)));
        kv.check_invariants().unwrap();

        assert!(kv.can_swap_in(tid(1)));
        let back = kv.swap_in(tid(1)).unwrap();
        assert_eq!(back, 16);
        assert_eq!(kv.residence(tid(1)), Some(KvResidence::Device));
        assert_eq!(kv.swapped_tokens(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn swap_in_requires_space() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 24).unwrap(); // 3 pages
        kv.swap_out(tid(1)).unwrap();
        kv.allocate(tid(2), 24).unwrap(); // takes 3 pages
        assert!(!kv.can_swap_in(tid(1))); // needs 3+1, only 1 free
        assert!(kv.swap_in(tid(1)).is_err());
        kv.release(tid(2)).unwrap();
        assert!(kv.can_swap_in(tid(1)));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn bounded_host_pool_limits_swap_outs() {
        let mut kv = BlockAllocator::new(8, 8);
        kv.set_host_capacity(16);
        assert_eq!(kv.host_capacity_tokens(), 16);
        kv.allocate(tid(1), 12).unwrap();
        kv.allocate(tid(2), 10).unwrap();
        assert!(kv.can_swap_out(tid(1)));
        kv.swap_out(tid(1)).unwrap(); // 12 of 16 host slots used
        assert_eq!(kv.host_free_tokens(), 4);
        // tid(2)'s 10 tokens no longer fit on host.
        assert!(!kv.can_swap_out(tid(2)));
        assert_eq!(kv.swap_out(tid(2)), Err(KvError::HostFull { need: 10, free: 4 }));
        kv.check_invariants().unwrap();
        // Swap-in frees host slots again.
        kv.swap_in(tid(1)).unwrap();
        assert_eq!(kv.host_free_tokens(), 16);
        assert!(kv.can_swap_out(tid(2)));
        kv.check_invariants().unwrap();
        // Unknown / swapped sequences are never swappable-out.
        assert!(!kv.can_swap_out(tid(9)));
    }

    #[test]
    fn drop_for_recompute_frees_private_keeps_shared() {
        let mut kv = BlockAllocator::new(6, 4);
        kv.allocate(tid(1), 8).unwrap(); // 2 pages
        let shared: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        kv.share_prefix(tid(2), &shared, 10).unwrap(); // 2 shared + 1 private
        assert_eq!(kv.free_pages(), 3);
        let dropped = kv.drop_for_recompute(tid(2)).unwrap();
        assert_eq!(dropped, 10);
        // The private page returned to the pool; the shared pages survive
        // for tid(1).
        assert_eq!(kv.free_pages(), 4);
        for &p in &shared {
            assert_eq!(kv.page_ref(p), 1);
        }
        assert_eq!(kv.seq_tokens(tid(2)), None, "allocation fully removed");
        assert_eq!(kv.device_tokens(), 8);
        kv.check_invariants().unwrap();
        // The id is reusable for the re-entry allocation.
        kv.allocate(tid(2), 4).unwrap();
        kv.check_invariants().unwrap();
        // Error paths: unknown and swapped sequences.
        assert_eq!(kv.drop_for_recompute(tid(9)), Err(KvError::UnknownSeq(tid(9))));
        kv.swap_out(tid(1)).unwrap();
        assert_eq!(kv.drop_for_recompute(tid(1)), Err(KvError::Swapped(tid(1))));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn release_swapped_seq() {
        let mut kv = BlockAllocator::new(4, 8);
        kv.allocate(tid(1), 10).unwrap();
        kv.swap_out(tid(1)).unwrap();
        kv.release(tid(1)).unwrap();
        assert_eq!(kv.swapped_tokens(), 0);
        assert_eq!(kv.free_pages(), 4);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn errors() {
        let mut kv = BlockAllocator::new(2, 8);
        assert_eq!(kv.release(tid(9)), Err(KvError::UnknownSeq(tid(9))));
        kv.allocate(tid(1), 4).unwrap();
        assert_eq!(kv.allocate(tid(1), 4), Err(KvError::AlreadyAllocated(tid(1))));
        assert!(matches!(kv.allocate(tid(2), 100), Err(KvError::OutOfPages { .. })));
        kv.swap_out(tid(1)).unwrap();
        assert_eq!(kv.swap_out(tid(1)), Err(KvError::Swapped(tid(1))));
        assert_eq!(kv.append_token(tid(1)), Err(KvError::Swapped(tid(1))));
    }

    #[test]
    fn zero_prompt_gets_one_page() {
        let mut kv = BlockAllocator::new(2, 8);
        kv.allocate(tid(1), 0).unwrap();
        assert_eq!(kv.block_table(tid(1)).unwrap().len(), 1);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn allocation_order_is_release_order_independent() {
        // Two allocators, identical allocations, mirrored release orders:
        // the next allocation must receive the same pages in both.
        let run = |release_order: [u32; 2]| {
            let mut kv = BlockAllocator::new(8, 4);
            kv.allocate(tid(1), 8).unwrap(); // pages 0,1
            kv.allocate(tid(2), 8).unwrap(); // pages 2,3
            kv.allocate(tid(3), 4).unwrap(); // page 4
            for s in release_order {
                kv.release(tid(s)).unwrap();
            }
            kv.allocate(tid(9), 12).unwrap();
            kv.block_table(tid(9)).unwrap().to_vec()
        };
        assert_eq!(run([1, 2]), run([2, 1]));
    }

    #[test]
    fn share_prefix_refcounts_pages() {
        let mut kv = BlockAllocator::new(6, 4);
        kv.allocate(tid(1), 8).unwrap(); // 2 private pages
        let shared: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        // Second sequence shares both pages + 1 fresh for its 10-token prompt.
        kv.share_prefix(tid(2), &shared, 10).unwrap();
        assert_eq!(kv.free_pages(), 3); // only 1 fresh page consumed
        assert_eq!(kv.device_tokens(), 18); // logical: 8 + 10
        for &p in &shared {
            assert_eq!(kv.page_ref(p), 2);
        }
        kv.check_invariants().unwrap();
        // Releasing the first sequence keeps the shared pages alive.
        kv.release(tid(1)).unwrap();
        assert_eq!(kv.free_pages(), 3);
        for &p in &shared {
            assert_eq!(kv.page_ref(p), 1);
        }
        kv.release(tid(2)).unwrap();
        assert_eq!(kv.free_pages(), 6);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn append_into_shared_tail_copy_on_writes() {
        let mut kv = BlockAllocator::new(6, 4);
        kv.allocate(tid(1), 6).unwrap(); // 2 pages, tail half-full
        let pages: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        // tid(2) shares BOTH pages (incl. the half-full tail) for an equal
        // 6-token prompt: the next decode token must not write into the
        // shared tail.
        kv.share_prefix(tid(2), &pages, 6).unwrap();
        assert_eq!(kv.page_ref(pages[1]), 2);
        kv.append_token(tid(2)).unwrap();
        let t2 = kv.block_table(tid(2)).unwrap();
        assert_ne!(t2[1], pages[1], "tail should have been copy-on-write split");
        assert_eq!(kv.page_ref(pages[1]), 1);
        assert_eq!(kv.seq_tokens(tid(2)), Some(7));
        // tid(1)'s table is untouched.
        assert_eq!(kv.block_table(tid(1)).unwrap(), pages.as_slice());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn extend_tokens_grows_chunk_by_chunk() {
        let mut kv = BlockAllocator::new(4, 4);
        kv.allocate(tid(1), 3).unwrap(); // 1 page, partially filled
        assert_eq!(kv.extend_need(tid(1), 1), 0, "fits in the private tail");
        kv.extend_tokens(tid(1), 1).unwrap();
        assert_eq!(kv.seq_tokens(tid(1)), Some(4));
        assert_eq!(kv.free_pages(), 3);
        // 5 more tokens: 9 total needs 3 pages, 2 fresh.
        assert_eq!(kv.extend_need(tid(1), 5), 2);
        kv.extend_tokens(tid(1), 5).unwrap();
        assert_eq!(kv.seq_tokens(tid(1)), Some(9));
        assert_eq!(kv.block_table(tid(1)).unwrap().len(), 3);
        assert_eq!(kv.free_pages(), 1);
        kv.check_invariants().unwrap();
        // All-or-nothing failure: 8 more tokens need 2 pages, only 1 free.
        assert_eq!(
            kv.extend_tokens(tid(1), 8),
            Err(KvError::OutOfPages { need: 2, free: 1 })
        );
        assert_eq!(kv.seq_tokens(tid(1)), Some(9));
        assert_eq!(kv.free_pages(), 1);
        kv.check_invariants().unwrap();
        kv.swap_out(tid(1)).unwrap();
        // Zero-token extension is a no-op even on a swapped sequence.
        kv.extend_tokens(tid(1), 0).unwrap();
        assert_eq!(kv.extend_tokens(tid(1), 2), Err(KvError::Swapped(tid(1))));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn extend_into_shared_tail_copy_on_writes() {
        let mut kv = BlockAllocator::new(6, 4);
        kv.allocate(tid(1), 6).unwrap(); // 2 pages, tail half-full
        let pages: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        kv.share_prefix(tid(2), &pages, 6).unwrap(); // shares the partial tail
        // Extending tid(2) writes into the shared tail: 1 CoW page + 1 fresh.
        assert_eq!(kv.extend_need(tid(2), 4), 2);
        kv.extend_tokens(tid(2), 4).unwrap();
        let t2 = kv.block_table(tid(2)).unwrap();
        assert_ne!(t2[1], pages[1], "tail must be copy-on-write split");
        assert_eq!(kv.page_ref(pages[1]), 1);
        assert_eq!(kv.seq_tokens(tid(2)), Some(10));
        assert_eq!(kv.block_table(tid(1)).unwrap(), pages.as_slice());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn cow_split_is_noop_on_private_page() {
        let mut kv = BlockAllocator::new(4, 4);
        kv.allocate(tid(1), 4).unwrap();
        let p = kv.block_table(tid(1)).unwrap()[0];
        assert_eq!(kv.cow_split(tid(1), 0), Ok(p));
        assert_eq!(kv.free_pages(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn cow_split_needs_a_free_page() {
        let mut kv = BlockAllocator::new(2, 4);
        kv.allocate(tid(1), 4).unwrap();
        let pages: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        kv.share_prefix(tid(2), &pages, 4).unwrap(); // shares the only page
        kv.allocate(tid(3), 4).unwrap(); // takes the last free page
        assert_eq!(kv.cow_split(tid(2), 0), Err(KvError::OutOfPages { need: 1, free: 0 }));
        assert!(!kv.can_append(tid(2)));
        kv.release(tid(3)).unwrap();
        assert!(kv.can_append(tid(2)));
        kv.cow_split(tid(2), 0).unwrap();
        kv.check_invariants().unwrap();
    }

    #[test]
    fn swap_out_shared_pages_survive_for_other_holders() {
        let mut kv = BlockAllocator::new(6, 4);
        kv.allocate(tid(1), 8).unwrap();
        let shared: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        kv.share_prefix(tid(2), &shared, 8).unwrap();
        kv.swap_out(tid(2)).unwrap();
        // Shared pages still owned by tid(1); nothing returned to free that
        // tid(1) uses.
        for &p in &shared {
            assert_eq!(kv.page_ref(p), 1);
        }
        assert_eq!(kv.free_pages(), 4);
        kv.check_invariants().unwrap();
        kv.swap_in(tid(2)).unwrap(); // comes back on private pages
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn external_holders_accounted_via_shared_check() {
        let mut kv = BlockAllocator::new(4, 4);
        kv.allocate(tid(1), 8).unwrap();
        let pages: Vec<PageId> = kv.block_table(tid(1)).unwrap().to_vec();
        // An external cache pins both pages.
        kv.retain_page(pages[0]);
        kv.retain_page(pages[1]);
        let external: HashMap<PageId, u32> = pages.iter().map(|&p| (p, 1)).collect();
        kv.check_invariants_shared(&external).unwrap();
        // Plain check must now flag the unexplained references.
        assert!(kv.check_invariants().is_err());
        // Sequence exits; cache still holds the pages (no leak to free).
        kv.release(tid(1)).unwrap();
        assert_eq!(kv.free_pages(), 2);
        kv.check_invariants_shared(&external).unwrap();
        kv.release_page(pages[0]);
        kv.release_page(pages[1]);
        assert_eq!(kv.free_pages(), 4);
        kv.check_invariants().unwrap();
    }
}
