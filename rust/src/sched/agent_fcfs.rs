//! Agent-level FCFS — the Parrot baseline (paper baseline (c)): agents are
//! served whole, in arrival order; tasks within an agent are FIFO. Avoids
//! inference-level interleaving but still head-of-line blocks on big agents.

use crate::config::Policy;
use crate::sched::{AgentInfo, AgentQueues, OrdF64, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet};

/// Parrot-style agent-level FCFS scheduler state.
pub struct AgentFcfs {
    arrivals: HashMap<AgentId, f64>,
    waiting: AgentQueues,
    heap: BinaryHeap<Reverse<(OrdF64, AgentId)>>,
    in_heap: HashSet<AgentId>,
}

impl AgentFcfs {
    /// Empty scheduler.
    pub fn new() -> Self {
        AgentFcfs {
            arrivals: HashMap::new(),
            waiting: AgentQueues::new(),
            heap: BinaryHeap::new(),
            in_heap: HashSet::new(),
        }
    }

    fn ensure_in_heap(&mut self, agent: AgentId) {
        if self.waiting.has_agent(agent) && self.in_heap.insert(agent) {
            let a = self.arrivals.get(&agent).copied().unwrap_or(f64::MAX);
            self.heap.push(Reverse((OrdF64(a), agent)));
        }
    }

    fn skim(&mut self) {
        while let Some(&Reverse((_, agent))) = self.heap.peek() {
            if self.waiting.has_agent(agent) {
                return;
            }
            self.heap.pop();
            self.in_heap.remove(&agent);
        }
    }
}

impl Default for AgentFcfs {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for AgentFcfs {
    fn policy(&self) -> Policy {
        Policy::AgentFcfs
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, _now: f64) {
        self.arrivals.insert(info.id, info.arrival);
    }

    fn push_task(&mut self, task: TaskInfo, _now: f64) {
        self.waiting.push(task);
        self.ensure_in_heap(task.id.agent);
    }

    fn pop_next(&mut self, _now: f64) -> Option<TaskInfo> {
        self.skim();
        let &Reverse((_, agent)) = self.heap.peek()?;
        let t = self.waiting.pop_agent(agent);
        if !self.waiting.has_agent(agent) {
            self.heap.pop();
            self.in_heap.remove(&agent);
        }
        t
    }

    fn peek_next(&mut self, _now: f64) -> Option<TaskInfo> {
        self.skim();
        let &Reverse((_, agent)) = self.heap.peek()?;
        self.waiting.peek_agent(agent).copied()
    }

    fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        self.arrivals.get(&agent).copied().unwrap_or(f64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn info(id: u32, arrival: f64) -> AgentInfo {
        AgentInfo::new(id, arrival, 0.0)
    }

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 1, predicted_decode: 1.0, seq }
    }

    #[test]
    fn whole_agent_before_next() {
        let mut s = AgentFcfs::new();
        s.on_agent_arrival(&info(1, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 1.0), 1.0);
        // Interleaved pushes; pops must group by agent arrival order.
        s.push_task(task(2, 0, 0), 1.0);
        s.push_task(task(1, 0, 1), 1.0);
        s.push_task(task(2, 1, 2), 1.0);
        s.push_task(task(1, 1, 3), 1.0);
        let order: Vec<u32> = (0..4).map(|_| s.pop_next(1.0).unwrap().id.agent).collect();
        assert_eq!(order, vec![1, 1, 2, 2]);
    }

    #[test]
    fn big_agent_blocks_later_small_one() {
        // The head-of-line-blocking behaviour the paper attributes to
        // Parrot: later (small) agents wait for earlier (big) ones.
        let mut s = AgentFcfs::new();
        s.on_agent_arrival(&info(1, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 0.5), 0.5);
        for i in 0..10 {
            s.push_task(task(1, i, i as u64), 0.0);
        }
        s.push_task(task(2, 0, 100), 0.5);
        for _ in 0..10 {
            assert_eq!(s.pop_next(1.0).unwrap().id.agent, 1);
        }
        assert_eq!(s.pop_next(1.0).unwrap().id.agent, 2);
    }

    #[test]
    fn later_stage_tasks_keep_position() {
        let mut s = AgentFcfs::new();
        s.on_agent_arrival(&info(1, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 1.0), 1.0);
        s.push_task(task(2, 0, 0), 1.0);
        // Agent 1's stage-1 task arrives later but agent 1 arrived first.
        s.push_task(task(1, 5, 1), 2.0);
        assert_eq!(s.pop_next(2.0).unwrap().id.agent, 1);
        assert_eq!(s.pop_next(2.0).unwrap().id.agent, 2);
    }
}
