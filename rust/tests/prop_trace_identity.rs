//! Differential property tests for the flight recorder (ISSUE 7 tentpole).
//!
//! Two guarantees, each over randomized tight-pool workloads × all six
//! schedulers × {prefix cache, chunked prefill, preemption auto, event
//! core} knob draws:
//!
//! 1. `--trace` is observation-only: turning the recorder on (any sample
//!    stride, any ring cap) must leave the results JSON — per-agent JCTs,
//!    per-task admit/complete times, makespan, counter metrics — byte
//!    identical to the untraced run. The recorder is `Option<TraceRecorder>`
//!    in the engine and every emit site reads engine state it never writes,
//!    so any divergence is a tentpole bug (DESIGN.md §13).
//! 2. The tick loop and the event-driven core must emit IDENTICAL trace
//!    streams (events, iteration samples, pick audit — `TraceRecorder`
//!    derives `PartialEq`): every emit site lives in code shared by both
//!    cores, extending `prop_event_core_identity` to trace equality.

use justitia::cluster::{ClusterDispatcher, FailureSchedule, Placement};
use justitia::config::{BackendProfile, Config, Policy, PreemptionMode};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::trace::TraceRecorder;
use justitia::util::json::{obj, Json};
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, SpawnSpec, Suite};

/// A randomized workload plus the knob draws tracing must be inert under.
#[derive(Clone, Debug)]
struct TraceScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    prefix_cache: bool,
    spawn: bool,
    chunked: bool,
    preempt_auto: bool,
    host_tokens: Option<u64>,
    swap_bw: f64,
    /// Which engine core the trace-off/on comparison runs on.
    event_core: bool,
    /// Recorder knobs: stride exercises the sampler, a small cap exercises
    /// ring-buffer eviction — neither may perturb the simulation.
    sample_stride: u32,
    trace_cap: usize,
    /// Seed for the random churn schedule the cluster inertness test draws
    /// ([`FailureSchedule::random`]); ignored by the single-engine tests.
    churn_seed: u64,
}

struct TraceStrategy;

impl Strategy for TraceStrategy {
    type Value = TraceScenario;

    fn generate(&self, rng: &mut Rng) -> TraceScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 48);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 6) as usize;
        let spawn = rng.chance(0.5);
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 4) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                let p = rng.range_u64(2, m_tokens / 3) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            let mut a = dag_agent(id as u32, t, tasks);
            if spawn {
                a.spawn = Some(SpawnSpec {
                    prob: 0.6,
                    branch: 2,
                    max_depth: 1,
                    seed: rng.next_u64(),
                });
            }
            agents.push(a);
        }
        TraceScenario {
            agents,
            pages,
            page_size,
            prefix_cache: rng.chance(0.5),
            spawn,
            chunked: rng.chance(0.5),
            preempt_auto: rng.chance(0.5),
            host_tokens: match rng.below(3) {
                0 => None,
                1 => Some(m_tokens / 4),
                _ => Some(0),
            },
            swap_bw: if rng.chance(0.5) { 1000.0 } else { 0.0 },
            event_core: rng.chance(0.5),
            sample_stride: [1u32, 3, 8][rng.below(3) as usize],
            trace_cap: if rng.chance(0.3) { 128 } else { 65536 },
            churn_seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &TraceScenario) -> Vec<TraceScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        for knob in 0..5 {
            let mut w = v.clone();
            let on = match knob {
                0 => std::mem::replace(&mut w.prefix_cache, false),
                1 => {
                    let on = w.spawn;
                    w.spawn = false;
                    for a in &mut w.agents {
                        a.spawn = None;
                    }
                    on
                }
                2 => std::mem::replace(&mut w.chunked, false),
                3 => std::mem::replace(&mut w.preempt_auto, false),
                _ => std::mem::replace(&mut w.event_core, false),
            };
            if on {
                out.push(w);
            }
        }
        out
    }
}

fn config_for(sc: &TraceScenario) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-trace".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: 1e-3,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: sc.host_tokens,
        swap_bw_tokens_per_sec: sc.swap_bw,
    };
    cfg.max_batch = 64;
    cfg.prefix_cache = sc.prefix_cache;
    if sc.preempt_auto {
        cfg.preemption = PreemptionMode::Auto;
    }
    if sc.chunked {
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 48;
    }
    cfg
}

fn suite_for(sc: &TraceScenario) -> Suite {
    let mut suite = Suite::new(sc.agents.clone());
    if sc.prefix_cache {
        justitia::workload::trace::annotate_families(&mut suite, 2, 16, 0xfa7e);
    }
    suite
}

/// Run one (scenario, policy, core, trace) configuration and canonicalize
/// everything the engine observably computed into one JSON byte string,
/// alongside the recorder (when tracing was on).
fn replay(
    sc: &TraceScenario,
    policy: Policy,
    event_core: bool,
    trace: bool,
) -> (String, Option<TraceRecorder>) {
    let mut cfg = config_for(sc);
    cfg.event_core = event_core;
    cfg.trace = trace;
    cfg.trace_sample = sc.sample_stride;
    cfg.trace_cap = sc.trace_cap;
    let suite = suite_for(sc);
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan = engine.run_suite(&suite, |a| model.agent_cost(a));
    let m = &engine.metrics;
    let mut tasks = Vec::new();
    for a in &suite.agents {
        for t in a.tasks.iter().chain(a.expand_spawns().iter()) {
            tasks.push(Json::Arr(vec![
                Json::Num(t.id.agent as f64),
                Json::Num(t.id.index as f64),
                m.task_admit_time(t.id).map(Json::Num).unwrap_or(Json::Null),
                m.task_complete_time(t.id).map(Json::Num).unwrap_or(Json::Null),
            ]));
        }
    }
    let json = obj([
        ("makespan", Json::Num(makespan)),
        (
            "jcts",
            Json::Arr(
                m.jcts()
                    .into_iter()
                    .map(|(a, j)| Json::Arr(vec![Json::Num(a as f64), Json::Num(j)]))
                    .collect(),
            ),
        ),
        ("tasks", Json::Arr(tasks)),
        ("iterations", Json::Num(m.iterations() as f64)),
        ("swap_outs", Json::Num(m.swap_out_count() as f64)),
        ("recomputes", Json::Num(m.recompute_count() as f64)),
        ("prefill_tokens", Json::Num(m.prefill_tokens_executed() as f64)),
        ("prefix_hits", Json::Num(m.prefix_hits() as f64)),
        ("spawned", Json::Num(m.spawned_tasks() as f64)),
        ("stalls", Json::Num(m.prefill_stalls() as f64)),
        ("ttft_samples", Json::Num(m.ttft_samples() as f64)),
        ("ttft_mean", Json::Num(m.ttft_mean())),
        ("ttft_p99", Json::Num(m.ttft_percentile(99.0))),
    ])
    .dump();
    (json, engine.take_trace())
}

/// Guarantee 1: the recorder is observation-only — results JSON bytes match
/// exactly with tracing off vs on, for every scheduler on the drawn core.
#[test]
fn prop_trace_off_vs_on_results_byte_identical() {
    let cfg = PropConfig { cases: prop_cases(20), seed: 0x7ace_0ff0, max_shrink_steps: 60 };
    check(&cfg, &TraceStrategy, |sc| {
        for policy in Policy::all_paper_baselines() {
            let (off_json, off_rec) = replay(sc, policy, sc.event_core, false);
            let (on_json, on_rec) = replay(sc, policy, sc.event_core, true);
            if off_rec.is_some() {
                return Err(format!("{policy:?}: untraced run allocated a recorder"));
            }
            let rec = match on_rec {
                Some(r) => r,
                None => return Err(format!("{policy:?}: traced run lost its recorder")),
            };
            if rec.event_count() == 0 {
                return Err(format!("{policy:?}: traced run recorded nothing"));
            }
            if off_json != on_json {
                return Err(format!(
                    "{policy:?} (event_core={}): --trace perturbed the results JSON\n off: {off_json}\n  on: {on_json}",
                    sc.event_core
                ));
            }
        }
        Ok(())
    });
}

/// Guarantee 2: both engine cores emit the identical trace stream (and, per
/// prop_event_core_identity, identical results — re-checked here since the
/// comparison is free).
#[test]
fn prop_trace_stream_identical_across_cores() {
    let cfg = PropConfig { cases: prop_cases(20), seed: 0x7ace_c04e, max_shrink_steps: 60 };
    check(&cfg, &TraceStrategy, |sc| {
        for policy in Policy::all_paper_baselines() {
            let (tick_json, tick_rec) = replay(sc, policy, false, true);
            let (event_json, event_rec) = replay(sc, policy, true, true);
            if tick_json != event_json {
                return Err(format!("{policy:?}: cores disagree on results JSON"));
            }
            let (tick_rec, event_rec) = (tick_rec.unwrap(), event_rec.unwrap());
            if tick_rec != event_rec {
                let what = if !tick_rec.events().eq(event_rec.events()) {
                    "lifecycle events"
                } else if !tick_rec.samples().eq(event_rec.samples()) {
                    "iteration samples"
                } else if !tick_rec.picks().eq(event_rec.picks()) {
                    "pick audit"
                } else {
                    "drop counters"
                };
                return Err(format!(
                    "{policy:?}: trace streams diverged on {what} \
                     (tick {} events / {} samples / {} picks, event {} / {} / {})",
                    tick_rec.event_count(),
                    tick_rec.sample_count(),
                    tick_rec.pick_count(),
                    event_rec.event_count(),
                    event_rec.sample_count(),
                    event_rec.pick_count(),
                ));
            }
        }
        Ok(())
    });
}

/// One churn replay over a 3-replica cluster; canonicalizes the merged-run
/// results into a JSON byte string alongside the merged Chrome export (which
/// exists only when tracing was on).
fn replay_churn(
    sc: &TraceScenario,
    policy: Policy,
    trace: bool,
) -> (String, Option<Json>) {
    let mut cfg = config_for(sc);
    cfg.event_core = sc.event_core;
    cfg.trace = trace;
    cfg.trace_sample = sc.sample_stride;
    cfg.trace_cap = sc.trace_cap;
    let suite = suite_for(sc);
    let horizon = suite.agents.last().map(|a| a.arrival).unwrap_or(0.0) + 30.0;
    let schedule = FailureSchedule::random(sc.churn_seed, 3, horizon, 4);
    let engine_for = |cfg: &Config| {
        let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
        Engine::new(cfg, sched, SimBackend::unit_time())
    };
    let replicas = (0..3).map(|_| engine_for(&cfg)).collect();
    let mut cluster =
        ClusterDispatcher::new(replicas, Placement::ClusterVtime, cfg.backend.kv_tokens, 1.0);
    let model = justitia::cost::CostModel::MemoryCentric;
    let makespan =
        cluster.run_suite_churn(&suite, |a| model.agent_cost(a), &schedule, || engine_for(&cfg));
    let m = cluster.merged_metrics();
    let json = obj([
        ("makespan", Json::Num(makespan)),
        (
            "jcts",
            Json::Arr(
                m.jcts()
                    .into_iter()
                    .map(|(a, j)| Json::Arr(vec![Json::Num(a as f64), Json::Num(j)]))
                    .collect(),
            ),
        ),
        ("iterations", Json::Num(m.iterations() as f64)),
        ("swap_outs", Json::Num(m.swap_out_count() as f64)),
        ("recomputes", Json::Num(m.recompute_count() as f64)),
        ("prefill_tokens", Json::Num(m.prefill_tokens_executed() as f64)),
        ("replicas_lost", Json::Num(m.replicas_lost() as f64)),
        ("recovered", Json::Num(m.recovered_agents() as f64)),
        ("rescheduled_tokens", Json::Num(m.rescheduled_tokens() as f64)),
    ])
    .dump();
    (json, cluster.merged_trace_chrome())
}

/// Guarantee 1 extended to the churn driver: with a random crash / drain /
/// join schedule running (recovery fold, re-placement, graveyard merge
/// included), `--trace` must still be observation-only — the merged results
/// are byte-identical with tracing off vs on, and only the traced run
/// produces a Chrome export.
#[test]
fn prop_trace_inert_under_churn() {
    let cfg = PropConfig { cases: prop_cases(12), seed: 0x7ace_c4a0, max_shrink_steps: 40 };
    check(&cfg, &TraceStrategy, |sc| {
        for policy in [Policy::Fcfs, Policy::Vtc, Policy::Justitia] {
            let (off_json, off_chrome) = replay_churn(sc, policy, false);
            let (on_json, on_chrome) = replay_churn(sc, policy, true);
            if off_chrome.is_some() {
                return Err(format!("{policy:?}: untraced churn run produced a Chrome export"));
            }
            if on_chrome.is_none() {
                return Err(format!("{policy:?}: traced churn run lost its Chrome export"));
            }
            if off_json != on_json {
                return Err(format!(
                    "{policy:?} (event_core={}): --trace perturbed a churn run\n off: {off_json}\n  on: {on_json}",
                    sc.event_core
                ));
            }
        }
        Ok(())
    });
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
