"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package has a reference implementation here written in
straightforward jax.numpy; pytest sweeps shapes/dtypes (hypothesis where
available) asserting allclose between kernel and oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Reference paged attention for one decode step.

    Args:
      q:            [B, H, D]   query for the new token of each sequence.
      k_pages:      [P, page, H, D]  paged key pool.
      v_pages:      [P, page, H, D]  paged value pool.
      block_tables: [B, max_pages] int32, page ids per sequence (row-padded
                    with any valid id; positions >= seq_len are masked).
      seq_lens:     [B] int32, current context length of each sequence
                    (including the token being decoded).

    Returns:
      [B, H, D] attention output.
    """
    b, h, d = q.shape
    _, page, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))

    # Gather each sequence's KV: [B, max_pages*page, H, D].
    k = k_pages[block_tables]  # [B, max_pages, page, H, D]
    v = v_pages[block_tables]
    k = k.reshape(b, max_pages * page, h, d)
    v = v.reshape(b, max_pages * page, h, d)

    # Scores per head: [B, H, T]
    scores = jnp.einsum("bhd,bthd->bht", q, k) * scale
    positions = jnp.arange(max_pages * page)[None, None, :]
    mask = positions < seq_lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bht,bthd->bhd", probs, v)


def causal_attention_ref(q, k, v):
    """Reference causal self-attention over a full sequence (prefill path).

    Args:
      q, k, v: [S, H, D]

    Returns:
      [S, H, D]
    """
    s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, v)


def masked_causal_attention_ref(q, k, v, valid_len):
    """Causal attention where only the first `valid_len` positions are real
    (the rest is right-padding). Padding queries produce garbage that the
    caller discards; padding keys are masked out of every real query's
    softmax.
    """
    s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("qhd,khd->hqk", q, k) * scale
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    key_ok = (jnp.arange(s) < valid_len)[None, None, :]
    mask = causal[None, :, :] & key_ok
    scores = jnp.where(mask, scores, -jnp.inf)
    # Rows with no valid key (padding queries) would be NaN; force uniform.
    all_masked = ~mask.any(axis=-1, keepdims=True)
    scores = jnp.where(all_masked, 0.0, scores)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", probs, v)
