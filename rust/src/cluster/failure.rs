//! Deterministic churn injection: replica crash / drain / join schedules
//! plus a closed-loop queue-depth autoscaler (DESIGN.md §14).
//!
//! A [`FailureSchedule`] is an exogenous, fully-deterministic list of
//! [`ChurnEvent`]s consumed by
//! [`ClusterDispatcher::run_suite_churn`](crate::cluster::ClusterDispatcher::run_suite_churn),
//! optionally augmented by an [`AutoscalePolicy`] that reacts to the live
//! queue depth at fixed ticks. Determinism is the point: the same
//! (suite, schedule, seed) triple replays the same churn run bit for bit,
//! which is what lets `tests/prop_churn_conservation.rs` treat churn as just
//! another adversarial input to every existing property.
//!
//! The empty schedule ([`FailureSchedule::none`]) is the OFF state: the
//! dispatcher delegates straight to the immortal-pool drivers, so a
//! churn-disabled run is byte-identical to one that never heard of this
//! module (the bit-identity gate, asserted by
//! `tests/test_elasticity_recovery.rs`).

use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// What happens to the replica pool at one schedule point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnKind {
    /// Replica `replica` dies instantly: device and host KV are lost;
    /// in-flight agents are recovered through the recompute fold and
    /// re-placed on the surviving pool.
    Crash {
        /// Pool slot that fails.
        replica: usize,
    },
    /// Replica `replica` stops taking placements, finishes (or swaps out and
    /// re-admits) its in-flight work, then leaves the pool. Nothing is lost.
    Drain {
        /// Pool slot that drains.
        replica: usize,
    },
    /// One replica (re)joins the pool: the lowest-index departed slot is
    /// revived with a fresh engine, or the pool grows by one if none is down.
    Join,
}

/// One timestamped churn transition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    /// Engine-seconds timestamp. Events take effect at the first iteration
    /// boundary at or after `t` (replicas simulate in discrete iterations).
    pub t: f64,
    /// The transition.
    pub kind: ChurnKind,
}

/// Closed-loop autoscaler evaluated at fixed ticks: joins a replica when the
/// cluster-wide waiting queue per live replica exceeds `up_queue`, drains
/// the highest-index live replica when it falls below `down_queue`.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalePolicy {
    /// Seconds between control-loop evaluations.
    pub interval: f64,
    /// Join one replica when waiting-tasks-per-live-replica exceeds this.
    pub up_queue: f64,
    /// Drain one replica when total waiting tasks fall below this.
    pub down_queue: f64,
    /// Never drain below this many live replicas.
    pub min_replicas: usize,
    /// Never join above this many live replicas.
    pub max_replicas: usize,
}

/// A deterministic churn plan: timestamped events plus an optional
/// autoscaler. Empty (the default) means an immortal pool.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FailureSchedule {
    /// Exogenous transitions, applied in (time, list-order) order.
    pub events: Vec<ChurnEvent>,
    /// Optional queue-depth control loop.
    pub autoscale: Option<AutoscalePolicy>,
}

impl FailureSchedule {
    /// The immortal pool: no events, no autoscaler.
    pub fn none() -> Self {
        FailureSchedule::default()
    }

    /// True when this schedule changes nothing — the dispatcher's signal to
    /// take the byte-identical immortal-pool path.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty() && self.autoscale.is_none()
    }

    /// Parse the CLI/JSON DSL: a comma-separated event list, e.g.
    /// `"crash@40:1,drain@60:0,join@90"` — `crash@T:R` kills replica R at
    /// t=T, `drain@T:R` drains it, `join@T` adds/revives one replica.
    pub fn parse(dsl: &str) -> Result<Self> {
        let mut events = Vec::new();
        for item in dsl.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (kind, rest) = item
                .split_once('@')
                .with_context(|| format!("churn event '{item}': expected kind@time[:replica]"))?;
            let (t_str, replica) = match rest.split_once(':') {
                Some((t, r)) => (
                    t,
                    Some(
                        r.parse::<usize>()
                            .with_context(|| format!("churn event '{item}': bad replica"))?,
                    ),
                ),
                None => (rest, None),
            };
            let t: f64 =
                t_str.parse().with_context(|| format!("churn event '{item}': bad time"))?;
            anyhow::ensure!(t >= 0.0 && t.is_finite(), "churn event '{item}': time must be >= 0");
            let kind = match (kind, replica) {
                ("crash", Some(r)) => ChurnKind::Crash { replica: r },
                ("drain", Some(r)) => ChurnKind::Drain { replica: r },
                ("join", None) => ChurnKind::Join,
                ("crash" | "drain", None) => {
                    bail!("churn event '{item}': {kind} needs a replica (kind@time:replica)")
                }
                ("join", Some(_)) => bail!("churn event '{item}': join takes no replica"),
                _ => bail!("churn event '{item}': unknown kind (crash|drain|join)"),
            };
            events.push(ChurnEvent { t, kind });
        }
        Ok(FailureSchedule { events, autoscale: None })
    }

    /// Parse the autoscaler DSL: `"every=30,up=8,down=1,min=1,max=8"`
    /// (all keys optional; shown values are the defaults).
    pub fn parse_autoscale(dsl: &str) -> Result<AutoscalePolicy> {
        let mut p = AutoscalePolicy {
            interval: 30.0,
            up_queue: 8.0,
            down_queue: 1.0,
            min_replicas: 1,
            max_replicas: 8,
        };
        for item in dsl.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .with_context(|| format!("autoscale '{item}': expected key=value"))?;
            match key {
                "every" => p.interval = val.parse().context("autoscale every")?,
                "up" => p.up_queue = val.parse().context("autoscale up")?,
                "down" => p.down_queue = val.parse().context("autoscale down")?,
                "min" => p.min_replicas = val.parse().context("autoscale min")?,
                "max" => p.max_replicas = val.parse().context("autoscale max")?,
                other => bail!("autoscale: unknown key '{other}' (every|up|down|min|max)"),
            }
        }
        anyhow::ensure!(p.interval > 0.0, "autoscale interval must be > 0");
        anyhow::ensure!(p.min_replicas >= 1, "autoscale min must be >= 1");
        anyhow::ensure!(p.max_replicas >= p.min_replicas, "autoscale max must be >= min");
        Ok(p)
    }

    /// Render back to the DSL (round-trips through [`parse`](Self::parse);
    /// used by config echo and test shrink labels).
    pub fn to_dsl(&self) -> String {
        self.events
            .iter()
            .map(|e| match e.kind {
                ChurnKind::Crash { replica } => format!("crash@{}:{replica}", e.t),
                ChurnKind::Drain { replica } => format!("drain@{}:{replica}", e.t),
                ChurnKind::Join => format!("join@{}", e.t),
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// A seeded random schedule over `n_replicas` slots within `[0,
    /// horizon)`: `n_events` draws of crash/drain/join with uniform times.
    /// Replica 0 is never crashed or drained, so the pool always keeps one
    /// immortal member and every generated schedule can finish any workload
    /// (the property tests rely on this liveness guarantee).
    pub fn random(seed: u64, n_replicas: usize, horizon: f64, n_events: usize) -> Self {
        let mut rng = Rng::with_stream(seed, 0xc4u64);
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let t = rng.range_f64(0.0, horizon.max(1e-9));
            let kind = if n_replicas <= 1 {
                ChurnKind::Join
            } else {
                match rng.below(3) {
                    0 => ChurnKind::Crash { replica: 1 + rng.below(n_replicas as u64 - 1) as usize },
                    1 => ChurnKind::Drain { replica: 1 + rng.below(n_replicas as u64 - 1) as usize },
                    _ => ChurnKind::Join,
                }
            };
            events.push(ChurnEvent { t, kind });
        }
        events.sort_by(|a, b| a.t.total_cmp(&b.t));
        FailureSchedule { events, autoscale: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_schedule_is_empty() {
        assert!(FailureSchedule::none().is_empty());
        assert!(FailureSchedule::parse("").unwrap().is_empty());
        let mut s = FailureSchedule::none();
        s.autoscale = Some(FailureSchedule::parse_autoscale("").unwrap());
        assert!(!s.is_empty());
    }

    #[test]
    fn dsl_roundtrip() {
        let s = FailureSchedule::parse("crash@40:1, drain@60:0 ,join@90").unwrap();
        assert_eq!(
            s.events,
            vec![
                ChurnEvent { t: 40.0, kind: ChurnKind::Crash { replica: 1 } },
                ChurnEvent { t: 60.0, kind: ChurnKind::Drain { replica: 0 } },
                ChurnEvent { t: 90.0, kind: ChurnKind::Join },
            ]
        );
        let again = FailureSchedule::parse(&s.to_dsl()).unwrap();
        assert_eq!(again, s);
    }

    #[test]
    fn dsl_rejects_malformed() {
        assert!(FailureSchedule::parse("crash@40").is_err()); // missing replica
        assert!(FailureSchedule::parse("join@10:2").is_err()); // join takes none
        assert!(FailureSchedule::parse("flood@10:0").is_err()); // unknown kind
        assert!(FailureSchedule::parse("crash@-5:0").is_err()); // negative time
        assert!(FailureSchedule::parse("crash:0").is_err()); // missing @time
    }

    #[test]
    fn autoscale_dsl_defaults_and_overrides() {
        let d = FailureSchedule::parse_autoscale("").unwrap();
        assert_eq!((d.interval, d.up_queue, d.down_queue), (30.0, 8.0, 1.0));
        assert_eq!((d.min_replicas, d.max_replicas), (1, 8));
        let p = FailureSchedule::parse_autoscale("every=10,up=4,down=0.5,min=2,max=6").unwrap();
        assert_eq!((p.interval, p.up_queue, p.down_queue), (10.0, 4.0, 0.5));
        assert_eq!((p.min_replicas, p.max_replicas), (2, 6));
        assert!(FailureSchedule::parse_autoscale("every=0").is_err());
        assert!(FailureSchedule::parse_autoscale("min=0").is_err());
        assert!(FailureSchedule::parse_autoscale("min=4,max=2").is_err());
        assert!(FailureSchedule::parse_autoscale("turbo=9").is_err());
    }

    #[test]
    fn random_schedules_are_seeded_and_spare_replica_zero() {
        let a = FailureSchedule::random(7, 4, 100.0, 12);
        let b = FailureSchedule::random(7, 4, 100.0, 12);
        assert_eq!(a, b, "same seed must reproduce the same schedule");
        let c = FailureSchedule::random(8, 4, 100.0, 12);
        assert_ne!(a, c, "different seeds should differ");
        assert_eq!(a.events.len(), 12);
        for e in &a.events {
            assert!((0.0..100.0).contains(&e.t));
            if let ChurnKind::Crash { replica } | ChurnKind::Drain { replica } = e.kind {
                assert!(replica >= 1, "replica 0 is immortal by construction");
                assert!(replica < 4);
            }
        }
        assert!(a.events.windows(2).all(|w| w[0].t <= w[1].t), "sorted by time");
    }

    #[test]
    fn single_replica_random_schedule_only_joins() {
        let s = FailureSchedule::random(3, 1, 50.0, 6);
        assert!(s.events.iter().all(|e| e.kind == ChurnKind::Join));
    }
}
