//! S³/Distillbert-style shared-model baseline (paper §4.2, Table 1;
//! substitution T4 in DESIGN.md).
//!
//! The paper's critique of S³-like prediction is structural: (i) one model
//! for all workloads — "different agents may exhibit heterogeneous cost
//! distribution patterns, rendering single-model prediction inaccurate" —
//! and (ii) the predictor is itself a transformer inference, adding ~55.7 ms
//! per prediction. We reproduce (i) exactly: a single wide MLP over a shared
//! hashed vocabulary trained on the mixed multi-class corpus, blind to the
//! class tag. (ii) is reproduced by measuring this model's real (larger)
//! inference cost and, for Table 1 parity, reporting the paper's measured
//! Distillbert latency alongside.

use crate::cost::CostModel;
use crate::predictor::{evaluate, mlp, tfidf, Predictor, TrainReport};
use crate::workload::AgentClass;

/// One shared model for every agent class (no class feature — the S³ setup
/// predicts from the prompt alone).
///
/// Like S³'s Distillbert fine-tune, the regression is MSE in *raw* cost
/// space: memory-centric agent costs span >2 orders of magnitude across
/// classes, so raw-MSE training is dominated by the large classes and
/// collapses small-class predictions toward the global scale — the source
/// of the paper's 452% relative error. (Justitia's per-class models don't
/// face this: within a class the scale is homogeneous.)
pub struct SharedModelPredictor {
    /// Shared TF-IDF vectorizer (all classes).
    pub tfidf: tfidf::TfIdf,
    /// Shared regressor.
    pub mlp: mlp::Mlp,
    /// Mean of the raw-cost targets.
    pub target_mean: f64,
    /// Std of the raw-cost targets.
    pub target_std: f64,
}

impl Predictor for SharedModelPredictor {
    fn predict(&self, _class: AgentClass, input_text: &str) -> f64 {
        let x = self.tfidf.transform(input_text);
        let y = self.mlp.forward(&x)[0] as f64;
        (y * self.target_std + self.target_mean).max(1.0)
    }
}

/// Train the shared baseline on the same per-class sample budget as the
/// per-class predictor (identical total data — the comparison isolates the
/// architecture choice).
pub fn train_shared(
    cost_model: CostModel,
    samples_per_class: usize,
    eval_per_class: usize,
    seed: u64,
) -> (SharedModelPredictor, TrainReport) {
    let t0 = std::time::Instant::now();
    let mut texts: Vec<String> = Vec::new();
    let mut targets: Vec<f64> = Vec::new();
    let mut eval_set: Vec<(AgentClass, String, f64)> = Vec::new();
    for (ci, class) in AgentClass::ALL.into_iter().enumerate() {
        let mut gen = crate::workload::generator::Generator::new(seed ^ (0x1000 + ci as u64));
        for i in 0..samples_per_class + eval_per_class {
            let a = gen.agent(class, i as u32, 0.0);
            let cost = cost_model.agent_cost(&a);
            if i < samples_per_class {
                texts.push(a.input_text);
                targets.push(cost);
            } else {
                eval_set.push((class, a.input_text, cost));
            }
        }
    }

    // A deliberately bigger shared net (Distillbert stand-in): wide first
    // layer over a larger hashed vocab; one model must fit 9 heterogeneous
    // cost distributions.
    let dim = 512;
    let mut tf = tfidf::TfIdf::new(dim);
    tf.fit(&texts);
    let xs: Vec<Vec<f32>> = texts.iter().map(|t| tf.transform(t)).collect();
    // Raw-space MSE (the S³ fine-tuning objective): standardized for
    // optimizer stability, but NOT log-transformed — the squared loss is
    // dominated by the large classes.
    let mean = crate::util::stats::mean(&targets);
    let std = crate::util::stats::std_dev(&targets).max(1e-6);
    let ys: Vec<f32> = targets.iter().map(|&y| ((y - mean) / std) as f32).collect();
    let mut net = mlp::Mlp::new(&[tf.feature_dim(), 256, 64, 1], seed ^ 0x53);
    net.train(
        &xs,
        &ys,
        &mlp::TrainConfig { epochs: 120, lr: 3e-3, l2: 1e-4, batch: 32, seed: seed ^ 0x54 },
    );
    let train_secs = t0.elapsed().as_secs_f64();

    let predictor = SharedModelPredictor { tfidf: tf, mlp: net, target_mean: mean, target_std: std };
    let (rel_error, infer_ms) = evaluate(&predictor, &eval_set);
    (predictor, TrainReport { train_secs, rel_error, infer_ms })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::train_per_class;

    #[test]
    fn shared_model_trains_and_predicts() {
        let (pred, report) = train_shared(CostModel::MemoryCentric, 25, 5, 21);
        let p = pred.predict(AgentClass::CodeChecking, "check code function test assert");
        assert!(p >= 1.0);
        assert!(report.train_secs > 0.0);
        assert!(report.rel_error.is_finite());
    }

    #[test]
    fn per_class_beats_shared_on_error() {
        // The Table-1 structural claim, at reduced training budget. The
        // shared model sees the same data but cannot separate classes.
        let seed = 31;
        let (_, shared) = train_shared(CostModel::MemoryCentric, 40, 12, seed);
        let (_, per_class) = train_per_class(CostModel::MemoryCentric, 40, 12, seed);
        assert!(
            per_class.rel_error < shared.rel_error,
            "per-class {} should beat shared {}",
            per_class.rel_error,
            shared.rel_error
        );
    }
}
