//! GPS (Generalized Processor Sharing) fluid reference (paper §4.3,
//! Appendix B). Computes, for a set of agents with arrival times and costs,
//! the exact completion time each would have under idealized fair sharing —
//! the yardstick both for Justitia's priorities and for the Theorem-B.1
//! delay-bound property tests.

use crate::cost::CostModel;
use crate::sched::vtime::VirtualClock;
use crate::workload::{AgentId, Suite};
use std::collections::HashMap;

/// Outcome of a GPS fluid run.
#[derive(Debug, Clone)]
pub struct GpsResult {
    /// Real-time completion per agent (f̄_j).
    pub finish: HashMap<AgentId, f64>,
    /// Virtual finish tags (F_j) — Justitia's priorities.
    pub tags: HashMap<AgentId, f64>,
}

impl GpsResult {
    /// Real-time GPS completion of an agent.
    pub fn finish_of(&self, agent: AgentId) -> f64 {
        self.finish[&agent]
    }

    /// GPS job completion time (completion − arrival).
    pub fn jct(&self, agent: AgentId, arrival: f64) -> f64 {
        self.finish[&agent] - arrival
    }
}

/// Run the GPS fluid over explicit (agent, arrival, cost) triples.
/// `capacity_tokens` = M; `rate_scale` = iterations/second (see vtime).
pub fn run(
    agents: &[(AgentId, f64, f64)],
    capacity_tokens: u64,
    rate_scale: f64,
) -> GpsResult {
    let mut sorted: Vec<_> = agents.to_vec();
    sorted.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
    let mut vc = VirtualClock::new(capacity_tokens, rate_scale);
    let mut tags = HashMap::new();
    for (id, arrival, cost) in &sorted {
        tags.insert(*id, vc.on_arrival(*id, *cost, *arrival));
    }
    vc.finish_all();
    let finish = sorted.iter().map(|(id, _, _)| (*id, vc.gps_finish(*id).unwrap())).collect();
    GpsResult { finish, tags }
}

/// Run the GPS fluid over a workload suite with a cost model. Agent costs
/// are the expanded end-to-end ground truth (static DAG + deterministically
/// spawned work) — identical to plain Eq. 1 sums for agents without a spawn
/// rule.
pub fn run_suite(
    suite: &Suite,
    model: CostModel,
    capacity_tokens: u64,
    rate_scale: f64,
) -> GpsResult {
    let triples: Vec<(AgentId, f64, f64)> = suite
        .agents
        .iter()
        .map(|a| (a.id, a.arrival, crate::cost::expanded_agent_cost(model, a)))
        .collect();
    run(&triples, capacity_tokens, rate_scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_hand_computed_two_agent_case() {
        // M=10/s. Agent 1: arrives 0, cost 60. Agent 2: arrives 2, cost 20.
        // [0,2): agent1 alone, served 20, remaining 40.
        // [2,..): both active at 5/s. Agent2 done after 4s (t=6), agent1 has
        // 40-20=20 left at t=6, alone at 10/s → t=8.
        let r = run(&[(1, 0.0, 60.0), (2, 2.0, 20.0)], 10, 1.0);
        assert!((r.finish_of(2) - 6.0).abs() < 1e-9);
        assert!((r.finish_of(1) - 8.0).abs() < 1e-9);
        assert!((r.jct(1, 0.0) - 8.0).abs() < 1e-9);
        assert!((r.jct(2, 2.0) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn idle_gap_between_agents() {
        // Agent 1 finishes before agent 2 arrives; server idles in between.
        let r = run(&[(1, 0.0, 10.0), (2, 5.0, 10.0)], 10, 1.0);
        assert!((r.finish_of(1) - 1.0).abs() < 1e-9);
        assert!((r.finish_of(2) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn tag_order_equals_finish_order_for_concurrent_agents() {
        let agents: Vec<(AgentId, f64, f64)> =
            vec![(1, 0.0, 300.0), (2, 0.0, 100.0), (3, 1.0, 50.0), (4, 2.0, 400.0)];
        let r = run(&agents, 50, 1.0);
        let mut by_tag: Vec<_> = agents.iter().map(|(id, ..)| *id).collect();
        by_tag.sort_by(|a, b| r.tags[a].total_cmp(&r.tags[b]));
        let mut by_finish: Vec<_> = agents.iter().map(|(id, ..)| *id).collect();
        by_finish.sort_by(|a, b| r.finish[a].total_cmp(&r.finish[b]));
        assert_eq!(by_tag, by_finish);
    }

    #[test]
    fn runs_over_suite() {
        let cfg = crate::config::WorkloadConfig { n_agents: 20, window_secs: 60.0, ..Default::default() };
        let suite = crate::workload::trace::build_suite(&cfg);
        let r = run_suite(&suite, CostModel::MemoryCentric, 7344, 20.0);
        assert_eq!(r.finish.len(), 20);
        for a in &suite.agents {
            assert!(r.finish_of(a.id) >= a.arrival);
        }
    }
}
