//! Fig. 11 — cost-model ablation: Justitia (memory-centric KV token-time)
//! vs Justitia/C (VTC's compute-centric p + 2d) on the Fig. 7a workload.
//!
//! Paper: compute-centric cost degrades JCT by up to 42.3%.

use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 11: memory-centric vs compute-centric cost modeling");
    let mut out = ResultsFile::new("bench_fig11.txt");
    out.line(format!("{:>7} {:<12} {:>10} {:>10}", "density", "variant", "avgJCT", "p90JCT"));
    for density in [2.0, 3.0] {
        let rows = justitia::experiments::fig11(300, density, 42);
        for r in &rows {
            out.line(format!(
                "{:>6}x {:<12} {:>9.1}s {:>9.1}s",
                density,
                r.policy.name(),
                r.avg_jct,
                r.p90_jct
            ));
        }
        out.line(format!(
            "{:>6}x degradation: avg {:+.1}%, p90 {:+.1}% (paper: up to 42.3%)",
            density,
            (rows[1].avg_jct / rows[0].avg_jct - 1.0) * 100.0,
            (rows[1].p90_jct / rows[0].p90_jct - 1.0) * 100.0
        ));
    }
}
