// Fixture: NaN-safe float ordering — the patterns the contract requires.

pub fn pick(keys: &mut Vec<(u32, f64)>) {
    // Total order over floats: no panic, NaN has a defined slot.
    keys.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
}

#[derive(PartialEq, PartialOrd)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

pub fn pick_min(keys: &[(u32, f64)]) -> Option<u32> {
    keys.iter().min_by_key(|(id, k)| (OrdF64(*k), *id)).map(|(id, _)| *id)
}
