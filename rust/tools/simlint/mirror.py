#!/usr/bin/env python3
"""Reference mirror of the simlint rule semantics (DESIGN.md §16).

The Rust binary (`cargo run -p simlint`) is authoritative; this mirror
re-implements the lexer and the four rules line-for-line so the contract can
be audited in environments without a cargo toolchain (e.g. minimal review
containers), and doubles as an executable specification: if the two ever
disagree on this tree, one of them has a bug.

Usage:
    python3 mirror.py [--root DIR] [--manifest FILE|--no-manifest]

Exit status mirrors the binary: 0 clean, 1 violations.
"""

import os
import sys

RULES = ("unordered-iter", "ambient-nondet", "nan-order", "knob-default")
CORE_PREFIXES = ("engine/", "sched/", "cluster/", "kv/", "prefix/", "cost/", "metrics/")
ITER_METHODS = {
    "iter", "iter_mut", "keys", "values", "values_mut", "drain",
    "into_iter", "into_keys", "into_values", "retain",
}

IDENT, PUNCT, LIT, LIFETIME = "Ident", "Punct", "Lit", "Lifetime"


# ---------------------------------------------------------------- lexer ----

def parse_annotation(comment, line):
    t = comment.lstrip("/!").lstrip()
    if not t.startswith("simlint::allow("):
        return None
    rest = t[len("simlint::allow("):]
    close = rest.find(")")
    if close < 0:
        return None
    rule = rest[:close].strip()
    after = rest[close + 1:]
    reason = after[1:].strip() if after.startswith(":") else ""
    return {"line": line, "own_line": False, "rule": rule, "reason": reason}


def char_literal_end(b, i):
    j = i + 1
    if j >= len(b):
        return None
    if b[j] == "\\":
        j += 2
        if j <= len(b) and j - 1 < len(b) and b[j - 1] == "u" and j < len(b) and b[j] == "{":
            while j < len(b) and b[j] != "}":
                j += 1
            j += 1
    elif b[j] == "'":
        return None
    else:
        j += 1
    return j + 1 if (j < len(b) and b[j] == "'") else None


def is_raw_or_byte_string(b, i):
    j = i
    if b[j] == "b":
        j += 1
    if j < len(b) and b[j] == "r":
        j += 1
    while j < len(b) and b[j] == "#":
        j += 1
    return (
        j > i
        and j < len(b)
        and b[j] == '"'
        and (b[i] == "r" or (b[i] == "b" and j > i + 1) or (i + 1 < len(b) and b[i + 1] == '"'))
    )


def lex(src):
    b = src
    toks, annotations = [], []
    code_lines = set()
    i, line = 0, 1
    n = len(b)
    while i < n:
        c = b[i]
        if c == "\n":
            line += 1
            i += 1
        elif c.isspace():
            i += 1
        elif c == "/" and i + 1 < n and b[i + 1] == "/":
            j = i + 2
            while j < n and b[j] != "\n":
                j += 1
            ann = parse_annotation(b[i + 2:j], line)
            if ann:
                annotations.append(ann)
            i = j
        elif c == "/" and i + 1 < n and b[i + 1] == "*":
            depth = 1
            i += 2
            while i < n and depth > 0:
                if b[i] == "\n":
                    line += 1
                    i += 1
                elif b[i] == "/" and i + 1 < n and b[i + 1] == "*":
                    depth += 1
                    i += 2
                elif b[i] == "*" and i + 1 < n and b[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
        elif c == '"':
            l0 = line
            i += 1
            while i < n:
                if b[i] == "\\":
                    i += 2
                elif b[i] == "\n":
                    line += 1
                    i += 1
                elif b[i] == '"':
                    i += 1
                    break
                else:
                    i += 1
            code_lines.add(l0)
            toks.append(('""', l0, LIT))
        elif c in "rb" and is_raw_or_byte_string(b, i):
            l0 = line
            if b[i] == "b":
                i += 1
            raw = i < n and b[i] == "r"
            if raw:
                i += 1
            hashes = 0
            while i < n and b[i] == "#":
                hashes += 1
                i += 1
            i += 1  # opening quote
            while i < n:
                if b[i] == "\n":
                    line += 1
                    i += 1
                elif b[i] == "\\" and not raw:
                    i += 2
                elif b[i] == '"':
                    j, h = i + 1, 0
                    while h < hashes and j < n and b[j] == "#":
                        h += 1
                        j += 1
                    if h == hashes:
                        i = j
                        break
                    i += 1
                else:
                    i += 1
            code_lines.add(l0)
            toks.append(('""', l0, LIT))
        elif c == "'":
            l0 = line
            end = char_literal_end(b, i)
            if end is not None:
                i = end
                code_lines.add(l0)
                toks.append(("' '", l0, LIT))
            else:
                j = i + 1
                while j < n and (b[j].isalnum() or b[j] == "_"):
                    j += 1
                code_lines.add(l0)
                toks.append((b[i:j], l0, LIFETIME))
                i = j
        elif c.isalpha() or c == "_":
            l0 = line
            j = i
            while j < n and (b[j].isalnum() or b[j] == "_"):
                j += 1
            code_lines.add(l0)
            toks.append((b[i:j], l0, IDENT))
            i = j
        elif c.isdigit():
            l0 = line
            j = i
            while j < n:
                d = b[j]
                if d.isalnum() or d == "_":
                    j += 1
                elif d == "." and j + 1 < n and b[j + 1] != "." and not b[j + 1].isalpha():
                    j += 1
                elif d in "+-" and j > i and b[j - 1] in "eE":
                    j += 1
                else:
                    break
            code_lines.add(l0)
            toks.append((b[i:j], l0, LIT))
            i = j
        else:
            code_lines.add(line)
            toks.append((c, line, PUNCT))
            i += 1
    for ann in annotations:
        ann["own_line"] = ann["line"] not in code_lines
    return toks, annotations, code_lines


def next_code_line(toks, line):
    for (_, l, _) in toks:
        if l > line:
            return l
    return None


# ---------------------------------------------------------------- rules ----

def is_core(rel):
    return rel.startswith(CORE_PREFIXES)


def collect_hash_names(toks):
    names = set()
    for i, (text, _, kind) in enumerate(toks):
        if kind != IDENT or text not in ("HashMap", "HashSet"):
            continue
        if i + 1 >= len(toks) or toks[i + 1][0] != "<":
            continue
        j = i
        while j >= 2 and toks[j - 1][0] == ":" and toks[j - 2][0] == ":":
            if j >= 3 and toks[j - 3][2] == IDENT:
                j -= 3
            else:
                break
        while j >= 1 and (toks[j - 1][0] in ("&", "mut") or toks[j - 1][2] == LIFETIME):
            j -= 1
        if j >= 2 and toks[j - 1][0] == ":" and toks[j - 2][2] == IDENT:
            name = toks[j - 2][0]
            before = toks[j - 3][0] if j >= 3 else None
            if name != "self" and before != ":":
                names.add(name)
    i = 0
    while i < len(toks):
        if toks[i][2] == IDENT and toks[i][0] == "let":
            j = i + 1
            if j < len(toks) and toks[j][0] == "mut":
                j += 1
            if j < len(toks) and toks[j][2] == IDENT:
                k, depth, has_hash = j + 1, 0, False
                while k < len(toks):
                    t = toks[k][0]
                    if t in "([{":
                        depth += 1
                    elif t in ")]}":
                        depth -= 1
                    elif t == ";" and depth <= 0:
                        break
                    elif t in ("HashMap", "HashSet"):
                        has_hash = True
                    k += 1
                if has_hash:
                    names.add(toks[j][0])
                i = k
                continue
        i += 1
    return names


def r1(rel, toks, names):
    out = []
    for i, (text, _, kind) in enumerate(toks):
        if kind == IDENT and text in names:
            prev = toks[i - 1][0] if i >= 1 else None
            if prev == ".":
                recv_ok = i >= 2 and toks[i - 2][0] == "self"
            elif prev == ":":
                recv_ok = False
            else:
                recv_ok = True
            if (
                recv_ok
                and i + 3 < len(toks)
                and toks[i + 1][0] == "."
                and toks[i + 3][0] == "("
                and toks[i + 2][0] in ITER_METHODS
            ):
                m = toks[i + 2]
                out.append((rel, m[1], "unordered-iter",
                            "iteration (`.%s()`) over unordered `%s`" % (m[0], text)))
        if kind == IDENT and text == "for":
            j, depth, found_in = i + 1, 0, None
            while j < len(toks) and j < i + 64:
                t = toks[j][0]
                if t in "([":
                    depth += 1
                elif t in ")]":
                    depth -= 1
                elif t in "{;":
                    break
                elif t == "in" and depth == 0 and toks[j][2] == IDENT:
                    found_in = j
                    break
                j += 1
            if found_in is None:
                continue
            j = found_in + 1
            while j < len(toks) and toks[j][0] in ("&", "mut"):
                j += 1
            if (
                j + 1 < len(toks)
                and toks[j][0] == "self"
                and toks[j + 1][0] == "."
            ):
                name_idx, brace_idx = j + 2, j + 3
            else:
                name_idx, brace_idx = j, j + 1
            if brace_idx < len(toks):
                nm = toks[name_idx]
                if nm[2] == IDENT and nm[0] in names and toks[brace_idx][0] == "{":
                    out.append((rel, nm[1], "unordered-iter",
                                "`for` over unordered `%s`" % nm[0]))
    return out


def r2(rel, toks):
    out = []

    def path2(i, a, b2):
        return (
            toks[i][0] == a
            and i + 3 < len(toks)
            and toks[i + 1][0] == ":"
            and toks[i + 2][0] == ":"
            and toks[i + 3][0] == b2
        )

    for i, (text, line, kind) in enumerate(toks):
        if kind != IDENT:
            continue
        if path2(i, "Instant", "now"):
            out.append((rel, line, "ambient-nondet", "`Instant::now()`"))
        elif text == "SystemTime":
            out.append((rel, line, "ambient-nondet", "`SystemTime`"))
        elif text in ("thread_rng", "ThreadRng"):
            out.append((rel, line, "ambient-nondet", "`thread_rng`"))
        elif (
            text == "env"
            and i + 3 < len(toks)
            and toks[i + 1][0] == ":"
            and toks[i + 2][0] == ":"
            and toks[i + 3][0] in ("var", "vars", "var_os", "vars_os", "args", "args_os", "temp_dir")
        ):
            out.append((rel, line, "ambient-nondet", "`std::env` read"))
        elif path2(i, "thread", "current"):
            out.append((rel, line, "ambient-nondet", "`thread::current()`"))
        elif text == "available_parallelism":
            out.append((rel, line, "ambient-nondet", "`available_parallelism()`"))
    return out


def r3(rel, toks):
    out = []
    for i, (text, line, kind) in enumerate(toks):
        if (
            kind == IDENT
            and text == "partial_cmp"
            and i >= 1
            and toks[i - 1][0] == "."
            and i + 1 < len(toks)
            and toks[i + 1][0] == "("
        ):
            out.append((rel, line, "nan-order", "`.partial_cmp(..)` call"))
    return out


def apply_annotations(rel, candidates, toks, annotations):
    violations, allowed, stale = [], [], []
    used = set()
    for c in candidates:
        hit = None
        for ai, a in enumerate(annotations):
            if a["rule"] == c[2] and (
                a["line"] == c[1]
                or (a["own_line"] and next_code_line(toks, a["line"]) == c[1])
            ):
                hit = ai
                break
        if hit is not None:
            used.add(hit)
            a = annotations[hit]
            if a["reason"] == "":
                violations.append((rel, a["line"], c[2], "allow annotation has no justification"))
            else:
                allowed.append(c)
        else:
            violations.append(c)
    for ai, a in enumerate(annotations):
        if ai not in used and a["rule"] in RULES:
            stale.append((rel, a["line"], "stale-allow",
                          "simlint::allow(%s) suppresses nothing" % a["rule"]))
        elif a["rule"] not in RULES:
            violations.append((rel, a["line"], "unknown-rule",
                               "unknown simlint rule `%s`" % a["rule"]))
    return violations, allowed, stale


def lint_file(rel, src):
    toks, annotations, _ = lex(src)
    candidates = []
    if is_core(rel):
        names = collect_hash_names(toks)
        candidates += r1(rel, toks, names)
        candidates += r2(rel, toks)
    candidates += r3(rel, toks)
    return apply_annotations(rel, candidates, toks, annotations)


def default_impl_fields(toks):
    i = 0
    n = len(toks)
    while i + 3 < n:
        if (toks[i][0], toks[i + 1][0], toks[i + 2][0], toks[i + 3][0]) == ("impl", "Default", "for", "Config"):
            break
        i += 1
    if i + 3 >= n:
        return None
    while i + 1 < n and not (toks[i][0] == "fn" and toks[i + 1][0] == "default"):
        i += 1
    while i + 1 < n and not (toks[i][0] == "Config" and toks[i + 1][0] == "{"):
        i += 1
    if i + 1 >= n:
        return None
    fields = []
    j = i + 2
    while j < n and toks[j][0] != "}":
        if toks[j][2] != IDENT or j + 1 >= n or toks[j + 1][0] != ":":
            return None
        name, line = toks[j][0], toks[j][1]
        k, depth, value = j + 2, 0, ""
        while k < n:
            t = toks[k][0]
            if t in "([{":
                depth += 1
            elif t in ")]":
                depth -= 1
            elif t == "}":
                if depth > 0:
                    depth -= 1
                else:
                    break
            elif t == "," and depth == 0:
                break
            value += t
            k += 1
        fields.append((name, value, line))
        j = k + 1 if (k < n and toks[k][0] == ",") else k
    return fields


def r4(rel, config_src, manifest_rel, manifest_src):
    out = []
    toks, _, _ = lex(config_src)
    manifest = []
    for ln, raw in enumerate(manifest_src.splitlines()):
        t = raw.strip()
        if not t or t.startswith("#"):
            continue
        if "=" in t:
            k, _, v = t.partition("=")
            manifest.append((k.strip(), "".join(v.split()), ln + 1))
        else:
            out.append((manifest_rel, ln + 1, "knob-default", "manifest line is not `field = value`"))
    fields = default_impl_fields(toks)
    if fields is None:
        out.append((rel, 1, "knob-default", "no `impl Default for Config` literal found"))
        return out
    for name, value, line in fields:
        pin = next((w for k, w, _ in manifest if k == name), None)
        if pin is None:
            out.append((rel, line, "knob-default", "knob `%s` is not registered" % name))
        elif pin != value:
            out.append((rel, line, "knob-default",
                        "default for knob `%s` is `%s` but manifest pins `%s`" % (name, value, pin)))
    for k, _, ln in manifest:
        if not any(f[0] == k for f in fields):
            out.append((manifest_rel, ln, "knob-default", "manifest registers knob `%s` with no field" % k))
    return out


# ----------------------------------------------------------------- main ----

def run(root, manifest):
    files = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                files.append(os.path.join(dirpath, f))
    files.sort()
    violations, allowed, stale = [], [], []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        v, a, s = lint_file(rel, src)
        violations += v
        allowed += a
        stale += s
    if manifest:
        cfg = os.path.join(root, "config/mod.rs")
        if os.path.exists(cfg):
            with open(cfg, encoding="utf-8") as fh:
                config_src = fh.read()
            with open(manifest, encoding="utf-8") as fh:
                manifest_src = fh.read()
            violations += r4("config/mod.rs", config_src, os.path.basename(manifest), manifest_src)
    violations.sort(key=lambda d: (d[0], d[1]))
    stale.sort(key=lambda d: (d[0], d[1]))
    return len(files), violations, allowed, stale


def main(argv):
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.join(here, "../../src")
    manifest = os.path.join(here, "knob_defaults.manifest")
    args = list(argv)
    while args:
        a = args.pop(0)
        if a == "--root":
            root = args.pop(0)
        elif a == "--manifest":
            manifest = args.pop(0)
        elif a == "--no-manifest":
            manifest = None
        else:
            print("unknown argument %r" % a, file=sys.stderr)
            return 2
    nfiles, violations, allowed, stale = run(root, manifest)
    for f, l, r, m in violations:
        print("%s:%s: simlint[%s] %s" % (f, l, r, m))
    for f, l, r, m in stale:
        print("%s:%s: simlint[%s] %s (warning)" % (f, l, r, m))
    print("simlint: %d files, %d violations, %d allowed (annotated), %d stale annotations"
          % (nfiles, len(violations), len(allowed), len(stale)))
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
