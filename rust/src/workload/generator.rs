//! Synthetic agent generation (substitution T3 in DESIGN.md).
//!
//! For each agent we draw an *input-size factor* u ∈ [0.5, 2.0] (how big the
//! user's input is relative to the class average), then per stage draw the
//! fan-out and per task the (p, d) token lengths from the class's skew-normal
//! distributions, scaled by u where the template says so. Finally we
//! synthesize a prompt *text* from the class theme whose word count tracks
//! the total prompt tokens — so the TF-IDF+MLP predictor (paper §4.2) has
//! real signal: cost correlates with input length and class keywords,
//! exactly the structure Appendix A reports.
//!
//! Beyond the staged form, [`Generator::dag_agent`] arranges the same
//! class-calibrated task sizes into the three DAG workflow shapes of
//! DESIGN.md §9 — map-reduce with partial combiners, tree-of-thought
//! branching, and sequential pipelines — optionally with a dynamic
//! [`SpawnSpec`](crate::workload::SpawnSpec) rule.

use crate::util::rng::Rng;
use crate::workload::classes::{AgentClass, LenDist, StageTemplate};
use crate::workload::{AgentId, AgentSpec, InferenceSpec, SpawnSpec, TaskId};

/// Draw a truncated skew-normal length.
pub fn sample_len(rng: &mut Rng, d: &LenDist, scale: f64) -> u32 {
    let x = rng.skew_normal(d.xi * scale, d.omega * scale.sqrt(), d.alpha);
    (x.round() as i64).clamp(d.min as i64, ((d.max as f64 * scale).round() as i64).max(d.min as i64 + 1))
        as u32
}

/// The three DAG workflow shape families (DESIGN.md §9): the scenario axes
/// the staged form cannot express.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DagShape {
    /// N map tasks → ⌈√N⌉-sized partial combiners → one final merge. The
    /// combiners depend on *subsets* of the maps, so the DAG is strictly
    /// more parallel than a stage barrier.
    MapReduce,
    /// Tree-of-thought: a root, `branch` children per node for two levels,
    /// and a final selection task over all leaves.
    Tree,
    /// A sequential chain of single-task levels (each task depends only on
    /// its predecessor) — the workflow with zero intra-agent parallelism.
    Pipeline,
}

impl DagShape {
    /// All shapes, in experiment/report order.
    pub const ALL: [DagShape; 3] = [DagShape::MapReduce, DagShape::Tree, DagShape::Pipeline];

    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            DagShape::MapReduce => "map-reduce",
            DagShape::Tree => "tree",
            DagShape::Pipeline => "pipeline",
        }
    }

    /// Parse a shape name.
    pub fn by_name(name: &str) -> Option<DagShape> {
        match name {
            "map-reduce" | "mapreduce" => Some(DagShape::MapReduce),
            "tree" => Some(DagShape::Tree),
            "pipeline" => Some(DagShape::Pipeline),
            _ => None,
        }
    }
}

/// Generator for agents of the nine §5.1 classes.
#[derive(Debug, Clone)]
pub struct Generator {
    rng: Rng,
}

impl Generator {
    /// Generator seeded for a reproducible agent stream.
    pub fn new(seed: u64) -> Self {
        Generator { rng: Rng::with_stream(seed, 0x9a9e) }
    }

    /// Generate one agent of `class` with a fresh input. `id` and `arrival`
    /// are assigned by the caller (trace builder).
    pub fn agent(&mut self, class: AgentClass, id: AgentId, arrival: f64) -> AgentSpec {
        let mut rng = self.rng.fork(id as u64 + 1);
        let template = class.template();
        // Input-size factor: lognormal around 1, clamped.
        let u = rng.lognormal(0.0, 0.25).clamp(0.5, 2.0);

        let mut stages: Vec<Vec<InferenceSpec>> = Vec::with_capacity(template.stages.len());
        let mut index = 0u32;
        for (s, st) in template.stages.iter().enumerate() {
            let n = stage_fan_out(&mut rng, st, u);
            let mut tasks = Vec::with_capacity(n as usize);
            for _ in 0..n {
                // Per-stage lengths follow the class's skew-normal fit
                // (Appendix A); the input-size factor u expresses itself
                // through fan-out (more chunks), not longer chunks — keeping
                // per-stage ranges tight, as the paper measures.
                let prompt = sample_len(&mut rng, &st.prompt, 1.0);
                let decode = sample_len(&mut rng, &st.decode, 1.0);
                tasks.push(InferenceSpec {
                    id: TaskId { agent: id, index },
                    stage: s as u32,
                    deps: Vec::new(),
                    prompt_tokens: prompt,
                    decode_tokens: decode,
                    kind: st.kind,
                    prefix_group: None,
                });
                index += 1;
            }
            stages.push(tasks);
        }

        let input_text = synthesize_input(&mut rng, &template.theme, &stages[0], u);
        AgentSpec::from_stages(id, class, arrival, stages, input_text)
    }

    /// Generate one *DAG-shaped* agent: the class's calibrated (p, d)
    /// distributions arranged into `shape`, with a deterministic spawn rule
    /// when `spawn_prob > 0`. Fully reproducible per (generator seed, id),
    /// like [`Generator::agent`].
    pub fn dag_agent(
        &mut self,
        class: AgentClass,
        shape: DagShape,
        id: AgentId,
        arrival: f64,
        spawn_prob: f64,
        branch: u32,
    ) -> AgentSpec {
        let mut rng = self.rng.fork(id as u64 + 1);
        let template = class.template();
        let u = rng.lognormal(0.0, 0.25).clamp(0.5, 2.0);
        let stages = template.stages;
        let first = &stages[0];
        let last = stages.last().unwrap();

        // Helper drawing one task from a stage template's distributions.
        let task =
            |rng: &mut Rng, index: u32, stage: u32, st: &StageTemplate, deps: Vec<u32>| {
                InferenceSpec {
                    id: TaskId { agent: id, index },
                    stage,
                    deps: deps.into_iter().map(|j| TaskId { agent: id, index: j }).collect(),
                    prompt_tokens: sample_len(rng, &st.prompt, 1.0),
                    decode_tokens: sample_len(rng, &st.decode, 1.0),
                    kind: st.kind,
                    prefix_group: None,
                }
            };

        let mut tasks: Vec<InferenceSpec> = Vec::new();
        match shape {
            DagShape::MapReduce => {
                let n = stage_fan_out(&mut rng, first, u).max(2);
                for i in 0..n {
                    tasks.push(task(&mut rng, i, 0, first, Vec::new()));
                }
                // Partial combiners over ⌈√n⌉-sized chunks of the maps,
                // clamped so there are always ≥ 2 combiners (a single
                // combiner would degenerate back into a stage barrier).
                let group = ((n as f64).sqrt().ceil() as u32).min((n - 1).max(1));
                let combiners: Vec<u32> = (0..n.div_ceil(group))
                    .map(|c| {
                        let deps: Vec<u32> = (c * group..((c + 1) * group).min(n)).collect();
                        let idx = tasks.len() as u32;
                        tasks.push(task(&mut rng, idx, 1, last, deps));
                        idx
                    })
                    .collect();
                let idx = tasks.len() as u32;
                tasks.push(task(&mut rng, idx, 2, last, combiners));
            }
            DagShape::Tree => {
                let b = branch.clamp(2, 6);
                tasks.push(task(&mut rng, 0, 0, first, Vec::new()));
                let mid = stages.get(1).unwrap_or(first);
                let level1: Vec<u32> = (0..b)
                    .map(|_| {
                        let idx = tasks.len() as u32;
                        tasks.push(task(&mut rng, idx, 1, mid, vec![0]));
                        idx
                    })
                    .collect();
                let mut leaves: Vec<u32> = Vec::new();
                for &p in &level1 {
                    for _ in 0..b {
                        let idx = tasks.len() as u32;
                        tasks.push(task(&mut rng, idx, 2, mid, vec![p]));
                        leaves.push(idx);
                    }
                }
                let idx = tasks.len() as u32;
                tasks.push(task(&mut rng, idx, 3, last, leaves));
            }
            DagShape::Pipeline => {
                let len = stages.len() as u32 + rng.range_u64(1, 3) as u32;
                for i in 0..len {
                    let st = &stages[(i as usize).min(stages.len() - 1)];
                    let deps = if i == 0 { Vec::new() } else { vec![i - 1] };
                    tasks.push(task(&mut rng, i, i, st, deps));
                }
            }
        }

        let spawn = (spawn_prob > 0.0).then(|| SpawnSpec {
            prob: spawn_prob,
            branch: branch.max(1),
            max_depth: 2,
            seed: rng.next_u64(),
        });
        let roots: Vec<InferenceSpec> =
            tasks.iter().filter(|t| t.deps.is_empty()).cloned().collect();
        let input_text = synthesize_input(&mut rng, &template.theme, &roots, u);
        AgentSpec { id, class, arrival, tasks, spawn, input_text }
    }
}

fn stage_fan_out(rng: &mut Rng, st: &StageTemplate, u: f64) -> u32 {
    let base = rng.range_u64(st.fan_out.lo as u64, st.fan_out.hi as u64) as f64;
    if st.fan_out.scales_with_input {
        ((base * u).round() as u32).max(1)
    } else {
        base as u32
    }
}

/// Synthesize the user-facing input text from the agent's *root* tasks (the
/// ones the user input directly feeds). Properties the predictor can exploit
/// (and that the paper's Appendix A documents for real agents):
///   - word count ≈ total root prompt tokens (the user input drives the
///     first level's prompts),
///   - class-theme keywords appear throughout (class-identifying signal),
///   - a "chunk marker" per root task (fan-out signal).
fn synthesize_input(rng: &mut Rng, theme: &str, roots: &[InferenceSpec], u: f64) -> String {
    let theme_words: Vec<&str> = theme.split_whitespace().collect();
    let filler = [
        "the", "and", "with", "for", "from", "that", "this", "into", "over", "under", "about",
        "data", "item", "value", "note", "case", "part", "line", "page", "field", "word",
    ];
    let target_words: usize = roots.iter().map(|t| t.prompt_tokens as usize).sum::<usize>()
        .saturating_sub(roots.len() * 8)
        .max(8);
    let mut out = String::with_capacity(target_words * 6);
    let mut words = 0usize;
    for (k, _task) in roots.iter().enumerate() {
        out.push_str(&format!("CHUNK {k} : "));
        words += 3;
        let per_chunk = target_words / roots.len().max(1);
        for _ in 0..per_chunk {
            // Mix ~30% theme words with filler; approximates real prompts
            // where the task vocabulary dominates TF-IDF.
            // Theme words are sparse (~10%): real prompts do not announce
            // their agent class, which is precisely why the paper's
            // per-class prior beats a single shared model (§4.2/Table 1) —
            // classes with similar-looking inputs (e.g. SC vs KBQAV) differ
            // 10-30x in decode-driven cost that text alone cannot reveal.
            let w = if rng.chance(0.1) {
                *rng.choose(&theme_words)
            } else {
                *rng.choose(&filler)
            };
            out.push_str(w);
            out.push(' ');
            words += 1;
        }
        out.push('\n');
    }
    // Scale hint token, as real inputs carry explicit size cues (file sizes,
    // document counts) that predictors learn from.
    out.push_str(&format!("scale {:.2}\n", u));
    let _ = words;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::CostModel;
    use crate::tokenizer::Tokenizer;

    #[test]
    fn deterministic_per_seed_and_id() {
        let mut g1 = Generator::new(5);
        let mut g2 = Generator::new(5);
        let a1 = g1.agent(AgentClass::DocumentMerging, 3, 1.0);
        let a2 = g2.agent(AgentClass::DocumentMerging, 3, 1.0);
        assert_eq!(a1, a2);
        let b = g1.agent(AgentClass::DocumentMerging, 4, 1.0);
        assert_ne!(a1.tasks, b.tasks);
    }

    #[test]
    fn respects_template_structure() {
        let mut g = Generator::new(7);
        for class in AgentClass::ALL {
            let a = g.agent(class, 0, 0.0);
            let t = class.template();
            let stages = a.as_stages().expect("agent() builds staged agents");
            assert_eq!(stages.len(), t.stages.len(), "{class:?}");
            for (stage, st) in stages.iter().zip(t.stages.iter()) {
                assert!(!stage.is_empty());
                for task in stage {
                    assert!(task.prompt_tokens >= st.prompt.min, "{class:?} {}", st.kind);
                    assert!(task.decode_tokens >= st.decode.min);
                    assert_eq!(task.kind, st.kind);
                }
            }
            // Task ids are dense and ordered.
            let ids: Vec<u32> = a.tasks().map(|t| t.id.index).collect();
            assert_eq!(ids, (0..a.n_tasks() as u32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn size_buckets_order_by_cost() {
        // Large-class agents must cost (in KV token-time) well beyond small
        // ones, or the 72/26/2 mix loses its meaning.
        let mut g = Generator::new(11);
        let m = CostModel::MemoryCentric;
        let avg = |class: AgentClass, g: &mut Generator| -> f64 {
            (0..30).map(|i| m.agent_cost(&g.agent(class, 1000 + i, 0.0))).sum::<f64>() / 30.0
        };
        let ev = avg(AgentClass::EquationVerification, &mut g);
        let sc = avg(AgentClass::SelfConsistency, &mut g);
        let mrs = avg(AgentClass::MapReduceSummarization, &mut g);
        let dm = avg(AgentClass::DocumentMerging, &mut g);
        assert!(ev * 5.0 < sc, "EV {ev} vs SC {sc}");
        assert!(sc * 2.0 < mrs, "SC {sc} vs MRS {mrs}");
        assert!(sc * 2.0 < dm, "SC {sc} vs DM {dm}");
    }

    #[test]
    fn input_text_tracks_prompt_volume() {
        let mut g = Generator::new(13);
        let tok = Tokenizer::new(4096);
        // Correlation between input token count and root prompt volume
        // across many agents should be strongly positive.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..60 {
            let a = g.agent(AgentClass::MapReduceSummarization, i, 0.0);
            xs.push(tok.count(&a.input_text) as f64);
            ys.push(
                a.tasks()
                    .filter(|t| t.deps.is_empty())
                    .map(|t| t.prompt_tokens as f64)
                    .sum::<f64>(),
            );
        }
        let corr = correlation(&xs, &ys);
        assert!(corr > 0.8, "corr={corr}");
    }

    #[test]
    fn input_text_contains_theme_and_chunks() {
        let mut g = Generator::new(17);
        let a = g.agent(AgentClass::CodeChecking, 0, 0.0);
        assert!(a.input_text.contains("CHUNK 0"));
        let theme_hit = AgentClass::CodeChecking
            .template()
            .theme
            .split_whitespace()
            .any(|w| a.input_text.contains(w));
        assert!(theme_hit);
    }

    #[test]
    fn dag_agent_shapes_are_well_formed() {
        let mut g = Generator::new(23);
        for (i, shape) in DagShape::ALL.into_iter().enumerate() {
            for class in [AgentClass::MapReduceSummarization, AgentClass::CodeChecking] {
                let a = g.dag_agent(class, shape, 100 + i as u32, 0.0, 0.3, 3);
                // Topological invariants: dense indices, deps point backward.
                for (j, t) in a.tasks.iter().enumerate() {
                    assert_eq!(t.id.index as usize, j);
                    for d in &t.deps {
                        assert!(d.index < t.id.index, "{shape:?} forward dep");
                        assert_eq!(d.agent, a.id);
                    }
                }
                assert!(a.spawn.is_some());
                assert!(!a.input_text.is_empty());
                match shape {
                    DagShape::MapReduce => {
                        assert!(a.as_stages().is_none(), "partial combiners break barriers");
                        assert_eq!(a.depth(), 3);
                    }
                    DagShape::Tree => {
                        assert_eq!(a.depth(), 4);
                        // Root, two branch levels, one selector.
                        assert_eq!(a.tasks.len(), 1 + 3 + 9 + 1);
                    }
                    DagShape::Pipeline => {
                        assert_eq!(a.depth(), a.tasks.len());
                        assert!(a.tasks.iter().skip(1).all(|t| t.deps.len() == 1));
                    }
                }
            }
        }
    }

    #[test]
    fn dag_agent_is_deterministic() {
        let mk = || {
            let mut g = Generator::new(31);
            g.dag_agent(AgentClass::SelfConsistency, DagShape::Tree, 5, 2.0, 0.4, 2)
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a, b);
        assert_eq!(a.spawn, b.spawn, "spawn seed must be reproducible");
        assert_eq!(a.expand_spawns(), b.expand_spawns());
    }

    #[test]
    fn dag_agent_without_spawn_prob_has_no_spawn_rule() {
        let mut g = Generator::new(37);
        let a = g.dag_agent(AgentClass::CodeChecking, DagShape::Pipeline, 0, 0.0, 0.0, 2);
        assert!(a.spawn.is_none());
    }

    fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
        let n = xs.len() as f64;
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let vx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
        let vy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
        cov / (vx.sqrt() * vy.sqrt())
    }
}
