#!/usr/bin/env bash
# Kick-tires (artifact-evaluation style): build the release binary, run the
# fast experiments + the cluster scale-out sweep, and collect everything
# under out/. Target: a few minutes on a laptop; no network, no GPU, no
# Python required (simulator paths only — see DESIGN.md §3, substitution T1).
#
# Usage: scripts/kick-tires.sh [--agents N] [--seed S]
set -euo pipefail

cd "$(dirname "$0")/.."
ROOT="$PWD"
AGENTS=300
SEED=42
while [ $# -gt 0 ]; do
  case "$1" in
    --agents) AGENTS="$2"; shift 2 ;;
    --seed) SEED="$2"; shift 2 ;;
    *) echo "unknown flag $1" >&2; exit 2 ;;
  esac
done

echo "== Kick Tires: Justitia reproduction =="
echo "[1/4] cargo build --release"
(cd rust && cargo build --release)
BIN="$ROOT/rust/target/release/justitia"

rm -rf out
mkdir -p out
# ResultsFile writes under ./results relative to the cwd.
cd "$ROOT"
rm -rf results
mkdir -p results

echo "[2/6] paper experiments (figs 3, 7-13, table 1) — $AGENTS agents, seed $SEED"
"$BIN" experiment all --agents "$AGENTS" --seed "$SEED"

echo "[3/6] cluster scale-out sweep (1/2/4/8 replicas x 4 placements)"
"$BIN" cluster --agents "$AGENTS" --seed "$SEED"

echo "[4/6] prefix-sharing sweep (radix-tree KV dedup off vs on)"
# `experiment all` above already ran the sweep with these arguments; only
# re-run if its JSON artifact is somehow missing.
if [ ! -f results/prefix_sharing.json ]; then
  "$BIN" experiment prefix_sharing --agents "$AGENTS" --seed "$SEED"
fi

echo "[5/6] DAG-agents sweep (map-reduce/tree/pipeline, correction off vs on)"
if [ ! -f results/dag_agents.json ]; then
  "$BIN" experiment dag_agents --agents "$AGENTS" --seed "$SEED"
fi

echo "[6/6] collecting outputs under out/"
cp results/*.txt out/
cp results/prefix_sharing.json out/BENCH_prefix.json
cp results/dag_agents.json out/BENCH_dag.json
{
  echo "kick-tires run: agents=$AGENTS seed=$SEED date=$(date -u +%Y-%m-%dT%H:%M:%SZ)"
  echo "binary: $BIN"
  "$BIN" help 2>/dev/null | head -3 || true
} > out/MANIFEST.txt

echo
echo "Done. Outputs:"
ls -1 out/
echo
echo "Transcribe the numbers into EXPERIMENTS.md (paper-vs-measured tables)."
