//! Execution backends for the engine.
//!
//! `SimBackend` is the calibrated discrete-event latency model used for the
//! 300-agent paper-scale benches (substitution T1). The real PJRT
//! transformer backend lives in `crate::runtime::PjrtBackend` and implements
//! the same trait — the engine cannot tell them apart.

use crate::config::BackendProfile;
use crate::kv::{BlockAllocator, PageId};
use crate::workload::TaskId;

/// One engine iteration's worth of work.
#[derive(Debug)]
pub struct IterationBatch<'a> {
    /// Sequences running their prefill this iteration: (id, prompt tokens).
    pub prefill: &'a [(TaskId, u32)],
    /// Sequences decoding one token this iteration.
    pub decode: &'a [TaskId],
    /// Tokens moved device→host by preemptions before this iteration.
    pub swap_out_tokens: u32,
    /// Tokens moved host→device by swap-ins before this iteration.
    pub swap_in_tokens: u32,
    /// The engine's KV allocator: single source of truth for block tables.
    /// Backends that execute a real model index their page pools with it.
    pub kv: &'a BlockAllocator,
}

impl IterationBatch<'_> {
    /// Total prompt tokens prefilled this iteration.
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill.iter().map(|(_, p)| *p as u64).sum()
    }

    /// Sequences in the iteration (prefill + decode).
    pub fn batch_size(&self) -> usize {
        self.prefill.len() + self.decode.len()
    }
}

/// Result of one iteration.
#[derive(Debug, Clone, Copy)]
pub struct IterationResult {
    /// Wall time of the iteration in engine seconds.
    pub elapsed: f64,
}

/// A model-execution backend. The KV *accounting* lives in the engine's
/// [`BlockAllocator`]; backends holding real KV data (the PJRT transformer)
/// implement the swap hooks to stash/restore page contents when the engine
/// preempts, and drop per-sequence state on release.
pub trait ExecBackend {
    fn run_iteration(&mut self, batch: &IterationBatch) -> IterationResult;

    /// Called just before the engine swaps `seq` out; `pages` is its block
    /// table (still valid) and `tokens` its current KV length.
    fn on_swap_out(&mut self, _seq: TaskId, _pages: &[PageId], _tokens: u32) {}

    /// Called just after the engine swapped `seq` back in; `pages` is the
    /// freshly-allocated block table to restore into.
    fn on_swap_in(&mut self, _seq: TaskId, _pages: &[PageId]) {}

    /// Called when `seq` finished and its pages are about to be freed.
    fn on_seq_released(&mut self, _seq: TaskId) {}
}

/// Calibrated latency model:
/// `t = alpha + beta_prefill·(prefill tokens) + beta_decode·(batch seqs)
///    + beta_mixed·(prefill tokens)·(decode seqs) + swap_cost·(tokens moved)
///    [+ (tokens moved)/swap_bw]`.
/// The coefficients per backend profile are chosen to land the §5.1 size
/// buckets in the paper's <1 min / 1–10 min / >10 min ranges; for the
/// tiny-cpu profile they are measured against the PJRT backend (see
/// EXPERIMENTS.md §Calibration). `beta_mixed` is the mixed-batch
/// interference term (DESIGN.md §10): the extra latency every decode in the
/// iteration pays per prefill token batched alongside it — zero in the
/// stock profiles, set explicitly by the chunked-prefill experiment. The
/// final term serializes swap traffic behind a finite host↔device bandwidth
/// (DESIGN.md §11) — the whole iteration waits for the transfer, so swaps
/// are no longer just priced per-token; `swap_bw = 0` (stock profiles)
/// disables it and reproduces the pre-subsystem latency bit for bit.
#[derive(Debug, Clone)]
pub struct SimBackend {
    alpha: f64,
    beta_prefill: f64,
    beta_decode: f64,
    beta_mixed: f64,
    swap_cost_per_token: f64,
    swap_bw_tokens_per_sec: f64,
    iterations: u64,
}

impl SimBackend {
    /// Simulator with a profile's calibrated coefficients.
    pub fn new(profile: &BackendProfile) -> Self {
        SimBackend {
            alpha: profile.alpha,
            beta_prefill: profile.beta_prefill,
            beta_decode: profile.beta_decode,
            beta_mixed: profile.beta_mixed,
            swap_cost_per_token: profile.swap_cost_per_token,
            swap_bw_tokens_per_sec: profile.swap_bw_tokens_per_sec,
            iterations: 0,
        }
    }

    /// Unit-time backend for property tests: every iteration takes exactly
    /// 1 "second" (i.e. time is measured in iterations).
    pub fn unit_time() -> Self {
        SimBackend {
            alpha: 1.0,
            beta_prefill: 0.0,
            beta_decode: 0.0,
            beta_mixed: 0.0,
            swap_cost_per_token: 0.0,
            swap_bw_tokens_per_sec: 0.0,
            iterations: 0,
        }
    }

    /// Iterations executed so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Average sustained iteration rate (iterations per second) for a pure
    /// decode batch of size `b` — used to derive the GPS `rate_scale`.
    pub fn decode_iter_rate(&self, b: usize) -> f64 {
        1.0 / (self.alpha + self.beta_decode * b as f64)
    }
}

impl ExecBackend for SimBackend {
    fn run_iteration(&mut self, batch: &IterationBatch) -> IterationResult {
        self.iterations += 1;
        let mut elapsed = self.alpha
            + self.beta_prefill * batch.prefill_tokens() as f64
            + self.beta_decode * batch.batch_size() as f64
            + self.beta_mixed * batch.prefill_tokens() as f64 * batch.decode.len() as f64
            + self.swap_cost_per_token * (batch.swap_out_tokens + batch.swap_in_tokens) as f64;
        // Serialize swap traffic behind the host↔device link: the iteration
        // cannot start until the transfers land. Guarded (not `+ 0.0`) so a
        // zero-bandwidth profile reproduces the pre-subsystem float exactly.
        if self.swap_bw_tokens_per_sec > 0.0 {
            elapsed += (batch.swap_out_tokens + batch.swap_in_tokens) as f64
                / self.swap_bw_tokens_per_sec;
        }
        IterationResult { elapsed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tid(i: u32) -> TaskId {
        TaskId { agent: 0, index: i }
    }

    fn kv() -> BlockAllocator {
        BlockAllocator::new(4, 16)
    }

    #[test]
    fn latency_model_composition() {
        let profile = BackendProfile {
            name: "t".into(),
            kv_tokens: 100,
            page_size: 10,
            alpha: 0.01,
            beta_prefill: 1e-4,
            beta_decode: 1e-3,
            swap_cost_per_token: 1e-5,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        };
        let mut b = SimBackend::new(&profile);
        let prefill = [(tid(0), 100u32)];
        let decode = [tid(1), tid(2)];
        let r = b.run_iteration(&IterationBatch {
            prefill: &prefill,
            decode: &decode,
            swap_out_tokens: 50,
            swap_in_tokens: 0,
            kv: &kv(),
        });
        let want = 0.01 + 1e-4 * 100.0 + 1e-3 * 3.0 + 1e-5 * 50.0;
        assert!((r.elapsed - want).abs() < 1e-12);
        assert_eq!(b.iterations(), 1);
    }

    #[test]
    fn mixed_batch_term_charges_prefill_decode_interference() {
        let profile = BackendProfile {
            name: "t".into(),
            kv_tokens: 100,
            page_size: 10,
            alpha: 0.01,
            beta_prefill: 1e-4,
            beta_decode: 1e-3,
            swap_cost_per_token: 0.0,
            beta_mixed: 1e-6,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        };
        let mut b = SimBackend::new(&profile);
        let prefill = [(tid(0), 200u32)];
        let decode = [tid(1), tid(2), tid(3)];
        let r = b.run_iteration(&IterationBatch {
            prefill: &prefill,
            decode: &decode,
            swap_out_tokens: 0,
            swap_in_tokens: 0,
            kv: &kv(),
        });
        // 200 prefill tokens × 3 decoders pay the interference term.
        let want = 0.01 + 1e-4 * 200.0 + 1e-3 * 4.0 + 1e-6 * 200.0 * 3.0;
        assert!((r.elapsed - want).abs() < 1e-12);
        // A pure-prefill iteration pays none (no decodes to interfere with).
        let r = b.run_iteration(&IterationBatch {
            prefill: &prefill,
            decode: &[],
            swap_out_tokens: 0,
            swap_in_tokens: 0,
            kv: &kv(),
        });
        let want = 0.01 + 1e-4 * 200.0 + 1e-3 * 1.0;
        assert!((r.elapsed - want).abs() < 1e-12);
    }

    #[test]
    fn swap_bandwidth_serializes_transfers() {
        let mut profile = BackendProfile {
            name: "t".into(),
            kv_tokens: 100,
            page_size: 10,
            alpha: 0.01,
            beta_prefill: 1e-4,
            beta_decode: 1e-3,
            swap_cost_per_token: 1e-5,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        };
        let batch = |kv: &BlockAllocator| IterationBatch {
            prefill: &[],
            decode: &[],
            swap_out_tokens: 300,
            swap_in_tokens: 100,
            kv,
        };
        let kv = kv();
        // bw = 0: only the per-token price — the pre-subsystem model.
        let r0 = SimBackend::new(&profile).run_iteration(&batch(&kv));
        assert_eq!(r0.elapsed, 0.01 + 1e-5 * 400.0);
        // bw > 0: the iteration additionally waits out the transfer.
        profile.swap_bw_tokens_per_sec = 2000.0;
        let r1 = SimBackend::new(&profile).run_iteration(&batch(&kv));
        assert!((r1.elapsed - (0.01 + 1e-5 * 400.0 + 400.0 / 2000.0)).abs() < 1e-12);
    }

    #[test]
    fn unit_time_is_constant() {
        let mut b = SimBackend::unit_time();
        let r1 = b.run_iteration(&IterationBatch {
            prefill: &[],
            decode: &[tid(0)],
            swap_out_tokens: 0,
            swap_in_tokens: 0,
            kv: &kv(),
        });
        let prefill = [(tid(1), 5000u32)];
        let r2 = b.run_iteration(&IterationBatch {
            prefill: &prefill,
            decode: &[],
            swap_out_tokens: 99,
            swap_in_tokens: 99,
            kv: &kv(),
        });
        assert_eq!(r1.elapsed, 1.0);
        assert_eq!(r2.elapsed, 1.0);
    }

    #[test]
    fn batch_helpers() {
        let prefill = [(tid(0), 10u32), (tid(1), 20u32)];
        let decode = [tid(2)];
        let b = IterationBatch { prefill: &prefill, decode: &decode, swap_out_tokens: 0, swap_in_tokens: 0, kv: &kv() };
        assert_eq!(b.prefill_tokens(), 30);
        assert_eq!(b.batch_size(), 3);
    }
}
