//! Multi-replica cluster serving with cluster-level fair queuing.
//!
//! The paper serves task-parallel agents on *one* shared GPU. This module
//! shards the engine across N independent replicas — each with its own
//! [`BlockAllocator`](crate::kv::BlockAllocator) pool and its own Justitia
//! scheduler — behind a [`ClusterDispatcher`] that routes each arriving
//! agent to one replica under a pluggable [`Placement`] policy. Agents are
//! never split across replicas: an agent's tasks share KV-locality and its
//! fairness guarantee is per-agent, so the placement decision is the only
//! cluster-level degree of freedom.
//!
//! Fairness composition: with [`Placement::ClusterVtime`], each replica's
//! mirror virtual clock estimates where the agent's GPS-order finish tag
//! would land, and the dispatcher picks the replica minimizing it. Each
//! replica then pampers its agents in local GPS-finish order, so the
//! cluster-wide service order approximates a single N×M-capacity GPS server
//! — the same yardstick Theorem B.1 bounds Justitia against on one GPU.
//! [`Placement::PrefixAffinity`] adds cache locality on top: agents of one
//! shared-prefix family ([`crate::workload::PrefixGroup`]) are routed to the
//! replica whose radix tree ([`crate::prefix`]) already holds their prompt
//! chain, with cluster-vtime seeding families and breaking ties.
//!
//! Determinism: placement ties break toward the lowest replica index and
//! replicas are simulated independently, so a trace replay is exactly
//! reproducible; with one replica, every placement policy degenerates to the
//! single-engine path and reproduces its results bit for bit (asserted by
//! `rust/tests/test_cluster_determinism.rs`).

pub mod placement;

pub use placement::Placement;

use crate::engine::exec::ExecBackend;
use crate::engine::Engine;
use crate::metrics::RunMetrics;
use crate::workload::{AgentId, AgentSpec, Suite};
use placement::Placer;
use std::collections::HashMap;

/// Routes agents across N independent engine replicas.
///
/// Two drive modes:
///
/// * **Trace replay** — [`run_suite`](ClusterDispatcher::run_suite) places
///   every agent in global arrival order, then runs each replica over its
///   sub-trace to completion (replicas are independent discrete-event
///   simulations; no cross-replica synchronization is needed).
/// * **Online serving** — [`submit`](ClusterDispatcher::submit) places one
///   agent against the replicas' *live* state and
///   [`step`](ClusterDispatcher::step) advances the laggard replica, which
///   keeps replica clocks loosely synchronized. The HTTP front-end drives
///   this mode.
pub struct ClusterDispatcher<B: ExecBackend> {
    replicas: Vec<Engine<B>>,
    placer: Placer,
    /// agent id → replica index, in placement order.
    assignments: HashMap<AgentId, usize>,
}

impl<B: ExecBackend> ClusterDispatcher<B> {
    /// Build a dispatcher over pre-constructed replica engines.
    ///
    /// `capacity_tokens` is one replica's KV capacity M and `rate_scale` its
    /// nominal iterations/second — the same pair the replicas' Justitia
    /// schedulers were built with; the placement mirrors reuse them.
    pub fn new(
        replicas: Vec<Engine<B>>,
        placement: Placement,
        capacity_tokens: u64,
        rate_scale: f64,
    ) -> Self {
        assert!(!replicas.is_empty(), "cluster needs at least one replica");
        let n = replicas.len();
        ClusterDispatcher {
            replicas,
            placer: Placer::new(placement, n, capacity_tokens, rate_scale),
            assignments: HashMap::new(),
        }
    }

    /// Number of replicas.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// The active placement policy.
    pub fn placement(&self) -> Placement {
        self.placer.policy()
    }

    /// The replica an agent was routed to, if it has been placed.
    pub fn replica_of(&self, agent: AgentId) -> Option<usize> {
        self.assignments.get(&agent).copied()
    }

    /// Number of agents placed on each replica so far.
    pub fn assignment_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.replicas.len()];
        for &r in self.assignments.values() {
            counts[r] += 1;
        }
        counts
    }

    /// Direct access to one replica's engine (tests / introspection).
    pub fn replica(&self, r: usize) -> &Engine<B> {
        &self.replicas[r]
    }

    /// One replica's run metrics.
    pub fn replica_metrics(&self, r: usize) -> &RunMetrics {
        &self.replicas[r].metrics
    }

    /// Whether any replica still has admitted or waiting work.
    pub fn has_work(&self) -> bool {
        self.replicas.iter().any(|e| e.has_work())
    }

    /// Largest replica engine clock — the cluster makespan so far.
    pub fn makespan(&self) -> f64 {
        self.replicas.iter().map(|e| e.now()).fold(0.0, f64::max)
    }

    /// Online submission: place `spec` against the replicas' live state and
    /// submit it to the chosen replica at that replica's current clock.
    /// Returns the replica index.
    ///
    /// For [`Placement::ClusterVtime`] the live schedulers' own virtual
    /// clocks are consulted first
    /// ([`Scheduler::gps_finish_estimate`](crate::sched::Scheduler::gps_finish_estimate));
    /// policies without a virtual clock fall back to the dispatcher mirrors.
    pub fn submit(&mut self, spec: AgentSpec, predicted_cost: f64) -> usize {
        let agent = spec.id;
        let group = spec.prefix_group_id();
        let nows: Vec<f64> = self.replicas.iter().map(|e| e.now()).collect();
        // Probing every replica's scheduler is a per-replica scan; skip it
        // when the placer's decision is already determined (e.g. a
        // prefix-affinity family that has a home replica).
        let live: Vec<Option<f64>> = if self.placer.wants_live_estimates(group) {
            self.replicas
                .iter_mut()
                .zip(&nows)
                .map(|(e, &now)| e.scheduler_mut().gps_finish_estimate(predicted_cost, now))
                .collect()
        } else {
            vec![None; self.replicas.len()]
        };
        let r = self.placer.place(agent, predicted_cost, group, &nows, Some(&live));
        self.assignments.insert(agent, r);
        self.replicas[r].submit(spec, predicted_cost);
        r
    }

    /// Online stepping: advance the replica with the smallest engine clock
    /// among those with work (keeps clocks loosely synchronized so placement
    /// compares like with like). Returns that iteration's elapsed engine
    /// seconds, or 0.0 when no replica has work.
    pub fn step(&mut self) -> f64 {
        let mut pick: Option<usize> = None;
        for (r, e) in self.replicas.iter().enumerate() {
            if e.has_work() && pick.map(|p| e.now() < self.replicas[p].now()).unwrap_or(true) {
                pick = Some(r);
            }
        }
        match pick {
            Some(r) => self.replicas[r].step(),
            None => 0.0,
        }
    }

    /// Completion time of an agent on whichever replica owns it.
    pub fn agent_complete_time(&self, agent: AgentId) -> Option<f64> {
        let r = self.replica_of(agent)?;
        self.replicas[r].metrics.agent_complete_time(agent)
    }

    /// Replay a whole suite through the cluster: place every agent in global
    /// arrival order (calling `predict` exactly once per agent, preserving
    /// any stateful noise stream), then run each replica over its sub-trace
    /// with [`Engine::run_suite`]. Returns the cluster makespan.
    ///
    /// With a single replica this is *exactly* the single-engine
    /// [`Engine::run_suite`] call — same injection order, same clock
    /// alignment — so JCTs are bit-identical to a non-clustered run.
    pub fn run_suite<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        mut predict: F,
    ) -> f64 {
        // Phase 1: placement, in global arrival order.
        let (subs, costs) = self.place_suite(suite, &mut predict);
        // Phase 2: independent replica runs over the (already arrival-sorted,
        // globally-id'd) sub-traces. Suite::new would re-index ids, so the
        // sub-suites are constructed directly.
        for (r, agents) in subs.into_iter().enumerate() {
            if agents.is_empty() {
                continue;
            }
            let sub = Suite { agents };
            self.replicas[r].run_suite(&sub, |a| costs[&a.id]);
        }
        self.makespan()
    }

    /// Placement phase shared by the serial and parallel suite drivers:
    /// route every agent in global arrival order, recording assignments and
    /// the predicted cost (`predict` is called exactly once per agent, in
    /// suite order, preserving any stateful noise stream). Returns the
    /// per-replica sub-traces and the cost table.
    fn place_suite<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        predict: &mut F,
    ) -> (Vec<Vec<AgentSpec>>, HashMap<AgentId, f64>) {
        let n = self.replicas.len();
        let mut subs: Vec<Vec<AgentSpec>> = vec![Vec::new(); n];
        let mut costs: HashMap<AgentId, f64> = HashMap::with_capacity(suite.len());
        for a in &suite.agents {
            let cost = predict(a);
            let nows = vec![a.arrival; n];
            let r = self.placer.place(a.id, cost, a.prefix_group_id(), &nows, None);
            self.assignments.insert(a.id, r);
            costs.insert(a.id, cost);
            subs[r].push(a.clone());
        }
        (subs, costs)
    }

    /// [`run_suite`](Self::run_suite) with the phase-2 replica simulations
    /// spread over a [`ThreadPool`](crate::util::threadpool::ThreadPool) of
    /// `threads` workers. Replicas are *independent* discrete-event
    /// simulations over disjoint sub-traces, so running them concurrently
    /// changes nothing observable: placement (phase 1) stays serial in
    /// global arrival order, every engine computes exactly what it computes
    /// under the serial driver, engines are reinstalled in replica index
    /// order (`ThreadPool::map` preserves input order), and
    /// [`merged_metrics`](Self::merged_metrics) folds them in that same
    /// order — so the merged metrics are byte-identical for ANY thread
    /// count, 1 worker included (asserted by
    /// `tests/test_parallel_replica_determinism.rs`). `threads <= 1`
    /// delegates to the serial driver outright.
    pub fn run_suite_parallel<F>(&mut self, suite: &Suite, mut predict: F, threads: usize) -> f64
    where
        F: FnMut(&AgentSpec) -> f64,
        B: Send + 'static,
    {
        if threads <= 1 {
            return self.run_suite(suite, predict);
        }
        let (subs, costs) = self.place_suite(suite, &mut predict);
        let costs = std::sync::Arc::new(costs);
        // Engines move onto the pool and come back in input order.
        let replicas = std::mem::take(&mut self.replicas);
        let jobs: Vec<(Engine<B>, Vec<AgentSpec>)> = replicas.into_iter().zip(subs).collect();
        let pool = crate::util::threadpool::ThreadPool::new(threads);
        self.replicas = pool.map(jobs, move |(mut engine, agents)| {
            if !agents.is_empty() {
                let sub = Suite { agents };
                engine.run_suite(&sub, |a| costs[&a.id]);
            }
            engine
        });
        self.makespan()
    }

    /// Merge all replicas' metrics into one cluster-level [`RunMetrics`]
    /// (agent ids are globally unique, so the union is disjoint).
    pub fn merged_metrics(&self) -> RunMetrics {
        let mut out = RunMetrics::new();
        for e in &self.replicas {
            out.merge(&e.metrics);
        }
        out
    }

    /// Export every traced replica's flight recorder as one Chrome trace:
    /// one Perfetto process per replica ("replica N"), one thread row per
    /// agent within it (see [`crate::trace::chrome_trace`]). Returns `None`
    /// when no replica carries a recorder — tracing off, the default — so
    /// the HTTP `/trace` endpoint can 404 instead of serving an empty dump.
    pub fn merged_trace_chrome(&self) -> Option<crate::util::json::Json> {
        let labels: Vec<String> =
            (0..self.replicas.len()).map(|r| format!("replica {r}")).collect();
        let parts: Vec<(u32, &str, &crate::trace::TraceRecorder)> = self
            .replicas
            .iter()
            .enumerate()
            .filter_map(|(r, e)| e.trace().map(|t| (r as u32, labels[r].as_str(), t)))
            .collect();
        if parts.is_empty() {
            None
        } else {
            Some(crate::trace::chrome_trace(&parts))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, Policy, WorkloadConfig};
    use crate::cost::CostModel;
    use crate::engine::exec::SimBackend;
    use crate::workload::test_support::simple_agent;
    use crate::workload::trace;

    fn engines(cfg: &Config, n: usize) -> Vec<Engine<SimBackend>> {
        (0..n)
            .map(|_| {
                let sched = crate::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
                Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
            })
            .collect()
    }

    fn dispatcher(cfg: &Config, n: usize, p: Placement) -> ClusterDispatcher<SimBackend> {
        ClusterDispatcher::new(engines(cfg, n), p, cfg.backend.kv_tokens, 1.0)
    }

    fn small_suite(n_agents: usize, seed: u64) -> Suite {
        let wl = WorkloadConfig { n_agents, seed, ..Default::default() }.with_density(3.0);
        trace::build_suite(&wl)
    }

    #[test]
    fn one_replica_matches_single_engine_exactly() {
        let cfg = Config::default();
        let suite = small_suite(40, 11);
        let model = CostModel::MemoryCentric;

        let mut single = engines(&cfg, 1).pop().unwrap();
        single.run_suite(&suite, |a| model.agent_cost(a));
        let want = single.metrics.jcts();

        for p in Placement::ALL {
            let mut c = dispatcher(&cfg, 1, p);
            c.run_suite(&suite, |a| model.agent_cost(a));
            assert_eq!(c.merged_metrics().jcts(), want, "{p:?} diverged with one replica");
        }
    }

    #[test]
    fn multi_replica_completes_everything_deterministically() {
        let cfg = Config::default();
        let suite = small_suite(60, 5);
        let model = CostModel::MemoryCentric;
        for p in Placement::ALL {
            let run = || {
                let mut c = dispatcher(&cfg, 4, p);
                c.run_suite(&suite, |a| model.agent_cost(a));
                (c.merged_metrics().jcts(), c.assignment_counts())
            };
            let (jcts1, counts1) = run();
            let (jcts2, counts2) = run();
            assert_eq!(jcts1.len(), 60, "{p:?} dropped agents");
            assert_eq!(jcts1, jcts2, "{p:?} nondeterministic");
            assert_eq!(counts1, counts2);
            assert_eq!(counts1.iter().sum::<usize>(), 60);
        }
    }

    #[test]
    fn prefix_affinity_coalesces_families() {
        let mut cfg = Config::default();
        cfg.workload = WorkloadConfig { n_agents: 24, seed: 9, ..Default::default() }
            .with_density(3.0)
            .with_shared_prefix(4, 256);
        let suite = trace::build_suite(&cfg.workload);
        let mut c = dispatcher(&cfg, 4, Placement::PrefixAffinity);
        c.run_suite(&suite, |a| CostModel::MemoryCentric.agent_cost(a));
        // Every family lands on exactly one replica.
        let mut homes: HashMap<u64, usize> = HashMap::new();
        for a in &suite.agents {
            let g = a.prefix_group_id().unwrap();
            let r = c.replica_of(a.id).unwrap();
            assert_eq!(*homes.entry(g).or_insert(r), r, "family {g} split across replicas");
        }
        assert!(homes.len() >= 2, "suite should contain several families");
        assert_eq!(c.merged_metrics().completed_agents(), 24);
    }

    #[test]
    fn round_robin_spreads_counts_evenly() {
        let cfg = Config::default();
        let suite = small_suite(40, 3);
        let mut c = dispatcher(&cfg, 4, Placement::RoundRobin);
        c.run_suite(&suite, |a| CostModel::MemoryCentric.agent_cost(a));
        assert_eq!(c.assignment_counts(), vec![10, 10, 10, 10]);
    }

    #[test]
    fn scaling_out_reduces_jct_under_contention() {
        let cfg = Config::default();
        let suite = small_suite(80, 42);
        let model = CostModel::MemoryCentric;
        let avg = |n: usize| {
            let mut c = dispatcher(&cfg, n, Placement::ClusterVtime);
            c.run_suite(&suite, |a| model.agent_cost(a));
            c.merged_metrics().avg_jct()
        };
        let (one, four) = (avg(1), avg(4));
        assert!(four < one, "4 replicas ({four:.1}s) should beat 1 ({one:.1}s)");
    }

    #[test]
    fn online_submit_and_step_drain() {
        let cfg = Config::default();
        let mut c = dispatcher(&cfg, 2, Placement::ClusterVtime);
        let r0 = c.submit(simple_agent(0, 0.0, 2, 20, 10), 1000.0);
        let r1 = c.submit(simple_agent(1, 0.0, 1, 10, 5), 100.0);
        assert_eq!(c.replica_of(0), Some(r0));
        assert_eq!(c.replica_of(1), Some(r1));
        // Big agent saturates its replica's GPS; the small one goes elsewhere.
        assert_ne!(r0, r1);
        let mut guard = 0;
        while c.has_work() {
            c.step();
            guard += 1;
            assert!(guard < 10_000, "runaway");
        }
        let m = c.merged_metrics();
        assert_eq!(m.completed_agents(), 2);
        assert!(c.agent_complete_time(0).is_some() && c.agent_complete_time(1).is_some());
        assert!(c.makespan() > 0.0);
    }

    #[test]
    fn merged_trace_spans_replicas_and_is_absent_when_off() {
        let cfg = Config::default();
        let suite = small_suite(24, 7);
        let model = CostModel::MemoryCentric;
        // Tracing off (the default): nothing to merge.
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        c.run_suite(&suite, |a| model.agent_cost(a));
        assert!(c.merged_trace_chrome().is_none());
        // Tracing on: one Perfetto process per replica.
        let mut cfg = cfg;
        cfg.trace = true;
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        c.run_suite(&suite, |a| model.agent_cost(a));
        let json = c.merged_trace_chrome().expect("both replicas traced");
        let events = json.get("traceEvents").as_arr().unwrap();
        let processes: Vec<&str> = events
            .iter()
            .filter(|e| e.get("name").as_str() == Some("process_name"))
            .filter_map(|e| e.get("args").get("name").as_str())
            .collect();
        assert_eq!(processes, vec!["replica 0", "replica 1"]);
    }

    #[test]
    fn step_without_work_is_zero() {
        let cfg = Config::default();
        let mut c = dispatcher(&cfg, 2, Placement::RoundRobin);
        assert_eq!(c.step(), 0.0);
        assert!(!c.has_work());
    }
}
