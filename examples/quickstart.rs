//! Quickstart: the full three-layer stack end to end.
//!
//! Loads the AOT-compiled tiny transformer (JAX + Pallas paged-attention
//! kernel → HLO text → PJRT CPU), stands up the serving engine with the
//! Justitia scheduler, submits a handful of task-parallel agents, and
//! reports per-agent JCT plus serving throughput.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use justitia::config::{BackendProfile, Config, Policy};
use justitia::cost::CostModel;
use justitia::engine::Engine;
use justitia::runtime::{PjrtBackend, PjrtModel};
use justitia::workload::test_support::{agent_at, inference};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    println!("loading AOT artifacts from {} …", artifacts.display());
    let model = PjrtModel::load(artifacts)?;
    println!(
        "  platform {}  |  {} layers, d_model {}, vocab {}  |  pool {} pages x {} tokens",
        model.platform(),
        model.manifest.n_layers,
        model.manifest.d_model,
        model.manifest.vocab,
        model.manifest.n_pages,
        model.manifest.page_size,
    );

    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "tiny-cpu".into(),
        kv_tokens: (model.manifest.n_pages * model.manifest.page_size) as u64,
        page_size: model.manifest.page_size as u32,
        alpha: 0.0,
        beta_prefill: 0.0,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: None,
        swap_bw_tokens_per_sec: 0.0,
    };
    cfg.max_batch = model.max_decode_batch();

    let scheduler = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, scheduler, PjrtBackend::new(model));

    // Three task-parallel agents, sized for the tiny artifact model
    // (prompts <= 64 tokens, contexts <= 128).
    let agents = vec![
        // "DocMerging"-shaped: 3 parallel merges then a score.
        agent_at(0, 0.0, vec![
            vec![inference(0, 0, 24, 12), inference(1, 0, 28, 10), inference(2, 0, 20, 14)],
            vec![inference(3, 1, 32, 8)],
        ]),
        // "Self-consistency"-shaped: 4 parallel reasoning paths.
        agent_at(1, 0.0, vec![vec![
            inference(0, 0, 16, 20),
            inference(1, 0, 16, 18),
            inference(2, 0, 16, 22),
            inference(3, 0, 16, 16),
        ]]),
        // Tiny verification agent.
        agent_at(2, 0.0, vec![vec![inference(0, 0, 10, 6), inference(1, 0, 12, 4)]]),
    ];

    let model_cost = CostModel::MemoryCentric;
    let mut total_tokens = 0u64;
    for a in agents {
        total_tokens += a.total_tokens();
        let cost = model_cost.agent_cost(&a);
        println!(
            "submit agent {} ({} tasks, {} tokens, KV token-time cost {:.0})",
            a.id,
            a.n_tasks(),
            a.total_tokens(),
            cost
        );
        engine.submit(a, cost);
    }

    let t0 = Instant::now();
    let mut iterations = 0u64;
    while engine.has_work() {
        engine.step();
        iterations += 1;
        assert!(iterations < 10_000, "runaway");
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n--- results ---");
    for id in 0..3u32 {
        println!(
            "agent {id}: JCT {:.3}s (engine time)",
            engine.metrics.jct(id).expect("completed")
        );
    }
    println!(
        "served {} agents / {} tokens in {:.2}s wall ({} engine iterations, {:.0} tok/s)",
        engine.metrics.completed_agents(),
        total_tokens,
        wall,
        iterations,
        total_tokens as f64 / wall
    );
    engine.kv.check_invariants().expect("KV pool clean");
    println!("KV pool clean: all {} pages returned", engine.kv.total_pages());
    Ok(())
}
