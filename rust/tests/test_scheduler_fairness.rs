//! Cross-scheduler integration tests on the calibrated simulator: the
//! paper's qualitative claims as assertions.

use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::experiments::{self, run_policy_oracle, CostSource};
use justitia::metrics::{fair_ratios, fairness_summary};
use justitia::workload::trace::build_suite;

fn suite_cfg(n: usize, density: f64, seed: u64) -> (Config, justitia::workload::Suite) {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents: n, seed, ..Default::default() }.with_density(density);
    let suite = build_suite(&cfg.workload);
    (cfg, suite)
}

#[test]
fn headline_efficiency_ordering_full_suite() {
    // §5.2: Justitia ≈ SRJF ≪ VTC < Parrot < vLLM-FCFS at 3× density.
    let (cfg, suite) = suite_cfg(300, 3.0, 42);
    let avg = |p: Policy| run_policy_oracle(&cfg, &suite, p).avg_jct();
    let (justitia, srjf, vtc, parrot, fcfs) = (
        avg(Policy::Justitia),
        avg(Policy::Srjf),
        avg(Policy::Vtc),
        avg(Policy::AgentFcfs),
        avg(Policy::Fcfs),
    );
    assert!(justitia < 0.6 * vtc, "justitia {justitia} vs vtc {vtc}");
    assert!(justitia < 0.6 * parrot, "justitia {justitia} vs parrot {parrot}");
    assert!(vtc < parrot, "vtc {vtc} vs parrot {parrot}");
    assert!(parrot < fcfs, "parrot {parrot} vs fcfs {fcfs}");
    assert!((justitia - srjf).abs() / srjf < 0.25, "justitia {justitia} ~ srjf {srjf}");
}

#[test]
fn fairness_92_percent_not_delayed() {
    // §5.2 fairness: the overwhelming majority of agents complete under
    // Justitia no later than under VTC (paper: 92%), with a bounded worst
    // case (paper: 26%).
    let (cfg, suite) = suite_cfg(300, 3.0, 42);
    let vtc = run_policy_oracle(&cfg, &suite, Policy::Vtc);
    let just = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    let s = fairness_summary(&fair_ratios(&just, &vtc));
    assert!(s.frac_not_delayed >= 0.90, "only {:.1}% not delayed", s.frac_not_delayed * 100.0);
    // Worst case: paper reports 26%; our small-scale suite has agents with
    // tiny VTC JCTs in the denominator, so the worst *ratio* runs higher —
    // the absolute Thm-B.1 bound is checked in prop_delay_bound.rs.
    assert!(s.worst_delay_pct <= 300.0, "worst delay {:.1}%", s.worst_delay_pct);
}

#[test]
fn justitia_beats_vtc_on_p90_too() {
    let (cfg, suite) = suite_cfg(300, 2.0, 7);
    let vtc = run_policy_oracle(&cfg, &suite, Policy::Vtc);
    let just = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    assert!(just.p90_jct() < vtc.p90_jct(), "{} vs {}", just.p90_jct(), vtc.p90_jct());
}

#[test]
fn density_monotonicity() {
    // Higher density → higher (or equal) average JCT for every policy.
    for policy in [Policy::Justitia, Policy::Vtc, Policy::Fcfs] {
        let mut prev = 0.0;
        for density in [1.0, 2.0, 3.0] {
            let (cfg, suite) = suite_cfg(200, density, 11);
            let avg = run_policy_oracle(&cfg, &suite, policy).avg_jct();
            assert!(
                avg >= prev * 0.9,
                "{policy:?}: JCT dropped sharply from {prev} to {avg} at {density}x"
            );
            prev = avg;
        }
    }
}

#[test]
fn justitia_c_ablation_is_worse() {
    // Fig. 11: compute-centric costs degrade Justitia.
    let rows = experiments::fig11(300, 2.0, 42);
    assert_eq!(rows.len(), 2);
    assert!(
        rows[1].avg_jct > rows[0].avg_jct,
        "Justitia/C {} should be worse than Justitia {}",
        rows[1].avg_jct,
        rows[0].avg_jct
    );
}

#[test]
fn noise_robustness_fig10_shape() {
    // Fig. 10: λ=3 inflates avg JCT mildly (paper: +9.5%); average over
    // seeds to dodge single-draw variance.
    let mut base = 0.0;
    let mut noisy = 0.0;
    for seed in [42u64, 43, 44] {
        let rows = experiments::fig10(&[1.0, 3.0], 300, 2.0, seed);
        base += rows[0].avg_jct;
        noisy += rows[1].avg_jct;
    }
    let inflation = noisy / base - 1.0;
    assert!(inflation < 0.35, "λ=3 inflation {:.1}% too large", inflation * 100.0);
}

#[test]
fn predictor_in_the_loop_close_to_oracle() {
    // End-to-end with the trained MLP predictor driving Justitia: JCT should
    // be within a modest factor of the oracle run (the Fig. 10 robustness
    // claim, realized with the real predictor instead of synthetic noise).
    let (cfg, suite) = suite_cfg(200, 2.0, 42);
    let (pred, report) = justitia::predictor::train_per_class(
        justitia::cost::CostModel::MemoryCentric,
        60,
        10,
        42,
    );
    assert!(report.rel_error < 1.0, "predictor too weak: {}", report.rel_error);
    let with_pred =
        experiments::run_policy(&cfg, &suite, Policy::Justitia, &CostSource::Model(&pred));
    let oracle = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    let ratio = with_pred.avg_jct() / oracle.avg_jct();
    assert!(ratio < 1.4, "predictor-driven JCT {ratio:.2}x of oracle");
    assert_eq!(with_pred.completed_agents(), 200);
}

#[test]
fn all_policies_complete_every_agent_under_stress() {
    // No scheduler may drop/stall agents even at extreme density.
    let (cfg, suite) = suite_cfg(150, 6.0, 99);
    for policy in Policy::all_paper_baselines() {
        let m = run_policy_oracle(&cfg, &suite, policy);
        assert_eq!(m.completed_agents(), 150, "{policy:?}");
    }
}
