//! Configuration system: typed configs with JSON-file loading and CLI
//! overrides. Every experiment and the server start from a `Config`, so runs
//! are fully reproducible from a single file (`configs/*.json`).

use crate::cli::Args;
use crate::cluster::{FailureSchedule, Placement};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Which backend "testbed profile" to emulate. The paper evaluates three
/// (model, GPU) pairs; each profile sets the KV capacity and the calibrated
/// iteration-latency coefficients used by the simulator (substitution T1).
#[derive(Debug, Clone, PartialEq)]
pub struct BackendProfile {
    /// Profile name (CLI/JSON key).
    pub name: String,
    /// Total KV cache capacity in token slots (paper's M, in per-token units;
    /// Fig. 3 uses 459 blocks x 16 tokens/block for LLaMA2-7B on A100-40G).
    pub kv_tokens: u64,
    /// KV page (block) size in tokens, vLLM-style.
    pub page_size: u32,
    /// Iteration latency model: t_iter = alpha + beta_prefill * prefill_tokens
    /// + beta_decode * decode_seqs (seconds).
    pub alpha: f64,
    /// Latency per prefill token (s).
    pub beta_prefill: f64,
    /// Latency per decoding sequence in the batch (s).
    pub beta_decode: f64,
    /// Swap-out/in penalty per token moved (seconds).
    pub swap_cost_per_token: f64,
    /// Mixed-batch interference: extra latency per (prefill token × decoding
    /// sequence) sharing one iteration (s). Models the kernel slowdown a
    /// prefill inflicts on the decodes batched with it — the term that makes
    /// decode tail latency under a concurrent long prefill a *modeled*
    /// quantity instead of an unpriced stall (DESIGN.md §10). Zero in the
    /// stock profiles, so every pre-chunking run is numerically unchanged;
    /// the chunked-prefill experiment sets it explicitly.
    pub beta_mixed: f64,
    /// Host (CPU) memory available for swapped-out KV, in token slots.
    /// `None` models an infinite host tier — the pre-preemption-subsystem
    /// behavior, and the default in every stock profile — while `Some(h)`
    /// bounds the swap area: once `h` tokens are resident on host, further
    /// swap-outs fail and the engine must recompute instead (DESIGN.md §11).
    pub host_kv_tokens: Option<u64>,
    /// Host↔device swap bandwidth in tokens per second. `0.0` (the stock
    /// default) disables transfer serialization: swaps cost only the
    /// per-token `swap_cost_per_token` price, exactly as before the
    /// preemption subsystem. A positive value additionally serializes the
    /// iteration behind `tokens_moved / bandwidth` seconds of transfer —
    /// the PCIe reality that makes swap-vs-recompute a genuine choice.
    pub swap_bw_tokens_per_sec: f64,
}

impl BackendProfile {
    /// LLaMA2-7B on one A100-PCIe-40GB (Fig. 3 / Fig. 7a testbed).
    ///
    /// Coefficients calibrated so the §5.1 suite produces the paper's
    /// contention regime: offered load ≈ 1.7× capacity at 3× density,
    /// ≈ 1.1× at 2×, ≈ 0.6× at 1× (EXPERIMENTS.md §Calibration).
    pub fn llama7b_a100() -> Self {
        BackendProfile {
            name: "llama7b-a100".into(),
            kv_tokens: 459 * 16,
            page_size: 16,
            alpha: 0.030,
            beta_prefill: 40.0e-6,
            beta_decode: 600.0e-6,
            swap_cost_per_token: 2.0e-6,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        }
    }

    /// LLaMA2-13B on four V100-PCIe-16GB, tensor-parallel (Fig. 7b).
    /// Slower iterations, smaller KV pool → heavier contention.
    pub fn llama13b_4v100() -> Self {
        BackendProfile {
            name: "llama13b-4v100".into(),
            kv_tokens: 320 * 16,
            page_size: 16,
            alpha: 0.055,
            beta_prefill: 80.0e-6,
            beta_decode: 1.1e-3,
            swap_cost_per_token: 3.5e-6,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        }
    }

    /// Qwen2.5-32B on one H800-PCIe-80GB (Fig. 7c).
    /// Bigger pool but a heavier model per iteration.
    pub fn qwen32b_h800() -> Self {
        BackendProfile {
            name: "qwen32b-h800".into(),
            kv_tokens: 700 * 16,
            page_size: 16,
            alpha: 0.040,
            beta_prefill: 55.0e-6,
            beta_decode: 800.0e-6,
            swap_cost_per_token: 1.5e-6,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        }
    }

    /// The tiny PJRT-CPU transformer that proves the stack end-to-end
    /// (examples/quickstart). Capacity mirrors the artifact's pool shape.
    pub fn tiny_cpu() -> Self {
        BackendProfile {
            name: "tiny-cpu".into(),
            kv_tokens: 64 * 16,
            page_size: 16,
            alpha: 0.0,
            beta_prefill: 0.0,
            beta_decode: 0.0,
            swap_cost_per_token: 0.0,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        }
    }

    /// Look up a built-in profile by name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "llama7b-a100" => Ok(Self::llama7b_a100()),
            "llama13b-4v100" => Ok(Self::llama13b_4v100()),
            "qwen32b-h800" => Ok(Self::qwen32b_h800()),
            "tiny-cpu" => Ok(Self::tiny_cpu()),
            other => bail!("unknown backend profile '{other}'"),
        }
    }

    /// Capacity in KV pages.
    pub fn kv_pages(&self) -> u64 {
        self.kv_tokens / self.page_size as u64
    }
}

/// Scheduling policy selector (paper baselines of §5.1 plus Justitia and the
/// Justitia/C cost-model ablation of Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// vLLM: inference-level FCFS.
    Fcfs,
    /// vLLM-SJF: inference-level shortest-predicted-job-first.
    Sjf,
    /// Parrot: agent-level FCFS.
    AgentFcfs,
    /// VTC: instantaneous fair sharing via virtual token counters.
    Vtc,
    /// SRJF: agent-level shortest-remaining-job-first (predicted).
    Srjf,
    /// Justitia: virtual-time fair queuing + selective pampering.
    Justitia,
    /// Justitia with VTC's compute-centric cost model (ablation, Fig. 11).
    JustitiaComputeCost,
}

impl Policy {
    /// Parse a policy name (paper aliases accepted).
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "fcfs" | "vllm" => Ok(Policy::Fcfs),
            "sjf" | "vllm-sjf" => Ok(Policy::Sjf),
            "agent-fcfs" | "parrot" => Ok(Policy::AgentFcfs),
            "vtc" => Ok(Policy::Vtc),
            "srjf" => Ok(Policy::Srjf),
            "justitia" => Ok(Policy::Justitia),
            "justitia-c" | "justitia-compute" => Ok(Policy::JustitiaComputeCost),
            other => bail!("unknown policy '{other}'"),
        }
    }

    /// Paper display name.
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fcfs => "vLLM",
            Policy::Sjf => "vLLM-SJF",
            Policy::AgentFcfs => "Parrot",
            Policy::Vtc => "VTC",
            Policy::Srjf => "SRJF",
            Policy::Justitia => "Justitia",
            Policy::JustitiaComputeCost => "Justitia/C",
        }
    }

    /// The six policies of the §5 evaluation.
    pub fn all_paper_baselines() -> [Policy; 6] {
        [Policy::Fcfs, Policy::Sjf, Policy::AgentFcfs, Policy::Vtc, Policy::Srjf, Policy::Justitia]
    }
}

/// What the engine does with a preemption victim when device KV must be
/// reclaimed (DESIGN.md §11). Default [`Swap`](PreemptionMode::Swap) is the
/// classical vLLM behavior and is bit-identical to the pre-subsystem engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PreemptionMode {
    /// Move the victim's KV to host memory and restore it later (vLLM swap
    /// preemption). Falls back to recompute when the bounded host pool
    /// cannot take the victim.
    Swap,
    /// Discard the victim's KV and re-run its prefill (over prompt + tokens
    /// generated so far) at re-entry — vLLM's recompute preemption.
    Recompute,
    /// Per victim, recompute when its cached-prefix-adjusted refill cost is
    /// cheaper than the round-trip swap cost, or when host memory is full;
    /// swap otherwise.
    Auto,
}

impl PreemptionMode {
    /// Parse a mode name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "swap" => Ok(PreemptionMode::Swap),
            "recompute" => Ok(PreemptionMode::Recompute),
            "auto" => Ok(PreemptionMode::Auto),
            other => bail!("unknown preemption mode '{other}' (swap|recompute|auto)"),
        }
    }

    /// Display name (CLI/JSON key).
    pub fn name(&self) -> &'static str {
        match self {
            PreemptionMode::Swap => "swap",
            PreemptionMode::Recompute => "recompute",
            PreemptionMode::Auto => "auto",
        }
    }
}

/// How the engine ranks preemption victims among running sequences
/// (DESIGN.md §11). Default [`Youngest`](VictimPolicy::Youngest) reproduces
/// the pre-subsystem behavior bit for bit: scheduler preemption rank first,
/// fewest generated tokens as the tie-break.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VictimPolicy {
    /// Scheduler preemption rank, ties to the youngest sequence (fewest
    /// generated tokens — the least work is wasted). The classical default.
    Youngest,
    /// The sequence holding the most KV pages goes first: one preemption
    /// frees the most memory, minimizing preemption churn.
    MostPages,
    /// The agent whose predicted remaining work is largest goes first
    /// (cheapest in completion-time terms: it finishes last anyway) —
    /// ranked by the scheduler's remaining-cost query
    /// ([`crate::sched::Scheduler::remaining_cost`]) with the engine's
    /// per-sequence remaining cost (Eq. 1) as the tie-break.
    CheapestRemaining,
    /// Selective pampering applied to preemption: protect agents the
    /// virtual clock says would finish early under GPS (smallest virtual
    /// finish tag, [`crate::sched::Scheduler::virtual_finish_tag`]) and
    /// preempt the GPS-latest agent first; within it, the sequence with the
    /// most remaining service.
    PamperAware,
}

impl VictimPolicy {
    /// Parse a victim-policy name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "youngest" => Ok(VictimPolicy::Youngest),
            "most-pages" => Ok(VictimPolicy::MostPages),
            "cheapest-remaining" => Ok(VictimPolicy::CheapestRemaining),
            "pamper-aware" => Ok(VictimPolicy::PamperAware),
            other => bail!(
                "unknown victim policy '{other}' \
                 (youngest|most-pages|cheapest-remaining|pamper-aware)"
            ),
        }
    }

    /// Display name (CLI/JSON key).
    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::Youngest => "youngest",
            VictimPolicy::MostPages => "most-pages",
            VictimPolicy::CheapestRemaining => "cheapest-remaining",
            VictimPolicy::PamperAware => "pamper-aware",
        }
    }

    /// Every victim policy (experiment sweeps).
    pub const ALL: [VictimPolicy; 4] = [
        VictimPolicy::Youngest,
        VictimPolicy::MostPages,
        VictimPolicy::CheapestRemaining,
        VictimPolicy::PamperAware,
    ];
}

/// How each iteration's token budget is split between running decodes and
/// pending prefill chunks (DESIGN.md §15). Only meaningful with
/// [`chunked_prefill`](Config::chunked_prefill) — without a finite budget
/// there is nothing to split, and every policy is inert. Default
/// [`Static`](BatchPolicyKind::Static) reproduces the pre-policy batch
/// composition bit for bit (`prop_batch_policy_identity`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BatchPolicyKind {
    /// Today's behavior: decodes take one token each, prefills greedily fill
    /// whatever budget remains. The bit-identical default.
    Static,
    /// Reserve [`decode_reserve`](Config::decode_reserve) tokens of the
    /// budget for decodes: prefill chunks may never use more than
    /// `max_batched_tokens − decode_reserve` tokens per iteration.
    FixedSplit,
    /// FairBatching-style closed loop (arxiv 2510.14392): shrink the prefill
    /// share when the windowed p99 ITL of running decodes breaches the
    /// tightest class SLO, grow it back when latency is comfortable and
    /// TTFT pressure (waiting prefills / TTFT deadline misses) dominates,
    /// with hysteresis and a cooldown to prevent oscillation.
    FairBatching,
}

impl BatchPolicyKind {
    /// Parse a batch-policy name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "static" => Ok(BatchPolicyKind::Static),
            "fixed-split" => Ok(BatchPolicyKind::FixedSplit),
            "fairbatching" => Ok(BatchPolicyKind::FairBatching),
            other => bail!(
                "unknown batch policy '{other}' (static|fixed-split|fairbatching)"
            ),
        }
    }

    /// Display name (CLI/JSON key).
    pub fn name(&self) -> &'static str {
        match self {
            BatchPolicyKind::Static => "static",
            BatchPolicyKind::FixedSplit => "fixed-split",
            BatchPolicyKind::FairBatching => "fairbatching",
        }
    }

    /// Every batch policy (experiment sweeps).
    pub const ALL: [BatchPolicyKind; 3] =
        [BatchPolicyKind::Static, BatchPolicyKind::FixedSplit, BatchPolicyKind::FairBatching];
}

/// Workload-suite configuration (§5.1 Workloads).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Number of agents in the suite (paper: 300).
    pub n_agents: usize,
    /// Submission window in seconds (paper: 6/9/18 min for 3x/2x/1x density).
    pub window_secs: f64,
    /// Sampling probability of small/medium/large classes (paper: 72/26/2).
    pub class_mix: [f64; 3],
    /// RNG seed.
    pub seed: u64,
    /// Shared-prefix family size: consecutive agents grouped this many at a
    /// time share one prompt prefix. 0/1 disables families (the default).
    pub prefix_fanout: usize,
    /// Length of the shared prompt prefix in tokens (0 disables).
    pub prefix_tokens: u32,
    /// Generate DAG-structured agents (map-reduce / tree / pipeline shapes,
    /// DESIGN.md §9) instead of the paper's staged agents. Off by default:
    /// the staged suite is bit-identical to pre-DAG builds.
    pub dag: bool,
    /// Probability that a completing task of a DAG agent spawns child tasks
    /// (0 disables dynamic spawning; only meaningful with `dag`).
    pub spawn_prob: f64,
    /// Children per spawn event, and the branching factor of tree-shaped
    /// DAG agents.
    pub branch: u32,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_agents: 300,
            window_secs: 9.0 * 60.0,
            class_mix: [0.72, 0.26, 0.02],
            seed: 42,
            prefix_fanout: 0,
            prefix_tokens: 0,
            dag: false,
            spawn_prob: 0.0,
            branch: 2,
        }
    }
}

impl WorkloadConfig {
    /// Paper's density presets: 1x -> 18 min, 2x -> 9 min, 3x -> 6 min.
    pub fn with_density(mut self, density: f64) -> Self {
        self.window_secs = 18.0 * 60.0 / density;
        self
    }

    /// Enable shared-prefix agent families (see [`crate::workload::trace::build_suite`]).
    pub fn with_shared_prefix(mut self, fanout: usize, prefix_tokens: u32) -> Self {
        self.prefix_fanout = fanout;
        self.prefix_tokens = prefix_tokens;
        self
    }

    /// Enable DAG-structured agents with the given spawn knobs
    /// (see [`crate::workload::trace::build_suite`]).
    pub fn with_dag(mut self, spawn_prob: f64, branch: u32) -> Self {
        self.dag = true;
        self.spawn_prob = spawn_prob;
        self.branch = branch;
        self
    }
}

/// Multi-replica cluster knobs (see [`crate::cluster`]). The default is a
/// single replica, which reproduces the single-engine paper setup exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterConfig {
    /// Number of independent engine replicas (each with its own KV pool and
    /// scheduler).
    pub replicas: usize,
    /// How arriving agents are routed across replicas.
    pub placement: Placement,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { replicas: 1, placement: Placement::ClusterVtime }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Backend testbed profile (KV capacity + latency coefficients).
    pub backend: BackendProfile,
    /// Scheduling policy each replica runs.
    pub policy: Policy,
    /// Workload-suite parameters.
    pub workload: WorkloadConfig,
    /// Max sequences admitted to one running batch (vLLM max_num_seqs).
    pub max_batch: usize,
    /// Use predicted costs (true) or ground truth (false) for scheduling.
    pub use_predictor: bool,
    /// Prediction-noise scale lambda for Fig. 10 (1.0 = exact).
    pub noise_lambda: f64,
    /// Multi-replica scale-out knobs.
    pub cluster: ClusterConfig,
    /// Deterministic churn schedule for cluster runs (DESIGN.md §14):
    /// replica crash / drain / join events plus an optional queue-depth
    /// autoscaler. Empty by default — the immortal pool — and cluster
    /// drivers delegate to the pre-elasticity path when empty, so a
    /// churn-off run is byte-identical to a build without the subsystem
    /// (`tests/test_elasticity_recovery.rs`). Lives here rather than on
    /// [`ClusterConfig`] because the schedule carries f64 times and
    /// `ClusterConfig` derives `Eq`.
    pub failures: FailureSchedule,
    /// Enable the radix-tree prefix cache (copy-on-write KV sharing across
    /// inferences with equal prompt prefixes). Off by default: the disabled
    /// engine path is bit-identical to a build without the cache.
    pub prefix_cache: bool,
    /// Online misprediction correction (paper §4.2): as tasks complete, the
    /// engine blends observed cost into each agent's remaining estimate and
    /// re-derives scheduler tags from the corrected remaining work. Off by
    /// default: the disabled path is bit-identical to a build without it.
    /// Composes with `prefix_cache`: observed-cost accounting accrues the
    /// very (dedup-aware) service deltas the schedulers see, so shared
    /// prefix pages are charged once — the same basis as the
    /// suite-deduplicated predictions (DESIGN.md §9).
    pub online_correction: bool,
    /// Chunked prefill (Sarathi-style, DESIGN.md §10): split prompt
    /// processing into [`prefill_chunk`](Config::prefill_chunk)-token pieces
    /// and compose each engine iteration from all running decodes plus as
    /// many prefill chunks as [`max_batched_tokens`](Config::max_batched_tokens)
    /// allows, acquiring KV pages chunk by chunk. Off by default: the
    /// disabled path is bit-identical to a build without chunking (and so is
    /// `prefill_chunk = u32::MAX` with an unbounded budget).
    pub chunked_prefill: bool,
    /// Per-iteration token budget shared by decodes (one token each) and
    /// prefill chunks. Only meaningful with
    /// [`chunked_prefill`](Config::chunked_prefill).
    pub max_batched_tokens: u32,
    /// Maximum prompt tokens one sequence may prefill per iteration. Only
    /// meaningful with [`chunked_prefill`](Config::chunked_prefill).
    pub prefill_chunk: u32,
    /// What to do with preemption victims when device KV runs out
    /// (DESIGN.md §11). Default [`PreemptionMode::Swap`] is the classical
    /// engine, bit-identical to a build without the subsystem.
    pub preemption: PreemptionMode,
    /// How preemption victims are ranked. Default [`VictimPolicy::Youngest`]
    /// reproduces the pre-subsystem victim choice bit for bit.
    pub victim: VictimPolicy,
    /// How each iteration's token budget is split between decodes and
    /// prefill chunks (DESIGN.md §15). Default
    /// [`BatchPolicyKind::Static`] reproduces the pre-policy composition
    /// bit for bit (`prop_batch_policy_identity`); only meaningful with
    /// [`chunked_prefill`](Config::chunked_prefill).
    pub batch_policy: BatchPolicyKind,
    /// Tokens of [`max_batched_tokens`](Config::max_batched_tokens) reserved
    /// for decodes under [`BatchPolicyKind::FixedSplit`]: prefill chunks may
    /// use at most `max_batched_tokens − decode_reserve` per iteration.
    pub decode_reserve: u32,
    /// Drive suites through the event/calendar-queue core (DESIGN.md §12):
    /// arrivals fire from a deterministic binary-heap calendar, batch
    /// composition is incremental between events, and the scheduler receives
    /// engine-event hooks. Off by default for one PR — the legacy tick loop
    /// is the differential-test oracle the event core is proven bit-identical
    /// against (`prop_event_core_identity`).
    pub event_core: bool,
    /// Observability layer (DESIGN.md §13): record a bounded flight
    /// recorder of lifecycle events, a per-iteration fairness sampler, and
    /// a scheduler decision audit log ([`crate::trace`]). Off by default:
    /// with the flag off no recorder exists and every engine path is
    /// bit-identical to a build without the subsystem
    /// (`prop_trace_identity`).
    pub trace: bool,
    /// Sampler stride: record one telemetry sample every this many engine
    /// iterations (only meaningful with [`trace`](Config::trace); ≥ 1).
    pub trace_sample: u32,
    /// Ring capacity per trace stream (events, samples, audit entries);
    /// the oldest entries are dropped — and counted — beyond it.
    pub trace_cap: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            backend: BackendProfile::llama7b_a100(),
            policy: Policy::Justitia,
            workload: WorkloadConfig::default(),
            max_batch: 64,
            use_predictor: false,
            noise_lambda: 1.0,
            cluster: ClusterConfig::default(),
            failures: FailureSchedule::none(),
            prefix_cache: false,
            online_correction: false,
            chunked_prefill: false,
            max_batched_tokens: 2048,
            prefill_chunk: 512,
            preemption: PreemptionMode::Swap,
            victim: VictimPolicy::Youngest,
            batch_policy: BatchPolicyKind::Static,
            decode_reserve: 256,
            event_core: false,
            trace: false,
            trace_sample: 8,
            trace_cap: 65536,
        }
    }
}

impl Config {
    /// Load from a JSON config file; missing keys fall back to defaults.
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        Self::from_json(&v)
    }

    /// Build a config from parsed JSON (missing keys fall back to defaults).
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = Config::default();
        if let Some(name) = v.get("backend").as_str() {
            cfg.backend = BackendProfile::by_name(name)?;
        }
        if let Some(obj) = v.get("backend").as_obj() {
            // Inline profile override.
            let mut b = cfg.backend.clone();
            if let Some(x) = obj.get("name").and_then(|j| j.as_str()) {
                b.name = x.to_string();
            }
            if let Some(x) = obj.get("kv_tokens").and_then(|j| j.as_u64()) {
                b.kv_tokens = x;
            }
            if let Some(x) = obj.get("page_size").and_then(|j| j.as_u64()) {
                b.page_size = x as u32;
            }
            if let Some(x) = obj.get("alpha").and_then(|j| j.as_f64()) {
                b.alpha = x;
            }
            if let Some(x) = obj.get("beta_prefill").and_then(|j| j.as_f64()) {
                b.beta_prefill = x;
            }
            if let Some(x) = obj.get("beta_decode").and_then(|j| j.as_f64()) {
                b.beta_decode = x;
            }
            if let Some(x) = obj.get("beta_mixed").and_then(|j| j.as_f64()) {
                b.beta_mixed = x;
            }
            if let Some(x) = obj.get("host_kv_tokens").and_then(|j| j.as_u64()) {
                b.host_kv_tokens = Some(x);
            }
            if let Some(x) = obj.get("swap_bw").and_then(|j| j.as_f64()) {
                anyhow::ensure!(x >= 0.0, "swap_bw must be >= 0");
                b.swap_bw_tokens_per_sec = x;
            }
            cfg.backend = b;
        }
        if let Some(name) = v.get("policy").as_str() {
            cfg.policy = Policy::by_name(name)?;
        }
        if let Some(x) = v.get("max_batch").as_u64() {
            cfg.max_batch = x as usize;
        }
        if let Some(x) = v.get("use_predictor").as_bool() {
            cfg.use_predictor = x;
        }
        if let Some(x) = v.get("noise_lambda").as_f64() {
            cfg.noise_lambda = x;
        }
        if let Some(x) = v.get("prefix_cache").as_bool() {
            cfg.prefix_cache = x;
        }
        if let Some(x) = v.get("online_correction").as_bool() {
            cfg.online_correction = x;
        }
        if let Some(x) = v.get("chunked_prefill").as_bool() {
            cfg.chunked_prefill = x;
        }
        if let Some(x) = v.get("max_batched_tokens").as_u64() {
            anyhow::ensure!(x >= 1, "max_batched_tokens must be >= 1");
            cfg.max_batched_tokens = x as u32;
        }
        if let Some(x) = v.get("prefill_chunk").as_u64() {
            anyhow::ensure!(x >= 1, "prefill_chunk must be >= 1");
            cfg.prefill_chunk = x as u32;
        }
        if let Some(x) = v.get("preemption").as_str() {
            cfg.preemption = PreemptionMode::by_name(x)?;
        }
        if let Some(x) = v.get("victim").as_str() {
            cfg.victim = VictimPolicy::by_name(x)?;
        }
        if let Some(x) = v.get("batch_policy").as_str() {
            cfg.batch_policy = BatchPolicyKind::by_name(x)?;
        }
        if let Some(x) = v.get("decode_reserve").as_u64() {
            cfg.decode_reserve = x as u32;
        }
        if let Some(x) = v.get("event_core").as_bool() {
            cfg.event_core = x;
        }
        if let Some(x) = v.get("trace").as_bool() {
            cfg.trace = x;
        }
        if let Some(x) = v.get("trace_sample").as_u64() {
            anyhow::ensure!(x >= 1, "trace_sample must be >= 1");
            cfg.trace_sample = x as u32;
        }
        if let Some(x) = v.get("trace_cap").as_u64() {
            anyhow::ensure!(x >= 1, "trace_cap must be >= 1");
            cfg.trace_cap = x as usize;
        }
        let c = v.get("cluster");
        if c.as_obj().is_some() {
            if let Some(x) = c.get("replicas").as_u64() {
                anyhow::ensure!(x >= 1, "cluster.replicas must be >= 1");
                cfg.cluster.replicas = x as usize;
            }
            if let Some(x) = c.get("placement").as_str() {
                cfg.cluster.placement = Placement::by_name(x)?;
            }
        }
        if let Some(x) = v.get("failures").as_str() {
            cfg.failures = FailureSchedule::parse(x)?;
        }
        if let Some(x) = v.get("autoscale").as_str() {
            cfg.failures.autoscale = Some(FailureSchedule::parse_autoscale(x)?);
        }
        let w = v.get("workload");
        if w.as_obj().is_some() {
            if let Some(x) = w.get("n_agents").as_u64() {
                cfg.workload.n_agents = x as usize;
            }
            if let Some(x) = w.get("window_secs").as_f64() {
                cfg.workload.window_secs = x;
            }
            if let Some(x) = w.get("density").as_f64() {
                cfg.workload = cfg.workload.clone().with_density(x);
            }
            if let Some(x) = w.get("seed").as_u64() {
                cfg.workload.seed = x;
            }
            if let Some(x) = w.get("prefix_fanout").as_u64() {
                cfg.workload.prefix_fanout = x as usize;
            }
            if let Some(x) = w.get("prefix_tokens").as_u64() {
                cfg.workload.prefix_tokens = x as u32;
            }
            if let Some(x) = w.get("dag").as_bool() {
                cfg.workload.dag = x;
            }
            if let Some(x) = w.get("spawn_prob").as_f64() {
                cfg.workload.spawn_prob = x;
            }
            if let Some(x) = w.get("branch").as_u64() {
                cfg.workload.branch = x as u32;
            }
        }
        Ok(cfg)
    }

    /// Apply CLI flag overrides on top of the loaded config.
    pub fn apply_args(mut self, args: &Args) -> Result<Self> {
        if let Some(b) = args.get("backend") {
            self.backend = BackendProfile::by_name(b)?;
        }
        if let Some(p) = args.get("policy") {
            self.policy = Policy::by_name(p)?;
        }
        if let Some(n) = args.get("agents") {
            self.workload.n_agents = n.parse().context("--agents")?;
        }
        if let Some(d) = args.get("density") {
            self.workload = self.workload.with_density(d.parse().context("--density")?);
        }
        if let Some(s) = args.get("seed") {
            self.workload.seed = s.parse().context("--seed")?;
        }
        if let Some(l) = args.get("lambda") {
            self.noise_lambda = l.parse().context("--lambda")?;
        }
        if args.has("predict") {
            self.use_predictor = true;
        }
        if let Some(r) = args.get("replicas") {
            let r: usize = r.parse().context("--replicas")?;
            anyhow::ensure!(r >= 1, "--replicas must be >= 1");
            self.cluster.replicas = r;
        }
        if let Some(p) = args.get("placement") {
            self.cluster.placement = Placement::by_name(p)?;
        }
        if let Some(f) = args.get("failures") {
            let autoscale = self.failures.autoscale.take();
            self.failures = FailureSchedule::parse(f).context("--failures")?;
            self.failures.autoscale = autoscale;
        }
        if let Some(a) = args.get("autoscale") {
            self.failures.autoscale =
                Some(FailureSchedule::parse_autoscale(a).context("--autoscale")?);
        }
        if args.has("prefix-cache") {
            self.prefix_cache = true;
        }
        if let Some(f) = args.get("prefix-fanout") {
            self.workload.prefix_fanout = f.parse().context("--prefix-fanout")?;
        }
        if let Some(t) = args.get("prefix-tokens") {
            self.workload.prefix_tokens = t.parse().context("--prefix-tokens")?;
        }
        if args.has("dag") {
            self.workload.dag = true;
        }
        if let Some(p) = args.get("spawn-prob") {
            self.workload.spawn_prob = p.parse().context("--spawn-prob")?;
        }
        if let Some(b) = args.get("branch") {
            self.workload.branch = b.parse().context("--branch")?;
        }
        if args.has("online-correction") {
            self.online_correction = true;
        }
        if args.has("chunked-prefill") {
            self.chunked_prefill = true;
        }
        if let Some(t) = args.get("max-batched-tokens") {
            let t: u32 = t.parse().context("--max-batched-tokens")?;
            anyhow::ensure!(t >= 1, "--max-batched-tokens must be >= 1");
            self.max_batched_tokens = t;
        }
        if let Some(c) = args.get("prefill-chunk") {
            let c: u32 = c.parse().context("--prefill-chunk")?;
            anyhow::ensure!(c >= 1, "--prefill-chunk must be >= 1");
            self.prefill_chunk = c;
        }
        if let Some(m) = args.get("preemption") {
            self.preemption = PreemptionMode::by_name(m)?;
        }
        if let Some(v) = args.get("victim") {
            self.victim = VictimPolicy::by_name(v)?;
        }
        if let Some(b) = args.get("batch-policy") {
            self.batch_policy = BatchPolicyKind::by_name(b)?;
        }
        if let Some(r) = args.get("decode-reserve") {
            self.decode_reserve = r.parse().context("--decode-reserve")?;
        }
        if args.has("event-core") {
            self.event_core = true;
        }
        if args.has("trace") {
            self.trace = true;
        }
        if let Some(s) = args.get("trace-sample") {
            let s: u32 = s.parse().context("--trace-sample")?;
            anyhow::ensure!(s >= 1, "--trace-sample must be >= 1");
            self.trace_sample = s;
        }
        if let Some(c) = args.get("trace-cap") {
            let c: usize = c.parse().context("--trace-cap")?;
            anyhow::ensure!(c >= 1, "--trace-cap must be >= 1");
            self.trace_cap = c;
        }
        if let Some(h) = args.get("host-mem-pages") {
            // Pages of the *current* backend profile (applied after any
            // --backend override above, so the page size is the right one).
            let pages: u64 = h.parse().context("--host-mem-pages")?;
            self.backend.host_kv_tokens = Some(pages * self.backend.page_size as u64);
        }
        if let Some(b) = args.get("swap-bw") {
            let bw: f64 = b.parse().context("--swap-bw")?;
            anyhow::ensure!(bw >= 0.0, "--swap-bw must be >= 0");
            self.backend.swap_bw_tokens_per_sec = bw;
        }
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ChurnKind;

    #[test]
    fn profiles_resolve() {
        for n in ["llama7b-a100", "llama13b-4v100", "qwen32b-h800", "tiny-cpu"] {
            let p = BackendProfile::by_name(n).unwrap();
            assert_eq!(p.name, n);
            assert!(p.kv_tokens > 0 && p.page_size > 0);
        }
        assert!(BackendProfile::by_name("tpu-v9").is_err());
    }

    #[test]
    fn fig3_capacity_matches_paper() {
        // 459 KV blocks with 16-token pages.
        assert_eq!(BackendProfile::llama7b_a100().kv_pages(), 459);
    }

    #[test]
    fn policy_names() {
        for n in ["fcfs", "sjf", "parrot", "vtc", "srjf", "justitia", "justitia-c"] {
            Policy::by_name(n).unwrap();
        }
        assert!(Policy::by_name("mlfq").is_err());
        assert_eq!(Policy::Justitia.name(), "Justitia");
    }

    #[test]
    fn density_presets() {
        let w = WorkloadConfig::default().with_density(3.0);
        assert!((w.window_secs - 360.0).abs() < 1e-9);
        let w = WorkloadConfig::default().with_density(1.0);
        assert!((w.window_secs - 1080.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_overrides() {
        let j = Json::parse(
            r#"{"backend": "qwen32b-h800", "policy": "vtc",
                "workload": {"n_agents": 50, "density": 3, "seed": 7},
                "cluster": {"replicas": 4, "placement": "least-loaded"},
                "max_batch": 32, "noise_lambda": 2.0}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.backend.name, "qwen32b-h800");
        assert_eq!(cfg.policy, Policy::Vtc);
        assert_eq!(cfg.workload.n_agents, 50);
        assert!((cfg.workload.window_secs - 360.0).abs() < 1e-9);
        assert_eq!(cfg.workload.seed, 7);
        assert_eq!(cfg.max_batch, 32);
        assert!((cfg.noise_lambda - 2.0).abs() < 1e-12);
        assert_eq!(cfg.cluster.replicas, 4);
        assert_eq!(cfg.cluster.placement, Placement::LeastLoaded);
    }

    #[test]
    fn cluster_defaults_and_validation() {
        let cfg = Config::default();
        assert_eq!(cfg.cluster, ClusterConfig::default());
        assert_eq!(cfg.cluster.replicas, 1);
        assert_eq!(cfg.cluster.placement, Placement::ClusterVtime);
        // Zero replicas is rejected.
        let j = Json::parse(r#"{"cluster": {"replicas": 0}}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
        // CLI overrides.
        let args = crate::cli::Args::parse(
            ["run", "--replicas", "8", "--placement", "rr"].iter().map(|s| s.to_string()),
            &[],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert_eq!(cfg.cluster.replicas, 8);
        assert_eq!(cfg.cluster.placement, Placement::RoundRobin);
    }

    #[test]
    fn prefix_cache_knobs() {
        // Default: off, no families.
        let cfg = Config::default();
        assert!(!cfg.prefix_cache);
        assert_eq!(cfg.workload.prefix_fanout, 0);
        assert_eq!(cfg.workload.prefix_tokens, 0);
        // JSON.
        let j = Json::parse(
            r#"{"prefix_cache": true,
                "workload": {"prefix_fanout": 4, "prefix_tokens": 512}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert!(cfg.prefix_cache);
        assert_eq!(cfg.workload.prefix_fanout, 4);
        assert_eq!(cfg.workload.prefix_tokens, 512);
        // CLI overrides (prefix-cache is a boolean switch).
        let args = crate::cli::Args::parse(
            ["run", "--prefix-cache", "--prefix-fanout", "8", "--prefix-tokens", "256"]
                .iter()
                .map(|s| s.to_string()),
            &["prefix-cache"],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert!(cfg.prefix_cache);
        assert_eq!(cfg.workload.prefix_fanout, 8);
        assert_eq!(cfg.workload.prefix_tokens, 256);
        // Builder helper.
        let w = WorkloadConfig::default().with_shared_prefix(4, 128);
        assert_eq!((w.prefix_fanout, w.prefix_tokens), (4, 128));
    }

    #[test]
    fn dag_and_correction_knobs() {
        // Defaults: everything off, bit-identical path.
        let cfg = Config::default();
        assert!(!cfg.workload.dag);
        assert_eq!(cfg.workload.spawn_prob, 0.0);
        assert_eq!(cfg.workload.branch, 2);
        assert!(!cfg.online_correction);
        // JSON.
        let j = Json::parse(
            r#"{"online_correction": true,
                "workload": {"dag": true, "spawn_prob": 0.25, "branch": 4}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert!(cfg.workload.dag);
        assert!((cfg.workload.spawn_prob - 0.25).abs() < 1e-12);
        assert_eq!(cfg.workload.branch, 4);
        assert!(cfg.online_correction);
        // CLI overrides (dag / online-correction are boolean switches).
        let args = crate::cli::Args::parse(
            ["run", "--dag", "--spawn-prob", "0.5", "--branch", "3", "--online-correction"]
                .iter()
                .map(|s| s.to_string()),
            &["dag", "online-correction"],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert!(cfg.workload.dag);
        assert!((cfg.workload.spawn_prob - 0.5).abs() < 1e-12);
        assert_eq!(cfg.workload.branch, 3);
        assert!(cfg.online_correction);
        // Builder helper.
        let w = WorkloadConfig::default().with_dag(0.3, 5);
        assert!(w.dag);
        assert!((w.spawn_prob - 0.3).abs() < 1e-12);
        assert_eq!(w.branch, 5);
    }

    #[test]
    fn chunked_prefill_knobs() {
        // Defaults: off, with sane chunk/budget values ready to enable.
        let cfg = Config::default();
        assert!(!cfg.chunked_prefill);
        assert_eq!(cfg.max_batched_tokens, 2048);
        assert_eq!(cfg.prefill_chunk, 512);
        // JSON.
        let j = Json::parse(
            r#"{"chunked_prefill": true, "max_batched_tokens": 1024,
                "prefill_chunk": 128, "backend": {"beta_mixed": 1e-9}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert!(cfg.chunked_prefill);
        assert_eq!(cfg.max_batched_tokens, 1024);
        assert_eq!(cfg.prefill_chunk, 128);
        assert!((cfg.backend.beta_mixed - 1e-9).abs() < 1e-24);
        // Zero chunk/budget are rejected (a zero budget can never batch).
        assert!(Config::from_json(&Json::parse(r#"{"prefill_chunk": 0}"#).unwrap()).is_err());
        assert!(
            Config::from_json(&Json::parse(r#"{"max_batched_tokens": 0}"#).unwrap()).is_err()
        );
        // CLI overrides (chunked-prefill is a boolean switch).
        let args = crate::cli::Args::parse(
            ["run", "--chunked-prefill", "--max-batched-tokens", "4096", "--prefill-chunk", "256"]
                .iter()
                .map(|s| s.to_string()),
            &["chunked-prefill"],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert!(cfg.chunked_prefill);
        assert_eq!(cfg.max_batched_tokens, 4096);
        assert_eq!(cfg.prefill_chunk, 256);
        // The stock profiles carry no mixed-batch term: the pre-chunking
        // latency model is numerically unchanged.
        for n in ["llama7b-a100", "llama13b-4v100", "qwen32b-h800", "tiny-cpu"] {
            assert_eq!(BackendProfile::by_name(n).unwrap().beta_mixed, 0.0);
        }
    }

    #[test]
    fn trace_knobs() {
        // Defaults: off, with sane stride/cap values ready to enable.
        let cfg = Config::default();
        assert!(!cfg.trace);
        assert_eq!(cfg.trace_sample, 8);
        assert_eq!(cfg.trace_cap, 65536);
        // JSON.
        let j = Json::parse(r#"{"trace": true, "trace_sample": 4, "trace_cap": 1024}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert!(cfg.trace);
        assert_eq!(cfg.trace_sample, 4);
        assert_eq!(cfg.trace_cap, 1024);
        // Degenerate values are rejected.
        assert!(Config::from_json(&Json::parse(r#"{"trace_sample": 0}"#).unwrap()).is_err());
        assert!(Config::from_json(&Json::parse(r#"{"trace_cap": 0}"#).unwrap()).is_err());
        // CLI overrides (--trace is a boolean switch).
        let args = crate::cli::Args::parse(
            ["run", "--trace", "--trace-sample", "2", "--trace-cap", "512"]
                .iter()
                .map(|s| s.to_string()),
            &["trace"],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert!(cfg.trace);
        assert_eq!(cfg.trace_sample, 2);
        assert_eq!(cfg.trace_cap, 512);
    }

    #[test]
    fn elasticity_knobs() {
        // Default: empty schedule — the immortal pool, bit-identical path.
        let cfg = Config::default();
        assert!(cfg.failures.is_empty());
        // JSON takes the same DSL strings as the CLI.
        let j = Json::parse(
            r#"{"failures": "crash@40:1,join@90", "autoscale": "every=10,up=4"}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.failures.events.len(), 2);
        assert_eq!(cfg.failures.events[0].kind, ChurnKind::Crash { replica: 1 });
        let a = cfg.failures.autoscale.as_ref().unwrap();
        assert_eq!((a.interval, a.up_queue), (10.0, 4.0));
        // Malformed DSL is rejected.
        assert!(Config::from_json(&Json::parse(r#"{"failures": "melt@4"}"#).unwrap()).is_err());
        assert!(Config::from_json(&Json::parse(r#"{"autoscale": "every=0"}"#).unwrap()).is_err());
        // CLI overrides; --failures replaces events but keeps a previously
        // configured autoscaler (they are orthogonal knobs).
        let args = crate::cli::Args::parse(
            ["run", "--failures", "drain@5:0,join@9", "--autoscale", "every=7,min=2"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let cfg = Config::from_json(&j).unwrap().apply_args(&args).unwrap();
        assert_eq!(cfg.failures.events.len(), 2);
        assert_eq!(cfg.failures.events[0].kind, ChurnKind::Drain { replica: 0 });
        let a = cfg.failures.autoscale.as_ref().unwrap();
        assert_eq!((a.interval, a.min_replicas), (7.0, 2));
        // DSL round-trips through the echo form.
        assert_eq!(FailureSchedule::parse(&cfg.failures.to_dsl()).unwrap().events,
                   cfg.failures.events);
    }

    #[test]
    fn preemption_knobs() {
        // Defaults: the classical engine — unbounded host, swap, youngest.
        let cfg = Config::default();
        assert_eq!(cfg.preemption, PreemptionMode::Swap);
        assert_eq!(cfg.victim, VictimPolicy::Youngest);
        assert_eq!(cfg.backend.host_kv_tokens, None);
        assert_eq!(cfg.backend.swap_bw_tokens_per_sec, 0.0);
        for n in ["llama7b-a100", "llama13b-4v100", "qwen32b-h800", "tiny-cpu"] {
            let p = BackendProfile::by_name(n).unwrap();
            assert_eq!(p.host_kv_tokens, None, "{n} must default to an unbounded host tier");
            assert_eq!(p.swap_bw_tokens_per_sec, 0.0, "{n} must not serialize swaps");
        }
        // Name round-trips.
        for m in [PreemptionMode::Swap, PreemptionMode::Recompute, PreemptionMode::Auto] {
            assert_eq!(PreemptionMode::by_name(m.name()).unwrap(), m);
        }
        for v in VictimPolicy::ALL {
            assert_eq!(VictimPolicy::by_name(v.name()).unwrap(), v);
        }
        assert!(PreemptionMode::by_name("drop").is_err());
        assert!(VictimPolicy::by_name("oldest").is_err());
        // JSON.
        let j = Json::parse(
            r#"{"preemption": "auto", "victim": "pamper-aware",
                "backend": {"host_kv_tokens": 2048, "swap_bw": 30000.0}}"#,
        )
        .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.preemption, PreemptionMode::Auto);
        assert_eq!(cfg.victim, VictimPolicy::PamperAware);
        assert_eq!(cfg.backend.host_kv_tokens, Some(2048));
        assert_eq!(cfg.backend.swap_bw_tokens_per_sec, 30000.0);
        // CLI: --host-mem-pages is in pages of the active profile.
        let args = crate::cli::Args::parse(
            [
                "run",
                "--preemption",
                "recompute",
                "--victim",
                "most-pages",
                "--host-mem-pages",
                "32",
                "--swap-bw",
                "20000",
            ]
            .iter()
            .map(|s| s.to_string()),
            &[],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert_eq!(cfg.preemption, PreemptionMode::Recompute);
        assert_eq!(cfg.victim, VictimPolicy::MostPages);
        assert_eq!(cfg.backend.host_kv_tokens, Some(32 * 16));
        assert_eq!(cfg.backend.swap_bw_tokens_per_sec, 20000.0);
    }

    #[test]
    fn batch_policy_knobs() {
        // Defaults: the bit-identical static split.
        let cfg = Config::default();
        assert_eq!(cfg.batch_policy, BatchPolicyKind::Static);
        assert_eq!(cfg.decode_reserve, 256);
        // Name round-trips.
        for k in BatchPolicyKind::ALL {
            assert_eq!(BatchPolicyKind::by_name(k.name()).unwrap(), k);
        }
        assert!(BatchPolicyKind::by_name("sarathi").is_err());
        // JSON.
        let j = Json::parse(r#"{"batch_policy": "fairbatching", "decode_reserve": 512}"#).unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.batch_policy, BatchPolicyKind::FairBatching);
        assert_eq!(cfg.decode_reserve, 512);
        // CLI.
        let args = crate::cli::Args::parse(
            ["run", "--batch-policy", "fixed-split", "--decode-reserve", "128"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let cfg = Config::default().apply_args(&args).unwrap();
        assert_eq!(cfg.batch_policy, BatchPolicyKind::FixedSplit);
        assert_eq!(cfg.decode_reserve, 128);
    }

    #[test]
    fn inline_backend_object() {
        let j = Json::parse(r#"{"backend": {"name": "custom", "kv_tokens": 1024, "page_size": 8}}"#)
            .unwrap();
        let cfg = Config::from_json(&j).unwrap();
        assert_eq!(cfg.backend.name, "custom");
        assert_eq!(cfg.backend.kv_tokens, 1024);
        assert_eq!(cfg.backend.kv_pages(), 128);
    }
}
