//! Integration: the AOT bridge (HLO text artifacts → PJRT CPU → engine).
//!
//! Requires `make artifacts` to have produced `artifacts/`; tests are
//! skipped (with a message) when artifacts are absent so `cargo test` stays
//! runnable before the Python step.

use justitia::config::{BackendProfile, Config, Policy};
use justitia::engine::Engine;
use justitia::runtime::{PjrtBackend, PjrtModel};
use justitia::workload::test_support::simple_agent;
use justitia::workload::TaskId;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let candidates = [
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        PathBuf::from("artifacts"),
    ];
    candidates.into_iter().find(|p| p.join("model_config.json").exists())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn pjrt_config(model: &PjrtModel) -> Config {
    let m = &model.manifest;
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "tiny-cpu".into(),
        kv_tokens: (m.n_pages * m.page_size) as u64,
        page_size: m.page_size as u32,
        alpha: 0.0,
        beta_prefill: 0.0,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: None,
        swap_bw_tokens_per_sec: 0.0,
    };
    cfg.max_batch = model.max_decode_batch();
    cfg
}

#[test]
fn model_loads_and_generates_deterministically() {
    let dir = require_artifacts!();
    let mut model = PjrtModel::load(Path::new(&dir)).expect("load artifacts");
    assert_eq!(model.platform(), "cpu");

    // Prefill a 5-token prompt into pages [0,1], then decode 4 steps.
    let run = |model: &mut PjrtModel| -> Vec<u32> {
        model.k_pool.iter_mut().for_each(|x| *x = 0.0);
        model.v_pool.iter_mut().for_each(|x| *x = 0.0);
        let mut toks = vec![model.prefill(&[5, 6, 7, 8, 9], &[0, 1]).unwrap()];
        for step in 0..4u32 {
            let t = model
                .decode(&[(toks[toks.len() - 1], 5 + step, vec![0, 1])])
                .unwrap();
            toks.push(t[0]);
        }
        toks
    };
    let a = run(&mut model);
    let b = run(&mut model);
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert!(a.iter().all(|&t| t < model.manifest.vocab as u32));
}

#[test]
fn decode_batch_variants_agree_with_single() {
    let dir = require_artifacts!();
    let mut model = PjrtModel::load(Path::new(&dir)).expect("load artifacts");

    // Prefill two sequences at disjoint pages.
    let n1 = model.prefill(&[11, 12, 13], &[2, 3]).unwrap();
    let n2 = model.prefill(&[40, 41, 42, 43], &[4, 5]).unwrap();

    // Decode them together (batch 2) and separately (batch 1) from the same
    // pool state; logits' argmax must agree.
    let k_snap = model.k_pool.clone();
    let v_snap = model.v_pool.clone();

    let both = model
        .decode(&[(n1, 3, vec![2, 3]), (n2, 4, vec![4, 5])])
        .unwrap();

    model.k_pool = k_snap.clone();
    model.v_pool = v_snap.clone();
    let solo1 = model.decode(&[(n1, 3, vec![2, 3])]).unwrap();
    model.k_pool = k_snap;
    model.v_pool = v_snap;
    let solo2 = model.decode(&[(n2, 4, vec![4, 5])]).unwrap();

    assert_eq!(both[0], solo1[0]);
    assert_eq!(both[1], solo2[0]);
}

#[test]
fn engine_serves_agents_on_real_model() {
    let dir = require_artifacts!();
    let model = PjrtModel::load(Path::new(&dir)).expect("load artifacts");
    let cfg = pjrt_config(&model);
    let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, PjrtBackend::new(model));

    // Two tiny agents: 2 parallel tasks each, prompts/decodes well inside
    // the artifact's max_prefill=64 / 8-page budget.
    engine.submit(simple_agent(0, 0.0, 2, 12, 6), 500.0);
    engine.submit(simple_agent(1, 0.0, 1, 8, 4), 100.0);

    let mut guard = 0;
    while engine.has_work() {
        engine.step();
        guard += 1;
        assert!(guard < 200, "runaway");
    }
    assert_eq!(engine.metrics.completed_agents(), 2);
    assert!(engine.metrics.jct(0).unwrap() > 0.0);
    engine.kv.check_invariants().unwrap();
    // All tasks really ran through the model.
    for (agent, n) in [(0u32, 2u32), (1, 1)] {
        for index in 0..n {
            let id = TaskId { agent, index };
            assert!(engine.metrics.task_complete_time(id).is_some(), "{id}");
        }
    }
}

#[test]
fn swap_stash_preserves_generation() {
    let dir = require_artifacts!();
    let model = PjrtModel::load(Path::new(&dir)).expect("load artifacts");
    let m = &model.manifest;
    // Shrink the engine's view of the pool to force preemption: 6 pages
    // only (the backend still addresses the full artifact pool, so page ids
    // stay valid).
    let mut cfg = pjrt_config(&model);
    cfg.backend.kv_tokens = 6 * m.page_size as u64;
    let sched = justitia::sched::build(Policy::Fcfs, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, PjrtBackend::new(model));

    // Two sequences that can't both fit: prompt 17 tokens → 2 pages + grow.
    engine.submit(simple_agent(0, 0.0, 2, 17, 40), 100.0);
    let mut guard = 0;
    while engine.has_work() {
        engine.step();
        guard += 1;
        assert!(guard < 500, "runaway");
    }
    assert_eq!(engine.metrics.completed_agents(), 1);
    assert!(engine.metrics.swap_out_count() > 0, "expected preemption under 6-page pool");
    engine.kv.check_invariants().unwrap();
}
