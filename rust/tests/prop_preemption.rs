//! Property tests for the preemption subsystem (ISSUE 5 tentpole,
//! DESIGN.md §11):
//!
//! * **Default identity** — the default knobs (unbounded host, `Swap`,
//!   `Youngest`) replay exactly the same engine as spelling those knobs out
//!   with a never-binding host bound, across all six schedulers, on
//!   swap-heavy workloads: same JCTs, same iteration count, same swap
//!   history, and zero recompute drops — the pre-subsystem engine bit for
//!   bit.
//! * **Conservation** — under every (mode × victim × host tier) drawn at
//!   random, per-step KV invariants hold (including the bounded-host
//!   overrun check), every agent completes, and the pool drains to fully
//!   free.

use justitia::config::{BackendProfile, Config, Policy, PreemptionMode, VictimPolicy};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, Suite};

/// A randomized preemption scenario: a small DAG workload over a pool tight
/// enough to force preemptions, plus the subsystem knobs.
#[derive(Clone, Debug)]
struct PreemptScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    mode: PreemptionMode,
    victim: VictimPolicy,
    /// Host pool in tokens; `None` = unbounded.
    host_tokens: Option<u64>,
    /// Chunked prefill on (exercises the starvation valve under recompute).
    chunked: bool,
    swap_bw: f64,
    beta_prefill: f64,
}

struct PreemptStrategy;

impl Strategy for PreemptStrategy {
    type Value = PreemptScenario;

    fn generate(&self, rng: &mut Rng) -> PreemptScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(24, 48);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 7) as usize;
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 5) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                // Prompts up to ~a third of the pool: several sequences
                // collide (forcing preemptions), and even a recompute
                // re-entry whose prompt absorbed its generated tokens
                // still fits an empty pool.
                let p = rng.range_u64(2, m_tokens / 3) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            agents.push(dag_agent(id as u32, t, tasks));
        }
        let mode = *rng.choose(&[
            PreemptionMode::Swap,
            PreemptionMode::Recompute,
            PreemptionMode::Auto,
        ]);
        let victim = *rng.choose(&VictimPolicy::ALL);
        let host_tokens = match rng.below(3) {
            0 => None,
            1 => Some(m_tokens / 4),
            _ => Some(0),
        };
        PreemptScenario {
            agents,
            pages,
            page_size,
            mode,
            victim,
            host_tokens,
            chunked: rng.chance(0.5),
            swap_bw: if rng.chance(0.5) { 1000.0 } else { 0.0 },
            beta_prefill: if rng.chance(0.5) { 1e-3 } else { 0.0 },
        }
    }

    fn shrink(&self, v: &PreemptScenario) -> Vec<PreemptScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        if v.chunked {
            let mut w = v.clone();
            w.chunked = false;
            out.push(w);
        }
        if v.host_tokens.is_some() {
            let mut w = v.clone();
            w.host_tokens = None;
            out.push(w);
        }
        out
    }
}

fn config_for(sc: &PreemptScenario) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-preempt".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: sc.beta_prefill,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: sc.host_tokens,
        swap_bw_tokens_per_sec: sc.swap_bw,
    };
    cfg.max_batch = 64;
    cfg.preemption = sc.mode;
    cfg.victim = sc.victim;
    if sc.chunked {
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 48;
    }
    cfg
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[test]
fn prop_default_knobs_are_bit_identical_across_schedulers() {
    let cfg = PropConfig { cases: prop_cases(30), seed: 0x9ee3_7a01, max_shrink_steps: 60 };
    check(&cfg, &PreemptStrategy, |sc| {
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::AgentFcfs,
            Policy::Vtc,
            Policy::Srjf,
            Policy::Justitia,
        ] {
            let run = |explicit: bool| {
                let mut cfg = config_for(sc);
                // Neutralize the scenario's preemption knobs: this property
                // is about the DEFAULT configuration.
                cfg.preemption = PreemptionMode::Swap;
                cfg.victim = VictimPolicy::Youngest;
                cfg.backend.host_kv_tokens = if explicit { Some(1 << 40) } else { None };
                cfg.backend.swap_bw_tokens_per_sec = 0.0;
                let suite = Suite::new(sc.agents.clone());
                let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
                let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
                let model = justitia::cost::CostModel::MemoryCentric;
                engine.run_suite(&suite, |a| model.agent_cost(a));
                (
                    engine.metrics.jcts(),
                    engine.metrics.iterations(),
                    engine.metrics.swap_out_count(),
                    engine.metrics.recompute_count(),
                )
            };
            let default = run(false);
            let explicit = run(true);
            if default != explicit {
                return Err(format!(
                    "{policy:?}: classical config diverged from default \
                     (default {:?} vs explicit {:?})",
                    (default.1, default.2, default.3),
                    (explicit.1, explicit.2, explicit.3),
                ));
            }
            if default.3 != 0 {
                return Err(format!(
                    "{policy:?}: default (swap/youngest/unbounded) engine recomputed \
                     {} times",
                    default.3
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_preemption_conservation() {
    let cfg = PropConfig { cases: prop_cases(40), seed: 0x5eed_90b2, max_shrink_steps: 60 };
    check(&cfg, &PreemptStrategy, |sc| {
        for policy in [Policy::Fcfs, Policy::Justitia, Policy::Srjf] {
            let cfg = config_for(sc);
            let suite = Suite::new(sc.agents.clone());
            let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
            let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
            let model = justitia::cost::CostModel::MemoryCentric;

            // Drive arrivals by hand so invariants can be checked per step.
            let mut next = 0usize;
            let mut guard = 0u64;
            loop {
                while next < suite.agents.len()
                    && suite.agents[next].arrival <= engine.now() + 1e-12
                {
                    let spec = suite.agents[next].clone();
                    let cost = model.agent_cost(&spec);
                    engine.submit(spec, cost);
                    next += 1;
                }
                if !engine.has_work() {
                    if next >= suite.agents.len() {
                        break;
                    }
                    engine.advance_clock(suite.agents[next].arrival);
                    continue;
                }
                engine.step();
                engine
                    .check_chunked_accounting()
                    .map_err(|e| format!("{policy:?} {:?}/{:?}: accounting: {e}", sc.mode, sc.victim))?;
                engine
                    .check_kv_invariants()
                    .map_err(|e| format!("{policy:?} {:?}/{:?}: kv: {e}", sc.mode, sc.victim))?;
                guard += 1;
                if guard > 2_000_000 {
                    return Err(format!("{policy:?}: did not terminate"));
                }
            }
            if engine.metrics.completed_agents() != suite.len() {
                return Err(format!(
                    "{policy:?} {:?}/{:?}: {}/{} agents completed",
                    sc.mode,
                    sc.victim,
                    engine.metrics.completed_agents(),
                    suite.len()
                ));
            }
            if engine.kv.free_pages() != sc.pages as u32 {
                return Err(format!(
                    "{policy:?}: leaked pages: {} free of {}",
                    engine.kv.free_pages(),
                    sc.pages
                ));
            }
            // A zero-token host can never hold a victim: every preemption
            // must have been a recompute drop.
            if sc.host_tokens == Some(0) && engine.metrics.swap_out_count() > 0 {
                return Err(format!(
                    "{policy:?}: {} swap-outs into a 0-token host pool",
                    engine.metrics.swap_out_count()
                ));
            }
            // Recompute mode never swaps.
            if sc.mode == PreemptionMode::Recompute && engine.metrics.swap_out_count() > 0 {
                return Err(format!(
                    "{policy:?}: recompute mode performed {} swap-outs",
                    engine.metrics.swap_out_count()
                ));
            }
        }
        Ok(())
    });
}
