//! The four determinism-contract rules (DESIGN.md §16).
//!
//! All rules operate on the token stream produced by [`crate::lexer`]:
//!
//! * **R1 `unordered-iter`** — no iteration over `HashMap` / `HashSet` in
//!   the core modules (`engine/`, `sched/`, `cluster/`, `kv/`, `prefix/`,
//!   `cost/`, `metrics/`). Hash-bound names are collected from field /
//!   parameter type ascriptions (`name: HashMap<...>`) and `let` statements
//!   whose initializer mentions a hash collection; iteration is any of
//!   `.iter() .iter_mut() .keys() .values() .values_mut() .drain()
//!   .into_iter() .into_keys() .into_values() .retain()` on such a name
//!   (as `self.name` or a bare local), or a `for _ in [&]name` loop.
//! * **R2 `ambient-nondet`** — no ambient nondeterminism in core modules:
//!   `Instant::now`, `SystemTime`, `thread_rng`, `std::env` reads,
//!   `thread::current` (thread-id inspection), `available_parallelism`.
//!   Paths outside the core list (`util/`, `server/`, ...) are exempt.
//! * **R3 `nan-order`** — no `.partial_cmp(..)` call sites anywhere in the
//!   tree: float ordering must go through `f64::total_cmp` or the `OrdF64`
//!   wrapper, both of which are total (a `fn partial_cmp` *definition*
//!   delegating to a total order is fine and is not flagged).
//! * **R4 `knob-default`** — every field default in `impl Default for
//!   Config` must byte-match (modulo whitespace) the committed
//!   `knob_defaults.manifest`, mechanizing the "new subsystems default
//!   OFF = bit-identical" policy: adding or flipping a knob forces a
//!   reviewed manifest diff.
//!
//! Any site can be accepted with an inline
//! `// simlint::allow(<rule>): <justification>` comment on the same line
//! or on a comment-only line directly above (the annotation then covers
//! the next code line). An annotation with an empty justification is
//! itself a violation; one that suppresses nothing is reported as stale.

use crate::lexer::{lex, Annotation, Lexed, Tok, TokKind};
use std::collections::BTreeSet;
use std::path::Path;

/// Rule identifiers, also the annotation keys.
pub const RULES: [&str; 4] = ["unordered-iter", "ambient-nondet", "nan-order", "knob-default"];

/// Core-module path prefixes (relative to the source root) covered by R1
/// and R2. `util/` (incl. `util::bench`), `server/`, `workload/`,
/// `predictor/`, `runtime/`, `trace/`, `experiments/` and the binary
/// front-ends are exempt by omission: they run off the replay path or are
/// proven observation-only (`prop_trace_identity`).
pub const CORE_PREFIXES: [&str; 7] =
    ["engine/", "sched/", "cluster/", "kv/", "prefix/", "cost/", "metrics/"];

/// One diagnostic.
#[derive(Debug, Clone)]
pub struct Diag {
    /// Path relative to the scanned root.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// Rule id.
    pub rule: &'static str,
    /// Human-readable message.
    pub msg: String,
}

impl Diag {
    /// `file:line: simlint[rule] msg` — the format CI greps for.
    pub fn render(&self) -> String {
        format!("{}:{}: simlint[{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Outcome of linting one file (R1–R3).
#[derive(Debug, Default)]
pub struct FileReport {
    /// Unsuppressed violations.
    pub violations: Vec<Diag>,
    /// Sites suppressed by a justified annotation.
    pub allowed: Vec<Diag>,
    /// Annotations that matched no candidate site.
    pub stale: Vec<Diag>,
}

fn is_core(rel: &str) -> bool {
    CORE_PREFIXES.iter().any(|p| rel.starts_with(p))
}

const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// Lint one file's source (rules R1–R3). `rel` is the path relative to the
/// source root, with `/` separators.
pub fn lint_file(rel: &str, src: &str) -> FileReport {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let mut candidates: Vec<Diag> = Vec::new();

    if is_core(rel) {
        let hash_names = collect_hash_names(toks);
        candidates.extend(r1_unordered_iter(rel, toks, &hash_names));
        candidates.extend(r2_ambient_nondet(rel, toks));
    }
    candidates.extend(r3_nan_order(rel, toks));

    apply_annotations(rel, candidates, &lexed)
}

/// Split candidate violations into suppressed and live using the file's
/// annotations; flag empty justifications and stale annotations.
fn apply_annotations(rel: &str, candidates: Vec<Diag>, lexed: &Lexed) -> FileReport {
    let mut rep = FileReport::default();
    let mut used: BTreeSet<usize> = BTreeSet::new();
    for c in candidates {
        match find_annotation(&lexed.annotations, lexed, c.line, c.rule) {
            Some(ai) => {
                used.insert(ai);
                let ann = &lexed.annotations[ai];
                if ann.reason.is_empty() {
                    rep.violations.push(Diag {
                        file: rel.into(),
                        line: ann.line,
                        rule: c.rule,
                        msg: format!(
                            "allow annotation for `{}` has no justification — write the reason after the colon",
                            c.rule
                        ),
                    });
                } else {
                    rep.allowed.push(c);
                }
            }
            None => rep.violations.push(c),
        }
    }
    for (i, ann) in lexed.annotations.iter().enumerate() {
        if !used.contains(&i) && RULES.contains(&ann.rule.as_str()) {
            rep.stale.push(Diag {
                file: rel.into(),
                line: ann.line,
                rule: "stale-allow",
                msg: format!("simlint::allow({}) suppresses nothing on this line", ann.rule),
            });
        } else if !RULES.contains(&ann.rule.as_str()) {
            rep.violations.push(Diag {
                file: rel.into(),
                line: ann.line,
                rule: "unknown-rule",
                msg: format!("unknown simlint rule `{}` in allow annotation", ann.rule),
            });
        }
    }
    rep
}

/// An annotation covers a candidate at `line` when it names the same rule
/// and sits on that line, or sits alone on a comment line whose next code
/// line is `line`.
fn find_annotation(
    annotations: &[Annotation],
    lexed: &Lexed,
    line: u32,
    rule: &str,
) -> Option<usize> {
    annotations.iter().position(|a| {
        a.rule == rule
            && (a.line == line || (a.own_line && lexed.next_code_line(a.line) == Some(line)))
    })
}

/// Collect identifiers bound to `HashMap` / `HashSet` in this file:
/// `name: [&[mut]] [std::collections::]Hash{Map,Set}<...>` type ascriptions
/// (struct fields, fn params, typed lets) plus `let [mut] name = ...` whose
/// statement mentions a hash type. Single-file and name-based by design —
/// see DESIGN.md §16 for the soundness discussion.
fn collect_hash_names(toks: &[Tok]) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident
            || (toks[i].text != "HashMap" && toks[i].text != "HashSet")
        {
            continue;
        }
        if toks.get(i + 1).map(|t| t.text.as_str()) != Some("<") {
            continue;
        }
        // Walk backwards over the optional path / reference decoration to
        // find a `name :` ascription.
        let mut j = i;
        while j >= 2 && toks[j - 1].text == ":" && toks[j - 2].text == ":" {
            // `std :: collections ::` or any path prefix
            if j >= 3 && toks[j - 3].kind == TokKind::Ident {
                j -= 3;
            } else {
                break;
            }
        }
        while j >= 1
            && (toks[j - 1].text == "&"
                || toks[j - 1].text == "mut"
                || toks[j - 1].kind == TokKind::Lifetime)
        {
            j -= 1;
        }
        if j >= 2 && toks[j - 1].text == ":" && toks[j - 2].kind == TokKind::Ident {
            // Exclude `::` (path segment) and `struct X:` style false hits.
            let name = &toks[j - 2].text;
            let before = j.checked_sub(3).map(|k| toks[k].text.as_str());
            if name != "self" && before != Some(":") {
                names.insert(name.clone());
            }
        }
    }
    // `let [mut] name` statements whose initializer mentions a hash type.
    let mut i = 0;
    while i < toks.len() {
        if toks[i].kind == TokKind::Ident && toks[i].text == "let" {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text.as_str()) == Some("mut") {
                j += 1;
            }
            if let Some(name_tok) = toks.get(j) {
                if name_tok.kind == TokKind::Ident {
                    // Scan the statement (to `;` at depth 0) for a hash type.
                    let mut k = j + 1;
                    let mut depth = 0i32;
                    let mut has_hash = false;
                    while k < toks.len() {
                        let t = &toks[k].text;
                        match t.as_str() {
                            "(" | "[" | "{" => depth += 1,
                            ")" | "]" | "}" => depth -= 1,
                            ";" if depth <= 0 => break,
                            "HashMap" | "HashSet" => has_hash = true,
                            _ => {}
                        }
                        k += 1;
                    }
                    if has_hash {
                        names.insert(name_tok.text.clone());
                    }
                    i = k;
                    continue;
                }
            }
        }
        i += 1;
    }
    names
}

/// R1: iteration over hash-ordered collections in core modules.
fn r1_unordered_iter(rel: &str, toks: &[Tok], names: &BTreeSet<String>) -> Vec<Diag> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        // `[self.]name . method (` with method in the iteration set.
        if toks[i].kind == TokKind::Ident && names.contains(&toks[i].text) {
            let recv_ok = match i.checked_sub(1).map(|k| toks[k].text.as_str()) {
                Some(".") => i >= 2 && toks[i - 2].text == "self",
                Some(":") => false, // path segment `x::name`
                _ => true, // bare local
            };
            if recv_ok
                && toks.get(i + 1).map(|t| t.text.as_str()) == Some(".")
                && toks.get(i + 3).map(|t| t.text.as_str()) == Some("(")
            {
                if let Some(m) = toks.get(i + 2) {
                    if ITER_METHODS.contains(&m.text.as_str()) {
                        out.push(Diag {
                            file: rel.into(),
                            line: m.line,
                            rule: "unordered-iter",
                            msg: format!(
                                "iteration (`.{}()`) over unordered `{}` — use BTreeMap/BTreeSet, collect-and-sort, or justify with simlint::allow(unordered-iter)",
                                m.text, toks[i].text
                            ),
                        });
                    }
                }
            }
        }
        // `for pat in [&][mut] [self.]name {`
        if toks[i].kind == TokKind::Ident && toks[i].text == "for" {
            let mut j = i + 1;
            let mut depth = 0i32;
            let mut found_in = None;
            while j < toks.len() && j < i + 64 {
                match toks[j].text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" | ";" => break,
                    "in" if depth == 0 && toks[j].kind == TokKind::Ident => {
                        found_in = Some(j);
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
            let Some(mut j) = found_in.map(|j| j + 1) else { continue };
            while j < toks.len() && (toks[j].text == "&" || toks[j].text == "mut") {
                j += 1;
            }
            // Receiver: `self . name` or bare `name`, directly followed by
            // `{` (a method-call tail is already covered above).
            let (name_idx, brace_idx) = if toks.get(j).map(|t| t.text.as_str()) == Some("self")
                && toks.get(j + 1).map(|t| t.text.as_str()) == Some(".")
            {
                (j + 2, j + 3)
            } else {
                (j, j + 1)
            };
            if let (Some(name), Some(brace)) = (toks.get(name_idx), toks.get(brace_idx)) {
                if name.kind == TokKind::Ident
                    && names.contains(&name.text)
                    && brace.text == "{"
                {
                    out.push(Diag {
                        file: rel.into(),
                        line: name.line,
                        rule: "unordered-iter",
                        msg: format!(
                            "`for` over unordered `{}` — use BTreeMap/BTreeSet, collect-and-sort, or justify with simlint::allow(unordered-iter)",
                            name.text
                        ),
                    });
                }
            }
        }
    }
    out
}

/// R2: ambient nondeterminism in core modules.
fn r2_ambient_nondet(rel: &str, toks: &[Tok]) -> Vec<Diag> {
    let mut out = Vec::new();
    let flag = |out: &mut Vec<Diag>, line: u32, what: &str| {
        out.push(Diag {
            file: rel.into(),
            line,
            rule: "ambient-nondet",
            msg: format!(
                "{what} in a core module — core state must be a pure function of config + seed; move it off the replay path or justify with simlint::allow(ambient-nondet)"
            ),
        });
    };
    for i in 0..toks.len() {
        if toks[i].kind != TokKind::Ident {
            continue;
        }
        let path2 = |a: &str, b: &str| {
            toks[i].text == a
                && toks.get(i + 1).map(|t| t.text.as_str()) == Some(":")
                && toks.get(i + 2).map(|t| t.text.as_str()) == Some(":")
                && toks.get(i + 3).map(|t| t.text.as_str()) == Some(b)
        };
        if path2("Instant", "now") {
            flag(&mut out, toks[i].line, "`Instant::now()` (wall-clock read)");
        } else if toks[i].text == "SystemTime" {
            flag(&mut out, toks[i].line, "`SystemTime` (wall-clock read)");
        } else if toks[i].text == "thread_rng" || toks[i].text == "ThreadRng" {
            flag(&mut out, toks[i].line, "`thread_rng` (unseeded RNG)");
        } else if toks[i].text == "env"
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some(":")
            && toks.get(i + 2).map(|t| t.text.as_str()) == Some(":")
            && matches!(
                toks.get(i + 3).map(|t| t.text.as_str()),
                Some("var" | "vars" | "var_os" | "vars_os" | "args" | "args_os" | "temp_dir")
            )
        {
            flag(&mut out, toks[i].line, "`std::env` read (ambient environment)");
        } else if path2("thread", "current") {
            flag(&mut out, toks[i].line, "`thread::current()` (thread-id inspection)");
        } else if toks[i].text == "available_parallelism" {
            flag(&mut out, toks[i].line, "`available_parallelism()` (machine-dependent width)");
        }
    }
    out
}

/// R3: NaN-unsafe float ordering — any `.partial_cmp(` call site.
fn r3_nan_order(rel: &str, toks: &[Tok]) -> Vec<Diag> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        if toks[i].kind == TokKind::Ident
            && toks[i].text == "partial_cmp"
            && i >= 1
            && toks[i - 1].text == "."
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("(")
        {
            out.push(Diag {
                file: rel.into(),
                line: toks[i].line,
                rule: "nan-order",
                msg: "`.partial_cmp(..)` call — NaN-unsafe ordering; use `f64::total_cmp` or `OrdF64` (both total), or justify with simlint::allow(nan-order)".into(),
            });
        }
    }
    out
}

/// R4: knob-default audit. Parses `impl Default for Config` in the config
/// source and cross-checks every `field: value` against the manifest
/// (`field = value` lines, `#` comments; values compared with all
/// whitespace removed). Returns violations only — R4 sites are not
/// annotatable; the manifest *is* the allow-list.
pub fn r4_knob_defaults(rel: &str, config_src: &str, manifest_rel: &str, manifest_src: &str) -> Vec<Diag> {
    let mut out = Vec::new();
    let lexed = lex(config_src);
    let toks = &lexed.toks;

    // Manifest: `name = value` per line.
    let mut manifest: Vec<(String, String, u32)> = Vec::new();
    for (ln, line) in manifest_src.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        match t.split_once('=') {
            Some((k, v)) => manifest.push((
                k.trim().to_string(),
                v.chars().filter(|c| !c.is_whitespace()).collect(),
                ln as u32 + 1,
            )),
            None => out.push(Diag {
                file: manifest_rel.into(),
                line: ln as u32 + 1,
                rule: "knob-default",
                msg: format!("manifest line is not `field = value`: `{t}`"),
            }),
        }
    }

    let Some(fields) = default_impl_fields(toks) else {
        out.push(Diag {
            file: rel.into(),
            line: 1,
            rule: "knob-default",
            msg: "no `impl Default for Config` with a `Config { .. }` literal found".into(),
        });
        return out;
    };

    for (name, value, line) in &fields {
        match manifest.iter().find(|(k, _, _)| k == name) {
            None => out.push(Diag {
                file: rel.into(),
                line: *line,
                rule: "knob-default",
                msg: format!(
                    "knob `{name}` is not registered in {manifest_rel} — new knobs must default to the OFF/sentinel state and be recorded there (ROADMAP: \"new subsystems default OFF = bit-identical\")"
                ),
            }),
            Some((_, want, _)) if want != value => out.push(Diag {
                file: rel.into(),
                line: *line,
                rule: "knob-default",
                msg: format!(
                    "default for knob `{name}` is `{value}` but {manifest_rel} pins `{want}` — changing a default breaks replay identity; update the manifest in the same reviewed diff if intended"
                ),
            }),
            _ => {}
        }
    }
    for (name, _, ln) in &manifest {
        if !fields.iter().any(|(f, _, _)| f == name) {
            out.push(Diag {
                file: manifest_rel.into(),
                line: *ln,
                rule: "knob-default",
                msg: format!("manifest registers knob `{name}` but `impl Default for Config` has no such field"),
            });
        }
    }
    out
}

/// Extract `field: value` pairs (value = token texts joined without
/// whitespace) from the `Config { ... }` literal inside
/// `impl Default for Config`.
fn default_impl_fields(toks: &[Tok]) -> Option<Vec<(String, String, u32)>> {
    let mut i = 0;
    // Find `impl Default for Config`.
    while i + 3 < toks.len() {
        if toks[i].text == "impl"
            && toks[i + 1].text == "Default"
            && toks[i + 2].text == "for"
            && toks[i + 3].text == "Config"
        {
            break;
        }
        i += 1;
    }
    if i + 3 >= toks.len() {
        return None;
    }
    // Skip past `fn default` so the impl header's own `Config {` is not
    // mistaken for the struct literal.
    while i + 1 < toks.len() && !(toks[i].text == "fn" && toks[i + 1].text == "default") {
        i += 1;
    }
    // Find the `Config {` literal inside the body.
    while i + 1 < toks.len() && !(toks[i].text == "Config" && toks[i + 1].text == "{") {
        i += 1;
    }
    if i + 1 >= toks.len() {
        return None;
    }
    let mut fields = Vec::new();
    let mut j = i + 2;
    while j < toks.len() && toks[j].text != "}" {
        // field name
        if toks[j].kind != TokKind::Ident || toks.get(j + 1).map(|t| t.text.as_str()) != Some(":")
        {
            return None;
        }
        let name = toks[j].text.clone();
        let line = toks[j].line;
        let mut k = j + 2;
        let mut depth = 0i32;
        let mut value = String::new();
        while k < toks.len() {
            let t = &toks[k].text;
            match t.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" => depth -= 1,
                "}" if depth > 0 => depth -= 1,
                "}" => break,
                "," if depth == 0 => break,
                _ => {}
            }
            if !(t == "," && depth == 0) && !(t == "}" && depth < 0) {
                value.push_str(t);
            }
            k += 1;
        }
        fields.push((name, value, line));
        j = if toks.get(k).map(|t| t.text.as_str()) == Some(",") { k + 1 } else { k };
    }
    Some(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn core_scope() {
        assert!(is_core("engine/mod.rs"));
        assert!(is_core("sched/gps.rs"));
        assert!(is_core("metrics/mod.rs"));
        assert!(!is_core("util/bench.rs"));
        assert!(!is_core("server/http.rs"));
        assert!(!is_core("main.rs"));
    }

    #[test]
    fn r1_flags_self_field_and_bare_local() {
        let src = "struct S { m: HashMap<u32, u32> }\nimpl S { fn f(&self) { for x in self.m.values() { } } }\nfn g() { let mut s = HashSet::new(); s.iter(); }\n";
        let rep = lint_file("engine/x.rs", src);
        assert_eq!(rep.violations.iter().filter(|d| d.rule == "unordered-iter").count(), 2);
    }

    #[test]
    fn r1_keyed_access_is_fine_and_vec_fields_are_not_flagged() {
        let src = "struct S { m: HashMap<u32, u32>, v: Vec<u32> }\nimpl S { fn f(&self) -> Option<&u32> { for x in self.v.iter() { }\n self.m.get(&1) } }\n";
        let rep = lint_file("kv/x.rs", src);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    }

    #[test]
    fn r1_for_loop_over_ref() {
        let src = "struct S { seqs: HashMap<u32, u32> }\nimpl S { fn f(&self) { for (a, b) in &self.seqs { } } }\n";
        let rep = lint_file("kv/x.rs", src);
        assert_eq!(rep.violations.len(), 1);
        assert_eq!(rep.violations[0].line, 2);
    }

    #[test]
    fn r1_not_applied_outside_core() {
        let src = "fn g() { let mut s = HashSet::new(); s.iter(); }\n";
        assert!(lint_file("util/x.rs", src).violations.is_empty());
    }

    #[test]
    fn r1_other_receiver_not_flagged() {
        // `suite.agents` where `agents` names a hash field of a *different*
        // struct: the `x.name` receiver form is only matched for `self`.
        let src = "struct S { agents: HashMap<u32, u32> }\nfn f(suite: &Suite) { for a in suite.agents.iter() { } }\n";
        assert!(lint_file("engine/x.rs", src).violations.is_empty());
    }

    #[test]
    fn annotation_same_line_and_own_line() {
        let src = "struct S { m: HashMap<u32, u32> }\nimpl S { fn f(&self) {\n// simlint::allow(unordered-iter): re-sorted by key below\nfor x in &self.m { }\nself.m.keys(); // simlint::allow(unordered-iter): min over total order\n} }\n";
        let rep = lint_file("engine/x.rs", src);
        assert!(rep.violations.is_empty(), "{:?}", rep.violations);
        assert_eq!(rep.allowed.len(), 2);
        assert!(rep.stale.is_empty());
    }

    #[test]
    fn empty_reason_is_a_violation() {
        let src = "fn f(x: f64, y: f64) { x.partial_cmp(&y); } // simlint::allow(nan-order)\n";
        let rep = lint_file("util/x.rs", src);
        assert_eq!(rep.violations.len(), 1);
        assert!(rep.violations[0].msg.contains("no justification"));
    }

    #[test]
    fn stale_annotation_reported() {
        let src = "// simlint::allow(ambient-nondet): nothing here\nfn f() {}\n";
        let rep = lint_file("engine/x.rs", src);
        assert!(rep.violations.is_empty());
        assert_eq!(rep.stale.len(), 1);
    }

    #[test]
    fn r2_patterns() {
        let src = "fn f() { let t = std::time::Instant::now(); let e = std::env::var(\"X\"); let id = thread::current().id(); }\n";
        let rep = lint_file("cluster/x.rs", src);
        assert_eq!(rep.violations.len(), 3);
        assert!(lint_file("server/x.rs", src).violations.is_empty());
    }

    #[test]
    fn r3_call_flagged_definition_not() {
        let src = "impl PartialOrd for X { fn partial_cmp(&self, o: &Self) -> Option<Ordering> { Some(self.cmp(o)) } }\nfn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let rep = lint_file("workload/x.rs", src);
        assert_eq!(rep.violations.len(), 1);
        assert_eq!(rep.violations[0].line, 2);
    }

    #[test]
    fn r4_matches_and_mismatches() {
        let cfg = "pub struct Config { pub a: bool, pub b: u32 }\nimpl Default for Config {\n fn default() -> Self {\n Config { a: false, b: Foo::bar(1, 2), }\n }\n}\n";
        let ok = "# comment\na = false\nb = Foo::bar(1, 2)\n";
        assert!(r4_knob_defaults("config/mod.rs", cfg, "m", ok).is_empty());
        let drift = "a = true\nb = Foo::bar(1, 2)\n";
        let d = r4_knob_defaults("config/mod.rs", cfg, "m", drift);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("knob `a`"));
        let missing = "a = false\n";
        let d = r4_knob_defaults("config/mod.rs", cfg, "m", missing);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("not registered"));
        let extra = "a = false\nb = Foo::bar(1, 2)\nzz = 1\n";
        let d = r4_knob_defaults("config/mod.rs", cfg, "m", extra);
        assert_eq!(d.len(), 1);
        assert!(d[0].msg.contains("no such field"));
    }

    #[test]
    fn r4_nested_braces_in_value() {
        let cfg = "impl Default for Config { fn default() -> Self { Config { w: WorkloadConfig { n: 3 }, b: false } } }\n";
        let ok = "w = WorkloadConfig { n: 3 }\nb = false\n";
        assert!(r4_knob_defaults("config/mod.rs", cfg, "m", ok).is_empty(), "nested literal");
    }
}
