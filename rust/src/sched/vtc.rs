//! Virtual Token Counter — the VTC fair scheduler of Sheng et al. (OSDI'24),
//! paper baseline (d). Tracks the service each agent has received (in
//! compute-centric token units, w_p·p + w_d·d with w_p=1, w_d=2) and always
//! admits the waiting agent with the LEAST counter — approximating
//! instantaneous fair sharing. New arrivals have their counter lifted to the
//! minimum over active agents so they cannot claim service retroactively.

use crate::config::Policy;
use crate::cost::CostModel;
use crate::sched::{AgentInfo, AgentQueues, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::collections::{HashMap, HashSet};

/// VTC weights (Sheng et al.): input tokens weight 1, output tokens weight 2.
pub const W_INPUT: f64 = 1.0;
/// VTC output-token weight w_d.
pub const W_OUTPUT: f64 = 2.0;

/// VTC scheduler state (per-agent service counters).
pub struct Vtc {
    counters: HashMap<AgentId, f64>,
    active: HashSet<AgentId>,
    waiting: AgentQueues,
    #[allow(dead_code)]
    cost_model: CostModel,
}

impl Vtc {
    /// Empty scheduler using `cost_model` for service accounting.
    pub fn new(cost_model: CostModel) -> Self {
        Vtc {
            counters: HashMap::new(),
            active: HashSet::new(),
            waiting: AgentQueues::new(),
            cost_model,
        }
    }

    /// Current counter of an agent.
    pub fn counter(&self, agent: AgentId) -> f64 {
        self.counters.get(&agent).copied().unwrap_or(0.0)
    }

    fn min_active_counter(&self) -> f64 {
        self.active
            .iter() // simlint::allow(unordered-iter): commutative min fold, order-independent
            .filter_map(|a| self.counters.get(a))
            .fold(f64::INFINITY, |m, &c| m.min(c))
    }
}

impl Scheduler for Vtc {
    fn policy(&self) -> Policy {
        Policy::Vtc
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, _now: f64) {
        // Counter lift: max(own, min over active) — prevents a newcomer from
        // monopolizing the backend to "catch up" on service it never queued
        // for (Sheng et al. §4).
        let lift = if self.active.is_empty() { 0.0 } else { self.min_active_counter() };
        let own = self.counters.get(&info.id).copied().unwrap_or(0.0);
        self.counters.insert(info.id, own.max(lift));
        self.active.insert(info.id);
    }

    fn push_task(&mut self, task: TaskInfo, _now: f64) {
        self.waiting.push(task);
    }

    fn pop_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let agent = self
            .waiting
            .min_agent_by(|a| self.counters.get(&a).copied().unwrap_or(0.0))?;
        self.waiting.pop_agent(agent)
    }

    fn peek_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let agent = self
            .waiting
            .min_agent_by(|a| self.counters.get(&a).copied().unwrap_or(0.0))?;
        self.waiting.peek_agent(agent).copied()
    }

    fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    fn on_service(&mut self, agent: AgentId, delta: f64) {
        *self.counters.entry(agent).or_insert(0.0) += delta;
    }

    fn on_agent_complete(&mut self, agent: AgentId, _now: f64) {
        self.active.remove(&agent);
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        // Preempt the agent that has received the MOST service first.
        self.counters.get(&agent).copied().unwrap_or(0.0)
    }
}

/// Service delta for VTC accounting when `tokens_in` prompt tokens are
/// prefilled and `tokens_out` tokens are decoded.
#[inline]
pub fn service_delta(tokens_in: u32, tokens_out: u32) -> f64 {
    W_INPUT * tokens_in as f64 + W_OUTPUT * tokens_out as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn info(id: u32) -> AgentInfo {
        AgentInfo::new(id, 0.0, 0.0)
    }

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 10, predicted_decode: 5.0, seq }
    }

    #[test]
    fn least_service_first() {
        let mut s = Vtc::new(CostModel::ComputeCentric);
        s.on_agent_arrival(&info(1), 0.0);
        s.on_agent_arrival(&info(2), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(2, 0, 1), 0.0);
        s.on_service(1, 100.0);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 2);
    }

    #[test]
    fn alternates_for_fair_share() {
        // With equal per-task service, VTC round-robins agents — the
        // instantaneous-fairness behaviour (and why agents finish late).
        let mut s = Vtc::new(CostModel::ComputeCentric);
        s.on_agent_arrival(&info(1), 0.0);
        s.on_agent_arrival(&info(2), 0.0);
        for i in 0..4 {
            s.push_task(task(1, i, (2 * i) as u64), 0.0);
            s.push_task(task(2, i, (2 * i + 1) as u64), 0.0);
        }
        let mut order = Vec::new();
        for _ in 0..8 {
            let t = s.pop_next(0.0).unwrap();
            s.on_service(t.id.agent, service_delta(t.prompt_tokens, 5));
            order.push(t.id.agent);
        }
        // Strict alternation given identical deltas (ties by agent id).
        assert_eq!(order, vec![1, 2, 1, 2, 1, 2, 1, 2]);
    }

    #[test]
    fn newcomer_counter_is_lifted() {
        let mut s = Vtc::new(CostModel::ComputeCentric);
        s.on_agent_arrival(&info(1), 0.0);
        s.on_service(1, 500.0);
        s.on_agent_arrival(&info(2), 10.0);
        // Lift to min over active = 500 (agent 1's counter).
        assert!((s.counter(2) - 500.0).abs() < 1e-12);
    }

    #[test]
    fn completed_agents_leave_active_set() {
        let mut s = Vtc::new(CostModel::ComputeCentric);
        s.on_agent_arrival(&info(1), 0.0);
        s.on_service(1, 900.0);
        s.on_agent_complete(1, 5.0);
        s.on_agent_arrival(&info(2), 6.0);
        // No active agents at lift time → counter starts at 0.
        assert_eq!(s.counter(2), 0.0);
    }

    #[test]
    fn vtc_weights_match_paper() {
        assert_eq!(service_delta(100, 50), 200.0);
    }
}
