//! A minimal Rust lexer: good enough to walk this crate's token stream.
//!
//! Produces identifier / punctuation / literal tokens tagged with line
//! numbers, strips comments and string contents (so rule patterns never
//! match inside them), and collects `// simlint::allow(<rule>): <reason>`
//! annotations. Not a full Rust lexer — no token trees, no macro
//! expansion — but comments, strings (including raw strings), char
//! literals and lifetimes are handled, which is what keeping the rule
//! matchers sound requires.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Single punctuation character (`::` arrives as two `:` tokens).
    Punct,
    /// String / char / numeric literal (contents not preserved for strings).
    Lit,
    /// Lifetime (`'a`).
    Lifetime,
}

/// One lexed token.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token text. Punctuation is a single character; string literals are
    /// collapsed to `""`.
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: u32,
    /// Token kind.
    pub kind: TokKind,
}

/// A `// simlint::allow(<rule>): <reason>` annotation.
#[derive(Debug, Clone)]
pub struct Annotation {
    /// 1-based line the comment sits on.
    pub line: u32,
    /// Whether any non-comment token shares this line (same-line annotation)
    /// as opposed to a comment-only line (covers the next code line).
    pub own_line: bool,
    /// Rule id inside the parentheses.
    pub rule: String,
    /// Justification text after the colon (may be empty — that's a lint
    /// violation in itself).
    pub reason: String,
}

/// Lexer output for one file.
#[derive(Debug, Default)]
pub struct Lexed {
    /// Token stream, comments and whitespace removed.
    pub toks: Vec<Tok>,
    /// All simlint annotations found in line comments.
    pub annotations: Vec<Annotation>,
}

impl Lexed {
    /// Smallest token line strictly greater than `line`, if any — the "next
    /// code line" an own-line annotation covers.
    pub fn next_code_line(&self, line: u32) -> Option<u32> {
        // Tokens are in source order, so a linear scan from the first token
        // past `line` terminates at the first hit.
        self.toks.iter().map(|t| t.line).find(|&l| l > line)
    }
}

/// Lex `src` into tokens + annotations.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    // Lines that carry at least one non-comment token; resolved into the
    // `own_line` flag at the end.
    let mut code_lines = std::collections::BTreeSet::new();

    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < b.len() && b[i + 1] == '/' => {
                // Line comment (includes /// and //! doc forms). Collect the
                // text so simlint::allow annotations can be parsed.
                let start = i + 2;
                let mut j = start;
                while j < b.len() && b[j] != '\n' {
                    j += 1;
                }
                let text: String = b[start..j].iter().collect();
                if let Some(ann) = parse_annotation(&text, line) {
                    out.annotations.push(ann);
                }
                i = j;
            }
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                // Block comment, nested per Rust rules. No annotations here:
                // the contract keeps allow-comments greppable as `//` lines.
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            '"' => {
                let l0 = line;
                i = skip_string(&b, i, &mut line);
                code_lines.insert(l0);
                out.toks.push(Tok { text: "\"\"".into(), line: l0, kind: TokKind::Lit });
            }
            'r' | 'b' if is_raw_or_byte_string(&b, i) => {
                let l0 = line;
                i = skip_raw_or_byte_string(&b, i, &mut line);
                code_lines.insert(l0);
                out.toks.push(Tok { text: "\"\"".into(), line: l0, kind: TokKind::Lit });
            }
            '\'' => {
                // Char literal or lifetime. `'x'` / `'\n'` are chars;
                // `'ident` without a closing quote is a lifetime.
                let l0 = line;
                if let Some(end) = char_literal_end(&b, i) {
                    i = end;
                    code_lines.insert(l0);
                    out.toks.push(Tok { text: "' '".into(), line: l0, kind: TokKind::Lit });
                } else {
                    let mut j = i + 1;
                    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                        j += 1;
                    }
                    let text: String = b[i..j].iter().collect();
                    code_lines.insert(l0);
                    out.toks.push(Tok { text, line: l0, kind: TokKind::Lifetime });
                    i = j;
                }
            }
            c if c.is_alphabetic() || c == '_' => {
                let l0 = line;
                let mut j = i;
                while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                let text: String = b[i..j].iter().collect();
                code_lines.insert(l0);
                out.toks.push(Tok { text, line: l0, kind: TokKind::Ident });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let l0 = line;
                let mut j = i;
                // Numbers: digits, underscores, one dot (not `..`), exponent
                // and type-suffix characters. `1.0f64`, `0xff`, `1_000`,
                // `1e-9` all arrive as one token; `0..n` splits at `..`.
                while j < b.len() {
                    let d = b[j];
                    if d.is_ascii_alphanumeric() || d == '_' {
                        j += 1;
                    } else if d == '.' && j + 1 < b.len() && b[j + 1] != '.' && !b[j + 1].is_alphabetic() {
                        j += 1;
                    } else if (d == '+' || d == '-') && j > i && (b[j - 1] == 'e' || b[j - 1] == 'E') {
                        j += 1;
                    } else {
                        break;
                    }
                }
                let text: String = b[i..j].iter().collect();
                code_lines.insert(l0);
                out.toks.push(Tok { text, line: l0, kind: TokKind::Lit });
                i = j;
            }
            _ => {
                code_lines.insert(line);
                out.toks.push(Tok { text: c.to_string(), line, kind: TokKind::Punct });
                i += 1;
            }
        }
    }

    for ann in &mut out.annotations {
        ann.own_line = !code_lines.contains(&ann.line);
    }
    out
}

fn parse_annotation(comment: &str, line: u32) -> Option<Annotation> {
    let t = comment.trim_start_matches(['/', '!']).trim_start();
    let rest = t.strip_prefix("simlint::allow(")?;
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = &rest[close + 1..];
    let reason = after.strip_prefix(':').map(|r| r.trim().to_string()).unwrap_or_default();
    Some(Annotation { line, own_line: false, rule, reason })
}

fn skip_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    debug_assert_eq!(b[i], '"');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

fn is_raw_or_byte_string(b: &[char], i: usize) -> bool {
    // r"...", r#"..."#, b"...", br"...", b'x' handled elsewhere (char path
    // only triggers on a bare quote, so b'x' lands here and is rejected —
    // treat it as ident `b` + char literal, which is harmless).
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
    }
    if j < b.len() && b[j] == 'r' {
        j += 1;
    }
    while j < b.len() && b[j] == '#' {
        j += 1;
    }
    j > i && j < b.len() && b[j] == '"' && (b[i] == 'r' || (b[i] == 'b' && j > i + 1) || b.get(i + 1) == Some(&'"'))
}

fn skip_raw_or_byte_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    if b[i] == 'b' {
        i += 1;
    }
    let raw = i < b.len() && b[i] == 'r';
    if raw {
        i += 1;
    }
    let mut hashes = 0;
    while i < b.len() && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    debug_assert!(i < b.len() && b[i] == '"');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\n' => {
                *line += 1;
                i += 1;
            }
            '\\' if !raw => i += 2,
            '"' => {
                // A raw string only closes when the quote is followed by the
                // right number of hashes.
                let mut j = i + 1;
                let mut h = 0;
                while h < hashes && j < b.len() && b[j] == '#' {
                    h += 1;
                    j += 1;
                }
                if h == hashes {
                    return j;
                }
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// If `b[i]` opens a char literal, return the index just past it.
fn char_literal_end(b: &[char], i: usize) -> Option<usize> {
    debug_assert_eq!(b[i], '\'');
    let mut j = i + 1;
    if j >= b.len() {
        return None;
    }
    if b[j] == '\\' {
        j += 2;
        // Unicode escapes: '\u{1F600}'.
        if j <= b.len() && b.get(j - 1) == Some(&'u') && b.get(j) == Some(&'{') {
            while j < b.len() && b[j] != '}' {
                j += 1;
            }
            j += 1;
        }
    } else if b[j] == '\'' {
        return None; // `''` is not a char literal
    } else {
        j += 1;
    }
    (j < b.len() && b[j] == '\'').then_some(j + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_puncts_lines() {
        let l = lex("let x = a::b;\nfoo.bar()");
        let t: Vec<(&str, u32)> = l.toks.iter().map(|t| (t.text.as_str(), t.line)).collect();
        assert_eq!(
            t,
            vec![
                ("let", 1),
                ("x", 1),
                ("=", 1),
                ("a", 1),
                (":", 1),
                (":", 1),
                ("b", 1),
                (";", 1),
                ("foo", 2),
                (".", 2),
                ("bar", 2),
                ("(", 2),
                (")", 2),
            ]
        );
    }

    #[test]
    fn comments_and_strings_do_not_tokenize() {
        assert_eq!(texts("// HashMap\n/* HashSet */ x \"HashMap.iter()\""), vec!["x", "\"\""]);
    }

    #[test]
    fn nested_block_comment() {
        assert_eq!(texts("/* a /* b */ c */ y"), vec!["y"]);
    }

    #[test]
    fn raw_string_with_hashes() {
        assert_eq!(texts("r#\"Instant::now() \" inside\"# z"), vec!["\"\"", "z"]);
    }

    #[test]
    fn char_vs_lifetime() {
        let l = lex("'a' x &'static str '\\n'");
        let kinds: Vec<TokKind> = l.toks.iter().map(|t| t.kind).collect();
        assert_eq!(
            kinds,
            vec![
                TokKind::Lit,
                TokKind::Ident,
                TokKind::Punct,
                TokKind::Lifetime,
                TokKind::Ident,
                TokKind::Lit,
            ]
        );
    }

    #[test]
    fn numeric_literals_stay_single_tokens() {
        assert_eq!(texts("1.0 65536 1e-9 0..n 1_000u64"), vec!["1.0", "65536", "1e-9", "0", ".", ".", "n", "1_000u64"]);
    }

    #[test]
    fn annotation_parsing() {
        let l = lex("x();\n// simlint::allow(unordered-iter): keyed merge, re-sorted below\ny.iter(); // simlint::allow(nan-order): proven finite\n// simlint::allow(ambient-nondet)\n");
        assert_eq!(l.annotations.len(), 3);
        assert_eq!(l.annotations[0].rule, "unordered-iter");
        assert_eq!(l.annotations[0].reason, "keyed merge, re-sorted below");
        assert!(l.annotations[0].own_line);
        assert_eq!(l.annotations[1].rule, "nan-order");
        assert!(!l.annotations[1].own_line);
        assert_eq!(l.annotations[2].rule, "ambient-nondet");
        assert_eq!(l.annotations[2].reason, "");
        assert_eq!(l.next_code_line(2), Some(3));
    }
}
