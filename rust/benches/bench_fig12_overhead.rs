//! Fig. 12 — Justitia scheduling delay under varying request arrival rates.
//!
//! Paper: consistently under 10 ms at all arrival rates. (Ours is far below:
//! the virtual-time update is O(log N) on arrival and the agent pick is a
//! heap peek.)

use justitia::util::bench::{fmt_ns, section, ResultsFile};

fn main() {
    section("Fig. 12: scheduling delay vs arrival rate");
    let mut out = ResultsFile::new("bench_fig12.txt");
    let rows = justitia::experiments::fig12(&[1.0, 2.0, 4.0, 8.0, 16.0, 32.0], 300, 42);
    out.line(format!("{:>8} {:>12} {:>12} {:>10}", "rate/s", "mean", "max", "decisions"));
    for r in &rows {
        out.line(format!(
            "{:>8.1} {:>12} {:>12} {:>10}",
            r.arrival_rate,
            fmt_ns(r.mean_delay_ms * 1e6),
            fmt_ns(r.max_delay_ms * 1e6),
            r.decisions
        ));
    }
    let worst = rows.iter().map(|r| r.mean_delay_ms).fold(0.0, f64::max);
    out.line(format!("worst mean delay {:.3} ms (paper bound: < 10 ms)", worst));
}
