"""AOT path checks: the HLO text artifacts and the JTT weight container.

Verifies that (a) lowering succeeds and produces parseable HLO text with the
expected parameter count/convention, (b) the JTT container round-trips, and
(c) executing the lowered prefill through xla_client reproduces the eager
model output — the same check the Rust runtime's integration test performs
from the other side of the bridge.
"""

import json
import os
import struct
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

CFG = M.ModelConfig(n_pages=8, max_pages_per_seq=2, max_prefill=16)


class TestJtt:
    def test_roundtrip_layout(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "w.jtt")
            tensors = {
                "b": np.arange(6, dtype=np.float32).reshape(2, 3),
                "a": np.asarray([1, -2], np.int32),
            }
            aot.write_jtt(path, tensors)
            raw = open(path, "rb").read()
            assert raw[:4] == b"JTT1"
            hlen = struct.unpack("<I", raw[4:8])[0]
            header = json.loads(raw[8 : 8 + hlen])
            names = [t["name"] for t in header["tensors"]]
            assert names == ["a", "b"]  # sorted
            data = raw[8 + hlen :]
            a = np.frombuffer(data[:8], "<i4")
            b = np.frombuffer(data[8:], "<f4").reshape(2, 3)
            np.testing.assert_array_equal(a, tensors["a"])
            np.testing.assert_array_equal(b, tensors["b"])

    def test_rejects_unsupported_dtype(self):
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(ValueError):
                aot.write_jtt(os.path.join(d, "w.jtt"), {"x": np.zeros(2, np.float64)})


class TestLowering:
    @staticmethod
    def entry_param_count(text):
        # Parameters of the ENTRY computation only (sub-computations like
        # reducers declare their own `parameter(` lines).
        entry = text[text.index("ENTRY ") :]
        return entry.count("parameter(")

    def test_prefill_lowers_to_hlo_text(self):
        text = aot.to_hlo_text(aot.lower_prefill(CFG))
        assert "HloModule" in text
        # Parameter convention: weights (15) + 5 state args.
        assert self.entry_param_count(text) == len(M.weight_names(CFG)) + 5

    def test_decode_lowers_for_all_batches(self):
        for b in [1, 2]:
            text = aot.to_hlo_text(aot.lower_decode(CFG, b))
            assert "HloModule" in text
            assert self.entry_param_count(text) == len(M.weight_names(CFG)) + 5

    def test_hlo_text_is_self_consistent(self):
        # The execute-and-compare half of the bridge lives in the Rust
        # integration test (rust/tests/test_runtime_pjrt.rs), which loads
        # these exact artifacts and checks numerics against values produced
        # here. On the Python side we assert the text contains an ENTRY with
        # the 3-tuple (logits, k_pool, v_pool) result.
        text = aot.to_hlo_text(aot.lower_prefill(CFG))
        entry = text[text.index("ENTRY ") :]
        assert "tuple(" in entry or "ROOT" in entry
        pool = f"f32[{CFG.n_layers},{CFG.n_pages + 1},{CFG.page_size},{CFG.n_heads},{CFG.d_head}]"
        assert pool in text, f"pool shape {pool} missing from HLO"


class TestArtifacts:
    def test_build_artifacts_manifest(self):
        with tempfile.TemporaryDirectory() as d:
            manifest = aot.build_artifacts(d, CFG, seed=3)
            assert set(manifest["artifacts"]) == {
                "weights",
                "prefill",
                "decode_b1",
                "decode_b2",
                "decode_b4",
                "decode_b8",
            }
            for rel in manifest["artifacts"].values():
                assert os.path.getsize(os.path.join(d, rel)) > 0
            cfg_json = json.load(open(os.path.join(d, "model_config.json")))
            assert cfg_json["model"]["n_pages"] == CFG.n_pages
            assert cfg_json["weight_names"] == M.weight_names(CFG)
