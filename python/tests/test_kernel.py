"""L1 correctness: Pallas paged-attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: hypothesis
sweeps shapes (batch, heads, head dim, page size, pool size, ragged sequence
lengths) and dtypes, asserting allclose against `ref.paged_attention_ref`.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.paged_attention import (
    mxu_flops_per_step,
    paged_attention,
    vmem_footprint_bytes,
)
from compile.kernels import ref


def make_case(rng, b, h, d, page, n_pages, max_pages, dtype=np.float32):
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(n_pages, page, h, d)), dtype)
    v = jnp.asarray(rng.normal(size=(n_pages, page, h, d)), dtype)
    bt = jnp.asarray(rng.integers(0, n_pages, size=(b, max_pages)), jnp.int32)
    sl = jnp.asarray(rng.integers(1, max_pages * page + 1, size=(b,)), jnp.int32)
    return q, k, v, bt, sl


def assert_matches_ref(q, k, v, bt, sl, rtol=3e-5, atol=3e-5):
    out = paged_attention(q, k, v, bt, sl)
    want = ref.paged_attention_ref(q, k, v, bt, sl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=rtol, atol=atol)


class TestPagedAttentionBasic:
    def test_single_sequence_single_page(self):
        rng = np.random.default_rng(1)
        assert_matches_ref(*make_case(rng, 1, 1, 8, 4, 2, 1))

    def test_batch_matches_ref(self):
        rng = np.random.default_rng(2)
        assert_matches_ref(*make_case(rng, 4, 4, 32, 16, 8, 4))

    def test_seq_len_one(self):
        rng = np.random.default_rng(3)
        q, k, v, bt, _ = make_case(rng, 2, 2, 16, 8, 4, 2)
        sl = jnp.asarray([1, 1], jnp.int32)
        assert_matches_ref(q, k, v, bt, sl)

    def test_full_pages(self):
        # seq_len exactly fills every page.
        rng = np.random.default_rng(4)
        q, k, v, bt, _ = make_case(rng, 2, 2, 16, 8, 4, 3)
        sl = jnp.asarray([24, 16], jnp.int32)
        assert_matches_ref(q, k, v, bt, sl)

    def test_partial_last_page_masked(self):
        # Garbage beyond seq_len in the last page must not leak in.
        rng = np.random.default_rng(5)
        q, k, v, bt, _ = make_case(rng, 1, 2, 16, 8, 4, 2)
        k = k.at[:, :, :, :].set(jnp.where(jnp.isnan(k), 0, k))
        # Poison positions >= seq_len by making the last page huge.
        k = k * 1.0
        big = k.at[int(bt[0, 1]), 5:, :, :].set(1e4)
        sl = jnp.asarray([13], jnp.int32)  # 8 + 5 valid
        assert_matches_ref(q, big, v, bt, sl)

    def test_shared_pages_between_sequences(self):
        # Two sequences whose block tables alias the same pages (prefix
        # sharing) must each read them correctly.
        rng = np.random.default_rng(6)
        q, k, v, _, _ = make_case(rng, 2, 2, 16, 8, 6, 2)
        bt = jnp.asarray([[0, 1], [0, 2]], jnp.int32)
        sl = jnp.asarray([12, 16], jnp.int32)
        assert_matches_ref(q, k, v, bt, sl)

    def test_softmax_normalization(self):
        # Uniform values ⇒ output equals value vector regardless of length.
        b, h, d, page, n_pages, maxp = 1, 2, 8, 4, 4, 2
        q = jnp.ones((b, h, d), jnp.float32)
        k = jnp.ones((n_pages, page, h, d), jnp.float32)
        v = jnp.full((n_pages, page, h, d), 2.5, jnp.float32)
        bt = jnp.zeros((b, maxp), jnp.int32)
        sl = jnp.asarray([7], jnp.int32)
        out = paged_attention(q, k, v, bt, sl)
        np.testing.assert_allclose(np.asarray(out), 2.5, rtol=1e-6)

    def test_numerical_stability_large_scores(self):
        rng = np.random.default_rng(7)
        q, k, v, bt, sl = make_case(rng, 2, 2, 16, 8, 4, 2)
        assert_matches_ref(q * 50.0, k * 50.0, v, bt, sl, rtol=1e-4, atol=1e-4)
        out = paged_attention(q * 50.0, k * 50.0, v, bt, sl)
        assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 6),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    page=st.sampled_from([4, 8, 16]),
    max_pages=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_attention_hypothesis_sweep(b, h, d, page, max_pages, seed):
    rng = np.random.default_rng(seed)
    n_pages = max_pages + int(rng.integers(1, 8))
    assert_matches_ref(*make_case(rng, b, h, d, page, n_pages, max_pages))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_paged_attention_ragged_lengths(seed):
    # Heavily ragged batches: lengths from 1 to max, mixed in one batch.
    rng = np.random.default_rng(seed)
    b, h, d, page, n_pages, maxp = 8, 2, 16, 8, 16, 4
    q, k, v, bt, _ = make_case(rng, b, h, d, page, n_pages, maxp)
    sl = jnp.asarray([1, 2, 7, 8, 9, 16, 31, 32], jnp.int32)
    assert_matches_ref(q, k, v, bt, sl)


class TestPerfModel:
    def test_vmem_footprint_within_budget(self):
        # DESIGN.md §Perf: the block shapes chosen for the artifact config
        # must fit comfortably in a 16 MiB VMEM (use << 1/4 of it).
        bytes_ = vmem_footprint_bytes(page_size=16, n_heads=4, d_head=32)
        assert bytes_ < 4 * 1024 * 1024
        assert bytes_ == 2 * 16 * 4 * 32 * 4 + 4 * 32 * 4 + 4 * 34 * 4

    def test_mxu_flops_positive_scaling(self):
        assert mxu_flops_per_step(16, 4, 32) == 2 * 2 * 16 * 4 * 32
        assert mxu_flops_per_step(32, 4, 32) == 2 * mxu_flops_per_step(16, 4, 32)


class TestCausalRefs:
    def test_masked_matches_unmasked_when_full(self):
        rng = np.random.default_rng(8)
        s, h, d = 12, 2, 16
        q = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        full = ref.causal_attention_ref(q, k, v)
        masked = ref.masked_causal_attention_ref(q, k, v, s)
        np.testing.assert_allclose(np.asarray(full), np.asarray(masked), rtol=1e-6, atol=1e-6)

    def test_padding_does_not_affect_valid_rows(self):
        rng = np.random.default_rng(9)
        s, h, d, valid = 16, 2, 8, 9
        q = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(s, h, d)), jnp.float32)
        out1 = ref.masked_causal_attention_ref(q, k, v, valid)
        # Poison the padding region; valid-row outputs must be unchanged.
        k2 = k.at[valid:].set(1e6)
        v2 = v.at[valid:].set(-1e6)
        out2 = ref.masked_causal_attention_ref(q, k2, v2, valid)
        np.testing.assert_allclose(
            np.asarray(out1[:valid]), np.asarray(out2[:valid]), rtol=1e-5, atol=1e-5
        )
