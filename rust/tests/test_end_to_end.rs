//! End-to-end: generated workload → predictor → engine → metrics, plus
//! trace save/load round-trips and CLI-level config handling — the full
//! Layer-3 pipeline on the simulator backend.

use justitia::cli::Args;
use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::experiments::{run_policy_oracle, CostSource};
use justitia::workload::trace;

#[test]
fn trace_roundtrip_preserves_scheduling_outcome() {
    // Saving a suite to JSON and reloading it must give identical runs.
    let wl = WorkloadConfig { n_agents: 60, ..Default::default() }.with_density(3.0);
    let suite = trace::build_suite(&wl);
    let dir = std::env::temp_dir().join("justitia-e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    trace::save_suite(&suite, &path, true).unwrap();
    let reloaded = trace::load_suite(&path).unwrap();

    let cfg = Config::default();
    let a = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    let b = run_policy_oracle(&cfg, &reloaded, Policy::Justitia);
    assert_eq!(a.completed_agents(), b.completed_agents());
    assert!((a.avg_jct() - b.avg_jct()).abs() < 1e-9, "{} vs {}", a.avg_jct(), b.avg_jct());
    assert!((a.p90_jct() - b.p90_jct()).abs() < 1e-9);
}

#[test]
fn deterministic_runs_same_seed() {
    let cfg = Config::default();
    let wl = WorkloadConfig { n_agents: 80, seed: 5, ..Default::default() }.with_density(2.0);
    let s1 = trace::build_suite(&wl);
    let s2 = trace::build_suite(&wl);
    let a = run_policy_oracle(&cfg, &s1, Policy::Justitia);
    let b = run_policy_oracle(&cfg, &s2, Policy::Justitia);
    assert_eq!(a.jcts(), b.jcts());
}

#[test]
fn different_seeds_differ() {
    let cfg = Config::default();
    let s1 = trace::build_suite(&WorkloadConfig { n_agents: 50, seed: 1, ..Default::default() });
    let s2 = trace::build_suite(&WorkloadConfig { n_agents: 50, seed: 2, ..Default::default() });
    let a = run_policy_oracle(&cfg, &s1, Policy::Justitia);
    let b = run_policy_oracle(&cfg, &s2, Policy::Justitia);
    assert_ne!(a.jcts(), b.jcts());
}

#[test]
fn cli_config_pipeline() {
    // `--policy vtc --agents 30 --density 3 --seed 9` through the real CLI
    // parsing + config plumbing.
    let args = Args::parse(
        ["run", "--policy", "vtc", "--agents", "30", "--density", "3", "--seed", "9"]
            .iter()
            .map(|s| s.to_string()),
        &[],
    );
    let cfg = Config::default().apply_args(&args).unwrap();
    assert_eq!(cfg.policy, Policy::Vtc);
    assert_eq!(cfg.workload.n_agents, 30);
    assert_eq!(cfg.workload.seed, 9);
    assert!((cfg.workload.window_secs - 360.0).abs() < 1e-9);
    let suite = trace::build_suite(&cfg.workload);
    let m = run_policy_oracle(&cfg, &suite, cfg.policy);
    assert_eq!(m.completed_agents(), 30);
}

#[test]
fn engine_metrics_are_internally_consistent() {
    let cfg = Config::default();
    let suite = trace::build_suite(&WorkloadConfig { n_agents: 100, ..Default::default() }.with_density(3.0));
    let m = run_policy_oracle(&cfg, &suite, Policy::Justitia);
    // Every completion after its arrival; engine time covers the last one.
    for (agent, jct) in m.jcts() {
        assert!(jct > 0.0, "agent {agent}");
        let done = m.agent_complete_time(agent).unwrap();
        assert!(done <= m.engine_time() + 1e-9);
    }
    // Every task of every agent admitted before it completed.
    for a in &suite.agents {
        for t in a.tasks() {
            let adm = m.task_admit_time(t.id).expect("admitted");
            let fin = m.task_complete_time(t.id).expect("completed");
            assert!(adm <= fin, "{}", t.id);
        }
    }
}

#[test]
fn cost_source_noisy_only_perturbs_schedule_not_correctness() {
    let cfg = Config::default();
    let suite = trace::build_suite(&WorkloadConfig { n_agents: 80, ..Default::default() }.with_density(3.0));
    let m = justitia::experiments::run_policy(
        &cfg,
        &suite,
        Policy::Justitia,
        &CostSource::Noisy { lambda: 3.0, seed: 1 },
    );
    assert_eq!(m.completed_agents(), 80);
}

#[test]
fn memory_centric_cost_dominates_for_decode_heavy_agents() {
    // Sanity link between workload generation and the cost model: the
    // quadratic d-term makes SC (decode-heavy) cost more per prompt token
    // than CC (prompt-heavy) — invisible to the compute-centric model.
    let mut gen = justitia::workload::generator::Generator::new(3);
    let sc = gen.agent(justitia::workload::AgentClass::SelfConsistency, 0, 0.0);
    let cc = gen.agent(justitia::workload::AgentClass::CodeChecking, 1, 0.0);
    let mem = CostModel::MemoryCentric;
    let cmp = CostModel::ComputeCentric;
    let mem_ratio = mem.agent_cost(&sc) / mem.agent_cost(&cc);
    let cmp_ratio = cmp.agent_cost(&sc) / cmp.agent_cost(&cc);
    assert!(
        mem_ratio > 2.0 * cmp_ratio,
        "memory-centric should amplify decode-heavy agents: {mem_ratio:.1} vs {cmp_ratio:.1}"
    );
}
