//! Agent service-cost prediction (paper §4.2, Table 1, Fig. 10).
//!
//! On agent arrival the scheduler needs the total cost Ĉ_j before any task
//! runs. Justitia's method: per-agent-class TF-IDF vectorization of the
//! input prompt followed by a small 4-layer MLP regressor, trained on 100
//! samples per class with SGD on MSE + L2. The S³/Distillbert-style baseline
//! (one big shared model for all classes) is reproduced structurally in
//! [`s3`] (substitution T4); the noisy oracle of Fig. 10 lives in [`oracle`].

pub mod mlp;
pub mod oracle;
pub mod s3;
pub mod tfidf;

use crate::cost::CostModel;
use crate::workload::{AgentClass, AgentSpec};
use std::collections::HashMap;

/// A cost predictor: maps an arriving agent's observable inputs (class tag +
/// prompt text) to a predicted total service cost.
pub trait Predictor: Send {
    /// Predict the total agent cost in the model's cost units.
    fn predict(&self, class: AgentClass, input_text: &str) -> f64;
}

/// Per-class predictor bundle (the Justitia design: "we respectively
/// maintain a prediction model for each agent \[class\]").
pub struct PerClassPredictor {
    /// One trained pipeline per agent class.
    pub models: HashMap<AgentClass, ClassModel>,
}

/// One class's pipeline: fitted TF-IDF + trained MLP (+ target scaling).
pub struct ClassModel {
    /// Fitted per-class TF-IDF vectorizer.
    pub tfidf: tfidf::TfIdf,
    /// Trained regressor.
    pub mlp: mlp::Mlp,
    /// Targets are trained in log1p space and de-normalized on predict.
    pub target_mean: f64,
    /// Std of the log1p targets (de-normalization).
    pub target_std: f64,
}

impl ClassModel {
    /// Predict one agent's total cost from its input text.
    pub fn predict(&self, input_text: &str) -> f64 {
        let x = self.tfidf.transform(input_text);
        let y = self.mlp.forward(&x)[0] as f64;
        let log = y * self.target_std + self.target_mean;
        log.exp() - 1.0
    }
}

impl Predictor for PerClassPredictor {
    fn predict(&self, class: AgentClass, input_text: &str) -> f64 {
        match self.models.get(&class) {
            Some(m) => m.predict(input_text).max(1.0),
            None => 1.0,
        }
    }
}

/// Training report (Table 1 columns).
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Wall-clock training time (s).
    pub train_secs: f64,
    /// Mean relative error |ŷ−y|/y on held-out samples.
    pub rel_error: f64,
    /// Mean single-prediction latency in milliseconds.
    pub infer_ms: f64,
}

/// Train a per-class predictor on `samples_per_class` generated agents per
/// class (paper: 100) and evaluate on `eval_per_class` held-out agents.
pub fn train_per_class(
    cost_model: CostModel,
    samples_per_class: usize,
    eval_per_class: usize,
    seed: u64,
) -> (PerClassPredictor, TrainReport) {
    let t0 = std::time::Instant::now();
    let mut models = HashMap::new();
    let mut eval_set: Vec<(AgentClass, String, f64)> = Vec::new();

    for (ci, class) in AgentClass::ALL.into_iter().enumerate() {
        let mut gen = crate::workload::generator::Generator::new(seed ^ (0x1000 + ci as u64));
        let mut texts: Vec<String> = Vec::with_capacity(samples_per_class);
        let mut targets: Vec<f64> = Vec::with_capacity(samples_per_class);
        for i in 0..samples_per_class + eval_per_class {
            let a = gen.agent(class, i as u32, 0.0);
            let cost = cost_model.agent_cost(&a);
            if i < samples_per_class {
                texts.push(a.input_text);
                targets.push(cost);
            } else {
                eval_set.push((class, a.input_text, cost));
            }
        }
        models.insert(class, train_class_model(&texts, &targets, seed ^ (0x2000 + ci as u64)));
    }
    let train_secs = t0.elapsed().as_secs_f64();

    let predictor = PerClassPredictor { models };
    let (rel_error, infer_ms) = evaluate(&predictor, &eval_set);
    (predictor, TrainReport { train_secs, rel_error, infer_ms })
}

/// Fit the TF-IDF + MLP pipeline for one class.
pub fn train_class_model(texts: &[String], targets: &[f64], seed: u64) -> ClassModel {
    // TF-IDF features; dimensionality "proportional to the average agent
    // input size" (paper): bucketized into one of a few capacity tiers.
    let avg_words = texts.iter().map(|t| t.split_whitespace().count()).sum::<usize>()
        / texts.len().max(1);
    let dim = (avg_words / 8).clamp(32, 256);
    let mut tfidf = tfidf::TfIdf::new(dim);
    tfidf.fit(texts);

    let xs: Vec<Vec<f32>> = texts.iter().map(|t| tfidf.transform(t)).collect();
    // log1p-standardized targets stabilize the quadratic-cost dynamic range.
    let logs: Vec<f64> = targets.iter().map(|&y| (y + 1.0).ln()).collect();
    let mean = crate::util::stats::mean(&logs);
    let std = crate::util::stats::std_dev(&logs).max(1e-6);
    let ys: Vec<f32> = logs.iter().map(|&l| ((l - mean) / std) as f32).collect();

    // Paper's 4-layer MLP; first layer proportional to input size.
    let feat = tfidf.feature_dim();
    let mut mlp = mlp::Mlp::new(&[feat, dim.min(64), 32, 1], seed);
    mlp.train(
        &xs,
        &ys,
        &mlp::TrainConfig { epochs: 300, lr: 5e-3, l2: 1e-4, batch: 16, seed },
    );
    ClassModel { tfidf, mlp, target_mean: mean, target_std: std }
}

/// Mean relative error and mean per-prediction latency over an eval set.
pub fn evaluate<P: Predictor + ?Sized>(
    predictor: &P,
    eval: &[(AgentClass, String, f64)],
) -> (f64, f64) {
    if eval.is_empty() {
        return (0.0, 0.0);
    }
    let mut errs = Vec::with_capacity(eval.len());
    let t0 = std::time::Instant::now();
    for (class, text, truth) in eval {
        let pred = predictor.predict(*class, text);
        errs.push(((pred - truth).abs() / truth.max(1.0)).min(100.0));
    }
    let infer_ms = t0.elapsed().as_secs_f64() * 1e3 / eval.len() as f64;
    (crate::util::stats::mean(&errs), infer_ms)
}

/// Oracle predictor plumbing for ground-truth / Fig. 10 runs.
pub fn true_cost(model: CostModel, agent: &AgentSpec) -> f64 {
    model.agent_cost(agent)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_class_training_beats_naive_guess() {
        // Tiny training budget to keep the test fast; accuracy bar is loose
        // (the bench uses the full budget).
        let (pred, report) = train_per_class(CostModel::MemoryCentric, 40, 10, 7);
        assert_eq!(pred.models.len(), 9);
        assert!(report.rel_error < 1.5, "rel_error={}", report.rel_error);
        assert!(report.infer_ms < 50.0, "infer_ms={}", report.infer_ms);
        assert!(report.train_secs > 0.0);
    }

    #[test]
    fn predictions_are_positive_and_class_sensitive() {
        let (pred, _) = train_per_class(CostModel::MemoryCentric, 30, 5, 11);
        let mut gen = crate::workload::generator::Generator::new(99);
        let small = gen.agent(AgentClass::EquationVerification, 0, 0.0);
        let large = gen.agent(AgentClass::MapReduceSummarization, 1, 0.0);
        let ps = pred.predict(AgentClass::EquationVerification, &small.input_text);
        let pl = pred.predict(AgentClass::MapReduceSummarization, &large.input_text);
        assert!(ps > 0.0 && pl > 0.0);
        assert!(pl > ps * 5.0, "large {pl} should dwarf small {ps}");
    }

    #[test]
    fn unknown_class_degrades_gracefully() {
        let pred = PerClassPredictor { models: HashMap::new() };
        assert_eq!(pred.predict(AgentClass::CodeChecking, "anything"), 1.0);
    }
}
