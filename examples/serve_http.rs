//! Serve-and-query demo: starts the HTTP front-end over the PJRT model on a
//! background thread, submits a few agents over real TCP, polls for
//! completion, and prints the serving metrics — what a downstream user's
//! first integration looks like.
//!
//! Run: `make artifacts && cargo run --release --example serve_http`

use justitia::config::Policy;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

const PORT: u16 = 18080;

fn http(method: &str, path: &str, body: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(("127.0.0.1", PORT))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let body_start = resp.find("\r\n\r\n").map(|i| i + 4).unwrap_or(0);
    Ok(resp[body_start..].to_string())
}

fn main() -> anyhow::Result<()> {
    // Server thread (blocks forever; the process exits when main does).
    std::thread::spawn(|| {
        if let Err(e) = justitia::server::http::serve(
            std::path::Path::new("artifacts"),
            PORT,
            Policy::Justitia,
            1,
            justitia::cluster::Placement::ClusterVtime,
            false,
        ) {
            eprintln!("server error: {e:#}");
            std::process::exit(1);
        }
    });

    // Wait for readiness.
    let mut ok = false;
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(200));
        if let Ok(b) = http("GET", "/healthz", "") {
            if b.contains("true") {
                ok = true;
                break;
            }
        }
    }
    anyhow::ensure!(ok, "server did not come up");
    println!("server up on :{PORT}");

    // Submit: one explicit-stage agent + three class-generated ones.
    let explicit = r#"{"class": "DM", "stages": [[{"p": 20, "d": 8}, {"p": 24, "d": 6}], [{"p": 16, "d": 5}]]}"#;
    println!("POST /agents (explicit DM): {}", http("POST", "/agents", explicit)?.trim());
    for class in ["EV", "CC", "SC"] {
        let body = format!(r#"{{"class": "{class}"}}"#);
        println!("POST /agents ({class}):        {}", http("POST", "/agents", &body)?.trim());
    }

    // Poll until all four complete.
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(300));
        let m = http("GET", "/metrics", "")?;
        print!("\r/metrics: {}          ", m.trim());
        std::io::stdout().flush()?;
        if m.contains("\"completed\":4") {
            println!();
            break;
        }
        anyhow::ensure!(t0.elapsed() < Duration::from_secs(120), "timed out: {m}");
    }

    for id in 0..4 {
        println!("GET /agents/{id}: {}", http("GET", &format!("/agents/{id}"), "")?.trim());
    }
    println!("done in {:.1}s wall", t0.elapsed().as_secs_f64());
    Ok(())
}
