//! Property tests for chunked prefill (ISSUE 4 satellite
//! `prop_chunked_conservation`):
//!
//! * **Conservation** — with chunking on (random chunk size and token
//!   budget), per-sequence filled-token/page accounting holds at every
//!   engine step (`Engine::check_chunked_accounting` + the KV pool
//!   invariants), every agent completes, and the pool drains to fully free;
//! * **Degenerate identity** — `prefill_chunk = u32::MAX` with an unbounded
//!   budget (and likewise the flag off) replays the unchunked engine bit
//!   for bit across all schedulers: same JCTs, same iteration count, same
//!   swap history.

use justitia::config::{BackendProfile, Config, Policy};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, Suite};

/// A randomized chunked-prefill scenario: a small DAG workload plus the
/// chunking knobs (chunk size and per-iteration token budget) and pool
/// shape, all drawn together so shrinking keeps them consistent.
#[derive(Clone, Debug)]
struct ChunkedScenario {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
    prefill_chunk: u32,
    max_batched_tokens: u32,
}

struct ChunkedStrategy;

impl Strategy for ChunkedStrategy {
    type Value = ChunkedScenario;

    fn generate(&self, rng: &mut Rng) -> ChunkedScenario {
        let page_size = 8u32;
        let pages = rng.range_u64(32, 64);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 7) as usize;
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 6) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                // Prompts up to ~half the pool so several mid-prefill
                // sequences can collide (exercising the starvation valve),
                // but no single task exceeds capacity.
                let p = rng.range_u64(2, m_tokens / 2) as u32;
                let d = rng.range_u64(1, 16) as u32;
                let deps = if i > 0 && rng.chance(0.3) {
                    vec![rng.below(i as u64) as u32]
                } else {
                    Vec::new()
                };
                tasks.push((p, d, deps));
            }
            agents.push(dag_agent(id as u32, t, tasks));
        }
        ChunkedScenario {
            agents,
            pages,
            page_size,
            prefill_chunk: rng.range_u64(1, 48) as u32,
            max_batched_tokens: rng.range_u64(4, 96) as u32,
        }
    }

    fn shrink(&self, v: &ChunkedScenario) -> Vec<ChunkedScenario> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        if v.prefill_chunk < 48 {
            let mut w = v.clone();
            w.prefill_chunk = 48;
            out.push(w);
        }
        if v.max_batched_tokens < 96 {
            let mut w = v.clone();
            w.max_batched_tokens = 96;
            out.push(w);
        }
        out
    }
}

fn config_for(sc: &ChunkedScenario) -> Config {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-chunked".into(),
        kv_tokens: sc.pages * sc.page_size as u64,
        page_size: sc.page_size,
        alpha: 1.0,
        beta_prefill: 0.0,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: None,
        swap_bw_tokens_per_sec: 0.0,
    };
    cfg.max_batch = 64;
    cfg.chunked_prefill = true;
    cfg.prefill_chunk = sc.prefill_chunk;
    cfg.max_batched_tokens = sc.max_batched_tokens;
    cfg
}

fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

#[test]
fn prop_chunked_conservation() {
    let cfg = PropConfig { cases: prop_cases(40), seed: 0xc4a4_2ed0, max_shrink_steps: 60 };
    check(&cfg, &ChunkedStrategy, |sc| {
        for policy in [Policy::Fcfs, Policy::Justitia, Policy::Vtc] {
            let cfg = config_for(sc);
            let suite = Suite::new(sc.agents.clone());
            let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
            let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
            let model = justitia::cost::CostModel::MemoryCentric;

            // Drive arrivals by hand so invariants can be checked per step.
            let mut next = 0usize;
            let mut guard = 0u64;
            loop {
                while next < suite.agents.len()
                    && suite.agents[next].arrival <= engine.now() + 1e-12
                {
                    let spec = suite.agents[next].clone();
                    let cost = model.agent_cost(&spec);
                    engine.submit(spec, cost);
                    next += 1;
                }
                if !engine.has_work() {
                    if next >= suite.agents.len() {
                        break;
                    }
                    engine.advance_clock(suite.agents[next].arrival);
                    continue;
                }
                engine.step();
                engine
                    .check_chunked_accounting()
                    .map_err(|e| format!("{policy:?}: accounting: {e}"))?;
                engine
                    .check_kv_invariants()
                    .map_err(|e| format!("{policy:?}: kv: {e}"))?;
                guard += 1;
                if guard > 2_000_000 {
                    return Err(format!("{policy:?}: did not terminate"));
                }
            }
            if engine.metrics.completed_agents() != suite.len() {
                return Err(format!(
                    "{policy:?}: {}/{} agents completed",
                    engine.metrics.completed_agents(),
                    suite.len()
                ));
            }
            if engine.kv.free_pages() != sc.pages as u32 {
                return Err(format!(
                    "{policy:?}: leaked pages: {} free of {}",
                    engine.kv.free_pages(),
                    sc.pages
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_degenerate_is_bit_identical_across_schedulers() {
    let cfg = PropConfig { cases: prop_cases(25), seed: 0x1de_47ca1, max_shrink_steps: 60 };
    check(&cfg, &ChunkedStrategy, |sc| {
        for policy in [
            Policy::Fcfs,
            Policy::Sjf,
            Policy::AgentFcfs,
            Policy::Vtc,
            Policy::Srjf,
            Policy::Justitia,
        ] {
            let run = |mode: u8| {
                let mut cfg = config_for(sc);
                match mode {
                    0 => cfg.chunked_prefill = false, // flag off
                    _ => {
                        // Flag on but degenerate: infinite chunk + budget.
                        cfg.prefill_chunk = u32::MAX;
                        cfg.max_batched_tokens = u32::MAX;
                    }
                }
                let suite = Suite::new(sc.agents.clone());
                let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
                let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
                let model = justitia::cost::CostModel::MemoryCentric;
                engine.run_suite(&suite, |a| model.agent_cost(a));
                (
                    engine.metrics.jcts(),
                    engine.metrics.iterations(),
                    engine.metrics.swap_out_count(),
                    engine.metrics.prefill_stalls(),
                )
            };
            let off = run(0);
            let degenerate = run(1);
            if off != degenerate {
                return Err(format!(
                    "{policy:?}: degenerate chunked run diverged from flag-off \
                     (off {:?} vs degenerate {:?})",
                    (off.1, off.2, off.3),
                    (degenerate.1, degenerate.2, degenerate.3),
                ));
            }
        }
        Ok(())
    });
}
