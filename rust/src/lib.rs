//! # Justitia
//!
//! A reproduction of *"Justitia: Fair and Efficient Scheduling of
//! Task-parallel LLM Agents with Selective Pampering"* as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: a vLLM-like
//!   continuous-batching engine over a paged KV cache ([`engine`], [`kv`]),
//!   the Justitia virtual-time fair-queuing scheduler and the five paper
//!   baselines ([`sched`]), memory-centric cost modeling ([`cost`]),
//!   TF-IDF + MLP demand prediction with §4.2 online misprediction
//!   correction ([`predictor`], `Config::online_correction`), the §5.1
//!   workload suite ([`workload`]) — agents as general task *DAGs*
//!   (dependency-count release, map-reduce/tree/pipeline shapes,
//!   deterministic dynamic spawning; staged barriers are the special case)
//!   — and the experiment harness ([`experiments`]).
//! * **Layer 2** — a JAX transformer (prefill/decode over a paged KV pool),
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! * **Layer 1** — a Pallas paged-attention kernel (interpret mode), called
//!   from the Layer-2 model and verified against a pure-jnp oracle.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT and exposes them
//! as an [`engine::exec::ExecBackend`], so the same engine code drives both
//! the calibrated simulator and the real model. Python never runs on the
//! request path.
//!
//! The [`cluster`] module scales the whole stack out: a `ClusterDispatcher`
//! routes agents across N independent engine replicas under pluggable
//! placement policies, extending Justitia's fairness guarantee to the
//! cluster level (DESIGN.md §5).
//!
//! The [`prefix`] module deduplicates shared prompt prefixes: a radix-tree
//! cache over token sequences with ref-counted, copy-on-write KV pages
//! ([`kv`]), fractional cost accounting ([`cost`]), and a prefix-affinity
//! cluster placement policy (DESIGN.md §8).
//!
//! The memory hierarchy is finite: swapped KV lands in a bounded host pool
//! over a finite link, and preemption chooses between swapping and
//! recomputing per victim under pluggable victim policies — up to
//! `pamper-aware`, selective pampering applied to eviction
//! ([`config::PreemptionMode`], [`config::VictimPolicy`], DESIGN.md §11).
//!
//! The [`trace`] module is the observability layer (DESIGN.md §13): a
//! bounded flight recorder of lifecycle events, a per-iteration fairness
//! sampler (virtual-time lag, realized-vs-GPS service gap), and a scheduler
//! decision audit log — off by default and bit-identity-preserving, with a
//! Chrome trace-event / Perfetto exporter and `/metrics`+`/trace` server
//! endpoints.

#![warn(missing_docs)]

pub mod cli;
pub mod cluster;
pub mod config;
pub mod cost;
pub mod engine;
pub mod experiments;
pub mod kv;
pub mod metrics;
pub mod predictor;
pub mod prefix;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod workload;
