//! Fig. 10 — robustness to prediction errors: ground-truth costs scaled by
//! a random factor in [1/λ, λ] before Justitia sees them.
//!
//! Paper: avg JCT inflated only 9.5% at λ = 3.

use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("Fig. 10: Justitia under controlled prediction error");
    let mut out = ResultsFile::new("bench_fig10.txt");
    let lambdas = [1.0, 1.5, 2.0, 3.0];
    // Average over several noise seeds — a single draw is high-variance.
    let seeds = [42u64, 43, 44, 45, 46];
    out.line(format!("{:>7} {:>10} {:>10} {:>10}", "lambda", "avgJCT", "p90JCT", "inflation"));
    let mut base = 0.0;
    for &lambda in &lambdas {
        let mut avg = 0.0;
        let mut p90 = 0.0;
        for &s in &seeds {
            let rows = justitia::experiments::fig10(&[lambda], 300, 2.0, s);
            avg += rows[0].avg_jct;
            p90 += rows[0].p90_jct;
        }
        avg /= seeds.len() as f64;
        p90 /= seeds.len() as f64;
        if lambda == 1.0 {
            base = avg;
        }
        out.line(format!(
            "{:>6.1}x {:>9.1}s {:>9.1}s {:>+9.1}%",
            lambda,
            avg,
            p90,
            (avg / base - 1.0) * 100.0
        ));
    }
    out.line("(paper: +9.5% at lambda=3)".to_string());
}
