//! [`PjrtBackend`]: the real-model [`ExecBackend`].
//!
//! The engine stays content-agnostic (schedulers only see token *counts*);
//! this backend owns token *values*: it synthesizes deterministic prompt ids
//! per task, feeds generated tokens back greedily (temperature 0, matching
//! the paper's recurrence setup in Fig. 10), and implements swap-out/in by
//! stashing/restoring page contents of the paged pools (the CPU plugin's
//! device memory is host memory, so the stash is a plain map).

use crate::engine::exec::{ExecBackend, IterationBatch, IterationResult};
use crate::kv::PageId;
use crate::runtime::PjrtModel;
use crate::workload::TaskId;
use std::collections::HashMap;
use std::time::Instant;

/// Per-sequence generation state.
#[derive(Debug, Clone)]
struct SeqGen {
    last_token: u32,
    /// Position of the NEXT token to be written (== current context length).
    position: u32,
}

/// Stashed KV of a swapped-out sequence: per (layer, page-index-in-table)
/// slabs for both pools.
struct SwapStash {
    k: Vec<f32>,
    v: Vec<f32>,
    tokens: u32,
}

/// The real-model execution backend: drives [`PjrtModel`] prefill/decode
/// calls from the engine's iteration batches.
pub struct PjrtBackend {
    model: PjrtModel,
    seqs: HashMap<TaskId, SeqGen>,
    stash: HashMap<TaskId, SwapStash>,
    iterations: u64,
    total_model_secs: f64,
}

impl PjrtBackend {
    /// Wrap a loaded model.
    pub fn new(model: PjrtModel) -> Self {
        PjrtBackend {
            model,
            seqs: HashMap::new(),
            stash: HashMap::new(),
            iterations: 0,
            total_model_secs: 0.0,
        }
    }

    /// The underlying model.
    pub fn model(&self) -> &PjrtModel {
        &self.model
    }

    /// Iterations executed so far.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Cumulative model-execution wall time (for calibration).
    pub fn total_model_secs(&self) -> f64 {
        self.total_model_secs
    }

    /// Deterministic synthetic prompt ids for a task (substitution: agent
    /// prompt *content* is synthetic; lengths and KV traffic are real).
    fn prompt_ids(&self, seq: TaskId, len: u32) -> Vec<u32> {
        let vocab = self.model.manifest.vocab as u64;
        (0..len)
            .map(|i| {
                let h = crate::tokenizer::fnv1a(
                    format!("{}-{}-{}", seq.agent, seq.index, i).as_bytes(),
                );
                (3 + h % (vocab - 3)) as u32
            })
            .collect()
    }

    /// The last token generated for a running sequence (tests/inspection).
    pub fn last_token(&self, seq: TaskId) -> Option<u32> {
        self.seqs.get(&seq).map(|s| s.last_token)
    }
}

impl ExecBackend for PjrtBackend {
    fn run_iteration(&mut self, batch: &IterationBatch) -> IterationResult {
        let t0 = Instant::now();

        // Prefills: one at a time (B=1 artifact), clamped to max_prefill.
        for &(id, prompt) in batch.prefill {
            let max_p = self.model.manifest.max_prefill as u32;
            let len = prompt.clamp(1, max_p);
            let ids = self.prompt_ids(id, len);
            let table: Vec<u32> =
                batch.kv.block_table(id).expect("prefill seq on device").to_vec();
            let next = self
                .model
                .prefill(&ids, &table)
                .expect("prefill execution");
            self.seqs.insert(id, SeqGen { last_token: next, position: len });
        }

        // Decodes: chunk into the largest compiled batch.
        let max_b = self.model.max_decode_batch();
        for chunk in batch.decode.chunks(max_b) {
            let mut calls: Vec<(u32, u32, Vec<u32>)> = Vec::with_capacity(chunk.len());
            for &id in chunk {
                let gen = self.seqs.get(&id).expect("decode seq was prefilled");
                let table: Vec<u32> =
                    batch.kv.block_table(id).expect("decode seq on device").to_vec();
                // Clamp position to what the artifact's page budget covers.
                let max_pos =
                    (self.model.manifest.max_pages_per_seq * self.model.manifest.page_size) as u32
                        - 1;
                calls.push((gen.last_token, gen.position.min(max_pos), table));
            }
            let next = self.model.decode(&calls).expect("decode execution");
            for (&id, tok) in chunk.iter().zip(next) {
                let gen = self.seqs.get_mut(&id).unwrap();
                gen.last_token = tok;
                gen.position += 1;
            }
        }

        self.iterations += 1;
        let elapsed = t0.elapsed().as_secs_f64();
        self.total_model_secs += elapsed;
        IterationResult { elapsed }
    }

    fn on_swap_out(&mut self, seq: TaskId, pages: &[PageId], tokens: u32) {
        // Copy this sequence's page slabs (every layer) out of the pools.
        let pe = self.model.page_elems();
        let layers = self.model.manifest.n_layers;
        let mut k = Vec::with_capacity(layers * pages.len() * pe);
        let mut v = Vec::with_capacity(layers * pages.len() * pe);
        for l in 0..layers {
            for &p in pages {
                let off = self.model.page_offset(l, p);
                k.extend_from_slice(&self.model.k_pool[off..off + pe]);
                v.extend_from_slice(&self.model.v_pool[off..off + pe]);
            }
        }
        self.stash.insert(seq, SwapStash { k, v, tokens });
    }

    fn on_swap_in(&mut self, seq: TaskId, pages: &[PageId]) {
        let stash = self.stash.remove(&seq).expect("swap-in without stash");
        let pe = self.model.page_elems();
        let layers = self.model.manifest.n_layers;
        let mut idx = 0usize;
        for l in 0..layers {
            for &p in pages {
                let off = self.model.page_offset(l, p);
                self.model.k_pool[off..off + pe].copy_from_slice(&stash.k[idx..idx + pe]);
                self.model.v_pool[off..off + pe].copy_from_slice(&stash.v[idx..idx + pe]);
                idx += pe;
            }
        }
        debug_assert!(stash.tokens <= (pages.len() * self.model.manifest.page_size) as u32);
    }

    fn on_seq_released(&mut self, seq: TaskId) {
        self.seqs.remove(&seq);
        self.stash.remove(&seq);
    }
}
