//! Task-parallel LLM agent workloads (paper §2.1, §5.1, Appendix A).
//!
//! An *agent* is a DAG of LLM inferences: each task lists the tasks it
//! depends on ([`InferenceSpec::deps`]) and becomes ready the moment every
//! dependency has completed. The classical *staged* form — sequential
//! barriers of parallel tasks (map→reduce, merge→score→final, plan→execute)
//! — is the special case where every task of level k+1 depends on all tasks
//! of level k; [`AgentSpec::from_stages`] builds it and
//! [`AgentSpec::as_stages`] recovers it. General DAGs additionally express
//! map-reduce with partial combiners, tree-of-thought branching, and
//! pipelines, and an optional [`SpawnSpec`] lets completing tasks emit new
//! child tasks at runtime (deterministically — see below). The nine agent
//! classes of §5.1 are synthesized by `generator` with per-class, per-stage
//! skew-normal (p, d) token-length distributions (substitution T3 in
//! DESIGN.md); `generator` also builds the three DAG shape families
//! (DESIGN.md §9).

pub mod classes;
pub mod generator;
pub mod trace;

pub use classes::AgentClass;
pub use generator::DagShape;

/// Identifies an agent within a workload suite.
pub type AgentId = u32;

/// Identifies one inference task: (agent, per-agent task index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId {
    /// Owning agent.
    pub agent: AgentId,
    /// Task index within the agent.
    pub index: u32,
}

impl std::fmt::Display for TaskId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "a{}-t{}", self.agent, self.index)
    }
}

/// Declares that the first `tokens` prompt tokens of an inference are the
/// *same content* as every other inference carrying the same `id` — the
/// shared system-prompt + accumulated-context prefix that task-parallel
/// agents fan out over (and that agent *families* re-submit across agents).
/// The prefix cache ([`crate::prefix`]) derives identical token streams from
/// equal ids, so two inferences share KV pages exactly up to
/// `min(tokens, prompt_tokens)` of both.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PrefixGroup {
    /// Content identity of the shared prefix (suite-unique per family).
    pub id: u64,
    /// Length of the shared prefix in tokens.
    pub tokens: u32,
}

/// One LLM inference task. `prompt_tokens`/`decode_tokens` are the ground
/// truth the engine executes; the scheduler only sees predictions.
///
/// Invariant (enforced by every constructor in this crate): within an
/// [`AgentSpec`], `tasks[i].id.index == i` and every dependency in `deps`
/// names a task with a *lower* index (the task list is a topological order).
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceSpec {
    /// Task identity.
    pub id: TaskId,
    /// DAG level label: 1 + the maximum level among dependencies (0 for
    /// roots). For staged agents this is exactly the stage index; it is kept
    /// for trace provenance and display and carries no release semantics —
    /// release is governed by `deps` alone.
    pub stage: u32,
    /// Direct dependencies: this task becomes ready only when every listed
    /// task has completed. Empty for root tasks.
    pub deps: Vec<TaskId>,
    /// Prompt (prefill) token length p.
    pub prompt_tokens: u32,
    /// Decode (output) token length d.
    pub decode_tokens: u32,
    /// Name of the inference kind (e.g. "generate-summary"), Appendix-A style.
    pub kind: &'static str,
    /// Shared-prefix annotation (`None` = fully unique prompt). Inert unless
    /// the prefix cache is enabled.
    pub prefix_group: Option<PrefixGroup>,
}

/// Dynamic task spawning (DESIGN.md §9): when a task of the owning agent
/// completes, it may emit `branch` child tasks that depend only on it.
///
/// Spawning is a *pure function* of the spec: the decision and the children's
/// (p, d) sizes are drawn from a [`crate::util::rng::Rng`] child stream keyed
/// by `(seed, parent index)`, and a child's index is the closed form
/// `base + parent_index * branch + k` (`base` = the agent's static task
/// count). Replays, different schedulers, and the static
/// [`AgentSpec::expand_spawns`] expansion therefore all observe the *same*
/// spawned task set — which is what lets the GPS fluid reference and the
/// oracle cost map price spawned work before the run begins.
#[derive(Debug, Clone, PartialEq)]
pub struct SpawnSpec {
    /// Probability that a completing task spawns children (per task).
    pub prob: f64,
    /// Number of children emitted per spawn event.
    pub branch: u32,
    /// Maximum spawn generation: tasks of generation `max_depth` (counting
    /// static tasks as generation 0) spawn nothing, bounding the cascade.
    pub max_depth: u32,
    /// Seed of the deterministic spawn stream (stored in the spec so suite
    /// re-indexing cannot change spawn outcomes).
    pub seed: u64,
}

impl SpawnSpec {
    /// Spawn generation of a task index: 0 for static tasks (`index < base`),
    /// else 1 + the generation of its parent (recovered by inverting the
    /// child-index closed form).
    pub fn generation(&self, index: u32, base: u32) -> u32 {
        if base == 0 {
            return 0; // empty agent: nothing to invert (and avoid i >= 0 loops)
        }
        let b = self.branch.max(1);
        let mut i = index;
        let mut g = 0;
        while i >= base {
            i = (i - base) / b;
            g += 1;
        }
        g
    }

    /// The children the given parent task emits on completion (possibly
    /// none). Pure: depends only on `self`, the parent's index and sizes,
    /// and `base` (the agent's static task count).
    pub fn children_of(
        &self,
        agent: AgentId,
        parent: &InferenceSpec,
        base: u32,
    ) -> Vec<InferenceSpec> {
        if self.prob <= 0.0 || self.branch == 0 || base == 0 {
            return Vec::new();
        }
        if self.generation(parent.id.index, base) >= self.max_depth {
            return Vec::new();
        }
        let mut rng = crate::util::rng::Rng::with_stream(self.seed, parent.id.index as u64 + 1);
        if !rng.chance(self.prob) {
            return Vec::new();
        }
        let mut children = Vec::with_capacity(self.branch as usize);
        for k in 0..self.branch {
            let index =
                base as u64 + parent.id.index as u64 * self.branch as u64 + k as u64;
            if index > (u32::MAX / 2) as u64 {
                break; // runaway-cascade guard; unreachable under max_depth
            }
            // Children are follow-up calls on the parent's output: smaller
            // prompts/decodes drawn from the parent's sizes.
            let fp = rng.range_f64(0.35, 0.85);
            let fd = rng.range_f64(0.35, 0.85);
            children.push(InferenceSpec {
                id: TaskId { agent, index: index as u32 },
                stage: parent.stage + 1,
                deps: vec![parent.id],
                prompt_tokens: ((parent.prompt_tokens as f64 * fp) as u32).max(4),
                decode_tokens: ((parent.decode_tokens as f64 * fd) as u32).max(2),
                kind: "spawned",
                prefix_group: None,
            });
        }
        children
    }
}

/// One task-parallel LLM agent: a DAG of inference tasks, optionally with
/// dynamic spawning.
#[derive(Debug, Clone, PartialEq)]
pub struct AgentSpec {
    /// Agent id (suite-unique).
    pub id: AgentId,
    /// Agent class (template).
    pub class: AgentClass,
    /// Arrival (submission) time in seconds from suite start.
    pub arrival: f64,
    /// Inference tasks in topological order (`tasks[i].id.index == i`;
    /// dependencies always point to lower indices).
    pub tasks: Vec<InferenceSpec>,
    /// Dynamic-spawning rule, if any (`None` for the paper's static agents).
    pub spawn: Option<SpawnSpec>,
    /// Synthesized user-input text; what the cost predictor sees on arrival.
    pub input_text: String,
}

impl AgentSpec {
    /// Build a *staged* agent: stage k+1's tasks depend on every task of
    /// stage k (the paper's sequential-barrier form). Ids, stage labels and
    /// dependencies are assigned here; whatever the input specs carried is
    /// overwritten.
    pub fn from_stages(
        id: AgentId,
        class: AgentClass,
        arrival: f64,
        stages: Vec<Vec<InferenceSpec>>,
        input_text: String,
    ) -> Self {
        let mut tasks: Vec<InferenceSpec> = Vec::with_capacity(stages.iter().map(Vec::len).sum());
        let mut index = 0u32;
        let mut prev_stage_ids: Vec<TaskId> = Vec::new();
        for (s, stage) in stages.into_iter().enumerate() {
            let mut this_stage_ids = Vec::with_capacity(stage.len());
            for mut t in stage {
                t.id = TaskId { agent: id, index };
                t.stage = s as u32;
                t.deps = prev_stage_ids.clone();
                this_stage_ids.push(t.id);
                tasks.push(t);
                index += 1;
            }
            prev_stage_ids = this_stage_ids;
        }
        AgentSpec { id, class, arrival, tasks, spawn: None, input_text }
    }

    /// Recover the staged form, if this DAG is exactly a barrier sequence:
    /// contiguous stage labels in index order, with every task depending on
    /// precisely the full previous stage (in index order). Returns `None`
    /// for general DAGs — the trace writer then uses the explicit task
    /// format.
    pub fn as_stages(&self) -> Option<Vec<Vec<&InferenceSpec>>> {
        let mut stages: Vec<Vec<&InferenceSpec>> = Vec::new();
        let mut prev_ids: Vec<TaskId> = Vec::new();
        let mut cur_ids: Vec<TaskId> = Vec::new();
        for t in &self.tasks {
            let s = t.stage as usize;
            if s == stages.len() {
                // New stage opens: the previous one is sealed.
                prev_ids = std::mem::take(&mut cur_ids);
                stages.push(Vec::new());
            } else if s + 1 != stages.len() {
                return None; // out-of-order or non-contiguous stage labels
            }
            if t.deps != prev_ids {
                return None; // not a full barrier on the previous stage
            }
            cur_ids.push(t.id);
            stages.last_mut().unwrap().push(t);
        }
        Some(stages)
    }

    /// Total number of (static) inference tasks.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Iterate over all static inference specs in index (topological) order.
    pub fn tasks(&self) -> impl Iterator<Item = &InferenceSpec> {
        self.tasks.iter()
    }

    /// Maximum single-inference decode length (bounds inference runtime).
    pub fn max_decode(&self) -> u32 {
        self.tasks().map(|t| t.decode_tokens).max().unwrap_or(0)
    }

    /// Total prompt + decode tokens (used by stats / Fig. 13).
    pub fn total_tokens(&self) -> u64 {
        self.tasks().map(|t| (t.prompt_tokens + t.decode_tokens) as u64).sum()
    }

    /// The agent's dominant shared-prefix family, if any task carries one
    /// (the cluster dispatcher's prefix-affinity placement keys on this).
    pub fn prefix_group_id(&self) -> Option<u64> {
        self.tasks().find_map(|t| t.prefix_group.map(|g| g.id))
    }

    /// DAG depth: the longest dependency chain, in tasks. Equals the stage
    /// count for staged agents; 0 for empty agents.
    pub fn depth(&self) -> usize {
        let mut level = vec![0usize; self.tasks.len()];
        let mut max = 0usize;
        for (i, t) in self.tasks.iter().enumerate() {
            let l = t
                .deps
                .iter()
                .map(|d| level[d.index as usize] + 1)
                .max()
                .unwrap_or(1);
            level[i] = l;
            max = max.max(l);
        }
        max
    }

    /// Statically materialize every task the spawn rule will emit at
    /// runtime, in breadth-first parent order. Empty without a [`SpawnSpec`].
    /// Because spawning is a pure function of the spec, this is exactly the
    /// set the engine discovers dynamically.
    pub fn expand_spawns(&self) -> Vec<InferenceSpec> {
        let Some(spawn) = &self.spawn else { return Vec::new() };
        let base = self.tasks.len() as u32;
        // Generation 1: children of the static tasks (borrowed, no cloning
        // of the static list). Later generations: children of
        // already-collected children, appended in parent order.
        let mut out: Vec<InferenceSpec> = Vec::new();
        for t in &self.tasks {
            out.extend(spawn.children_of(self.id, t, base));
        }
        let mut qi = 0usize;
        while qi < out.len() {
            let parent = out[qi].clone();
            let kids = spawn.children_of(self.id, &parent, base);
            out.extend(kids);
            qi += 1;
        }
        out
    }
}

/// A full workload suite: agents sorted by arrival time.
#[derive(Debug, Clone)]
pub struct Suite {
    /// Agents sorted by arrival; ids follow arrival order.
    pub agents: Vec<AgentSpec>,
}

impl Suite {
    /// Sort by arrival and re-index ids to 0..n.
    pub fn new(mut agents: Vec<AgentSpec>) -> Self {
        agents.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        // Re-index so ids follow arrival order (stable, deterministic).
        // Dependency TaskIds are intra-agent, so they are re-stamped too.
        for (i, a) in agents.iter_mut().enumerate() {
            let new_id = i as AgentId;
            a.id = new_id;
            for t in &mut a.tasks {
                t.id.agent = new_id;
                for d in &mut t.deps {
                    d.agent = new_id;
                }
            }
        }
        Suite { agents }
    }

    /// Number of agents.
    pub fn len(&self) -> usize {
        self.agents.len()
    }

    /// Whether the suite has no agents.
    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }
}

/// Test helpers shared by unit/integration/property tests.
pub mod test_support {
    use super::*;

    /// Build a bare inference spec (no dependencies; constructors that take
    /// stages overwrite id/stage/deps anyway).
    pub fn inference(index: u32, stage: u32, prompt: u32, decode: u32) -> InferenceSpec {
        InferenceSpec {
            id: TaskId { agent: 0, index },
            stage,
            deps: Vec::new(),
            prompt_tokens: prompt,
            decode_tokens: decode,
            kind: "test",
            prefix_group: None,
        }
    }

    /// Build an agent from explicit stages (ids re-labelled consistently).
    pub fn agent_with_stages(stages: Vec<Vec<InferenceSpec>>) -> AgentSpec {
        agent_at(0, 0.0, stages)
    }

    /// Build a staged agent with explicit id/arrival.
    pub fn agent_at(id: AgentId, arrival: f64, stages: Vec<Vec<InferenceSpec>>) -> AgentSpec {
        AgentSpec::from_stages(
            id,
            AgentClass::EquationVerification,
            arrival,
            stages,
            String::new(),
        )
    }

    /// A simple single-stage agent with `n` identical parallel tasks.
    pub fn simple_agent(id: AgentId, arrival: f64, n: usize, prompt: u32, decode: u32) -> AgentSpec {
        agent_at(id, arrival, vec![(0..n as u32).map(|i| inference(i, 0, prompt, decode)).collect()])
    }

    /// A general-DAG agent from `(prompt, decode, deps-by-index)` triples,
    /// in topological order. Stage labels are derived from dependency depth.
    pub fn dag_agent(id: AgentId, arrival: f64, tasks: Vec<(u32, u32, Vec<u32>)>) -> AgentSpec {
        let mut specs = Vec::with_capacity(tasks.len());
        let mut level = vec![0u32; tasks.len()];
        for (i, (p, d, deps)) in tasks.into_iter().enumerate() {
            let stage =
                deps.iter().map(|&j| level[j as usize] + 1).max().unwrap_or(0);
            level[i] = stage;
            specs.push(InferenceSpec {
                id: TaskId { agent: id, index: i as u32 },
                stage,
                deps: deps.into_iter().map(|j| TaskId { agent: id, index: j }).collect(),
                prompt_tokens: p,
                decode_tokens: d,
                kind: "test",
                prefix_group: None,
            });
        }
        AgentSpec {
            id,
            class: AgentClass::EquationVerification,
            arrival,
            tasks: specs,
            spawn: None,
            input_text: String::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::*;
    use super::*;

    #[test]
    fn agent_accessors() {
        let a = agent_with_stages(vec![
            vec![inference(0, 0, 10, 5), inference(1, 0, 20, 9)],
            vec![inference(2, 1, 30, 2)],
        ]);
        assert_eq!(a.n_tasks(), 3);
        assert_eq!(a.max_decode(), 9);
        assert_eq!(a.total_tokens(), 10 + 5 + 20 + 9 + 30 + 2);
        assert_eq!(a.tasks().count(), 3);
        assert_eq!(a.depth(), 2);
    }

    #[test]
    fn from_stages_builds_barrier_deps() {
        let a = agent_with_stages(vec![
            vec![inference(0, 0, 10, 5), inference(1, 0, 20, 9)],
            vec![inference(2, 1, 30, 2), inference(3, 1, 8, 2)],
        ]);
        assert!(a.tasks[0].deps.is_empty() && a.tasks[1].deps.is_empty());
        for t in &a.tasks[2..] {
            assert_eq!(
                t.deps,
                vec![TaskId { agent: 0, index: 0 }, TaskId { agent: 0, index: 1 }]
            );
        }
        // Indices are dense and match positions.
        for (i, t) in a.tasks.iter().enumerate() {
            assert_eq!(t.id.index as usize, i);
        }
        // The staged form round-trips structurally.
        let stages = a.as_stages().expect("barrier DAG is stage-form");
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].len(), 2);
        assert_eq!(stages[1].len(), 2);
    }

    #[test]
    fn general_dag_is_not_stage_form() {
        // Diamond with a partial dependency: task 3 depends on 1 only.
        let a = dag_agent(
            0,
            0.0,
            vec![
                (10, 5, vec![]),
                (10, 5, vec![]),
                (10, 5, vec![0, 1]),
                (10, 5, vec![1]),
            ],
        );
        assert!(a.as_stages().is_none());
        assert_eq!(a.depth(), 2);
        assert_eq!(a.tasks[3].stage, 1);
    }

    #[test]
    fn suite_sorts_and_reindexes() {
        let a = simple_agent(7, 5.0, 1, 10, 10);
        let b = simple_agent(3, 1.0, 2, 10, 10);
        let suite = Suite::new(vec![a, b]);
        assert_eq!(suite.len(), 2);
        assert!(suite.agents[0].arrival < suite.agents[1].arrival);
        assert_eq!(suite.agents[0].id, 0);
        assert_eq!(suite.agents[1].id, 1);
        for (i, agent) in suite.agents.iter().enumerate() {
            for t in agent.tasks() {
                assert_eq!(t.id.agent, i as AgentId);
                for d in &t.deps {
                    assert_eq!(d.agent, i as AgentId);
                }
            }
        }
    }

    #[test]
    fn suite_reindex_restamps_deps() {
        let a = agent_at(9, 4.0, vec![vec![inference(0, 0, 5, 5)], vec![inference(1, 1, 5, 5)]]);
        let b = agent_at(2, 1.0, vec![vec![inference(0, 0, 5, 5)]]);
        let suite = Suite::new(vec![a, b]);
        // The 2-stage agent arrived later → id 1; its dep must follow.
        assert_eq!(suite.agents[1].tasks[1].deps, vec![TaskId { agent: 1, index: 0 }]);
    }

    #[test]
    fn task_id_display() {
        let t = TaskId { agent: 3, index: 11 };
        assert_eq!(t.to_string(), "a3-t11");
    }

    #[test]
    fn prefix_group_id_finds_first_annotation() {
        let mut a = agent_with_stages(vec![vec![inference(0, 0, 10, 5), inference(1, 0, 10, 5)]]);
        assert_eq!(a.prefix_group_id(), None);
        a.tasks[1].prefix_group = Some(PrefixGroup { id: 7, tokens: 64 });
        assert_eq!(a.prefix_group_id(), Some(7));
    }

    #[test]
    fn spawn_expansion_is_deterministic_and_bounded() {
        let mut a = simple_agent(0, 0.0, 3, 40, 16);
        a.spawn = Some(SpawnSpec { prob: 1.0, branch: 2, max_depth: 2, seed: 0xabc });
        let s1 = a.expand_spawns();
        let s2 = a.expand_spawns();
        assert_eq!(s1, s2, "expansion must be pure");
        // prob 1.0, branch 2, depth 2 over 3 roots: 6 children + 12 grandchildren.
        assert_eq!(s1.len(), 18);
        let base = a.tasks.len() as u32;
        let spawn = a.spawn.as_ref().unwrap();
        for c in &s1 {
            assert_eq!(c.kind, "spawned");
            assert_eq!(c.deps.len(), 1);
            let g = spawn.generation(c.id.index, base);
            assert!((1..=2).contains(&g), "generation {g}");
            // Child index closed form inverts to the parent.
            let parent = (c.id.index - base) / spawn.branch;
            assert_eq!(c.deps[0].index, parent);
            assert!(c.prompt_tokens >= 4 && c.decode_tokens >= 2);
        }
        // Indices are unique across the expansion.
        let mut ids: Vec<u32> = s1.iter().map(|c| c.id.index).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 18);
    }

    #[test]
    fn spawn_probability_zero_expands_nothing() {
        let mut a = simple_agent(0, 0.0, 4, 40, 16);
        a.spawn = Some(SpawnSpec { prob: 0.0, branch: 2, max_depth: 2, seed: 1 });
        assert!(a.expand_spawns().is_empty());
        a.spawn = None;
        assert!(a.expand_spawns().is_empty());
    }

    #[test]
    fn spawn_generation_inverts_index_form() {
        let s = SpawnSpec { prob: 0.5, branch: 3, max_depth: 4, seed: 0 };
        let base = 5u32;
        assert_eq!(s.generation(0, base), 0);
        assert_eq!(s.generation(4, base), 0);
        let child = base + 2 * 3 + 1; // child 1 of static task 2
        assert_eq!(s.generation(child, base), 1);
        let grand = base + child * 3; // child 0 of that child
        assert_eq!(s.generation(grand, base), 2);
        // Degenerate empty agent (base 0): defined, and must not loop.
        assert_eq!(s.generation(0, 0), 0);
        assert_eq!(s.generation(7, 0), 0);
    }
}
