//! FairBatching — 300 agents at 3× density per workload family (staged /
//! DAG / shared-prefix), three schedulers × three batch policies, chunked
//! prefill on everywhere (chunk 512 under a 2048-token budget).
//!
//! Beyond the paper: FairBatching's closed-loop prefill/decode split layered
//! on the fair queue. The queue decides *which* prefills run; the batch
//! policy decides *how many tokens* they may take this iteration, shrinking
//! the prefill share when decode p99 inter-token latency breaches the
//! per-class SLO and growing it back only under TTFT pressure. Expected
//! shape: `fairbatching` beats `static` on decode p99 ITL at
//! equal-or-better TTFT on congested cells; `fixed-split` pays TTFT for its
//! always-on decode reservation.

use justitia::config::{BatchPolicyKind, Config, Policy};
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("FairBatching: workload x scheduler x batch policy (300 agents, 3x density)");
    let mut out = ResultsFile::new("bench_fairbatching.txt");
    let rows = justitia::experiments::fairbatching(&Config::default(), 300, 3.0, 42);
    out.line(justitia::experiments::FairBatchingRow::table_header());
    for r in &rows {
        out.line(r.table_row());
    }
    for w in justitia::experiments::FAIRBATCH_WORKLOADS {
        let get = |b: BatchPolicyKind| {
            rows.iter().find(|r| r.workload == w && r.policy == Policy::Justitia && r.batch == b)
        };
        if let (Some(st), Some(fb)) =
            (get(BatchPolicyKind::Static), get(BatchPolicyKind::FairBatching))
        {
            out.line(format!(
                "headline {w} (Justitia): decode ITL p99 {:.1} ms -> {:.1} ms, ttft p99 \
                 {:.0} ms -> {:.0} ms, deadline miss {:.1}% -> {:.1}%",
                st.decode_itl_p99_ms,
                fb.decode_itl_p99_ms,
                st.ttft_p99_ms,
                fb.ttft_p99_ms,
                st.deadline_miss_rate * 100.0,
                fb.deadline_miss_rate * 100.0
            ));
        }
    }
}
