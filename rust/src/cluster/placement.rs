//! Placement policies: which replica an arriving agent is routed to.
//!
//! The cluster-level fairness question (left open by VTC and Equinox for
//! multi-server deployments) is *where* to put an agent so that Justitia's
//! per-replica selective pampering composes into a globally fair schedule.
//! Three policies are provided:
//!
//! * [`Placement::RoundRobin`] — the classic strawman: agent k goes to
//!   replica k mod N. Balances *counts*, not *work*: one DocMerging elephant
//!   weighs as much as a thousand EquationVerification mice.
//! * [`Placement::LeastLoaded`] — route to the replica with the smallest
//!   outstanding *predicted KV cost* (a fluid backlog that drains at the
//!   replica's nominal GPS service rate `M × rate_scale`). Balances work,
//!   but ignores fair-queuing order.
//! * [`Placement::ClusterVtime`] — route to the replica whose GPS fluid
//!   reference would finish the agent *earliest in real time*: each replica
//!   keeps a mirror [`VirtualClock`], and the dispatcher simulates the
//!   hypothetical arrival on every mirror
//!   ([`VirtualClock::hypothetical_gps_finish`]). Because Justitia serves
//!   agents in GPS-finish order, minimizing the GPS finish tag across
//!   replicas keeps selective pampering globally fair — the cluster behaves
//!   like one big GPS server partitioned on the fly.
//! * [`Placement::PrefixAffinity`] — route to the replica holding the
//!   longest cached prompt prefix for the agent's family: the replica that
//!   previously received an agent of the same
//!   [`PrefixGroup`](crate::workload::PrefixGroup) has the family's chain in
//!   its radix tree, so landing there skips the shared prefill entirely.
//!   Agents without a family — and the *first* agent of each family — fall
//!   back to the cluster-vtime rule, so prefix locality is bought without
//!   abandoning the fairness yardstick (cf. Locality-aware Fair Scheduling,
//!   Cao et al. 2025). The family→home mirror is best-effort for *eviction*:
//!   it is not invalidated when the home replica merely evicts the chain
//!   (the routed agent then simply misses and re-primes the cache there).
//!   It IS invalidated when the home replica leaves the pool
//!   ([`Placer::on_replica_down`]) — a departed replica's radix tree is
//!   gone and routing a family at a dead slot would black-hole placements,
//!   so the next family member re-homes via the vtime fallback (regression:
//!   `tests/test_elasticity_recovery.rs::family_rehomes_after_home_crash`).
//!   An eviction-feedback channel would still be needed before an unbounded
//!   multi-tenant deployment.
//!
//! All four are deterministic: ties break toward the lowest replica index,
//! so a cluster run is exactly reproducible from (suite, seed, placement).
//!
//! Elasticity (DESIGN.md §14): every slot carries an eligibility bit. The
//! churn driver clears it on drain-start and crash and sets it on join;
//! every policy then chooses among eligible slots only. With all slots
//! eligible — the immortal default — each policy's decision sequence is
//! bit-identical to the pre-elasticity placer.

use crate::sched::vtime::VirtualClock;
use crate::workload::AgentId;
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Replica-placement policy selector (see module docs for semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Placement {
    /// Agent k → replica k mod N (balances agent counts).
    RoundRobin,
    /// Replica with the least outstanding predicted KV cost (fluid backlog).
    LeastLoaded,
    /// Replica minimizing the agent's hypothetical GPS-order finish tag —
    /// the cluster-fair extension of Justitia's virtual-time queuing.
    #[default]
    ClusterVtime,
    /// Replica holding the longest cached prefix for the agent's family,
    /// tie-broken (and seeded) by the cluster-vtime rule.
    PrefixAffinity,
}

impl Placement {
    /// Every placement policy, in report order.
    pub const ALL: [Placement; 4] = [
        Placement::RoundRobin,
        Placement::LeastLoaded,
        Placement::ClusterVtime,
        Placement::PrefixAffinity,
    ];

    /// Parse a CLI/JSON policy name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "round-robin" | "rr" => Ok(Placement::RoundRobin),
            "least-loaded" | "ll" => Ok(Placement::LeastLoaded),
            "cluster-vtime" | "vtime" => Ok(Placement::ClusterVtime),
            "prefix-affinity" | "pa" => Ok(Placement::PrefixAffinity),
            other => bail!(
                "unknown placement '{other}' \
                 (round-robin|least-loaded|cluster-vtime|prefix-affinity)"
            ),
        }
    }

    /// Canonical display name.
    pub fn name(&self) -> &'static str {
        match self {
            Placement::RoundRobin => "round-robin",
            Placement::LeastLoaded => "least-loaded",
            Placement::ClusterVtime => "cluster-vtime",
            Placement::PrefixAffinity => "prefix-affinity",
        }
    }
}

/// Per-replica placement bookkeeping owned by the dispatcher: a fluid
/// backlog of predicted cost (least-loaded) and a mirror virtual clock
/// (cluster-vtime). Both are updated on every placement regardless of the
/// active policy, so policies can be compared or switched without state
/// loss.
#[derive(Debug, Clone)]
pub(crate) struct ReplicaLoad {
    /// Outstanding predicted cost, drained at `drain_rate` per second.
    backlog: f64,
    /// Last time the backlog was decayed.
    last_t: f64,
    /// Cost units drained per second: M × rate_scale (one replica's nominal
    /// GPS service rate).
    drain_rate: f64,
    /// Mirror of the replica's fair-queuing virtual clock.
    pub(crate) vclock: VirtualClock,
}

impl ReplicaLoad {
    pub(crate) fn new(capacity_tokens: u64, rate_scale: f64) -> Self {
        ReplicaLoad {
            backlog: 0.0,
            last_t: 0.0,
            drain_rate: capacity_tokens as f64 * rate_scale,
            vclock: VirtualClock::new(capacity_tokens, rate_scale),
        }
    }

    /// Decay the fluid backlog to time `now` (monotone per replica).
    fn decay(&mut self, now: f64) {
        let now = now.max(self.last_t);
        self.backlog = (self.backlog - self.drain_rate * (now - self.last_t)).max(0.0);
        self.last_t = now;
    }

    /// Outstanding predicted cost at `now`.
    pub(crate) fn backlog_at(&mut self, now: f64) -> f64 {
        self.decay(now);
        self.backlog
    }

    /// Record that an agent with predicted `cost` was placed here at `now`.
    pub(crate) fn assign(&mut self, agent: AgentId, cost: f64, now: f64) {
        self.decay(now);
        self.backlog += cost;
        self.vclock.on_arrival(agent, cost, now.max(self.last_t));
    }
}

/// The placement decision engine: pure state machine, no engine access.
/// `nows[r]` is the time base of replica r (global arrival time for offline
/// trace replay; the replica's own engine clock for online serving).
#[derive(Debug, Clone)]
pub(crate) struct Placer {
    policy: Placement,
    rr_next: usize,
    pub(crate) loads: Vec<ReplicaLoad>,
    /// Prefix-affinity mirror: family id → replica whose radix tree holds
    /// the family's chain (the replica its first agent was routed to).
    /// Entries are purged when their home leaves the pool (see module docs).
    family_home: HashMap<u64, usize>,
    /// Per-slot placement eligibility: false while a slot is draining or
    /// down. All-true in the immortal default.
    eligible: Vec<bool>,
    /// One replica's KV capacity M — kept so joined slots get fresh mirrors.
    capacity_tokens: u64,
    /// Nominal iterations/second — ditto.
    rate_scale: f64,
}

impl Placer {
    pub(crate) fn new(policy: Placement, n: usize, capacity_tokens: u64, rate_scale: f64) -> Self {
        Placer {
            policy,
            rr_next: 0,
            loads: (0..n).map(|_| ReplicaLoad::new(capacity_tokens, rate_scale)).collect(),
            family_home: HashMap::new(),
            eligible: vec![true; n],
            capacity_tokens,
            rate_scale,
        }
    }

    pub(crate) fn policy(&self) -> Placement {
        self.policy
    }

    /// Whether slot `r` currently takes placements.
    pub(crate) fn is_eligible(&self, r: usize) -> bool {
        self.eligible[r]
    }

    /// Slots currently taking placements.
    pub(crate) fn n_eligible(&self) -> usize {
        self.eligible.iter().filter(|&&e| e).count()
    }

    /// Stop routing to slot `r` (drain-start: the replica still runs its
    /// in-flight work, so its load mirror and family homes stay intact).
    pub(crate) fn set_ineligible(&mut self, r: usize) {
        self.eligible[r] = false;
    }

    /// Slot `r` left the pool (crash, or drain completed): stop routing to
    /// it, reset its load mirror, and purge family homes pointing at it —
    /// its radix tree is gone, so surviving family members must re-home via
    /// the vtime fallback instead of black-holing at a dead slot.
    pub(crate) fn on_replica_down(&mut self, r: usize) {
        self.eligible[r] = false;
        self.loads[r] = ReplicaLoad::new(self.capacity_tokens, self.rate_scale);
        // simlint::allow(unordered-iter): pure per-entry predicate; resulting map state is order-independent
        self.family_home.retain(|_, home| *home != r);
    }

    /// Slot `r` (re)joined the pool with a fresh engine.
    pub(crate) fn on_replica_up(&mut self, r: usize) {
        self.eligible[r] = true;
    }

    /// Grow the pool by one fresh, eligible slot; returns its index.
    pub(crate) fn add_replica(&mut self) -> usize {
        self.loads.push(ReplicaLoad::new(self.capacity_tokens, self.rate_scale));
        self.eligible.push(true);
        self.loads.len() - 1
    }

    /// Whether the next [`place`](Self::place) call for `prefix_group`
    /// would consult live GPS-finish estimates. False when the decision is
    /// already determined (single replica, non-vtime policy, or a
    /// prefix-affinity family that has a home) — lets the dispatcher skip
    /// probing every replica's scheduler on the hot path.
    pub(crate) fn wants_live_estimates(&self, prefix_group: Option<u64>) -> bool {
        if self.n_eligible() == 1 {
            return false;
        }
        match self.policy {
            Placement::ClusterVtime => true,
            Placement::PrefixAffinity => {
                // A home entry always points at an eligible slot (purged on
                // departure), so a homed family never needs estimates.
                prefix_group.and_then(|g| self.family_home.get(&g)).is_none()
            }
            _ => false,
        }
    }

    /// Choose a replica for (`agent`, predicted `cost`) and update the
    /// per-replica bookkeeping. `live_estimates[r]`, when provided, replaces
    /// the mirror's GPS-finish estimate for cluster-vtime (used online where
    /// the live scheduler's virtual clock is exact). `prefix_group` is the
    /// agent's shared-prefix family, consulted by prefix-affinity.
    pub(crate) fn place(
        &mut self,
        agent: AgentId,
        cost: f64,
        prefix_group: Option<u64>,
        nows: &[f64],
        live_estimates: Option<&[Option<f64>]>,
    ) -> usize {
        debug_assert_eq!(nows.len(), self.loads.len());
        let n = self.loads.len();
        // Only eligible slots compete; with every slot eligible (the
        // immortal default) each arm below reduces to the pre-elasticity
        // decision bit for bit.
        let elig: Vec<usize> = (0..n).filter(|&r| self.eligible[r]).collect();
        assert!(!elig.is_empty(), "placement with no eligible replica");
        let vtime_choice = |loads: &[ReplicaLoad]| {
            argmin_over(elig.iter().map(|&r| {
                let v = live_estimates
                    .and_then(|es| es[r])
                    .unwrap_or_else(|| loads[r].vclock.hypothetical_gps_finish(agent, cost, nows[r]));
                (r, v)
            }))
        };
        let chosen = match self.policy {
            _ if elig.len() == 1 => elig[0],
            Placement::RoundRobin => {
                // Cyclic scan from the cursor to the next eligible slot.
                let r = (0..n)
                    .map(|k| (self.rr_next + k) % n)
                    .find(|&r| self.eligible[r])
                    .expect("eligible slot exists");
                self.rr_next = (r + 1) % n;
                r
            }
            Placement::LeastLoaded => {
                let backlogs: Vec<(usize, f64)> = elig
                    .iter()
                    .map(|&r| {
                        let b = self.loads[r].backlog_at(nows[r]);
                        (r, b)
                    })
                    .collect();
                argmin_over(backlogs.into_iter())
            }
            Placement::ClusterVtime => vtime_choice(&self.loads),
            Placement::PrefixAffinity => {
                match prefix_group.and_then(|g| self.family_home.get(&g).copied()) {
                    // The family's chain is cached there — follow it (homes
                    // at departed slots are purged, so `home` is eligible
                    // unless the slot is mid-drain; then fall through).
                    Some(home) if self.eligible[home] => home,
                    // First of its family (or no family, or home draining):
                    // the fairness-preserving cluster-vtime rule.
                    _ => vtime_choice(&self.loads),
                }
            }
        };
        if self.policy == Placement::PrefixAffinity {
            if let Some(g) = prefix_group {
                self.family_home.entry(g).or_insert(chosen);
            }
        }
        self.loads[chosen].assign(agent, cost, nows[chosen]);
        chosen
    }
}

/// Slot index of the minimum value over `(index, value)` pairs; ties break
/// toward the earliest pair (slots are iterated in ascending index order, so
/// this is the lowest eligible index — same rule as before elasticity).
fn argmin_over(it: impl Iterator<Item = (usize, f64)>) -> usize {
    let mut best = 0usize;
    let mut best_v = f64::INFINITY;
    let mut first = true;
    for (i, v) in it {
        if first || v < best_v {
            best = i;
            best_v = v;
            first = false;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(Placement::by_name(p.name()).unwrap(), p);
        }
        assert_eq!(Placement::by_name("rr").unwrap(), Placement::RoundRobin);
        assert_eq!(Placement::by_name("vtime").unwrap(), Placement::ClusterVtime);
        assert_eq!(Placement::by_name("pa").unwrap(), Placement::PrefixAffinity);
        assert!(Placement::by_name("random").is_err());
        assert_eq!(Placement::default(), Placement::ClusterVtime);
    }

    #[test]
    fn round_robin_cycles() {
        let mut p = Placer::new(Placement::RoundRobin, 3, 100, 1.0);
        let nows = [0.0, 0.0, 0.0];
        let seq: Vec<usize> = (0..6).map(|i| p.place(i, 10.0, None, &nows, None)).collect();
        assert_eq!(seq, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_tracks_backlog() {
        let mut p = Placer::new(Placement::LeastLoaded, 2, 10, 1.0);
        // Heavy agent to replica 0 (tie → 0), light one must go to 1.
        assert_eq!(p.place(0, 1000.0, None, &[0.0, 0.0], None), 0);
        assert_eq!(p.place(1, 10.0, None, &[0.0, 0.0], None), 1);
        // Replica 1 drains (rate 10/s): by t=2 its backlog is 0, replica 0
        // still has ~980 → next goes to 1 again.
        assert_eq!(p.place(2, 10.0, None, &[2.0, 2.0], None), 1);
    }

    #[test]
    fn least_loaded_backlog_drains_to_zero() {
        let mut l = ReplicaLoad::new(10, 1.0);
        l.assign(0, 50.0, 0.0);
        assert!((l.backlog_at(1.0) - 40.0).abs() < 1e-9);
        assert_eq!(l.backlog_at(100.0), 0.0);
    }

    #[test]
    fn cluster_vtime_prefers_idle_replica() {
        let mut p = Placer::new(Placement::ClusterVtime, 2, 10, 1.0);
        // Saturate replica 0 with a big agent…
        assert_eq!(p.place(0, 500.0, None, &[0.0, 0.0], None), 0);
        // …the next agent's GPS finish is earlier on the empty replica 1.
        assert_eq!(p.place(1, 100.0, None, &[0.0, 0.0], None), 1);
        // A third agent (cost 200) at t=0: on replica 0 it shares with 500
        // the whole way (5/s → t=40); on replica 1 it shares with 100 until
        // t=20, then runs alone (t=30) → replica 1 wins.
        assert_eq!(p.place(2, 200.0, None, &[0.0, 0.0], None), 1);
    }

    #[test]
    fn cluster_vtime_honors_live_estimates() {
        let mut p = Placer::new(Placement::ClusterVtime, 2, 10, 1.0);
        // Live estimates invert the mirror-based choice.
        let r = p.place(0, 100.0, None, &[0.0, 0.0], Some(&[Some(9.0), Some(3.0)]));
        assert_eq!(r, 1);
    }

    #[test]
    fn prefix_affinity_keeps_families_together() {
        let mut p = Placer::new(Placement::PrefixAffinity, 2, 10, 1.0);
        // Family 7's opener saturates replica 0 (vtime tie → 0)…
        assert_eq!(p.place(0, 500.0, Some(7), &[0.0, 0.0], None), 0);
        // …a family-less agent avoids it (vtime fallback)…
        assert_eq!(p.place(1, 100.0, None, &[0.0, 0.0], None), 1);
        // …but family members follow the cached chain despite the load.
        assert_eq!(p.place(2, 100.0, Some(7), &[0.0, 0.0], None), 0);
        assert_eq!(p.place(3, 100.0, Some(7), &[1.0, 1.0], None), 0);
        // A new family starts wherever vtime points (replica 1 now lighter
        // than 0? 0 carries 700, 1 carries 100 → family 8 opens on 1).
        assert_eq!(p.place(4, 100.0, Some(8), &[1.0, 1.0], None), 1);
        assert_eq!(p.place(5, 100.0, Some(8), &[2.0, 2.0], None), 1);
    }

    #[test]
    fn single_replica_short_circuits() {
        for policy in Placement::ALL {
            let mut p = Placer::new(policy, 1, 100, 1.0);
            for i in 0..5 {
                assert_eq!(p.place(i, 100.0, Some(3), &[i as f64], None), 0);
            }
        }
    }

    #[test]
    fn round_robin_skips_ineligible_slots() {
        let mut p = Placer::new(Placement::RoundRobin, 3, 100, 1.0);
        let nows = [0.0, 0.0, 0.0];
        assert_eq!(p.place(0, 10.0, None, &nows, None), 0);
        p.on_replica_down(1);
        let seq: Vec<usize> = (1..5).map(|i| p.place(i, 10.0, None, &nows, None)).collect();
        assert_eq!(seq, vec![2, 0, 2, 0], "cursor cycles over the live slots");
        p.on_replica_up(1);
        assert_eq!(p.place(5, 10.0, None, &nows, None), 1, "revived slot rejoins the cycle");
    }

    #[test]
    fn vtime_and_least_loaded_ignore_down_slots() {
        for policy in [Placement::ClusterVtime, Placement::LeastLoaded] {
            let mut p = Placer::new(policy, 2, 10, 1.0);
            // Load replica 0 heavily, then kill the empty replica 1: the
            // heavy slot must win anyway — it is the only eligible one.
            assert_eq!(p.place(0, 500.0, None, &[0.0, 0.0], None), 0);
            p.on_replica_down(1);
            assert_eq!(p.place(1, 10.0, None, &[0.0, 0.0], None), 0, "{policy:?}");
        }
    }

    #[test]
    fn family_home_purged_when_home_goes_down() {
        let mut p = Placer::new(Placement::PrefixAffinity, 2, 10, 1.0);
        // Family 7 homes on replica 0 and sticks there despite the load…
        assert_eq!(p.place(0, 500.0, Some(7), &[0.0, 0.0], None), 0);
        assert_eq!(p.place(1, 100.0, Some(7), &[0.0, 0.0], None), 0);
        // …until replica 0 leaves the pool: the home entry is purged and the
        // next member re-homes on a live slot instead of black-holing.
        p.on_replica_down(0);
        assert_eq!(p.place(2, 100.0, Some(7), &[1.0, 1.0], None), 1);
        // The re-home sticks: later members follow the new home.
        p.on_replica_up(0);
        assert_eq!(p.place(3, 100.0, Some(7), &[2.0, 2.0], None), 1);
    }

    #[test]
    fn draining_home_defers_without_rehoming() {
        let mut p = Placer::new(Placement::PrefixAffinity, 2, 10, 1.0);
        assert_eq!(p.place(0, 100.0, Some(9), &[0.0, 0.0], None), 0);
        // Drain-start: the home still holds the cache but takes no new work.
        p.set_ineligible(0);
        assert_eq!(p.place(1, 100.0, Some(9), &[0.0, 0.0], None), 1);
        // The home entry survives the drain *start* (not the departure), so
        // an aborted drain would resume routing there.
        p.on_replica_up(0);
        assert_eq!(p.place(2, 100.0, Some(9), &[1.0, 1.0], None), 0);
    }

    #[test]
    fn add_replica_grows_the_pool() {
        let mut p = Placer::new(Placement::RoundRobin, 2, 100, 1.0);
        assert_eq!(p.add_replica(), 2);
        assert_eq!(p.n_eligible(), 3);
        let nows = [0.0, 0.0, 0.0];
        let seq: Vec<usize> = (0..3).map(|i| p.place(i, 10.0, None, &nows, None)).collect();
        assert_eq!(seq, vec![0, 1, 2]);
    }
}
