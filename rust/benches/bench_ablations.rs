//! Ablations over the design choices DESIGN.md calls out (beyond the
//! paper's own Fig. 11 cost-model ablation):
//!
//!  A1. KV page size — granularity vs fragmentation of the paged pool.
//!  A2. Batch-slot cap (`max_batch`) — slot pressure vs alpha amortization.
//!  A3. Predictor in the loop vs oracle costs for Justitia (does the real
//!      TF-IDF+MLP close the loop at suite scale?).
//!  A4. Bursty (Gamma, CV≈1.4) vs smooth (uniform-stretched) arrivals —
//!      does the Mooncake-style burstiness matter for the headline gap?

use justitia::config::{Config, Policy, WorkloadConfig};
use justitia::cost::CostModel;
use justitia::experiments::{run_policy, run_policy_oracle, CostSource};
use justitia::util::bench::{section, ResultsFile};
use justitia::workload::trace::build_suite;

fn cfg_at(density: f64, seed: u64) -> (Config, justitia::workload::Suite) {
    let mut cfg = Config::default();
    cfg.workload = WorkloadConfig { n_agents: 300, seed, ..Default::default() }.with_density(density);
    let suite = build_suite(&cfg.workload);
    (cfg, suite)
}

fn main() {
    let mut out = ResultsFile::new("bench_ablations.txt");

    section("A1: KV page size (Justitia vs VTC, 3x)");
    out.line(format!("{:>9} {:>12} {:>12} {:>8}", "page", "Justitia", "VTC", "gap"));
    for page in [8u32, 16, 32, 64] {
        let (mut cfg, suite) = cfg_at(3.0, 42);
        cfg.backend.page_size = page; // kv_tokens constant → pages vary
        let j = run_policy_oracle(&cfg, &suite, Policy::Justitia).avg_jct();
        let v = run_policy_oracle(&cfg, &suite, Policy::Vtc).avg_jct();
        out.line(format!("{page:>9} {j:>11.1}s {v:>11.1}s {:>7.1}%", (1.0 - j / v) * 100.0));
    }

    section("A2: batch-slot cap (3x)");
    out.line(format!("{:>9} {:>12} {:>12}", "max_batch", "Justitia", "VTC"));
    for mb in [8usize, 16, 32, 64, 128] {
        let (mut cfg, suite) = cfg_at(3.0, 42);
        cfg.max_batch = mb;
        let j = run_policy_oracle(&cfg, &suite, Policy::Justitia).avg_jct();
        let v = run_policy_oracle(&cfg, &suite, Policy::Vtc).avg_jct();
        out.line(format!("{mb:>9} {j:>11.1}s {v:>11.1}s"));
    }

    section("A3: predictor in the loop (2x)");
    {
        let (cfg, suite) = cfg_at(2.0, 42);
        let oracle = run_policy_oracle(&cfg, &suite, Policy::Justitia).avg_jct();
        let (pred, report) =
            justitia::predictor::train_per_class(CostModel::MemoryCentric, 100, 20, 42);
        let mlp = run_policy(&cfg, &suite, Policy::Justitia, &CostSource::Model(&pred)).avg_jct();
        out.line(format!(
            "oracle costs: {oracle:.1}s | MLP predictor ({:.0}% rel-err): {mlp:.1}s ({:+.1}%)",
            report.rel_error * 100.0,
            (mlp / oracle - 1.0) * 100.0
        ));
    }

    section("A4: arrival burstiness (3x)");
    {
        // Smooth arrivals: same count/window, uniform spacing.
        let (cfg, bursty) = cfg_at(3.0, 42);
        let smooth = justitia::workload::Suite::new(
            bursty
                .agents
                .iter()
                .enumerate()
                .map(|(i, a)| {
                    let mut a = a.clone();
                    a.arrival = cfg.workload.window_secs * i as f64 / bursty.len() as f64;
                    a
                })
                .collect(),
        );
        for (label, suite) in [("bursty (Gamma)", &bursty), ("smooth (uniform)", &smooth)] {
            let j = run_policy_oracle(&cfg, suite, Policy::Justitia).avg_jct();
            let v = run_policy_oracle(&cfg, suite, Policy::Vtc).avg_jct();
            out.line(format!(
                "{label:<18} Justitia {j:>7.1}s  VTC {v:>7.1}s  gap {:>5.1}%",
                (1.0 - j / v) * 100.0
            ));
        }
    }
}
