//! Inference-level First-Come-First-Serve — what vanilla vLLM does
//! (paper baseline (a)). Subject to head-of-line blocking by construction.

use crate::config::Policy;
use crate::sched::{AgentInfo, OrdF64, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Inference-level FCFS scheduler state.
pub struct Fcfs {
    /// Min-heap on submission sequence number.
    heap: BinaryHeap<Reverse<(u64, TaskKey)>>,
    tasks: HashMap<TaskKey, TaskInfo>,
    arrivals: HashMap<AgentId, f64>,
}

type TaskKey = (u32, u32);

fn key(t: &TaskInfo) -> TaskKey {
    (t.id.agent, t.id.index)
}

impl Fcfs {
    /// Empty scheduler.
    pub fn new() -> Self {
        Fcfs { heap: BinaryHeap::new(), tasks: HashMap::new(), arrivals: HashMap::new() }
    }
}

impl Default for Fcfs {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Fcfs {
    fn policy(&self) -> Policy {
        Policy::Fcfs
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, _now: f64) {
        self.arrivals.insert(info.id, info.arrival);
    }

    fn push_task(&mut self, task: TaskInfo, _now: f64) {
        self.heap.push(Reverse((task.seq, key(&task))));
        self.tasks.insert(key(&task), task);
    }

    fn pop_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let Reverse((_, k)) = self.heap.pop()?;
        self.tasks.remove(&k)
    }

    fn peek_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let &Reverse((_, k)) = self.heap.peek()?;
        self.tasks.get(&k).copied()
    }

    fn waiting_len(&self) -> usize {
        self.heap.len()
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        // vLLM preempts the most recently arrived first.
        self.arrivals.get(&agent).copied().unwrap_or(f64::MAX)
    }
}

/// Agent-level FCFS lives in `agent_fcfs`; keep OrdF64 referenced for the
/// doc-consistency of the module set.
#[allow(dead_code)]
type _Unused = OrdF64;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 1, predicted_decode: 1.0, seq }
    }

    #[test]
    fn strict_submission_order() {
        let mut s = Fcfs::new();
        s.push_task(task(2, 0, 5), 0.0);
        s.push_task(task(1, 0, 3), 0.0);
        s.push_task(task(1, 1, 7), 0.0);
        let seqs: Vec<u64> = (0..3).map(|_| s.pop_next(0.0).unwrap().seq).collect();
        assert_eq!(seqs, vec![3, 5, 7]);
    }

    #[test]
    fn interleaves_agents() {
        // FCFS at the inference level interleaves tasks of different agents
        // (the head-of-line-blocking setup the paper criticizes).
        let mut s = Fcfs::new();
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(2, 0, 1), 0.0);
        s.push_task(task(1, 1, 2), 0.0);
        let agents: Vec<u32> = (0..3).map(|_| s.pop_next(0.0).unwrap().id.agent).collect();
        assert_eq!(agents, vec![1, 2, 1]);
    }

    #[test]
    fn preemption_rank_latest_first() {
        let mut s = Fcfs::new();
        s.on_agent_arrival(&AgentInfo::new(1, 0.0, 1.0), 0.0);
        s.on_agent_arrival(&AgentInfo::new(2, 9.0, 1.0), 9.0);
        assert!(s.preemption_rank(2, 9.0) > s.preemption_rank(1, 9.0));
    }
}
