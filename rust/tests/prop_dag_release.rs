//! Property tests for DAG-structured agents (ISSUE 3):
//!
//! * **Release safety** — no task is ever admitted before every one of its
//!   dependencies (static or spawned-parent) has completed;
//! * **Replay determinism** — the same suite replayed through the same
//!   policy produces bit-identical JCTs and spawned-task counts;
//! * **Spawn purity** — the spawned task set is a function of the suite
//!   alone: different schedulers (and the static `expand_spawns` oracle)
//!   observe exactly the same children.

use justitia::config::{BackendProfile, Config, Policy};
use justitia::engine::exec::SimBackend;
use justitia::engine::Engine;
use justitia::util::prop::{check, Config as PropConfig, Strategy};
use justitia::util::rng::Rng;
use justitia::workload::test_support::dag_agent;
use justitia::workload::{AgentSpec, SpawnSpec, Suite, TaskId};
use std::collections::HashMap;

/// A randomized DAG workload: agents with random topology (every task
/// depends on a random subset of earlier tasks) and random spawn rules.
#[derive(Clone, Debug)]
struct DagSuite {
    agents: Vec<AgentSpec>,
    pages: u64,
    page_size: u32,
}

struct DagStrategy;

impl Strategy for DagStrategy {
    type Value = DagSuite;

    fn generate(&self, rng: &mut Rng) -> DagSuite {
        let page_size = 8u32;
        let pages = rng.range_u64(32, 64);
        let m_tokens = pages * page_size as u64;
        let n_agents = rng.range_u64(2, 8) as usize;
        let mut agents = Vec::with_capacity(n_agents);
        let mut t = 0.0;
        for id in 0..n_agents {
            t += rng.exponential(0.05);
            let n_tasks = rng.range_u64(1, 8) as usize;
            let mut tasks = Vec::with_capacity(n_tasks);
            for i in 0..n_tasks {
                let p = rng.range_u64(2, (m_tokens / 8).max(3)) as u32;
                let d = rng.range_u64(2, 24) as u32;
                // Random backward dependencies: up to 3 distinct earlier
                // tasks, each picked with probability ~1/2.
                let mut deps = Vec::new();
                for _ in 0..rng.range_u64(0, 3.min(i as u64)) {
                    let j = rng.below(i as u64) as u32;
                    if !deps.contains(&j) {
                        deps.push(j);
                    }
                }
                deps.sort_unstable();
                tasks.push((p, d, deps));
            }
            let mut a = dag_agent(id as u32, t, tasks);
            if rng.chance(0.7) {
                a.spawn = Some(SpawnSpec {
                    prob: rng.range_f64(0.2, 1.0),
                    branch: rng.range_u64(1, 3) as u32,
                    max_depth: rng.range_u64(1, 2) as u32,
                    seed: rng.next_u64(),
                });
            }
            agents.push(a);
        }
        DagSuite { agents, pages, page_size }
    }

    fn shrink(&self, v: &DagSuite) -> Vec<DagSuite> {
        let mut out = Vec::new();
        if v.agents.len() > 1 {
            let mut w = v.clone();
            w.agents.pop();
            out.push(w);
        }
        // Strip spawn rules (cheapest structural simplification).
        if v.agents.iter().any(|a| a.spawn.is_some()) {
            let mut w = v.clone();
            for a in &mut w.agents {
                a.spawn = None;
            }
            out.push(w);
        }
        out
    }
}

fn run(ds: &DagSuite, policy: Policy) -> (Engine<SimBackend>, Suite) {
    let mut cfg = Config::default();
    cfg.backend = BackendProfile {
        name: "prop-dag".into(),
        kv_tokens: ds.pages * ds.page_size as u64,
        page_size: ds.page_size,
        alpha: 1.0,
        beta_prefill: 0.0,
        beta_decode: 0.0,
        swap_cost_per_token: 0.0,
        beta_mixed: 0.0,
        host_kv_tokens: None,
        swap_bw_tokens_per_sec: 0.0,
    };
    cfg.max_batch = 1024;
    let suite = Suite::new(ds.agents.clone());
    let sched = justitia::sched::build(policy, cfg.backend.kv_tokens, 1.0);
    let mut engine = Engine::new(&cfg, sched, SimBackend::unit_time());
    let model = justitia::cost::CostModel::MemoryCentric;
    engine.run_suite(&suite, |a| model.agent_cost(a));
    (engine, suite)
}

/// Dependency map over the *full* runtime task set: static deps from the
/// spec, spawned tasks (from the deterministic expansion) depending on
/// their parent.
fn full_dep_map(suite: &Suite) -> HashMap<TaskId, Vec<TaskId>> {
    let mut deps: HashMap<TaskId, Vec<TaskId>> = HashMap::new();
    for a in &suite.agents {
        for t in &a.tasks {
            deps.insert(t.id, t.deps.clone());
        }
        for t in a.expand_spawns() {
            deps.insert(t.id, t.deps.clone());
        }
    }
    deps
}

#[test]
fn no_task_admitted_before_its_deps_complete() {
    let cfg = PropConfig { cases: prop_cases(30), seed: 0xda6, max_shrink_steps: 40 };
    check(&cfg, &DagStrategy, |ds| {
        for policy in [Policy::Fcfs, Policy::Justitia] {
            let (engine, suite) = run(ds, policy);
            if engine.metrics.completed_agents() != suite.len() {
                return Err(format!(
                    "{policy:?}: {}/{} agents completed",
                    engine.metrics.completed_agents(),
                    suite.len()
                ));
            }
            let deps = full_dep_map(&suite);
            for (task, dep_list) in &deps {
                let Some(admit) = engine.metrics.task_admit_time(*task) else {
                    return Err(format!("{policy:?}: task {task} never admitted"));
                };
                for d in dep_list {
                    let done = engine
                        .metrics
                        .task_complete_time(*d)
                        .ok_or_else(|| format!("{policy:?}: dep {d} never completed"))?;
                    if admit + 1e-9 < done {
                        return Err(format!(
                            "{policy:?}: task {task} admitted at {admit} before \
                             dep {d} completed at {done}"
                        ));
                    }
                }
            }
            engine.kv.check_invariants()?;
            if engine.kv.device_tokens() != 0 {
                return Err("leaked device tokens".into());
            }
        }
        Ok(())
    });
}

#[test]
fn replays_are_deterministic_and_spawns_are_pure() {
    let cfg = PropConfig { cases: prop_cases(25), seed: 0x5eed, max_shrink_steps: 40 };
    check(&cfg, &DagStrategy, |ds| {
        // Replay determinism under one policy.
        let (e1, suite) = run(ds, Policy::Justitia);
        let (e2, _) = run(ds, Policy::Justitia);
        if e1.metrics.jcts() != e2.metrics.jcts() {
            return Err("replay JCTs diverged".into());
        }
        if e1.metrics.spawned_tasks() != e2.metrics.spawned_tasks() {
            return Err("replay spawned-task counts diverged".into());
        }
        // Spawn purity across schedulers: the set of spawned tasks equals
        // the static expansion regardless of execution order.
        let expected: u64 = suite.agents.iter().map(|a| a.expand_spawns().len() as u64).sum();
        let (e3, _) = run(ds, Policy::Fcfs);
        for (label, e) in [("justitia", &e1), ("fcfs", &e3)] {
            if e.metrics.spawned_tasks() != expected {
                return Err(format!(
                    "{label}: spawned {} tasks, static expansion says {expected}",
                    e.metrics.spawned_tasks()
                ));
            }
        }
        // Every statically-expanded child actually ran to completion.
        for a in &suite.agents {
            for t in a.expand_spawns() {
                if e1.metrics.task_complete_time(t.id).is_none() {
                    return Err(format!("spawned task {} never completed", t.id));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dag_suite_from_config_is_replay_deterministic() {
    // The generator-level DAG suite (all three shapes mixed) through the
    // full engine: two replays must agree bit for bit.
    let wl = justitia::config::WorkloadConfig {
        n_agents: 24,
        window_secs: 30.0,
        ..Default::default()
    }
    .with_dag(0.4, 2);
    let suite = justitia::workload::trace::build_suite(&wl);
    let run_once = || {
        let cfg = Config::default();
        let sched = justitia::sched::build(Policy::Justitia, cfg.backend.kv_tokens, 1.0);
        let mut engine = Engine::new(&cfg, sched, SimBackend::new(&cfg.backend));
        let model = justitia::cost::CostModel::MemoryCentric;
        engine.run_suite(&suite, |a| model.agent_cost(a));
        (engine.metrics.jcts(), engine.metrics.spawned_tasks())
    };
    let (j1, s1) = run_once();
    let (j2, s2) = run_once();
    assert_eq!(j1.len(), 24);
    assert_eq!(j1, j2);
    assert_eq!(s1, s2);
}

/// Honor the env knob while keeping CI fast by default.
fn prop_cases(default: usize) -> usize {
    std::env::var("JUSTITIA_PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}
