//! Agent-level Shortest-Remaining-Job-First — the SRJF baseline (paper
//! baseline (e)): uses the same predicted agent costs as Justitia but ranks
//! by *remaining* predicted work. Near-optimal mean JCT; starves elephants
//! (Fig. 9).

use crate::config::Policy;
use crate::sched::{AgentInfo, AgentQueues, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::collections::HashMap;

/// Agent-level SRJF scheduler state.
pub struct Srjf {
    remaining: HashMap<AgentId, f64>,
    /// Last corrected end-to-end cost estimate per agent (§4.2): corrections
    /// apply as *total-estimate deltas* on top of the service-decremented
    /// `remaining` counter, so service already delivered to in-flight tasks
    /// is never re-added.
    last_total: HashMap<AgentId, f64>,
    waiting: AgentQueues,
}

impl Srjf {
    /// Empty scheduler.
    pub fn new() -> Self {
        Srjf { remaining: HashMap::new(), last_total: HashMap::new(), waiting: AgentQueues::new() }
    }

    /// Remaining predicted work of an agent (for tests).
    pub fn remaining(&self, agent: AgentId) -> f64 {
        self.remaining.get(&agent).copied().unwrap_or(0.0)
    }
}

impl Default for Srjf {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Srjf {
    fn policy(&self) -> Policy {
        Policy::Srjf
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, _now: f64) {
        self.remaining.insert(info.id, info.cost.max(0.0));
        self.last_total.insert(info.id, info.cost.max(0.0));
    }

    fn push_task(&mut self, task: TaskInfo, _now: f64) {
        self.waiting.push(task);
    }

    fn pop_next(&mut self, _now: f64) -> Option<TaskInfo> {
        // Dynamic priority: linear scan over waiting agents (A ≤ hundreds).
        let agent = self.waiting.min_agent_by(|a| self.remaining.get(&a).copied().unwrap_or(0.0))?;
        self.waiting.pop_agent(agent)
    }

    fn peek_next(&mut self, _now: f64) -> Option<TaskInfo> {
        let agent = self.waiting.min_agent_by(|a| self.remaining.get(&a).copied().unwrap_or(0.0))?;
        self.waiting.peek_agent(agent).copied()
    }

    fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    fn on_service(&mut self, agent: AgentId, delta: f64) {
        if let Some(r) = self.remaining.get_mut(&agent) {
            *r = (*r - delta).max(0.0);
        }
    }

    fn on_cost_update(&mut self, agent: AgentId, _remaining: f64, total: f64, _now: f64) {
        // §4.2 correction, applied as a delta on the corrected *total*: the
        // local counter has already been decremented by on_service for
        // partially-served in-flight tasks, so replacing it wholesale with
        // the engine's completed-tasks-only remaining would re-add that
        // service and deprioritize nearly-done agents. Shifting by the
        // total-estimate change preserves the in-flight credit exactly.
        let (Some(r), Some(lt)) = (self.remaining.get_mut(&agent), self.last_total.get_mut(&agent))
        else {
            return;
        };
        *r = (*r + (total - *lt)).max(0.0);
        *lt = total;
    }

    fn on_agent_complete(&mut self, agent: AgentId, _now: f64) {
        self.remaining.remove(&agent);
        self.last_total.remove(&agent);
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        // Preempt the agent with the most remaining work first.
        self.remaining.get(&agent).copied().unwrap_or(f64::MAX)
    }

    fn remaining_cost(&self, agent: AgentId) -> Option<f64> {
        self.remaining.get(&agent).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn info(id: u32, cost: f64) -> AgentInfo {
        AgentInfo::new(id, 0.0, cost)
    }

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 10, predicted_decode: 5.0, seq }
    }

    #[test]
    fn smallest_remaining_first() {
        let mut s = Srjf::new();
        s.on_agent_arrival(&info(1, 100.0), 0.0);
        s.on_agent_arrival(&info(2, 50.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(2, 0, 1), 0.0);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 2);
    }

    #[test]
    fn service_updates_change_order() {
        let mut s = Srjf::new();
        s.on_agent_arrival(&info(1, 100.0), 0.0);
        s.on_agent_arrival(&info(2, 80.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(2, 0, 1), 0.0);
        // Deliver 50 units to agent 1: remaining 50 < 80.
        s.on_service(1, 50.0);
        assert!((s.remaining(1) - 50.0).abs() < 1e-12);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 1);
    }

    #[test]
    fn cost_update_shifts_remaining_by_total_delta() {
        let mut s = Srjf::new();
        s.on_agent_arrival(&info(1, 100.0), 0.0);
        // No service yet: correcting the total to 40 lands remaining at 40.
        s.on_cost_update(1, 12.0, 40.0, 0.0);
        assert!((s.remaining(1) - 40.0).abs() < 1e-12);
        // 30 units served, then total corrected 40 → 55: the in-flight
        // service credit survives (remaining = 55 − 30, not 55).
        s.on_service(1, 30.0);
        s.on_cost_update(1, 0.0, 55.0, 0.0);
        assert!((s.remaining(1) - 25.0).abs() < 1e-12);
        // Unknown agents are ignored (no resurrection after completion).
        s.on_agent_complete(1, 0.0);
        s.on_cost_update(1, 99.0, 99.0, 0.0);
        assert_eq!(s.remaining(1), 0.0);
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut s = Srjf::new();
        s.on_agent_arrival(&info(1, 10.0), 0.0);
        s.on_service(1, 50.0);
        assert_eq!(s.remaining(1), 0.0);
    }

    #[test]
    fn elephant_starves_under_mice_stream() {
        // The exact Fig. 9 failure mode at the queue level.
        let mut s = Srjf::new();
        s.on_agent_arrival(&info(0, 1_000_000.0), 0.0);
        s.push_task(task(0, 0, 0), 0.0);
        for i in 1..=50 {
            s.on_agent_arrival(&info(i, 100.0), i as f64);
            s.push_task(task(i, 0, i as u64), i as f64);
        }
        for _ in 0..50 {
            assert_ne!(s.pop_next(100.0).unwrap().id.agent, 0);
        }
        assert_eq!(s.pop_next(100.0).unwrap().id.agent, 0);
    }
}
