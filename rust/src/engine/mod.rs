//! The vLLM-like serving engine (substrate S1): continuous batching over a
//! paged KV cache with waiting / running / swapped queues and non-preemptive
//! inference execution (paper §4.3 footnote 3):
//!
//!   * a pending request never preempts a running inference;
//!   * when KV is exhausted mid-decode, running sequences are preempted —
//!     swapped out to a (possibly bounded) host tier or dropped for
//!     recompute, per [`PreemptionMode`], with the victim chosen by the
//!     configured [`VictimPolicy`] (DESIGN.md §11);
//!   * the swapped and recompute queues have priority over the waiting
//!     queue — no new admissions while anything is preempted.
//!
//! The engine is generic over an [`ExecBackend`]: the discrete-event
//! simulator backend (`exec::SimBackend`, calibrated latency model) and the
//! real PJRT transformer backend (`runtime::PjrtBackend`) run the *same*
//! engine/scheduler code — DESIGN.md substitution T1 hinges on this.

pub mod arena;
pub mod batch;
pub mod event;
pub mod exec;

use crate::config::{Config, Policy, PreemptionMode, VictimPolicy};
use crate::cost::CostModel;
use crate::kv::{BlockAllocator, KvError};
use crate::metrics::RunMetrics;
use crate::prefix::{PrefixCache, PrefixMatch};
use crate::sched::{AgentInfo, Scheduler, TaskInfo};
use crate::trace::{BatchDecision, IterSample, PickDecision, TraceEventKind, TraceRecorder, ENGINE_ROW};
use crate::workload::{AgentClass, AgentId, AgentSpec, InferenceSpec, PrefixGroup, Suite, TaskId};
use arena::Arena;
use batch::{BatchConfig, BatchObs, BatchPolicy};
use event::{EngineEvent, EventKind, EventQueue};
use exec::{ExecBackend, IterationBatch};
use std::collections::{BTreeMap, HashMap, VecDeque};

/// Runtime state of one admitted sequence.
#[derive(Debug, Clone)]
struct SeqState {
    id: TaskId,
    prompt: u32,
    target_decode: u32,
    generated: u32,
    /// Set while the sequence still needs prefill work; the iteration that
    /// completes the prefill also emits the first output token.
    needs_prefill: bool,
    /// Prompt tokens whose KV is computed so far: starts at `cached_tokens`
    /// and advances one chunk per iteration under chunked prefill (jumps
    /// straight to `prompt` after the single prefill iteration otherwise).
    prefilled: u32,
    /// Prompt tokens served from the prefix cache (prefill skipped).
    cached_tokens: u32,
    /// Prefix-tree nodes this sequence is attached to (admission match,
    /// extended to the full prompt chain after prefill). Empty when the
    /// cache is disabled or the sequence was swapped out.
    prefix_path: Vec<usize>,
    /// Length of the prompt portion that can participate in prefix caching,
    /// fixed at first admission. A recompute preemption folds generated
    /// tokens into `prompt`, so this cap (not the live `prompt`) bounds
    /// cache lookups/inserts — generated content never masquerades as the
    /// family prefix.
    shareable: u32,
    /// Service (in the scheduler's cost units) delivered to this sequence so
    /// far — the dedup-aware observed-cost basis the §4.2 correction loop
    /// reads at completion: exactly the deltas `on_service` saw, so shared
    /// prefix pages are charged once (fractionally per sharer) rather than
    /// re-derived at full Eq. 1 price from the spec.
    served: f64,
    /// Set by a recompute preemption: any later prefill is a *re-run* of
    /// work whose charge is already in `served` (or, for a mid-prefill
    /// victim, of work that never completed), so refill deltas still feed
    /// the scheduler's fairness counters but are excluded from the
    /// observed-cost accrual — a preempted agent must not look up to twice
    /// as expensive to the §4.2 correction loop under the compute-centric
    /// model (memory-centric prefill deltas are 0 either way).
    recompute_refill: bool,
    /// Whether this sequence already emitted its first output token (TTFT
    /// recorded). Survives preemption — a recompute re-entry's second
    /// prefill completion must not re-record TTFT, while a mid-prefill
    /// valve victim that never produced a token still gets one.
    first_token_done: bool,
    /// The owning agent's class, cached at admission: SLO deadline verdicts
    /// (TTFT / p99 ITL) are judged per token against the class targets
    /// (DESIGN.md §15) and an agent-map lookup per decoder per iteration
    /// would put a hash on the hot path. Survives swap and recompute.
    class: AgentClass,
}

/// Per-agent progress tracking: dependency-count release over the task DAG
/// (stage barriers are the special case where every task of level k+1 waits
/// on all of level k), dynamic spawning, and §4.2 online cost correction.
#[derive(Debug)]
struct AgentState {
    spec: AgentSpec,
    /// Tasks discovered at runtime via the spawn rule, keyed by task index.
    /// BTreeMap (not HashMap): recovery snapshots iterate it in index order
    /// (simlint R1 / DESIGN.md §16).
    spawned: BTreeMap<u32, InferenceSpec>,
    /// Unfinished-dependency count per *static* task (indexed by task
    /// index; spawned tasks depend only on their just-completed parent and
    /// are released immediately, so they never enter this table).
    dep_remaining: Vec<u32>,
    /// Static reverse adjacency: `dependents[i]` = indices waiting on `i`,
    /// ascending.
    dependents: Vec<Vec<u32>>,
    /// Tasks released but not yet completed + tasks not yet released.
    tasks_remaining: usize,
    /// Tasks known so far (static + spawned) — the correction denominator.
    known_tasks: u32,
    /// Tasks completed so far.
    completed_tasks: u32,
    /// Initial scheduler-facing prediction Ĉ_j.
    predicted_cost: f64,
    /// True cost of completed tasks under the engine's cost model
    /// (maintained only when online correction is on).
    observed_cost: f64,
    /// Ground-truth end-to-end cost including statically-expanded spawned
    /// work (correction-error metric; 0 when correction is off).
    true_total: f64,
}

impl AgentState {
    fn new(spec: AgentSpec, predicted_cost: f64, true_total: f64) -> Self {
        let n = spec.tasks.len();
        let mut dep_remaining = vec![0u32; n];
        let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); n];
        for t in &spec.tasks {
            dep_remaining[t.id.index as usize] = t.deps.len() as u32;
            for d in &t.deps {
                dependents[d.index as usize].push(t.id.index);
            }
        }
        AgentState {
            tasks_remaining: n,
            known_tasks: n as u32,
            completed_tasks: 0,
            predicted_cost,
            observed_cost: 0.0,
            true_total,
            spawned: BTreeMap::new(),
            dep_remaining,
            dependents,
            spec,
        }
    }

    /// The spec of a task by index, whether static or spawned.
    fn task_spec(&self, index: u32) -> &InferenceSpec {
        if (index as usize) < self.spec.tasks.len() {
            &self.spec.tasks[index as usize]
        } else {
            &self.spawned[&index]
        }
    }
}

/// One in-flight agent salvaged from a crashed replica
/// ([`Engine::extract_for_recovery`], DESIGN.md §14): its remaining task DAG
/// with generated tokens folded into prompts via the recompute path, ready
/// to re-submit through the live placement policy.
#[derive(Debug, Clone)]
pub struct RecoveredAgent {
    /// The remaining work as a fresh spec: surviving tasks densely
    /// re-indexed, deps filtered to survivors, in-flight sequences folded.
    pub spec: AgentSpec,
    /// The agent's originally recorded arrival time — the JCT anchor the
    /// churn driver re-stamps on the recovery replica.
    pub arrival: f64,
    /// Scheduler-facing prediction for the remaining work: the original
    /// prediction scaled by the cost-model ratio of remaining to original
    /// work, so the recovery replica's virtual-time tag lands where the
    /// agent's residual service would (pampering survives migration).
    pub predicted_cost: f64,
    /// Device+host KV tokens the crash destroyed for this agent.
    pub lost_tokens: u64,
}

/// The serving engine.
pub struct Engine<B: ExecBackend> {
    /// The paged KV-cache allocator (single source of truth for pages).
    pub kv: BlockAllocator,
    /// Radix-tree prefix cache (`Some` iff `cfg.prefix_cache`); with `None`
    /// every code path below reduces to the cache-free engine bit for bit.
    prefix: Option<PrefixCache>,
    backend: B,
    scheduler: Box<dyn Scheduler>,
    policy: Policy,
    cost_model: CostModel,
    max_batch: usize,
    /// Running sequences in admission order.
    running: Vec<SeqState>,
    /// Swapped-out sequences, FIFO (vLLM swaps back in order).
    swapped: VecDeque<SeqState>,
    /// Recompute-preempted sequences, FIFO: their KV was dropped and they
    /// re-enter as (chunked) prefills over prompt + already-generated
    /// tokens. Same strict priority over fresh admissions as `swapped` —
    /// a preempted sequence is not a new request (footnote 3).
    recompute: VecDeque<SeqState>,
    /// What to do with preemption victims (DESIGN.md §11).
    preemption: PreemptionMode,
    /// How preemption victims are ranked.
    victim_policy: VictimPolicy,
    /// Auto-mode price of moving one token host↔device one way (per-token
    /// swap cost + serialized transfer time), from the backend profile.
    auto_swap_unit: f64,
    /// Auto-mode price of re-prefilling one token (`beta_prefill`).
    auto_refill_unit: f64,
    /// Derive per-task scheduler tags from the agent-level prediction Ĉ_j
    /// (`cfg.use_predictor`) instead of echoing the oracle decode length.
    use_predictor_tags: bool,
    agents: HashMap<AgentId, AgentState>,
    clock: f64,
    seq_counter: u64,
    /// Metrics collected over this run.
    pub metrics: RunMetrics,
    /// Record KV occupancy samples (Fig. 3) — off by default (hot path).
    pub record_occupancy: bool,
    /// Admission memo (§Perf): set when the last admission attempt ended
    /// blocked (head task didn't fit / queue empty / batch full). Free KV
    /// only shrinks between unblocking events (completion, swap-out, new
    /// task), so re-scanning the scheduler every decode iteration is wasted
    /// work — the dominant cost for the O(A)-scan policies (VTC, SRJF).
    admission_blocked: bool,
    /// §4.2 online misprediction correction (`cfg.online_correction`): on
    /// every task completion, blend observed cost into the agent's remaining
    /// estimate and re-derive the scheduler's tags. Off ⇒ bit-identical to
    /// an engine without the loop.
    online_correction: bool,
    /// Resolved per-iteration batching knobs (DESIGN.md §10/§15): chunk
    /// size and token budget (`u32::MAX` sentinels when `chunked_prefill`
    /// is off — the classical atomic-admission engine bit for bit) plus the
    /// batch-policy selection, consolidated from the legacy tri-state
    /// config surface at construction.
    batch: BatchConfig,
    /// The batch-formation policy sizing each iteration's prefill share
    /// (DESIGN.md §15). Consulted only in chunk mode; the default
    /// [`batch::StaticBudget`] returns the unbounded plan, reducing
    /// composition to the pre-policy arithmetic bit for bit
    /// (`prop_batch_policy_identity`).
    batch_policy: Box<dyn BatchPolicy>,
    /// Cached `batch_policy.wants_feedback()`: lets step-5 bookkeeping skip
    /// all SLO-feedback work for open-loop policies with one branch.
    batch_feedback: bool,
    /// Event/calendar-queue core (`cfg.event_core`, DESIGN.md §12): suites
    /// run off a deterministic event calendar, batch composition becomes
    /// incremental between events, and the scheduler receives
    /// [`EngineEvent`] hooks. Off ⇒ the legacy tick loop, untouched — the
    /// differential-test oracle.
    event_core: bool,
    /// Incremental-composition dirty bit: set whenever the running set's
    /// membership (admission, swap, preemption, completion) or a prefill
    /// transition invalidates [`decode_cache`](Self::decode_cache).
    batch_dirty: bool,
    /// The cached all-decoder batch, valid iff `!batch_dirty`: outside
    /// chunk mode, composition is a pure function of running-set
    /// membership, so between mutating events it need not be recomputed.
    decode_cache: Vec<TaskId>,
    /// Observability layer (`Some` iff `cfg.trace`, DESIGN.md §13): flight
    /// recorder + per-iteration sampler + scheduler decision audit log.
    /// `None` means no emit site runs — the off path is bit-identical to a
    /// build without the subsystem. Every emit site lives in code shared by
    /// both engine cores, stamped with the engine clock, so tick and event
    /// cores produce identical streams by construction
    /// (`prop_trace_identity`).
    trace: Option<TraceRecorder>,
}

impl<B: ExecBackend> Engine<B> {
    /// Engine from a config, a policy scheduler, and an execution backend.
    pub fn new(cfg: &Config, scheduler: Box<dyn Scheduler>, backend: B) -> Self {
        let mut kv = BlockAllocator::new(cfg.backend.kv_pages() as u32, cfg.backend.page_size);
        if let Some(host) = cfg.backend.host_kv_tokens {
            kv.set_host_capacity(host);
        }
        // With the prefix cache on, memory-centric service accounting
        // switches to the dedup-aware variant (shared pages charged
        // fractionally across sharers — see step 5 of `step()`).
        let base_model = crate::sched::cost_model_for(scheduler.policy());
        let cost_model = if cfg.prefix_cache && base_model == CostModel::MemoryCentric {
            CostModel::SharedMemoryCentric
        } else {
            base_model
        };
        let batch = BatchConfig::resolve(cfg);
        let batch_policy = batch::build(&batch);
        let batch_feedback = batch_policy.wants_feedback();
        Engine {
            kv,
            prefix: cfg.prefix_cache.then(|| PrefixCache::new(cfg.backend.page_size)),
            backend,
            policy: scheduler.policy(),
            cost_model,
            scheduler,
            max_batch: cfg.max_batch,
            running: Vec::new(),
            swapped: VecDeque::new(),
            recompute: VecDeque::new(),
            preemption: cfg.preemption,
            victim_policy: cfg.victim,
            auto_swap_unit: cfg.backend.swap_cost_per_token
                + if cfg.backend.swap_bw_tokens_per_sec > 0.0 {
                    1.0 / cfg.backend.swap_bw_tokens_per_sec
                } else {
                    0.0
                },
            auto_refill_unit: cfg.backend.beta_prefill,
            use_predictor_tags: cfg.use_predictor,
            agents: HashMap::new(),
            clock: 0.0,
            seq_counter: 0,
            metrics: RunMetrics::new(),
            record_occupancy: false,
            admission_blocked: false,
            // Observed-cost accounting accrues the very service deltas the
            // schedulers see (SeqState::served), so it is dedup-aware by
            // construction: with the prefix cache on, shared pages are
            // charged fractionally per sharer — the same basis as the
            // suite-deduplicated predictions. Correction therefore composes
            // with the cache (the historical gate is gone).
            online_correction: cfg.online_correction,
            batch,
            batch_policy,
            batch_feedback,
            event_core: cfg.event_core,
            batch_dirty: true,
            decode_cache: Vec::new(),
            trace: cfg.trace.then(|| TraceRecorder::new(cfg.trace_cap, cfg.trace_sample)),
        }
    }

    /// The active scheduling policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Current engine clock (s).
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Submit an agent at the current engine time. `predicted_cost` is the
    /// scheduler-facing cost (ground truth, noisy oracle, or MLP output).
    pub fn submit(&mut self, spec: AgentSpec, predicted_cost: f64) {
        let id = spec.id;
        let arrival = self.clock;
        // Pure spec bookkeeping happens OUTSIDE the timed window below: the
        // Fig. 12 metric measures scheduling-decision latency, not metric
        // preparation. `true_total` (ground-truth end-to-end cost incl.
        // deterministically-expanded spawned work) feeds only the
        // correction-error metric.
        let critical_path = crate::cost::critical_path_cost(self.cost_model, &spec);
        let true_total = if self.online_correction {
            crate::cost::expanded_agent_cost(self.cost_model, &spec)
        } else {
            0.0
        };
        // simlint::allow(ambient-nondet): observation-only overhead clock (Fig. 12); never read back into sim state
        let t0 = std::time::Instant::now();
        self.scheduler.on_agent_arrival(
            &AgentInfo { id, arrival, cost: predicted_cost, critical_path },
            self.clock,
        );
        let state = AgentState::new(spec, predicted_cost, true_total);
        // Release every root task (dependency count zero) in index order.
        // For staged agents these are exactly the stage-0 tasks. The agent
        // state is registered first so `push_task` can derive per-task tags
        // from the agent-level prediction (predictor mode).
        let roots: Vec<(TaskId, u32, u32)> = state
            .spec
            .tasks
            .iter()
            .filter(|t| t.deps.is_empty())
            .map(|t| (t.id, t.prompt_tokens, t.decode_tokens))
            .collect();
        self.agents.insert(id, state);
        for (tid, p, d) in roots {
            self.push_task(tid, p, d);
        }
        self.metrics.on_agent_arrival(id, arrival);
        self.metrics.record_sched_decision(t0.elapsed());
        if let Some(tr) = self.trace.as_mut() {
            tr.push(arrival, id, None, TraceEventKind::Arrival);
        }
        if state_is_empty(&self.agents, id) {
            // Degenerate agent with zero tasks: completes instantly.
            self.complete_agent(id);
        }
    }

    fn push_task(&mut self, id: TaskId, prompt: u32, decode: u32) {
        self.admission_blocked = false;
        self.seq_counter += 1;
        // TTFT anchor: the task became ready now (dependencies met / just
        // spawned), so queueing delay counts toward its first token.
        self.metrics.on_task_ready(id, self.clock);
        // Per-inference tag the scheduler ranks by (inference-level SJF).
        // Oracle mode echoes the true decode length; predictor mode derives
        // the task's share of the trained model's agent-level prediction
        // Ĉ_j — without this, `--predict` runs silently fed the scheduler
        // ground truth at the task level (the ISSUE 5 predictor bugfix).
        let predicted_decode = if self.use_predictor_tags {
            let a = &self.agents[&id.agent];
            a.predicted_cost / a.known_tasks.max(1) as f64
        } else {
            decode as f64
        };
        self.scheduler.push_task(
            TaskInfo { id, prompt_tokens: prompt, predicted_decode, seq: self.seq_counter },
            self.clock,
        );
    }

    /// Whether any work remains (waiting, swapped, recompute-pending, or
    /// running).
    pub fn has_work(&self) -> bool {
        !self.running.is_empty()
            || !self.swapped.is_empty()
            || !self.recompute.is_empty()
            || self.scheduler.waiting_len() > 0
    }

    /// Advance the clock directly (used when idle between arrivals).
    pub fn advance_clock(&mut self, to: f64) {
        debug_assert!(to + 1e-9 >= self.clock);
        self.clock = self.clock.max(to);
    }

    /// One engine iteration: admission, then a model step, then bookkeeping.
    /// Returns the iteration's wall time in engine seconds.
    pub fn step(&mut self) -> f64 {
        // simlint::allow(ambient-nondet): observation-only overhead clock (Fig. 12); never read back into sim state
        let t0 = std::time::Instant::now();
        let mut swap_in_tokens = 0u32;
        let mut swap_out_tokens = 0u32;

        // 1. Swap-in has strict priority over fresh admissions (footnote 3).
        while let Some(seq) = self.swapped.front() {
            if self.running.len() >= self.max_batch {
                break;
            }
            let id = seq.id;
            if !self.kv.can_swap_in(id) {
                // Memory pressure: reclaim unpinned prefix-cache pages first
                // (only when that can actually cover the shortfall — partial
                // flushes buy nothing while admissions are swap-gated).
                let need = self.kv.pages_for(self.kv.seq_tokens(id).unwrap_or(0)) + 1;
                self.evict_cache_for(need);
                if !self.kv.can_swap_in(id) {
                    break;
                }
            }
            let seq = self.swapped.pop_front().unwrap();
            swap_in_tokens += self.kv.swap_in(seq.id).expect("can_swap_in checked");
            self.backend.on_swap_in(seq.id, self.kv.block_table(seq.id).unwrap());
            self.running.push(seq);
            self.batch_dirty = true;
            if let Some(tr) = self.trace.as_mut() {
                tr.push(self.clock, id.agent, Some(id.index), TraceEventKind::SwapIn);
            }
            if self.event_core {
                self.scheduler.on_event(&EngineEvent::SwapDone { task: id }, self.clock);
            }
        }

        // 1b. Recompute re-entry, once the swap queue has drained: dropped
        //     victims re-enter as (chunked) prefills over their folded
        //     prompt — cached prefix + first chunk + decode headroom, like
        //     any admission — keeping strict priority over fresh work. The
        //     blocked-admission memo applies here too (§Perf memo audit):
        //     a failed re-entry repeats its radix-tree lookup + pin/detach
        //     only after an event that grew the free pool, not every
        //     iteration of a long decode phase.
        if self.swapped.is_empty() && !self.admission_blocked {
            while self.running.len() < self.max_batch {
                let Some(front) = self.recompute.front() else { break };
                let (id, prompt, cap) = (front.id, front.prompt, front.shareable);
                match self.try_admit_kv(id, prompt, cap) {
                    Some((cached, path, _)) => {
                        let mut seq = self.recompute.pop_front().unwrap();
                        seq.prefilled = cached;
                        seq.cached_tokens = cached;
                        seq.prefix_path = path;
                        self.running.push(seq);
                        self.batch_dirty = true;
                        if let Some(tr) = self.trace.as_mut() {
                            tr.push(
                                self.clock,
                                id.agent,
                                Some(id.index),
                                TraceEventKind::RecomputeReady,
                            );
                        }
                        if self.event_core {
                            self.scheduler
                                .on_event(&EngineEvent::RecomputeReady { task: id }, self.clock);
                        }
                    }
                    None => {
                        if let Some(tr) = self.trace.as_mut() {
                            tr.push(self.clock, id.agent, Some(id.index), TraceEventKind::Blocked);
                        }
                        self.admission_blocked = true;
                        break;
                    }
                }
            }
        }

        // 2. Fresh admissions only if nothing is preempted (swapped or
        //    recompute-pending). Under chunked prefill a sequence is
        //    admitted on its *first chunk's* pages (cached prefix + one
        //    chunk + decode headroom) instead of the whole prompt; later
        //    chunks acquire pages incrementally in step 4. With chunking
        //    off `admit_tokens == prompt_tokens` and this is the classical
        //    atomic admission, call for call.
        if self.swapped.is_empty() && self.recompute.is_empty() && !self.admission_blocked {
            while self.running.len() < self.max_batch {
                let Some(next) = self.scheduler.peek_next(self.clock) else {
                    self.admission_blocked = true;
                    break;
                };
                let Some((cached_tokens, prefix_path, shareable)) =
                    self.try_admit_kv(next.id, next.prompt_tokens, u32::MAX)
                else {
                    if let Some(tr) = self.trace.as_mut() {
                        tr.push(
                            self.clock,
                            next.id.agent,
                            Some(next.id.index),
                            TraceEventKind::Blocked,
                        );
                    }
                    self.admission_blocked = true;
                    break;
                };
                if self.trace.is_some() {
                    // Audit the pick BEFORE pop_next, while the policy's
                    // queues are intact (Justitia's heap still holds the
                    // runner-up). explain_pick may mutate only lazily-
                    // skimmable state, so the untraced run is unaffected.
                    let expl =
                        self.scheduler.explain_pick(&next, self.clock).unwrap_or_default();
                    self.trace.as_mut().unwrap().push_pick(PickDecision {
                        t: self.clock,
                        agent: next.id.agent,
                        task_index: next.id.index,
                        winner_tag: expl.winner_tag,
                        runner_up: expl.runner_up,
                        runner_up_tag: expl.runner_up_tag,
                        pampered: expl.pampered,
                    });
                }
                let task = self.scheduler.pop_next(self.clock).unwrap();
                let spec_decode = self.task_decode(task.id);
                let class = self.agents[&task.id.agent].spec.class;
                self.running.push(SeqState {
                    id: task.id,
                    prompt: task.prompt_tokens,
                    target_decode: spec_decode,
                    generated: 0,
                    needs_prefill: true,
                    prefilled: cached_tokens,
                    cached_tokens,
                    prefix_path,
                    shareable,
                    served: 0.0,
                    recompute_refill: false,
                    first_token_done: false,
                    class,
                });
                self.batch_dirty = true;
                self.metrics.on_task_admitted(task.id, self.clock);
                if let Some(tr) = self.trace.as_mut() {
                    tr.push(
                        self.clock,
                        task.id.agent,
                        Some(task.id.index),
                        TraceEventKind::Admitted,
                    );
                }
                if self.event_core {
                    self.scheduler.on_event(&EngineEvent::Admission { task: task.id }, self.clock);
                }
            }
            if self.running.len() >= self.max_batch {
                self.admission_blocked = true;
            }
        }
        self.metrics.record_sched_decision(t0.elapsed());

        if self.running.is_empty() {
            // Nothing admitted and nothing running: zero-length iteration.
            return 0.0;
        }

        // 3. Ensure every decoding sequence can append one token; swap out
        //    victims otherwise (non-preemptive w.r.t. waiting queue, but
        //    running sequences yield to each other under memory pressure).
        let mut i = 0;
        while i < self.running.len() {
            let id = self.running[i].id;
            let needs_append = !self.running[i].needs_prefill;
            if needs_append && !self.kv.can_append(id) {
                // Cheapest reclaim first: drop unpinned prefix-cache pages
                // before preempting a running sequence (skip when nothing
                // reclaimable would actually free a page).
                if self.prefix.is_some() {
                    self.evict_cache_for(1);
                    if self.kv.can_append(id) {
                        i += 1;
                        continue;
                    }
                }
                match self.pick_victim(i) {
                    Some(v) => {
                        swap_out_tokens += self.preempt_running(v);
                        if v < i {
                            i -= 1; // indices shifted
                        }
                        continue; // re-check seq i
                    }
                    None => break, // only this seq left; it must wait
                }
            }
            i += 1;
        }

        if swap_out_tokens > 0 || swap_in_tokens > 0 {
            // Page/slot occupancy changed; re-evaluate admission next step.
            self.admission_blocked = false;
        }

        // 4. Compose the iteration under the token budget (DESIGN.md §10):
        //    every decoder contributes one token, then prefill-pending
        //    sequences claim chunks from the remaining budget in admission
        //    order, acquiring each chunk's KV pages on the spot. Cached-
        //    prefix tokens are excluded from the prefill work (their KV
        //    already exists). With chunking off the budget is unbounded and
        //    every pending prefill runs its whole uncached remainder —
        //    exactly the atomic-admission batch. `plan[i]` holds running
        //    sequence i's prefill tokens this iteration (`None` = decoder,
        //    or a pending prefill stalled by the budget / page shortage).
        let mut plan: Vec<Option<u32>> = Vec::new();
        let mut prefill: Vec<(TaskId, u32)> = Vec::new();
        let mut decode: Vec<TaskId>;
        let mut stalls: u64 = 0;
        // Real chunking in effect (not the flag-off / degenerate path whose
        // bit-identity to the atomic engine is guaranteed).
        let chunk_mode = self.batch.chunk_mode();
        // Incremental composition (event core, DESIGN.md §12): outside chunk
        // mode the batch is a pure function of running-set membership, so
        // when no admission, swap, preemption, completion, or prefill
        // transition has fired since the last iteration, the cached
        // all-decoder list IS the batch — no per-sequence re-examination.
        let cached_batch = self.event_core && !chunk_mode && !self.batch_dirty;
        if cached_batch {
            decode = std::mem::take(&mut self.decode_cache);
            debug_assert_eq!(
                decode,
                self.running.iter().map(|s| s.id).collect::<Vec<_>>(),
                "decode cache out of sync with the running set"
            );
        } else {
            loop {
                plan = vec![None; self.running.len()];
                prefill = Vec::new();
                decode = Vec::new();
                stalls = 0;
                let mut budget = self.batch.budget;
                for s in &self.running {
                    if !s.needs_prefill {
                        decode.push(s.id);
                        budget = budget.saturating_sub(1);
                    }
                }
                // Batch-policy consultation (DESIGN.md §15, chunk mode
                // only): the policy sizes this iteration's prefill share;
                // the fair queue already decided *which* sequences hold the
                // prefill cursors. The default StaticBudget returns the
                // unbounded plan, making every `min`/`saturating_sub` below
                // an arithmetic identity — the pre-policy composition bit
                // for bit (`prop_batch_policy_identity`).
                let mut prefill_budget = u32::MAX;
                let mut prefill_slots = u32::MAX;
                if chunk_mode {
                    let obs = BatchObs {
                        total_budget: self.batch.budget,
                        budget,
                        decoders: decode.len() as u32,
                        prefills_pending: (self.running.len() - decode.len()) as u32,
                        waiting: self.scheduler.waiting_len() as u64,
                        kv_free_pages: self.kv.free_pages() as u64,
                    };
                    let bplan = self.batch_policy.plan(&obs);
                    prefill_budget = bplan.prefill_tokens;
                    prefill_slots = bplan.prefill_seqs;
                    if decode.is_empty() {
                        // No decode headroom to protect: a reservation (or a
                        // shrunken share) must not push an all-prefill batch
                        // into the starvation valve below.
                        prefill_budget = u32::MAX;
                        prefill_slots = u32::MAX;
                    }
                    if self.trace.is_some() {
                        // Adjustments join the pick audit (drained here, in
                        // shared-core code, so both cores emit identically;
                        // the drain never feeds back into `plan`).
                        if let Some(a) = self.batch_policy.audit() {
                            self.trace.as_mut().unwrap().push_batch(BatchDecision {
                                t: self.clock,
                                policy: self.batch_policy.name(),
                                prefill_share: a.prefill_share,
                                prefill_tokens: a.prefill_tokens,
                                itl_p99_ms: a.itl_p99_ms,
                                grew: a.grew,
                            });
                        }
                    }
                }
                for i in 0..self.running.len() {
                    let (id, prefilled, remaining) = {
                        let s = &self.running[i];
                        if !s.needs_prefill {
                            continue;
                        }
                        (s.id, s.prefilled, s.prompt - s.prefilled)
                    };
                    if prefill_slots == 0 {
                        stalls += 1; // policy's sequence allowance exhausted
                        continue;
                    }
                    let mut take = remaining.min(self.batch.chunk).min(budget).min(prefill_budget);
                    if take == 0 && remaining > 0 {
                        stalls += 1; // budget spent before this sequence's turn
                        continue;
                    }
                    // Pages already acquired but not yet filled (the admission
                    // chunk, or a prior iteration's budget shortfall).
                    let covered = self.kv.seq_tokens(id).expect("running seq allocated") - prefilled;
                    if take > covered && self.try_extend(id, take - covered).is_err() {
                        // No page even after cache eviction: prefill only what
                        // is already covered, possibly nothing, this iteration.
                        take = covered;
                        if take == 0 {
                            stalls += 1;
                            continue;
                        }
                    }
                    if chunk_mode && take == remaining && !self.kv.can_append(id) {
                        // The iteration completing this prefill also appends the
                        // first output token, but try_extend reclaimed only the
                        // chunk's own pages. Give the append the same cheapest-
                        // reclaim chance the decode path gets, or a lone runner
                        // could hit the capacity panic in step 5 while
                        // reclaimable cache pages still exist.
                        self.evict_cache_for(1);
                    }
                    plan[i] = Some(take);
                    prefill.push((id, take));
                    budget = budget.saturating_sub(take);
                    prefill_budget = prefill_budget.saturating_sub(take);
                    prefill_slots = prefill_slots.saturating_sub(1);
                }
                if !prefill.is_empty() || !decode.is_empty() {
                    break;
                }
                // Chunked-prefill starvation valve: every runner is a
                // mid-prefill sequence that could not acquire a single page.
                // Preempt one (under the configured victim policy — the
                // youngest by default) so the others can progress next round
                // (no waiting task is touched, so the non-preemptive rule
                // holds). Unreachable with chunking off: whole prompts are
                // page-backed at admission.
                if self.running.len() == 1 {
                    panic!(
                        "sequence {} needs more KV than the whole pool ({} tokens): \
                         workload exceeds capacity",
                        self.running[0].id,
                        self.kv.capacity_tokens()
                    );
                }
                swap_out_tokens += self.preempt_running(self.pick_valve_victim());
                self.admission_blocked = false;
            }
            // Composition re-examined every running sequence: the cached-
            // batch state is clean until the next membership or prefill
            // mutation re-dirties it.
            self.batch_dirty = false;
        }
        if stalls > 0 {
            self.metrics.on_prefill_stalls(stalls);
        }
        let result = self.backend.run_iteration(&IterationBatch {
            prefill: &prefill,
            decode: &decode,
            swap_out_tokens,
            swap_in_tokens,
            kv: &self.kv,
        });
        self.clock += result.elapsed;
        let prefill_tokens: u64 = prefill.iter().map(|(_, p)| *p as u64).sum();
        self.metrics.on_iteration(
            self.clock,
            result.elapsed,
            prefill.len(),
            decode.len(),
            prefill_tokens,
        );
        if self.trace.is_some() {
            self.trace_iteration(&prefill, &decode, prefill_tokens);
        }
        if self.event_core {
            // Endogenous events fire at the iteration boundary, stamped with
            // the post-iteration clock (DESIGN.md §12): each chunk that ran,
            // then the batch-retirement summary.
            for &(task, tokens) in &prefill {
                self.scheduler.on_event(&EngineEvent::ChunkComplete { task, tokens }, self.clock);
            }
            self.scheduler.on_event(
                &EngineEvent::DecodeBatchComplete {
                    decoders: decode.len(),
                    prefills: prefill.len(),
                },
                self.clock,
            );
        }

        // 5. Token bookkeeping: sequences whose prefill completed become
        //    decoders (that iteration also emits their first token);
        //    mid-prefill sequences only advance their cursor; decoders gain
        //    one token (KV already reserved above); completions retire.
        let mut completed: Vec<TaskId> = Vec::new();
        let mut service: Vec<(AgentId, f64)> = Vec::new();
        let mut stalled = 0usize;
        let page_size = self.kv.page_size();
        // Every decoder experienced this iteration's wall time as its
        // inter-token gap; judged below against each class's p99-ITL budget
        // and fed (aggregated) to a closed-loop batch policy.
        let itl_ms = result.elapsed * 1e3;
        let mut fb_decoders = 0u32;
        let mut fb_min_slo_ms = f64::INFINITY;
        for (i, s) in self.running.iter_mut().enumerate() {
            if s.needs_prefill {
                // Stalled sequences ran no chunk: no progress, no service.
                // (`plan` is empty on the cached-batch path, which carries
                // no prefills — `.get` keeps the lookup total.)
                let Some(take) = plan.get(i).copied().flatten() else { continue };
                // VTC-style service accounting for the prompt tokens
                // actually prefilled this iteration; cached-prefix tokens
                // consumed no service (cache off ⇒ cached_tokens = 0), and
                // chunked prefill charges chunk by chunk — the per-sequence
                // total is exactly the unchunked charge.
                let delta = serve_delta_prefill(self.cost_model, take);
                if !s.recompute_refill {
                    s.served += delta;
                }
                service.push((s.id.agent, delta));
                s.prefilled += take;
                if s.prefilled < s.prompt {
                    continue; // mid-prefill: no output token yet
                }
                s.needs_prefill = false;
                // The iteration finishing the prefill also emits the first
                // token.
                if !s.first_token_done {
                    s.first_token_done = true;
                    if let Some(ttft) = self.metrics.on_first_token(s.id, self.clock) {
                        let slo_ms = s.class.ttft_slo_ms();
                        let ttft_ms = ttft * 1e3;
                        self.metrics.on_ttft_deadline(s.class, ttft_ms > slo_ms);
                        if self.batch_feedback {
                            self.batch_policy.on_first_token(ttft_ms, slo_ms);
                        }
                    }
                    if let Some(tr) = self.trace.as_mut() {
                        tr.push(
                            self.clock,
                            s.id.agent,
                            Some(s.id.index),
                            TraceEventKind::FirstToken,
                        );
                    }
                }
                if let Some(cache) = self.prefix.as_mut() {
                    // Register the freshly-built *shareable* chain (full
                    // pages of the family prefix only — unique suffixes
                    // would bloat the tree with unmatchable nodes) so later
                    // arrivals can share it; same-iteration siblings adopt
                    // each other's pages here. The cap fixed at first
                    // admission bounds the chain — a recompute re-entry's
                    // folded prompt must not publish generated tokens as
                    // family content.
                    let group = prefix_group_in(&self.agents, s.id);
                    let shareable = s.shareable;
                    if shareable >= page_size {
                        let ids = crate::prefix::prompt_token_ids(s.id, shareable, group);
                        let free_before = self.kv.free_pages();
                        s.prefix_path =
                            cache.insert_and_attach(s.id, &ids, &mut self.kv, &s.prefix_path);
                        if self.kv.free_pages() > free_before {
                            // Adoption deduplicated sibling pages: free KV
                            // grew, so the admission memo may be stale.
                            self.admission_blocked = false;
                        }
                    }
                }
            }
            match self.kv.append_token(s.id) {
                Ok(()) => {
                    s.generated += 1;
                    // ITL deadline verdict for sequences that entered this
                    // iteration as decoders (`plan[i]` is `None`; a prefill
                    // completer's first token is TTFT, not ITL — and the
                    // cached-batch fast path carries only decoders).
                    if plan.get(i).copied().flatten().is_none() {
                        let slo_ms = s.class.itl_p99_slo_ms();
                        self.metrics.on_itl_deadlines(s.class, 1, (itl_ms > slo_ms) as u64);
                        if self.batch_feedback {
                            fb_decoders += 1;
                            if slo_ms < fb_min_slo_ms {
                                fb_min_slo_ms = slo_ms;
                            }
                        }
                    }
                    // With the cache on, memory-centric service is the
                    // sequence's *physical* occupancy: private tokens in
                    // full, each shared page split across its sharers
                    // (SharedMemoryCentric accounting identity).
                    let delta = match (&self.prefix, self.cost_model) {
                        (
                            Some(cache),
                            CostModel::MemoryCentric | CostModel::SharedMemoryCentric,
                        ) => {
                            (s.prompt + s.generated) as f64
                                - (s.prefix_path.len() as u32 * page_size) as f64
                                + cache.shared_charge(&s.prefix_path)
                        }
                        _ => serve_delta_decode(self.cost_model, s.prompt, s.generated),
                    };
                    s.served += delta;
                    service.push((s.id.agent, delta));
                    if s.generated >= s.target_decode {
                        completed.push(s.id);
                    }
                }
                Err(KvError::OutOfPages { .. }) => {
                    // Could not reserve even after victim search: stall this
                    // iteration (legal while other sequences drain). A single
                    // running sequence holding the whole pool can never
                    // progress — that workload exceeds KV capacity.
                    stalled += 1;
                }
                Err(e) => panic!("append failed: {e}"),
            }
        }
        if stalled > 0 && self.running.len() == 1 {
            panic!(
                "sequence {} needs more KV than the whole pool ({} tokens): \
                 workload exceeds capacity",
                self.running[0].id,
                self.kv.capacity_tokens()
            );
        }
        if self.batch_feedback && fb_decoders > 0 {
            // One aggregated sample per iteration (not per decoder): the
            // controller windows iterations, and the tightest SLO among the
            // decoders that actually appended is the breach threshold.
            self.batch_policy.on_iteration(itl_ms, fb_min_slo_ms, fb_decoders);
        }
        for (agent, delta) in service {
            self.scheduler.on_service(agent, delta);
        }
        for id in completed {
            self.finish_seq(id);
        }
        if self.record_occupancy {
            self.metrics.sample_kv(self.clock, self.kv.device_tokens(), per_agent_tokens(&self.running, &self.kv));
        }
        if let Some(cache) = self.prefix.as_ref() {
            self.metrics.on_cache_occupancy(cache.cached_pages() as u64);
        }
        if self.event_core {
            if !chunk_mode && !self.batch_dirty && prefill.is_empty() {
                // The batch that just ran was the pure all-decoder membership
                // list and nothing mutated the running set during bookkeeping
                // (no completion, no prefill transition): it IS the next
                // iteration's batch.
                self.decode_cache = decode;
            } else {
                self.decode_cache.clear();
                self.batch_dirty = true;
            }
        }
        result.elapsed
    }

    fn task_decode(&self, id: TaskId) -> u32 {
        self.agents[&id.agent].task_spec(id.index).decode_tokens
    }

    /// Trace bookkeeping for the iteration that just ran (called only when
    /// tracing is on, right after `metrics.on_iteration`, from code shared
    /// by both engine cores): per-sequence prefill-chunk events always, and
    /// on every `sample_stride`-th iteration the engine-row decode-batch
    /// event plus one [`IterSample`]. Every value read here is identical
    /// across cores at this point, and the sampler's virtual-clock probe is
    /// exact piecewise-linear integration — extra `vt(now)` calls never
    /// perturb later tags, so metrics are unchanged with tracing on.
    fn trace_iteration(
        &mut self,
        prefill: &[(TaskId, u32)],
        decode: &[TaskId],
        prefill_tokens: u64,
    ) {
        for &(id, tokens) in prefill {
            self.trace.as_mut().unwrap().push(
                self.clock,
                id.agent,
                Some(id.index),
                TraceEventKind::PrefillChunk { tokens },
            );
        }
        if !self.trace.as_mut().unwrap().tick_iteration() {
            return;
        }
        let batch_tokens = prefill_tokens + decode.len() as u64;
        let token_budget_util = if self.batch.budget == u32::MAX {
            0.0 // chunking off: the budget is unbounded, utilization undefined
        } else {
            batch_tokens as f64 / self.batch.budget as f64
        };
        // Virtual-time lag per active agent, sorted by id: HashMap iteration
        // order is nondeterministic and must not leak into the artifact.
        let mut vt_lags: Vec<(AgentId, f64)> = Vec::new();
        let mut max_gap = 0.0f64;
        if let Some(v) = self.scheduler.virtual_time(self.clock) {
            let mut ids: Vec<AgentId> = self
                .agents
                .iter() // simlint::allow(unordered-iter): ids collected then sorted ascending below
                .filter(|(_, a)| a.tasks_remaining > 0)
                .map(|(&id, _)| id)
                .collect();
            ids.sort_unstable();
            for id in ids {
                if let Some(f) = self.scheduler.virtual_finish_tag(id) {
                    let lag = v - f;
                    max_gap = max_gap.max(lag);
                    vt_lags.push((id, lag));
                }
            }
        }
        let sample = IterSample {
            t: self.clock,
            iteration: self.metrics.iterations(),
            batch_seqs: (prefill.len() + decode.len()) as u32,
            batch_tokens,
            token_budget_util,
            kv_free_pages: self.kv.free_pages() as u64,
            kv_swapped_tokens: self.kv.swapped_tokens(),
            kv_host_free_tokens: if self.kv.host_capacity_tokens() == u64::MAX {
                u64::MAX // unbounded pool: "free" is meaningless, mark it
            } else {
                self.kv.host_free_tokens()
            },
            waiting: self.scheduler.waiting_len() as u64,
            running: self.running.len() as u64,
            swapped_q: self.swapped.len() as u64,
            recompute_q: self.recompute.len() as u64,
            vt_lags,
            max_service_gap: max_gap,
        };
        let tr = self.trace.as_mut().unwrap();
        tr.push(
            self.clock,
            ENGINE_ROW,
            None,
            TraceEventKind::DecodeBatch { seqs: decode.len() as u32 },
        );
        tr.push_sample(sample);
    }

    /// Try to allocate KV (and pin any cached prefix) for a sequence about
    /// to (re-)enter the running set: radix-tree lookup + chain pin,
    /// LRU eviction when that can cover the shortfall, then
    /// `share_prefix`/`allocate` over the admission tokens (cached prefix +
    /// first chunk + decode headroom). On failure every pin taken here is
    /// dropped and `None` returned. `shareable_cap` clamps the prompt
    /// portion eligible for caching — `u32::MAX` for fresh admissions,
    /// the first-admission cap for recompute re-entries (whose prompt has
    /// absorbed generated tokens that must never match the family stream).
    /// Returns `(cached_tokens, prefix_path, shareable)`.
    fn try_admit_kv(
        &mut self,
        id: TaskId,
        prompt_tokens: u32,
        shareable_cap: u32,
    ) -> Option<(u32, Vec<usize>, u32)> {
        // Prefix-cache path: match the prompt against the radix tree, pin
        // the matched chain, and — if the uncached remainder doesn't fit —
        // evict unpinned LRU nodes before giving up.
        let mut shareable = 0u32;
        let mut lookup: Option<PrefixMatch> = None;
        if let Some(cache) = self.prefix.as_mut() {
            // Only the task's *shareable* prefix participates in caching;
            // unique suffixes could never match anyone.
            let group = prefix_group_in(&self.agents, id);
            shareable = shareable_tokens(group, prompt_tokens).min(shareable_cap);
            let ids = crate::prefix::prompt_token_ids(id, shareable, group);
            let m = cache.lookup(&ids);
            cache.attach(&m.path); // pin before any eviction
            lookup = Some(m);
        }
        match lookup {
            Some(m) => {
                let admit_tokens = admission_tokens(prompt_tokens, m.tokens, self.batch.chunk);
                // Only spend cached chains when eviction can actually make
                // this admission fit; an infeasible request must not flush
                // other families' prefixes.
                let need = self.kv.fresh_pages_needed(admit_tokens, m.pages.len() as u32);
                self.evict_cache_for(need);
                if !self.kv.can_admit_with_prefix(admit_tokens, m.pages.len() as u32) {
                    if let Some(cache) = self.prefix.as_mut() {
                        cache.detach(&m.path);
                    }
                    return None;
                }
                self.kv.share_prefix(id, &m.pages, admit_tokens).expect("admit checked");
                self.metrics.on_prefix_lookup(m.tokens as u64);
                Some((m.tokens, m.path, shareable))
            }
            None => {
                let admit_tokens = admission_tokens(prompt_tokens, 0, self.batch.chunk);
                if !self.kv.can_admit(admit_tokens) {
                    return None;
                }
                self.kv.allocate(id, admit_tokens).expect("can_admit checked");
                Some((0, Vec::new(), shareable))
            }
        }
    }

    /// Choose the preemption victim among running seqs, excluding index
    /// `protect` and mid-prefill sequences (the starvation valve handles
    /// those). Victim = max [`victim_key`](Self::victim_key) under the
    /// configured [`VictimPolicy`]; the default `Youngest` reproduces the
    /// classical choice bit for bit (max scheduler preemption rank; within
    /// the agent, the youngest sequence goes first).
    fn pick_victim(&mut self, protect: usize) -> Option<usize> {
        let mut best: Option<(f64, f64, usize)> = None;
        for (i, s) in self.running.iter().enumerate() {
            if i == protect || s.needs_prefill {
                continue;
            }
            let key = self.victim_key(s);
            if best.map(|(k0, k1, _)| (key.0, key.1) > (k0, k1)).unwrap_or(true) {
                best = Some((key.0, key.1, i));
            }
        }
        best.map(|(_, _, i)| i)
    }

    /// Victim-ranking key of one running sequence (larger = preempted
    /// first) under the configured policy — DESIGN.md §11.
    fn victim_key(&self, s: &SeqState) -> (f64, f64) {
        let agent = s.id.agent;
        match self.victim_policy {
            // Scheduler rank, ties broken toward the youngest sequence
            // (fewest generated tokens): the pre-subsystem key exactly
            // (u32 fits f64 losslessly, so the tuple order is unchanged).
            VictimPolicy::Youngest => (
                self.scheduler.preemption_rank(agent, self.clock),
                (u32::MAX - s.generated) as f64,
            ),
            // Free the most memory per preemption.
            VictimPolicy::MostPages => (
                self.kv.block_table(s.id).map(|t| t.len()).unwrap_or(0) as f64,
                self.scheduler.preemption_rank(agent, self.clock),
            ),
            // Delay the agent whose remaining work is largest — it finishes
            // last anyway, so its delay is the cheapest in completion-time
            // terms. SRJF answers the remaining-cost query directly; other
            // policies fall back to the engine's per-sequence Eq. 1
            // remaining cost.
            VictimPolicy::CheapestRemaining => {
                let seq_rem = self.cost_model.remaining_inference_cost(
                    s.prompt,
                    s.target_decode,
                    s.generated,
                );
                match self.scheduler.remaining_cost(agent) {
                    Some(rem) => (rem, seq_rem),
                    None => (seq_rem, seq_rem),
                }
            }
            // Selective pampering applied to preemption: protect agents the
            // virtual clock says would finish early under GPS (smallest
            // F_j); within the GPS-latest agent, preempt the sequence with
            // the most remaining service.
            VictimPolicy::PamperAware => {
                let tag = self
                    .scheduler
                    .virtual_finish_tag(agent)
                    .unwrap_or_else(|| self.scheduler.preemption_rank(agent, self.clock));
                let seq_rem = self.cost_model.remaining_inference_cost(
                    s.prompt,
                    s.target_decode,
                    s.generated,
                );
                (tag, seq_rem)
            }
        }
    }

    /// The starvation-valve victim: every runner is a mid-prefill sequence
    /// that could not acquire a page. Under the default `Youngest` policy
    /// this is the last-admitted runner — bit-identical to the
    /// pre-subsystem valve; other policies apply
    /// [`victim_key`](Self::victim_key) with late indices winning ties
    /// (the same youngest-leaning bias).
    fn pick_valve_victim(&self) -> usize {
        match self.victim_policy {
            VictimPolicy::Youngest => self.running.len() - 1,
            _ => {
                let mut best = (f64::NEG_INFINITY, f64::NEG_INFINITY, 0usize);
                for (i, s) in self.running.iter().enumerate() {
                    let k = self.victim_key(s);
                    if (k.0, k.1) >= (best.0, best.1) {
                        best = (k.0, k.1, i);
                    }
                }
                best.2
            }
        }
    }

    /// Preempt the running sequence at `idx` under the configured
    /// [`PreemptionMode`]: swap its KV to host, or drop it for recompute
    /// when the mode demands it, the bounded host pool is full, or (Auto)
    /// the cached-prefix-adjusted refill is cheaper than the round-trip
    /// swap (DESIGN.md §11). Returns the tokens moved device→host (0 for a
    /// recompute drop).
    fn preempt_running(&mut self, idx: usize) -> u32 {
        let id = self.running[idx].id;
        let swap_allowed = self.kv.can_swap_out(id);
        let recompute = match self.preemption {
            // Bounded host pool full: forced recompute (the engine cannot
            // stall forever waiting for host slots that only *it* frees).
            PreemptionMode::Swap => !swap_allowed,
            PreemptionMode::Recompute => true,
            PreemptionMode::Auto => {
                let s = &self.running[idx];
                let tokens = self.kv.seq_tokens(id).expect("running seq allocated");
                let refill =
                    tokens.saturating_sub(s.cached_tokens) as f64 * self.auto_refill_unit;
                let round_trip = 2.0 * tokens as f64 * self.auto_swap_unit;
                !swap_allowed || refill < round_trip
            }
        };
        if recompute {
            self.drop_running_for_recompute(idx);
            0
        } else {
            self.swap_out_running(idx)
        }
    }

    /// Drop the running sequence at `idx` for recompute: discard its device
    /// KV (shared pages survive via the tree / sibling references), fold
    /// the generated tokens into the prompt — their content is known, so
    /// re-entry re-prefills them instead of re-sampling — and queue it for
    /// FIFO re-admission as a fresh (chunked) prefill.
    fn drop_running_for_recompute(&mut self, idx: usize) {
        let mut victim = self.running.remove(idx);
        let dropped = self.kv.drop_for_recompute(victim.id).expect("victim on device");
        if let Some(cache) = self.prefix.as_mut() {
            cache.detach(&victim.prefix_path);
        }
        victim.prefix_path = Vec::new();
        victim.prompt += victim.generated;
        victim.target_decode -= victim.generated;
        victim.generated = 0;
        victim.needs_prefill = true;
        victim.prefilled = 0;
        victim.cached_tokens = 0;
        victim.recompute_refill = true;
        self.metrics.on_recompute_drop(victim.id, self.clock, dropped as u64);
        if let Some(tr) = self.trace.as_mut() {
            tr.push(
                self.clock,
                victim.id.agent,
                Some(victim.id.index),
                TraceEventKind::PreemptRecompute { dropped_tokens: dropped as u64 },
            );
        }
        self.recompute.push_back(victim);
        // Pages returned to the pool: the blocked-admission memo is stale.
        self.admission_blocked = false;
        self.batch_dirty = true;
    }

    /// Swap the running sequence at `idx` out to host: release its device
    /// pages, drop its prefix-tree pins (shared prefix pages survive via
    /// the tree; the victim re-enters on private pages at swap-in), and
    /// queue it for FIFO swap-in. Returns the tokens moved, for
    /// swap-latency accounting. Shared by the decode-pressure victim path
    /// and the chunked-prefill starvation valve.
    fn swap_out_running(&mut self, idx: usize) -> u32 {
        let mut victim = self.running.remove(idx);
        let pages = self.kv.block_table(victim.id).unwrap().to_vec();
        let tokens = self.kv.seq_tokens(victim.id).unwrap();
        self.backend.on_swap_out(victim.id, &pages, tokens);
        let moved = self.kv.swap_out(victim.id).expect("victim on device");
        if let Some(cache) = self.prefix.as_mut() {
            cache.detach(&victim.prefix_path);
        }
        victim.prefix_path = Vec::new();
        victim.cached_tokens = 0;
        self.metrics.on_swap_out(victim.id, self.clock);
        if let Some(tr) = self.trace.as_mut() {
            tr.push(
                self.clock,
                victim.id.agent,
                Some(victim.id.index),
                TraceEventKind::PreemptSwap,
            );
        }
        self.swapped.push_back(victim);
        self.batch_dirty = true;
        moved
    }

    /// Reclaim unpinned prefix-cache pages until `need` pages are free,
    /// when (and only when) eviction can actually cover the shortfall.
    ///
    /// Any eviction that grows the free pool is an admission-unblocking
    /// event (§Perf memo audit): capacity grew without a completion, swap,
    /// or queue change, so the blocked memo must drop here — every eviction
    /// site funnels through this helper so none can miss it.
    fn evict_cache_for(&mut self, need: u32) {
        let Some(cache) = self.prefix.as_mut() else { return };
        let before = self.kv.free_pages();
        if before >= need || before + cache.reclaimable_pages(&self.kv) < need {
            return;
        }
        cache.evict_until(&mut self.kv, need);
        if self.kv.free_pages() > before {
            self.admission_blocked = false;
        }
    }

    /// Acquire KV for `tokens` more prompt tokens of a mid-prefill
    /// sequence (chunked prefill), reclaiming unpinned prefix-cache pages
    /// first when that covers the shortfall.
    fn try_extend(&mut self, seq: TaskId, tokens: u32) -> Result<(), KvError> {
        let need = self.kv.extend_need(seq, tokens);
        if need > self.kv.free_pages() {
            self.evict_cache_for(need);
        }
        self.kv.extend_tokens(seq, tokens)
    }

    fn finish_seq(&mut self, id: TaskId) {
        self.admission_blocked = false;
        self.backend.on_seq_released(id);
        let mut served = 0.0;
        if let Some(s) = self.running.iter().find(|s| s.id == id) {
            // Service actually delivered to this task — dedup-aware by
            // construction (shared pages were charged fractionally per
            // sharer as they were served), and exactly the Eq. 1 closed
            // form without the cache: the per-iteration deltas are
            // integer-valued, so the sum is bit-exact.
            served = s.served;
            if let Some(cache) = self.prefix.as_mut() {
                // The tree keeps its own page references; only this
                // sequence's pins are dropped.
                cache.detach(&s.prefix_path);
            }
        }
        self.kv.release(id).expect("release finished seq");
        self.running.retain(|s| s.id != id);
        self.batch_dirty = true;
        self.metrics.on_task_complete(id, self.clock);
        if let Some(tr) = self.trace.as_mut() {
            tr.push(self.clock, id.agent, Some(id.index), TraceEventKind::TaskComplete);
        }

        let now = self.clock;
        let correcting = self.online_correction;
        let agent_state = self.agents.get_mut(&id.agent).expect("agent exists");
        agent_state.tasks_remaining -= 1;
        agent_state.completed_tasks += 1;
        if correcting {
            agent_state.observed_cost += served;
        }

        // 1. Dependency-count release: every static task whose last
        //    unfinished dependency was `id` becomes ready, in index order
        //    (for staged agents this is exactly the next-stage barrier
        //    release). Spawned tasks have no dependents.
        let mut released: Vec<(TaskId, u32, u32)> = Vec::new();
        if (id.index as usize) < agent_state.dependents.len() {
            for di in std::mem::take(&mut agent_state.dependents[id.index as usize]) {
                let dep = &mut agent_state.dep_remaining[di as usize];
                *dep -= 1;
                if *dep == 0 {
                    let t = &agent_state.spec.tasks[di as usize];
                    released.push((t.id, t.prompt_tokens, t.decode_tokens));
                }
            }
        }

        // 2. Dynamic spawning: the completed task may emit children (a pure
        //    function of the spec — see workload::SpawnSpec). Children
        //    depend only on their parent, so they are released immediately,
        //    after any dependency releases (deterministic order).
        let mut spawned_events: Vec<TaskId> = Vec::new();
        if let Some(spawn) = agent_state.spec.spawn.clone() {
            let base = agent_state.spec.tasks.len() as u32;
            let parent = agent_state.task_spec(id.index).clone();
            for child in spawn.children_of(id.agent, &parent, base) {
                agent_state.tasks_remaining += 1;
                agent_state.known_tasks += 1;
                released.push((child.id, child.prompt_tokens, child.decode_tokens));
                spawned_events.push(child.id);
                agent_state.spawned.insert(child.id.index, child);
                self.metrics.on_task_spawned();
            }
        }

        // 3. §4.2 online correction: blend the observed cost of completed
        //    tasks into the total estimate with confidence growing in the
        //    completed fraction w:
        //      Ĉ' = (1 − w)·Ĉ + w·(C_obs / w),   R̂ = max(Ĉ' − C_obs, 0).
        //    Spawned tasks grow the denominator, so undiscovered work keeps
        //    the prior's weight up.
        let correction: Option<(f64, f64)> = if correcting && agent_state.tasks_remaining > 0 {
            let w = agent_state.completed_tasks as f64 / agent_state.known_tasks.max(1) as f64;
            let implied_total = agent_state.observed_cost / w.max(1e-12);
            let corrected = (1.0 - w) * agent_state.predicted_cost + w * implied_total;
            let rel_err = (corrected - agent_state.true_total).abs()
                / agent_state.true_total.max(1.0);
            self.metrics.on_cost_correction(now, rel_err);
            Some(((corrected - agent_state.observed_cost).max(0.0), corrected))
        } else {
            None
        };
        let done = agent_state.tasks_remaining == 0;

        for (tid, p, d) in released {
            self.push_task(tid, p, d);
        }
        if self.event_core {
            for &task in &spawned_events {
                self.scheduler.on_event(&EngineEvent::Spawn { task }, self.clock);
            }
        }
        if let Some(tr) = self.trace.as_mut() {
            for task in spawned_events {
                tr.push(self.clock, task.agent, Some(task.index), TraceEventKind::Spawn);
            }
        }
        if let Some((remaining, total)) = correction {
            self.scheduler.on_cost_update(id.agent, remaining, total, now);
        }
        if done {
            self.complete_agent(id.agent);
        }
    }

    fn complete_agent(&mut self, agent: AgentId) {
        self.scheduler.on_agent_complete(agent, self.clock);
        self.metrics.on_agent_complete(agent, self.clock);
        if let Some(tr) = self.trace.as_mut() {
            tr.push(self.clock, agent, None, TraceEventKind::Complete);
        }
    }

    /// Scheduler introspection for tests.
    pub fn waiting_len(&self) -> usize {
        self.scheduler.waiting_len()
    }

    /// Number of running sequences.
    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Number of swapped-out sequences.
    pub fn swapped_len(&self) -> usize {
        self.swapped.len()
    }

    /// Number of recompute-preempted sequences awaiting re-entry.
    pub fn recompute_len(&self) -> usize {
        self.recompute.len()
    }

    /// Direct access to the scheduler (GPS reference extraction, tests).
    pub fn scheduler_mut(&mut self) -> &mut dyn Scheduler {
        self.scheduler.as_mut()
    }

    /// The prefix cache, when enabled.
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix.as_ref()
    }

    /// The trace recorder, when tracing is on (`cfg.trace`).
    pub fn trace(&self) -> Option<&TraceRecorder> {
        self.trace.as_ref()
    }

    /// Move the trace recorder out of the engine (end-of-run export; later
    /// iterations would record into a fresh void, so only call when done).
    pub fn take_trace(&mut self) -> Option<TraceRecorder> {
        self.trace.take()
    }

    /// Per-sequence chunked-prefill accounting invariants (DESIGN.md §10),
    /// checked between steps: for every running sequence the filled-token
    /// cursor never passes the prompt, nothing decodes before its prefill
    /// completes, and the KV tokens it holds cover exactly its filled plus
    /// generated tokens up to at most one admission chunk of slack
    /// (`prefilled + generated ≤ kv ≤ prompt + generated`, tight once
    /// decoding). Composes with
    /// [`check_kv_invariants`](Self::check_kv_invariants) in the
    /// `prop_chunked_conservation` property test.
    pub fn check_chunked_accounting(&self) -> Result<(), String> {
        for s in &self.running {
            let kv_tokens = self
                .kv
                .seq_tokens(s.id)
                .ok_or_else(|| format!("{}: running but unallocated", s.id))?;
            if s.prefilled > s.prompt {
                return Err(format!("{}: prefilled {} > prompt {}", s.id, s.prefilled, s.prompt));
            }
            if s.cached_tokens > s.prefilled {
                return Err(format!(
                    "{}: cached {} tokens but only {} prefilled (cursor ran backwards)",
                    s.id, s.cached_tokens, s.prefilled
                ));
            }
            if s.needs_prefill && s.generated != 0 {
                return Err(format!("{}: decoded before prefill completed", s.id));
            }
            let low = s.prefilled + s.generated;
            let high = s.prompt + s.generated;
            if kv_tokens < low || kv_tokens > high {
                return Err(format!(
                    "{}: kv tokens {kv_tokens} outside [{low}, {high}] \
                     (prefilled {}, generated {})",
                    s.id, s.prefilled, s.generated
                ));
            }
            if !s.needs_prefill && kv_tokens != high {
                return Err(format!(
                    "{}: decoder holds {kv_tokens} kv tokens, expected {high}",
                    s.id
                ));
            }
        }
        Ok(())
    }

    /// KV-pool invariant check that accounts for pages pinned by the prefix
    /// cache; with the cache disabled this is exactly
    /// [`BlockAllocator::check_invariants`].
    pub fn check_kv_invariants(&self) -> Result<(), String> {
        match &self.prefix {
            Some(cache) => self.kv.check_invariants_shared(&cache.page_holds()),
            None => self.kv.check_invariants(),
        }
    }

    /// Predicted cost recorded for an agent at submission.
    pub fn predicted_cost(&self, agent: AgentId) -> Option<f64> {
        self.agents.get(&agent).map(|a| a.predicted_cost)
    }

    /// Cluster-layer trace hook: record a churn transition (crash / drain /
    /// join / recovered re-placement, DESIGN.md §14) at the current engine
    /// clock. No-op when tracing is off, like every other emit site.
    pub fn trace_churn(&mut self, agent: AgentId, kind: TraceEventKind) {
        if let Some(tr) = self.trace.as_mut() {
            tr.push(self.clock, agent, None, kind);
        }
    }

    /// Salvage every incomplete agent from this (about-to-be-discarded)
    /// replica for re-placement on the surviving pool — the crash-recovery
    /// half of DESIGN.md §14. The engine itself is left untouched: the
    /// caller replaces it wholesale, so its KV, scheduler, and queues die
    /// with it and only the returned specs matter.
    ///
    /// Per agent, the remaining work is rebuilt as a fresh [`AgentSpec`]:
    ///
    /// * Completed tasks (per the metrics ledger) are dropped; their deps on
    ///   surviving tasks were already released, so edges into them vanish.
    /// * In-flight sequences (running / swapped / recompute-queued) get the
    ///   recompute fold — generated tokens become prompt, the decode target
    ///   shrinks accordingly — exactly what `drop_for_recompute` re-entry
    ///   does within one replica, because a crash IS a recompute preemption
    ///   whose re-entry happens on a different replica. Their shared-prefix
    ///   annotation is clamped to the sequence's shareable cap so folded
    ///   (agent-private) tokens never enter the family's radix chain.
    /// * Surviving tasks are densely re-indexed (the engine requires
    ///   `tasks[i].id.index == i`) in original-index order, which preserves
    ///   topology: spawned survivors' only dep was their completed parent.
    ///   Re-indexing means a carried spawn rule draws fresh decisions on the
    ///   new replica — deterministic and conservation-safe, but a recovered
    ///   run is NOT replay-identical to an uninterrupted one (nor could it
    ///   be: the crash destroyed real work).
    ///
    /// Ordering is deterministic (ascending agent id); `lost_tokens` counts
    /// the device+host KV the crash destroyed.
    pub fn extract_for_recovery(&self) -> Vec<RecoveredAgent> {
        // In-flight fold state by task id. Recompute-queued sequences are
        // already folded (and hold no KV); running/swapped ones fold here.
        let mut folded: HashMap<TaskId, (u32, u32, u32)> = HashMap::new();
        let mut lost: HashMap<AgentId, u64> = HashMap::new();
        for s in self.running.iter().chain(self.swapped.iter()) {
            let prompt = s.prompt + s.generated;
            let decode = (s.target_decode - s.generated).max(1);
            folded.insert(s.id, (prompt, decode, s.shareable));
            *lost.entry(s.id.agent).or_insert(0) +=
                self.kv.seq_tokens(s.id).unwrap_or(0) as u64;
        }
        for s in &self.recompute {
            folded.insert(s.id, (s.prompt, s.target_decode.max(1), s.shareable));
        }
        let mut ids: Vec<AgentId> = self
            .agents
            .iter() // simlint::allow(unordered-iter): ids collected then sorted ascending below
            .filter(|(_, st)| st.tasks_remaining > 0)
            .map(|(&id, _)| id)
            .collect();
        ids.sort_unstable();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let st = &self.agents[&id];
            // Surviving tasks in original-index order: statics, then spawned
            // (a BTreeMap keyed by index, so `.values()` is already sorted).
            let spawned: Vec<&InferenceSpec> = st.spawned.values().collect();
            let survivors: Vec<&InferenceSpec> = st
                .spec
                .tasks
                .iter()
                .chain(spawned)
                .filter(|t| self.metrics.task_complete_time(t.id).is_none())
                .collect();
            debug_assert_eq!(survivors.len(), st.tasks_remaining);
            let remap: HashMap<u32, u32> = survivors
                .iter()
                .enumerate()
                .map(|(new, t)| (t.id.index, new as u32))
                .collect();
            let tasks: Vec<InferenceSpec> = survivors
                .iter()
                .enumerate()
                .map(|(new, t)| {
                    let (prompt, decode, cap) = folded
                        .get(&t.id)
                        .copied()
                        .unwrap_or((t.prompt_tokens, t.decode_tokens, u32::MAX));
                    InferenceSpec {
                        id: TaskId { agent: id, index: new as u32 },
                        stage: t.stage,
                        deps: t
                            .deps
                            .iter()
                            .filter_map(|d| remap.get(&d.index))
                            .map(|&i| TaskId { agent: id, index: i })
                            .collect(),
                        prompt_tokens: prompt,
                        decode_tokens: decode,
                        kind: t.kind,
                        prefix_group: t
                            .prefix_group
                            .map(|g| PrefixGroup { id: g.id, tokens: g.tokens.min(cap) }),
                    }
                })
                .collect();
            let arrival = self.metrics.agent_arrival_time(id).unwrap_or(st.spec.arrival);
            let spec = AgentSpec {
                id,
                class: st.spec.class,
                arrival,
                tasks,
                spawn: st.spec.spawn.clone(),
                input_text: st.spec.input_text.clone(),
            };
            // Scale the original prediction by the model-cost ratio of the
            // remaining work, so the recovery replica's virtual-time tag
            // (F = V(t) + cost) lands where the agent's residual service
            // would — pampering decisions survive the migration.
            let orig_cost = self.cost_model.agent_cost(&st.spec).max(1e-12);
            let rem_cost = self.cost_model.agent_cost(&spec);
            let predicted_cost = (st.predicted_cost * rem_cost / orig_cost).max(1e-9);
            out.push(RecoveredAgent {
                spec,
                arrival,
                predicted_cost,
                lost_tokens: lost.get(&id).copied().unwrap_or(0),
            });
        }
        out
    }

    /// Drive the engine over a whole suite to completion, injecting arrivals
    /// at their trace times. `predict` maps an agent spec to the cost the
    /// scheduler sees. Returns total engine time.
    ///
    /// With `cfg.event_core` the suite runs off the event calendar
    /// ([`run_suite_events`](Self::run_suite_events)); the default is the
    /// legacy tick loop — `prop_event_core_identity` proves the two
    /// bit-identical.
    pub fn run_suite<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        mut predict: F,
    ) -> f64 {
        if self.event_core {
            return self.run_suite_events(suite, predict);
        }
        let mut next = 0usize;
        loop {
            // Inject all arrivals due at or before the current clock.
            while next < suite.agents.len() && suite.agents[next].arrival <= self.clock + 1e-12 {
                let spec = suite.agents[next].clone();
                let cost = predict(&spec);
                let arrival = spec.arrival;
                // Align engine clock with the trace arrival (idle-skip safe).
                if arrival > self.clock {
                    self.clock = arrival;
                }
                self.submit(spec, cost);
                next += 1;
            }
            if !self.has_work() {
                if next >= suite.agents.len() {
                    break;
                }
                // Idle: jump to the next arrival.
                self.clock = suite.agents[next].arrival;
                continue;
            }
            let elapsed = self.step();
            if elapsed == 0.0 && self.running.is_empty() {
                // Blocked (nothing admissible); advance to next arrival or
                // bail if the workload is stuck (cannot happen with sane
                // prompts, guarded for safety).
                if next < suite.agents.len() {
                    self.clock = self.clock.max(suite.agents[next].arrival);
                } else if self.swapped.is_empty() && !self.recompute.is_empty() {
                    // A recompute re-entry that cannot be admitted into an
                    // EMPTY device pool can never run.
                    let s = self.recompute.front().expect("checked nonempty");
                    panic!(
                        "stuck: recompute re-entry of {} with prompt {} cannot fit \
                         KV capacity {}",
                        s.id,
                        s.prompt,
                        self.kv.capacity_tokens()
                    );
                } else if self.swapped.is_empty() && self.scheduler.waiting_len() > 0 {
                    let t = self.scheduler.pop_next(self.clock).expect("waiting task");
                    panic!(
                        "stuck: task {} with prompt {} cannot fit KV capacity {}",
                        t.id,
                        t.prompt_tokens,
                        self.kv.capacity_tokens()
                    );
                }
            }
        }
        self.clock
    }

    /// The event/calendar-queue suite driver (DESIGN.md §12). The calendar
    /// carries the exogenous events — one [`EventKind::Admission`] per
    /// agent, timestamped with its trace arrival, payload a dense slot into
    /// the pending-arrival [`Arena`] — and pops them in deterministic
    /// `(time, insertion seq)` order, which is exactly the tick loop's
    /// suite order (suites are arrival-sorted, equal arrivals in index
    /// order). Between events the engine steps as usual; endogenous events
    /// (chunk-complete, batch-complete, swap-done, recompute-ready, spawn)
    /// are emitted from [`step`](Self::step) into the scheduler's
    /// [`on_event`](crate::sched::Scheduler::on_event) hook at the
    /// iteration boundary where their timestamps become known.
    fn run_suite_events<F: FnMut(&AgentSpec) -> f64>(
        &mut self,
        suite: &Suite,
        mut predict: F,
    ) -> f64 {
        // Pending arrivals live in a flat arena; the event payload is the
        // dense slot id (== suite index here: inserts precede every
        // remove). Specs are cloned lazily at fire time, so the calendar
        // itself stays a few machine words per agent.
        let mut pending: Arena<u32> = Arena::with_capacity(suite.agents.len());
        let mut calendar = EventQueue::new();
        for (i, a) in suite.agents.iter().enumerate() {
            let slot = pending.insert(i as u32);
            calendar.push(a.arrival, EventKind::Admission { slot });
        }
        loop {
            // Fire every event due at or before the current clock — the
            // same epsilon as the tick loop's arrival injection.
            while let Some(ev) = calendar.peek() {
                if ev.time > self.clock + 1e-12 {
                    break;
                }
                let ev = calendar.pop().expect("peeked event");
                match ev.kind {
                    EventKind::Admission { slot } => {
                        let idx = pending.remove(slot).expect("pending arrival") as usize;
                        let spec = suite.agents[idx].clone();
                        let cost = predict(&spec);
                        // Align the engine clock with the trace arrival
                        // (idle-skip safe), exactly as the tick loop does.
                        if spec.arrival > self.clock {
                            self.clock = spec.arrival;
                        }
                        self.submit(spec, cost);
                    }
                }
            }
            if !self.has_work() {
                match calendar.peek() {
                    None => break,
                    // Idle: hop the clock straight to the next event.
                    Some(ev) => {
                        self.clock = ev.time;
                        continue;
                    }
                }
            }
            let elapsed = self.step();
            if elapsed == 0.0 && self.running.is_empty() {
                // Blocked (nothing admissible): advance to the next
                // calendar event, or bail if the workload is stuck — the
                // same guards (and messages) as the tick loop.
                if let Some(ev) = calendar.peek() {
                    self.clock = self.clock.max(ev.time);
                } else if self.swapped.is_empty() && !self.recompute.is_empty() {
                    let s = self.recompute.front().expect("checked nonempty");
                    panic!(
                        "stuck: recompute re-entry of {} with prompt {} cannot fit \
                         KV capacity {}",
                        s.id,
                        s.prompt,
                        self.kv.capacity_tokens()
                    );
                } else if self.swapped.is_empty() && self.scheduler.waiting_len() > 0 {
                    let t = self.scheduler.pop_next(self.clock).expect("waiting task");
                    panic!(
                        "stuck: task {} with prompt {} cannot fit KV capacity {}",
                        t.id,
                        t.prompt_tokens,
                        self.kv.capacity_tokens()
                    );
                }
            }
        }
        self.clock
    }
}

fn state_is_empty(agents: &HashMap<AgentId, AgentState>, id: AgentId) -> bool {
    agents.get(&id).map(|a| a.tasks_remaining == 0).unwrap_or(false)
}

/// Shared-prefix annotation of a task, looked up in its agent's runtime
/// state (static tasks by index, spawned tasks in the discovery map).
fn prefix_group_in(agents: &HashMap<AgentId, AgentState>, id: TaskId) -> Option<PrefixGroup> {
    agents.get(&id.agent).and_then(|a| a.task_spec(id.index).prefix_group)
}

/// Length of the prompt portion that can possibly be shared: the family
/// prefix clamped to the prompt (0 without a family — nothing to cache).
fn shareable_tokens(group: Option<PrefixGroup>, prompt_tokens: u32) -> u32 {
    group.map(|g| g.tokens.min(prompt_tokens)).unwrap_or(0)
}

/// Tokens a new sequence's admission allocates KV for: the cached prefix
/// plus the first prefill chunk, clamped to the prompt. With chunking off
/// (`chunk = u32::MAX`) this is the whole prompt — atomic admission.
fn admission_tokens(prompt_tokens: u32, cached_tokens: u32, chunk: u32) -> u32 {
    cached_tokens.saturating_add(chunk).min(prompt_tokens)
}

/// Service-accounting deltas in the scheduler's cost units.
fn serve_delta_prefill(model: CostModel, prompt: u32) -> f64 {
    match model {
        // Memory-centric accounting delivers occupancy per iteration; the
        // prompt itself contributes nothing until decode iterations occur.
        CostModel::MemoryCentric | CostModel::SharedMemoryCentric => 0.0,
        CostModel::ComputeCentric => crate::sched::vtc::W_INPUT * prompt as f64,
    }
}

fn serve_delta_decode(model: CostModel, prompt: u32, generated: u32) -> f64 {
    match model {
        // One decode iteration with occupancy (p + g) tokens.
        CostModel::MemoryCentric | CostModel::SharedMemoryCentric => (prompt + generated) as f64,
        CostModel::ComputeCentric => crate::sched::vtc::W_OUTPUT,
    }
}

fn per_agent_tokens(running: &[SeqState], kv: &BlockAllocator) -> Vec<(AgentId, u64)> {
    // BTreeMap so the fold drains in ascending agent order directly.
    let mut by_agent: BTreeMap<AgentId, u64> = BTreeMap::new();
    for s in running {
        if let Some(t) = kv.seq_tokens(s.id) {
            *by_agent.entry(s.id.agent).or_insert(0) += t as u64;
        }
    }
    by_agent.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendProfile, Config};
    use crate::engine::exec::SimBackend;
    use crate::workload::test_support::simple_agent;

    fn tiny_config(pages: u64, page_size: u32) -> Config {
        let mut cfg = Config::default();
        cfg.backend = BackendProfile {
            name: "test".into(),
            kv_tokens: pages * page_size as u64,
            page_size,
            alpha: 0.01,
            beta_prefill: 1e-5,
            beta_decode: 1e-4,
            swap_cost_per_token: 1e-6,
            beta_mixed: 0.0,
            host_kv_tokens: None,
            swap_bw_tokens_per_sec: 0.0,
        };
        cfg.max_batch = 16;
        cfg
    }

    fn engine(cfg: &Config, policy: Policy) -> Engine<SimBackend> {
        let sched = crate::sched::build(policy, cfg.backend.kv_tokens, 1.0);
        Engine::new(cfg, sched, SimBackend::new(&cfg.backend))
    }

    #[test]
    fn single_agent_completes() {
        let cfg = tiny_config(32, 16);
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 20, 10), 100.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            guard += 1;
            assert!(guard < 1000, "did not terminate");
        }
        let m = &e.metrics;
        assert_eq!(m.completed_agents(), 1);
        assert!(m.jct(0).unwrap() > 0.0);
        e.kv.check_invariants().unwrap();
        assert_eq!(e.kv.free_pages(), 32);
    }

    #[test]
    fn decode_takes_d_iterations() {
        let cfg = tiny_config(32, 16);
        let mut e = engine(&cfg, Policy::Fcfs);
        // One task, d=5: prefill iteration emits token 1, then 4 decodes.
        e.submit(simple_agent(0, 0.0, 1, 8, 5), 10.0);
        let mut iters = 0;
        while e.has_work() {
            e.step();
            iters += 1;
        }
        assert_eq!(iters, 5);
    }

    #[test]
    fn stage_release_order() {
        let cfg = tiny_config(64, 16);
        let mut e = engine(&cfg, Policy::Fcfs);
        let agent = crate::workload::test_support::agent_at(
            0,
            0.0,
            vec![
                vec![
                    crate::workload::test_support::inference(0, 0, 8, 3),
                    crate::workload::test_support::inference(1, 0, 8, 6),
                ],
                vec![crate::workload::test_support::inference(2, 1, 8, 2)],
            ],
        );
        e.submit(agent, 50.0);
        // Stage 1 not released until both stage-0 tasks finish.
        while e.has_work() {
            e.step();
            let stage1_admitted = e.metrics.task_admit_time(TaskId { agent: 0, index: 2 });
            let t0done = e.metrics.task_complete_time(TaskId { agent: 0, index: 0 });
            let t1done = e.metrics.task_complete_time(TaskId { agent: 0, index: 1 });
            if let Some(ts1) = stage1_admitted {
                assert!(t0done.unwrap() <= ts1 && t1done.unwrap() <= ts1);
            }
        }
        assert_eq!(e.metrics.completed_agents(), 1);
    }

    #[test]
    fn dag_release_respects_partial_deps() {
        // Diamond with a shortcut: t0, t1 roots; t2 waits on both; t3 waits
        // on t1 only — it must be admittable before t0 finishes.
        let cfg = tiny_config(64, 16);
        let mut e = engine(&cfg, Policy::Fcfs);
        let agent = crate::workload::test_support::dag_agent(
            0,
            0.0,
            vec![
                (8, 20, vec![]),  // t0: slow root
                (8, 2, vec![]),   // t1: fast root
                (8, 2, vec![0, 1]),
                (8, 2, vec![1]),  // t3: depends on t1 alone
            ],
        );
        e.submit(agent, 50.0);
        while e.has_work() {
            e.step();
        }
        let m = &e.metrics;
        assert_eq!(m.completed_agents(), 1);
        let t = |i: u32| TaskId { agent: 0, index: i };
        // t3 admitted as soon as t1 completed — strictly before t0 finished.
        assert!(m.task_admit_time(t(3)).unwrap() >= m.task_complete_time(t(1)).unwrap());
        assert!(m.task_admit_time(t(3)).unwrap() < m.task_complete_time(t(0)).unwrap());
        // t2 admitted only after both of its dependencies completed.
        let t2_admit = m.task_admit_time(t(2)).unwrap();
        assert!(t2_admit >= m.task_complete_time(t(0)).unwrap());
        assert!(t2_admit >= m.task_complete_time(t(1)).unwrap());
    }

    #[test]
    fn spawned_tasks_run_and_count() {
        let cfg = tiny_config(64, 16);
        let run = || {
            let mut e = engine(&cfg, Policy::Fcfs);
            let mut a = simple_agent(0, 0.0, 2, 16, 4);
            a.spawn = Some(crate::workload::SpawnSpec {
                prob: 1.0,
                branch: 2,
                max_depth: 1,
                seed: 7,
            });
            let expected = a.expand_spawns().len() as u64;
            e.submit(a, 100.0);
            let mut guard = 0;
            while e.has_work() {
                e.step();
                guard += 1;
                assert!(guard < 10_000);
            }
            (e.metrics.spawned_tasks(), expected, e.metrics.completed_agents())
        };
        let (spawned, expected, completed) = run();
        assert_eq!(completed, 1, "agent completes only after spawned work drains");
        assert_eq!(spawned, 4, "2 roots × branch 2 at prob 1.0");
        assert_eq!(spawned, expected, "runtime spawning must match static expansion");
        // Replay determinism.
        assert_eq!(run().0, spawned);
    }

    #[test]
    fn online_correction_records_trace_and_is_gated() {
        let mk_agent = || {
            let mut a = simple_agent(0, 0.0, 4, 16, 4);
            a.spawn =
                Some(crate::workload::SpawnSpec { prob: 0.6, branch: 2, max_depth: 2, seed: 3 });
            a
        };
        let run = |correct: bool, predicted: f64| {
            let mut cfg = tiny_config(64, 16);
            cfg.online_correction = correct;
            let mut e = engine(&cfg, Policy::Justitia);
            e.submit(mk_agent(), predicted);
            while e.has_work() {
                e.step();
            }
            e.metrics
        };
        // Correction off: no samples, zero counter.
        let off = run(false, 5000.0);
        assert_eq!(off.correction_samples(), 0);
        // Correction on with a badly wrong prediction: samples recorded and
        // the error estimate shrinks as completions accumulate.
        let on = run(true, 5000.0);
        assert!(on.correction_samples() > 0);
        let trace = on.correction_trace();
        let (first, last) = (trace.first().unwrap().1, trace.last().unwrap().1);
        assert!(
            last <= first + 1e-9,
            "correction error should not grow: first {first:.3}, last {last:.3}"
        );
        // Both runs complete the same workload (correction changes tags,
        // not the set of work).
        assert_eq!(off.spawned_tasks(), on.spawned_tasks());
        assert_eq!(off.completed_agents(), 1);
        assert_eq!(on.completed_agents(), 1);
    }

    #[test]
    fn correction_off_is_bit_identical() {
        // The flag default (off) must leave a mispredicted multi-stage run
        // exactly as it was: same JCTs bit for bit.
        let cfg = tiny_config(128, 16);
        let run = || {
            let mut e = engine(&cfg, Policy::Justitia);
            e.submit(simple_agent(0, 0.0, 3, 24, 12), 9999.0);
            e.submit(simple_agent(1, 0.0, 2, 16, 6), 10.0);
            while e.has_work() {
                e.step();
            }
            e.metrics.jcts()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn kv_pressure_triggers_swap() {
        // Tiny pool: 4 pages of 4 tokens = 16 tokens. Two long sequences
        // cannot both stay resident.
        let cfg = tiny_config(4, 4);
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
        let mut swaps = 0;
        let mut guard = 0;
        while e.has_work() {
            e.step();
            swaps = e.metrics.swap_out_count();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert!(swaps > 0, "expected swap-outs under KV pressure");
        assert_eq!(e.metrics.completed_agents(), 1);
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn no_admission_while_swapped() {
        let cfg = tiny_config(4, 4);
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
        e.submit(simple_agent(1, 0.0, 1, 4, 2), 10.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            if e.swapped_len() > 0 {
                // Agent 1's task must not be admitted while a swapped seq
                // exists... unless it was admitted before the swap occurred.
                // The engine admits waiting work only when swapped is empty;
                // verify through queue state instead of history:
                assert!(e.swapped_len() > 0);
            }
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(e.metrics.completed_agents(), 2);
    }

    #[test]
    fn justitia_orders_by_gps_finish() {
        let cfg = tiny_config(64, 16);
        let mut e = engine(&cfg, Policy::Justitia);
        // Expensive agent first, cheap second, same instant: cheap must
        // complete first under Justitia.
        e.submit(simple_agent(0, 0.0, 4, 32, 40), 10_000.0);
        e.submit(simple_agent(1, 0.0, 1, 16, 4), 100.0);
        while e.has_work() {
            e.step();
        }
        let j0 = e.metrics.agent_complete_time(0).unwrap();
        let j1 = e.metrics.agent_complete_time(1).unwrap();
        assert!(j1 < j0, "cheap agent should finish first ({j1} vs {j0})");
    }

    #[test]
    fn prefix_cache_skips_prefill_and_keeps_invariants() {
        let mut cfg = tiny_config(64, 16);
        cfg.prefix_cache = true;
        let mut e = engine(&cfg, Policy::Fcfs);
        // Two agents of one family: 2 parallel tasks each, 32-token prompts
        // drawn entirely from the family stream (2 full pages).
        let mk = |id: u32| {
            let mut a = simple_agent(id, 0.0, 2, 32, 4);
            for t in &mut a.tasks {
                t.prefix_group = Some(crate::workload::PrefixGroup { id: 9, tokens: 32 });
            }
            a
        };
        e.submit(mk(0), 100.0);
        e.step(); // admit + prefill agent 0; its chain enters the tree
        e.submit(mk(1), 100.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            guard += 1;
            assert!(guard < 1000);
        }
        let m = &e.metrics;
        assert_eq!(m.completed_agents(), 2);
        assert_eq!(m.prefix_lookups(), 4, "every admission consults the cache");
        assert_eq!(m.prefix_hits(), 2, "agent 1's tasks hit agent 0's chain");
        assert_eq!(m.prefill_tokens_saved(), 64);
        // 4 × 32 = 128 total prompt tokens; 64 skipped.
        assert_eq!(m.prefill_tokens_executed(), 64);
        assert!(m.cache_pages_peak() >= 2);
        e.check_kv_invariants().unwrap();
        assert_eq!(e.kv.device_tokens(), 0);
        // The chain is still cached (tree-owned) until evicted.
        assert_eq!(e.prefix_cache().unwrap().cached_pages(), 2);
    }

    #[test]
    fn prefix_cache_disabled_matches_plain_engine_on_annotated_workload() {
        let cfg = tiny_config(64, 16);
        let mk = |annotate: bool, id: u32| {
            let mut a = simple_agent(id, 0.0, 3, 20, 6);
            if annotate {
                for t in &mut a.tasks {
                    t.prefix_group = Some(crate::workload::PrefixGroup { id: 1, tokens: 20 });
                }
            }
            a
        };
        let run = |annotate: bool| {
            let mut e = engine(&cfg, Policy::Justitia);
            e.submit(mk(annotate, 0), 500.0);
            e.submit(mk(annotate, 1), 200.0);
            while e.has_work() {
                e.step();
            }
            e.metrics.jcts()
        };
        // Annotations are inert while cfg.prefix_cache is false.
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn chunked_with_unbounded_knobs_is_bit_identical() {
        // chunk = u32::MAX with an unbounded budget must replay the
        // unchunked engine exactly, policy by policy (same JCTs bit for
        // bit) — the flag-off path and the degenerate chunked path are the
        // same engine.
        for policy in Policy::all_paper_baselines() {
            let run = |chunked: bool| {
                let mut cfg = tiny_config(64, 16);
                cfg.chunked_prefill = chunked;
                cfg.prefill_chunk = u32::MAX;
                cfg.max_batched_tokens = u32::MAX;
                let mut e = engine(&cfg, policy);
                e.submit(simple_agent(0, 0.0, 3, 40, 12), 900.0);
                e.submit(simple_agent(1, 0.0, 2, 24, 6), 100.0);
                while e.has_work() {
                    e.step();
                }
                e.metrics.jcts()
            };
            assert_eq!(run(false), run(true), "{policy:?} diverged");
        }
    }

    #[test]
    fn chunked_prefill_splits_prompts_and_completes() {
        let mut cfg = tiny_config(64, 16);
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 8;
        cfg.max_batched_tokens = 16;
        let mut e = engine(&cfg, Policy::Fcfs);
        // One 64-token prompt: 8 chunks of 8 tokens, pages acquired chunk
        // by chunk; the final chunk's iteration emits the first token.
        e.submit(simple_agent(0, 0.0, 1, 64, 4), 10.0);
        let mut iters = 0;
        while e.has_work() {
            e.step();
            e.check_chunked_accounting().unwrap();
            e.check_kv_invariants().unwrap();
            iters += 1;
            assert!(iters < 1000);
        }
        // 8 prefill iterations (the last emits token 1) + 3 pure decodes.
        assert_eq!(iters, 11);
        assert_eq!(e.metrics.completed_agents(), 1);
        assert_eq!(e.kv.free_pages(), 64);
    }

    #[test]
    fn token_budget_stalls_excess_prefills_and_counts_them() {
        let mut cfg = tiny_config(64, 16);
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 16;
        cfg.max_batched_tokens = 16;
        let mut e = engine(&cfg, Policy::Fcfs);
        // Two 32-token prompts admitted together, but only one 16-token
        // chunk fits per iteration: the second sequence must stall (and be
        // counted) while the first prefills.
        e.submit(simple_agent(0, 0.0, 2, 32, 2), 10.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            e.check_chunked_accounting().unwrap();
            guard += 1;
            assert!(guard < 1000);
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert!(e.metrics.prefill_stalls() > 0, "second prefill never waited");
        e.kv.check_invariants().unwrap();
    }

    #[test]
    fn chunking_caps_decode_inter_token_latency() {
        // A long-lived decoder sharing the engine with an elephant prompt:
        // unchunked, one iteration carries the whole prompt and every
        // decode in it eats that latency; chunked, the worst decode gap is
        // bounded by the chunk. Tail ITL must improve as the chunk shrinks
        // at a fixed budget (the chunked_prefill experiment's headline).
        let run = |chunk: Option<u32>| {
            let mut cfg = tiny_config(256, 16);
            cfg.backend.alpha = 0.01;
            cfg.backend.beta_prefill = 1e-4;
            if let Some(c) = chunk {
                cfg.chunked_prefill = true;
                cfg.prefill_chunk = c;
                cfg.max_batched_tokens = 2048;
            }
            let mut e = engine(&cfg, Policy::Fcfs);
            e.submit(simple_agent(0, 0.0, 1, 8, 50), 10.0); // the decoder
            e.step(); // decoder prefilled; it is now mid-decode
            e.submit(simple_agent(1, 0.0, 1, 1600, 4), 10.0); // the elephant
            while e.has_work() {
                e.step();
            }
            assert_eq!(e.metrics.completed_agents(), 2);
            e.metrics.decode_itl_percentile(99.0)
        };
        let off = run(None);
        let c512 = run(Some(512));
        let c128 = run(Some(128));
        assert!(c512 < off, "chunk 512 must beat atomic admission ({c512} vs {off})");
        assert!(c128 < c512, "chunk 128 must beat chunk 512 ({c128} vs {c512})");
    }

    #[test]
    fn chunked_valve_swaps_youngest_when_all_prefills_starve() {
        // Pool of 8 pages; two 96-token prompts admitted on 2-page first
        // chunks. Their incremental growth collides mid-prefill with no
        // decoder to retire: the valve must swap the youngest out instead
        // of spinning, and both agents must still finish.
        let mut cfg = tiny_config(8, 16); // 128-token pool
        cfg.max_batch = 4;
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 32;
        cfg.max_batched_tokens = 64;
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 96, 2), 10.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            e.check_chunked_accounting().unwrap();
            e.check_kv_invariants().unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert!(e.metrics.swap_out_count() > 0, "valve never fired");
        assert_eq!(e.kv.free_pages(), 8);
    }

    #[test]
    fn unblocking_events_clear_admission_memo() {
        // §Perf memo audit: every event that can make the head task
        // admissible again must drop the blocked-admission memo. Spawn
        // discovery and stage release funnel through `push_task`; prefix-
        // cache eviction funnels through `evict_cache_for`; this pins both.
        let cfg = tiny_config(4, 4);
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 1, 4, 40), 100.0);
        e.step(); // admit + prefill the runner
        // A waiting task too big for the remaining pool blocks the memo.
        e.submit(simple_agent(1, 0.0, 1, 12, 2), 100.0);
        e.step();
        assert!(e.admission_blocked, "oversized head task must set the memo");
        // Queue-change event (the runtime-spawn / dependency-release path).
        e.push_task(TaskId { agent: 1, index: 9 }, 2, 2);
        assert!(!e.admission_blocked, "a pushed task must clear the memo");

        // Eviction that grows the free pool clears it too: without this a
        // newly-fitting head stalls until an unrelated completion.
        let mut cfg = tiny_config(8, 4);
        cfg.prefix_cache = true;
        let mut e = engine(&cfg, Policy::Fcfs);
        let mut a = simple_agent(0, 0.0, 1, 8, 2);
        a.tasks[0].prefix_group = Some(crate::workload::PrefixGroup { id: 3, tokens: 8 });
        e.submit(a, 10.0);
        while e.has_work() {
            e.step();
        }
        assert_eq!(e.prefix_cache().unwrap().cached_pages(), 2);
        e.admission_blocked = true; // as if a head task had failed to fit
        e.evict_cache_for(e.kv.free_pages() + 1);
        assert!(
            !e.admission_blocked,
            "eviction grew the free pool: a stale memo would stall admission"
        );
    }

    #[test]
    fn recompute_mode_drops_and_refills() {
        // The kv-pressure scenario under pure recompute preemption: victims
        // lose their KV instead of swapping, re-enter as prefills over
        // prompt + generated tokens, and everything still completes.
        let mut cfg = tiny_config(4, 4);
        cfg.preemption = PreemptionMode::Recompute;
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            e.check_kv_invariants().unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert!(e.metrics.recompute_count() > 0, "expected recompute drops under pressure");
        assert!(e.metrics.recomputed_tokens() > 0, "wasted-token gauge must move");
        assert_eq!(e.metrics.swap_out_count(), 0, "recompute mode must never swap");
        assert_eq!(e.kv.free_pages(), 4);
    }

    #[test]
    fn bounded_host_pool_forces_recompute_fallback() {
        // Swap mode with a zero-token host tier: every swap is impossible,
        // so the engine must fall back to recompute rather than deadlock.
        let mut cfg = tiny_config(4, 4);
        cfg.backend.host_kv_tokens = Some(0);
        assert_eq!(cfg.preemption, PreemptionMode::Swap);
        let mut e = engine(&cfg, Policy::Fcfs);
        e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            e.check_kv_invariants().unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert_eq!(e.metrics.swap_out_count(), 0, "a 0-token host cannot take any victim");
        assert!(e.metrics.recompute_count() > 0);
        assert_eq!(e.kv.free_pages(), 4);
    }

    #[test]
    fn auto_mode_picks_the_cheaper_side() {
        let run = |beta_prefill: f64, swap_cost: f64| {
            let mut cfg = tiny_config(4, 4);
            cfg.preemption = PreemptionMode::Auto;
            cfg.backend.beta_prefill = beta_prefill;
            cfg.backend.swap_cost_per_token = swap_cost;
            let mut e = engine(&cfg, Policy::Fcfs);
            e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
            let mut guard = 0;
            while e.has_work() {
                e.step();
                guard += 1;
                assert!(guard < 10_000);
            }
            assert_eq!(e.metrics.completed_agents(), 1);
            (e.metrics.swap_out_count(), e.metrics.recompute_count())
        };
        // Free refill vs expensive swap: Auto must always recompute.
        let (swaps, recomputes) = run(0.0, 1.0);
        assert_eq!(swaps, 0, "refill is free: swapping is never the cheaper side");
        assert!(recomputes > 0);
        // Expensive refill vs free swap: Auto must always swap.
        let (swaps, recomputes) = run(1.0, 0.0);
        assert!(swaps > 0);
        assert_eq!(recomputes, 0, "swap is free: recompute is never the cheaper side");
    }

    #[test]
    fn default_knobs_match_explicit_classical_config() {
        // Unbounded host + Swap + Youngest spelled out must replay the
        // default engine bit for bit on a swap-heavy run (the host bound is
        // merely large enough to never bind).
        let run = |explicit: bool| {
            let mut cfg = tiny_config(4, 4);
            if explicit {
                cfg.preemption = PreemptionMode::Swap;
                cfg.victim = VictimPolicy::Youngest;
                cfg.backend.host_kv_tokens = Some(1 << 40);
            }
            let mut e = engine(&cfg, Policy::Fcfs);
            e.submit(simple_agent(0, 0.0, 2, 4, 12), 100.0);
            while e.has_work() {
                e.step();
            }
            (e.metrics.jcts(), e.metrics.swap_out_count(), e.metrics.recompute_count())
        };
        assert_eq!(run(false), run(true));
        assert_eq!(run(false).2, 0, "classical config never recomputes");
    }

    #[test]
    fn victim_policies_all_complete_under_pressure() {
        for victim in VictimPolicy::ALL {
            for mode in
                [PreemptionMode::Swap, PreemptionMode::Recompute, PreemptionMode::Auto]
            {
                let mut cfg = tiny_config(6, 4);
                cfg.preemption = mode;
                cfg.victim = victim;
                let mut e = engine(&cfg, Policy::Justitia);
                e.submit(simple_agent(0, 0.0, 2, 4, 10), 500.0);
                e.submit(simple_agent(1, 0.0, 1, 4, 8), 50.0);
                let mut guard = 0;
                while e.has_work() {
                    e.step();
                    e.check_kv_invariants().unwrap();
                    guard += 1;
                    assert!(guard < 10_000, "{victim:?}/{mode:?} did not terminate");
                }
                assert_eq!(e.metrics.completed_agents(), 2, "{victim:?}/{mode:?}");
                assert_eq!(e.kv.free_pages(), 6, "{victim:?}/{mode:?} leaked pages");
            }
        }
    }

    #[test]
    fn victim_key_ranks_by_policy() {
        // Two decoders under Justitia: agent 0 expensive (GPS-latest, big
        // F tag), agent 1 cheap but holding more pages.
        let cfg = tiny_config(64, 16);
        let mut e = engine(&cfg, Policy::Justitia);
        e.submit(simple_agent(0, 0.0, 1, 16, 30), 5000.0);
        e.submit(simple_agent(1, 0.0, 1, 64, 30), 50.0);
        e.step(); // both prefilled; both now decoders
        assert_eq!(e.running_len(), 2);
        let victim_agent = |e: &mut Engine<SimBackend>, policy: VictimPolicy| {
            e.victim_policy = policy;
            let v = e.pick_victim(usize::MAX).unwrap();
            e.running[v].id.agent
        };
        // PamperAware protects the cheap (GPS-early) agent.
        assert_eq!(victim_agent(&mut e, VictimPolicy::PamperAware), 0);
        // Youngest keys on the scheduler rank — same agent here (largest
        // virtual finish tag under Justitia).
        assert_eq!(victim_agent(&mut e, VictimPolicy::Youngest), 0);
        // MostPages frees the most memory: agent 1's 64-token prompt.
        assert_eq!(victim_agent(&mut e, VictimPolicy::MostPages), 1);
        // CheapestRemaining (engine fallback): agent 1's sequence has the
        // larger per-sequence remaining cost (64-token prompt occupancy).
        assert_eq!(victim_agent(&mut e, VictimPolicy::CheapestRemaining), 1);
    }

    #[test]
    fn predictor_tags_reach_the_task_queue() {
        // `--use-predictor`: per-task scheduler tags must derive from the
        // agent-level prediction Ĉ_j, not echo the oracle decode length
        // (the ISSUE 5 predictor bugfix).
        let mut cfg = tiny_config(64, 16);
        cfg.use_predictor = true;
        let mut e = engine(&cfg, Policy::Sjf);
        e.submit(simple_agent(0, 0.0, 2, 16, 8), 500.0);
        let t = e.scheduler_mut().peek_next(0.0).unwrap();
        assert_eq!(t.predicted_decode, 250.0, "tag = Ĉ_j / known_tasks, not the decode oracle");
        // Oracle mode is unchanged: the tag is the true decode length.
        let mut e = engine(&tiny_config(64, 16), Policy::Sjf);
        e.submit(simple_agent(0, 0.0, 2, 16, 8), 500.0);
        let t = e.scheduler_mut().peek_next(0.0).unwrap();
        assert_eq!(t.predicted_decode, 8.0);
    }

    #[test]
    fn predictor_run_differs_from_oracle_run_under_noisy_predictions() {
        // A noisy predictor that inverts the two agents' costs must produce
        // a different SJF schedule than the oracle run — before the fix,
        // inference-level tags silently fell back to ground truth and the
        // two runs were identical.
        let run = |use_predictor: bool| {
            let mut cfg = tiny_config(64, 16);
            cfg.max_batch = 1;
            cfg.use_predictor = use_predictor;
            let mut e = engine(&cfg, Policy::Sjf);
            // Noisy predictions: slow agent predicted tiny, fast predicted
            // huge (oracle-mode costs are ignored by inference-level SJF).
            e.submit(simple_agent(0, 0.0, 1, 16, 20), 1.0);
            e.submit(simple_agent(1, 0.0, 1, 16, 2), 1000.0);
            while e.has_work() {
                e.step();
            }
            e.metrics.jcts()
        };
        let oracle = run(false);
        let predicted = run(true);
        assert_ne!(oracle, predicted, "noisy predictor must change the SJF schedule");
        // Oracle SJF runs the short job first; the inverted predictor runs
        // the long one first, delaying the short job past it.
        let jct = |m: &[(u32, f64)], a: u32| m.iter().find(|(id, _)| *id == a).unwrap().1;
        assert!(jct(&oracle, 1) < jct(&oracle, 0));
        assert!(jct(&predicted, 1) > jct(&predicted, 0));
    }

    #[test]
    fn correction_composes_with_prefix_cache() {
        // ISSUE 5 satellite: observed-service accounting is dedup-aware
        // (accrued from the very service deltas the scheduler sees), so the
        // historical correction×cache gate is gone — with both flags on the
        // loop must run and its error must shrink, not explode.
        let mk = || {
            let mut a = simple_agent(0, 0.0, 4, 32, 8);
            for t in &mut a.tasks {
                t.prefix_group = Some(crate::workload::PrefixGroup { id: 5, tokens: 32 });
            }
            a
        };
        // The discriminating check: predict the *deduplicated* truth
        // exactly. Dedup-aware observed accounting keeps the corrected
        // estimate pinned near it; the old plain-Eq. 1 accounting would
        // extrapolate the UNdeduplicated total (~2.9× here) and drift the
        // error up to ~0.5 by the third event.
        let truth = crate::cost::CostModel::SharedMemoryCentric.agent_cost(&mk());
        let mut cfg = tiny_config(64, 16);
        cfg.prefix_cache = true;
        cfg.online_correction = true;
        let mut e = engine(&cfg, Policy::Justitia);
        e.submit(mk(), truth);
        while e.has_work() {
            e.step();
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert!(
            e.metrics.correction_samples() > 0,
            "correction must run with the prefix cache on (the gate is gone)"
        );
        for (t, err) in e.metrics.correction_trace() {
            assert!(
                *err < 0.2,
                "correction drifted from an exact deduped prediction at t={t:.2}: {err:.3}"
            );
        }

        // And from a badly wrong prediction the error must shrink, not
        // explode, as completions accumulate.
        let mut e = engine(&cfg, Policy::Justitia);
        e.submit(mk(), truth * 10.0);
        while e.has_work() {
            e.step();
        }
        let trace = e.metrics.correction_trace();
        let (first, last) = (trace.first().unwrap().1, trace.last().unwrap().1);
        assert!(
            last <= first + 1e-9,
            "dedup-aware correction error must shrink: first {first:.3}, last {last:.3}"
        );
    }

    /// Records the swap hooks a backend sees (S3 regression harness).
    struct RecordingBackend {
        inner: SimBackend,
        /// (seq, token count at swap-out, pages at swap-out).
        outs: std::rc::Rc<std::cell::RefCell<Vec<(TaskId, u32, usize)>>>,
        /// (seq, pages at swap-in).
        ins: std::rc::Rc<std::cell::RefCell<Vec<(TaskId, usize)>>>,
    }

    impl ExecBackend for RecordingBackend {
        fn run_iteration(&mut self, batch: &IterationBatch) -> exec::IterationResult {
            self.inner.run_iteration(batch)
        }
        fn on_swap_out(&mut self, seq: TaskId, pages: &[crate::kv::PageId], tokens: u32) {
            self.outs.borrow_mut().push((seq, tokens, pages.len()));
        }
        fn on_swap_in(&mut self, seq: TaskId, pages: &[crate::kv::PageId]) {
            self.ins.borrow_mut().push((seq, pages.len()));
        }
    }

    #[test]
    fn valve_swap_preserves_prefill_cursor_and_shared_tail() {
        // ISSUE 5 satellite: a mid-prefill sequence swapped out by the
        // starvation valve must swap back in with its `prefilled` cursor
        // and CoW-shared tail intact — no prompt token is ever prefilled
        // twice, and the backend's swap hooks see consistent page sets.
        let mut cfg = tiny_config(8, 16); // 128-token pool
        cfg.max_batch = 4;
        cfg.chunked_prefill = true;
        cfg.prefill_chunk = 32;
        cfg.max_batched_tokens = 64;
        cfg.prefix_cache = true;
        let outs = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let ins = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
        let backend = RecordingBackend {
            inner: SimBackend::new(&cfg.backend),
            outs: std::rc::Rc::clone(&outs),
            ins: std::rc::Rc::clone(&ins),
        };
        let sched = crate::sched::build(Policy::Fcfs, cfg.backend.kv_tokens, 1.0);
        let mut e = Engine::new(&cfg, sched, backend);
        let mut a = simple_agent(0, 0.0, 2, 96, 2);
        for t in &mut a.tasks {
            t.prefix_group = Some(crate::workload::PrefixGroup { id: 9, tokens: 32 });
        }
        e.submit(a, 10.0);
        let mut guard = 0;
        while e.has_work() {
            e.step();
            e.check_chunked_accounting().unwrap();
            e.check_kv_invariants().unwrap();
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(e.metrics.completed_agents(), 1);
        assert!(e.metrics.swap_out_count() > 0, "valve never fired");
        // Every page is either free or retained by the radix tree.
        assert_eq!(e.kv.device_tokens(), 0);
        assert_eq!(
            e.kv.free_pages() as u64 + e.prefix_cache().unwrap().cached_pages() as u64,
            8
        );
        // Cursor intact: every prompt token was prefilled exactly once (or
        // served from the cache) — a reset cursor would re-run tokens and
        // break this identity.
        assert_eq!(
            e.metrics.prefill_tokens_executed() + e.metrics.prefill_tokens_saved(),
            192,
            "prefill work must be conserved across valve swaps"
        );
        // Backend hooks: every swap-out is matched by a swap-in of the same
        // sequence with the same page count (tokens did not change while
        // off-device).
        let outs = outs.borrow();
        let ins = ins.borrow();
        assert_eq!(outs.len(), ins.len(), "every victim must return");
        for ((so, st, sp), (is, ip)) in outs.iter().zip(ins.iter()) {
            assert_eq!(so, is, "FIFO swap order");
            assert_eq!(sp, ip, "page count must survive the round trip");
            assert!(*st > 0, "mid-prefill victim held real tokens");
        }
    }

    #[test]
    fn run_suite_completes_all() {
        let cfg = tiny_config(128, 16);
        let wl = crate::config::WorkloadConfig { n_agents: 8, window_secs: 5.0, ..Default::default() };
        let suite = crate::workload::trace::build_suite(&wl);
        // Scale down token counts for the tiny pool.
        let suite = crate::workload::Suite::new(
            suite
                .agents
                .into_iter()
                .map(|mut a| {
                    for t in &mut a.tasks {
                        t.prompt_tokens = (t.prompt_tokens / 20).max(2);
                        t.decode_tokens = (t.decode_tokens / 20).max(2);
                    }
                    a
                })
                .collect(),
        );
        for policy in Policy::all_paper_baselines() {
            let mut e = engine(&cfg, policy);
            let m = CostModel::MemoryCentric;
            e.run_suite(&suite, |a| m.agent_cost(a));
            assert_eq!(e.metrics.completed_agents(), 8, "{policy:?}");
            e.kv.check_invariants().unwrap();
            assert_eq!(e.kv.device_tokens(), 0);
        }
    }

    #[test]
    fn trace_off_by_default_and_absent() {
        let cfg = tiny_config(32, 16);
        let mut e = engine(&cfg, Policy::Justitia);
        assert!(e.trace().is_none(), "default config must not allocate a recorder");
        e.submit(simple_agent(0, 0.0, 2, 20, 10), 100.0);
        while e.has_work() {
            e.step();
        }
        assert!(e.take_trace().is_none());
    }

    #[test]
    fn trace_records_full_lifecycle() {
        let mut cfg = tiny_config(32, 16);
        cfg.trace = true;
        cfg.trace_sample = 1;
        let mut e = engine(&cfg, Policy::Justitia);
        e.submit(simple_agent(0, 0.0, 2, 20, 10), 100.0);
        while e.has_work() {
            e.step();
        }
        let rec = e.take_trace().unwrap();
        let count = |k: &str| rec.events().filter(|ev| ev.kind.name() == k).count();
        assert_eq!(count("arrival"), 1);
        assert_eq!(count("admitted"), 2, "one admission per task");
        assert_eq!(count("first_token"), 2, "one first token per task");
        assert_eq!(count("task_complete"), 2);
        assert_eq!(count("complete"), 1);
        // Stride 1 samples every iteration; each admission is audited, and
        // Justitia explains its picks with virtual finish tags.
        assert!(rec.sample_count() > 0);
        assert_eq!(rec.pick_count(), 2);
        assert!(rec.picks().all(|p| p.agent == 0 && p.winner_tag.is_some()));
        // Timestamps are the engine clock: non-decreasing across the stream.
        let ts: Vec<f64> = rec.events().map(|ev| ev.t).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]));
        // The TTFT histogram fed off the same first-token transitions.
        assert_eq!(e.metrics.ttft_samples(), 2);
        assert!(e.metrics.ttft_mean() > 0.0);
    }

    #[test]
    fn trace_streams_identical_across_cores() {
        // Every emit site lives in code shared by the tick and event cores,
        // so the recorders must compare equal stream for stream (the full
        // randomized version is tests/prop_trace_identity.rs).
        let mut recs = Vec::new();
        for event_core in [false, true] {
            let mut cfg = tiny_config(24, 8);
            cfg.trace = true;
            cfg.trace_sample = 2;
            cfg.event_core = event_core;
            let mut e = engine(&cfg, Policy::Justitia);
            for i in 0..3 {
                e.submit(simple_agent(i, 0.0, 2, 24, 8), 40.0);
            }
            while e.has_work() {
                e.step();
            }
            recs.push(e.take_trace().unwrap());
        }
        assert_eq!(recs[0], recs[1]);
    }
}
