//! The paper's motivating scenario (Fig. 1 / Fig. 3): two DocMerging agents
//! compete for one backend. Serve them under instantaneous fair sharing
//! (VTC) and under selective pampering (Justitia) on the calibrated
//! simulator, and print the per-agent JCTs plus the KV-occupancy timeline —
//! the exact comparison of Fig. 3.
//!
//! Run: `cargo run --release --example doc_merging`

fn main() {
    println!("Two DocMerging agents on llama7b-a100 (M = 459 blocks x 16 tokens)\n");
    let r = justitia::experiments::fig3(42);

    for (name, jcts, avg) in &r.rows {
        println!("{name:<10}  agent-0 JCT {:>6.1}s   agent-1 JCT {:>6.1}s   avg {:>6.1}s", jcts[0], jcts[1], avg);
    }
    let (vtc, just) = (&r.rows[0], &r.rows[1]);
    println!(
        "\nselective pampering cuts average JCT {:.1}% (paper: 210 s -> 166 s = 21%)",
        (1.0 - just.2 / vtc.2) * 100.0
    );
    let delayed = just.1.iter().zip(&vtc.1).any(|(j, v)| j > &(v * 1.001));
    println!(
        "per-agent delay vs fair sharing: {}",
        if delayed { "some (within the Thm B.1 bound)" } else { "none" }
    );

    // ASCII occupancy timelines (Fig. 3a/3b): KV tokens in use over time.
    for (name, tl) in &r.timelines {
        let span = tl.last().map(|(t, _)| *t).unwrap_or(1.0);
        let cols = 64usize;
        let mut sums = vec![(0u64, 0u64); cols];
        for (t, v) in tl {
            let i = ((t / span * cols as f64) as usize).min(cols - 1);
            sums[i].0 += v;
            sums[i].1 += 1;
        }
        let max = 459 * 16u64;
        print!("\n{name:<10} |");
        for (s, n) in &sums {
            let frac = if *n > 0 { (*s / *n) as f64 / max as f64 } else { 0.0 };
            let glyph = match (frac * 8.0) as usize {
                0 => ' ',
                1 => '.',
                2 => ':',
                3 => '-',
                4 => '=',
                5 => '+',
                6 => '*',
                7 => '#',
                _ => '@',
            };
            print!("{glyph}");
        }
        println!("| 0..{:.0}s (height = KV usage)", span);
    }
}
