//! DAG agents — 300 agents at 3× density per workflow shape (map-reduce /
//! tree / pipeline), dynamic spawning on, §4.2 online cost correction off
//! vs on, under 2× log-uniform prediction noise.
//!
//! Beyond the paper's staged agents: the DAG opens workload families with
//! partial-barrier release and runtime-spawned follow-up calls, and the
//! correction loop claws back both the noise and the arrival-invisible
//! spawned work. Expected shape: every suite completes, spawning counts are
//! identical across the correction pair (pure function of the suite), and
//! the correction-on rows carry a finite mean estimate error with a max-min
//! fair-share ratio vs GPS no worse than correction-off by a wide margin.

use justitia::config::Config;
use justitia::util::bench::{section, ResultsFile};

fn main() {
    section("DAG agents: shapes x correction (300 agents, 3x density, lambda 2x)");
    let mut out = ResultsFile::new("bench_dag_agents.txt");
    let rows = justitia::experiments::dag_agents(&Config::default(), 300, 3.0, 0.3, 3, 2.0, 42);
    out.line(justitia::experiments::DagAgentsRow::table_header());
    for r in &rows {
        out.line(r.table_row());
    }
    for shape in justitia::workload::DagShape::ALL {
        let off = rows.iter().find(|r| r.shape == shape && !r.correction);
        let on = rows.iter().find(|r| r.shape == shape && r.correction);
        if let (Some(off), Some(on)) = (off, on) {
            out.line(format!(
                "headline {}: avg JCT {:.1}s -> {:.1}s, maxmin {:.2}x -> {:.2}x with correction",
                shape.name(),
                off.avg_jct,
                on.avg_jct,
                off.maxmin_ratio,
                on.maxmin_ratio
            ));
        }
    }
}
