//! Agent/task scheduling policies (paper §4.3 + the §5.1 baselines).
//!
//! The engine owns the queues' *mechanics* (admission, swap, batching); a
//! `Scheduler` owns the *policy*: which waiting task to admit next, and which
//! running agent to preempt first when KV is exhausted. Tasks are pushed the
//! moment their DAG dependencies complete (stage barriers are the special
//! case, and dynamically spawned tasks arrive mid-flight); all schedulers
//! here are work-conserving.

pub mod agent_fcfs;
pub mod fcfs;
pub mod gps;
pub mod justitia;
pub mod sjf;
pub mod srjf;
pub mod vtc;
pub mod vtime;

use crate::config::Policy;
use crate::cost::CostModel;
pub use crate::engine::event::EngineEvent;
pub use crate::trace::PickExplanation;
use crate::workload::{AgentId, TaskId};

/// What the scheduler learns about an agent on arrival. `cost` is the
/// *predicted* total service cost Ĉ_j under the scheduler's cost model
/// (ground truth in oracle mode, MLP output in predictor mode).
#[derive(Debug, Clone, Copy)]
pub struct AgentInfo {
    /// Agent id.
    pub id: AgentId,
    /// Arrival time (s).
    pub arrival: f64,
    /// Predicted total service cost Ĉ_j.
    pub cost: f64,
    /// Critical-path cost: the heaviest dependency chain through the agent's
    /// task DAG under the scheduler's cost model — a lower bound on the
    /// agent's serial work even at infinite parallelism. Equals `cost` for
    /// single-chain agents; the built-in policies order by `cost` alone and
    /// expose this for pampering diagnostics and experiments.
    pub critical_path: f64,
}

impl AgentInfo {
    /// Info with `critical_path` defaulted to `cost` (single-chain
    /// assumption) — the common case in tests and micro-benches.
    pub fn new(id: AgentId, arrival: f64, cost: f64) -> Self {
        AgentInfo { id, arrival, cost, critical_path: cost }
    }
}

/// A waiting inference task, as seen by the scheduler.
#[derive(Debug, Clone, Copy)]
pub struct TaskInfo {
    /// Task identity.
    pub id: TaskId,
    /// Prompt length p.
    pub prompt_tokens: u32,
    /// Predicted decode length (for inference-level SJF).
    pub predicted_decode: f64,
    /// Monotonic submission sequence number (FCFS / tie-breaks).
    pub seq: u64,
}

/// Scheduling policy interface. `now` is engine time in seconds.
pub trait Scheduler: Send {
    fn policy(&self) -> Policy;

    /// A new agent arrived (called before its root tasks are pushed).
    fn on_agent_arrival(&mut self, info: &AgentInfo, now: f64);

    /// A task became ready (all DAG dependencies completed — or it was just
    /// spawned) and entered the waiting queue.
    fn push_task(&mut self, task: TaskInfo, now: f64);

    /// Pick the next waiting task to admit; removes it from the queue.
    fn pop_next(&mut self, now: f64) -> Option<TaskInfo>;

    /// Look at what `pop_next` would return without removing it.
    fn peek_next(&mut self, now: f64) -> Option<TaskInfo>;

    /// Number of waiting tasks.
    fn waiting_len(&self) -> usize;

    /// Service-delivery accounting: `delta` units of the scheduler's cost
    /// metric were served to `agent` (used by VTC counters and SRJF
    /// remaining-work tracking; others ignore it).
    fn on_service(&mut self, _agent: AgentId, _delta: f64) {}

    /// All tasks of the agent finished.
    fn on_agent_complete(&mut self, _agent: AgentId, _now: f64) {}

    /// Online misprediction correction (paper §4.2): the engine revised the
    /// agent's cost estimate mid-flight. `remaining` is the corrected
    /// remaining work and `total` the corrected end-to-end cost, both in the
    /// scheduler's cost units. Policies with static tags re-derive them from
    /// the corrected estimate (Justitia re-tags F_j from the arrival-time
    /// virtual clock plus the corrected total); the default ignores it.
    fn on_cost_update(&mut self, _agent: AgentId, _remaining: f64, _total: f64, _now: f64) {}

    /// Preemption rank among *running* agents when KV must be reclaimed:
    /// the engine swaps out sequences of the agent with the HIGHEST rank
    /// first. Default mirrors admission priority (last-to-be-chosen is
    /// first-to-be-preempted).
    fn preemption_rank(&self, agent: AgentId, now: f64) -> f64;

    /// Remaining predicted cost of an agent, if this policy tracks it
    /// (SRJF's service-decremented counter). The engine's
    /// [`VictimPolicy::CheapestRemaining`](crate::config::VictimPolicy)
    /// victim ranking consults it; `None` (the default) falls back to the
    /// engine-side per-sequence remaining-cost estimate (Eq. 1).
    fn remaining_cost(&self, _agent: AgentId) -> Option<f64> {
        None
    }

    /// The agent's virtual finish tag F_j under this policy's GPS clock, if
    /// it keeps one (Justitia). The engine's
    /// [`VictimPolicy::PamperAware`](crate::config::VictimPolicy) victim
    /// ranking protects agents with the *smallest* tag — the ones the
    /// virtual clock says would finish early under GPS — and `None` (the
    /// default) falls back to [`preemption_rank`](Self::preemption_rank).
    fn virtual_finish_tag(&self, _agent: AgentId) -> Option<f64> {
        None
    }

    /// Estimate the real-time GPS finish a hypothetical agent with predicted
    /// cost `cost` arriving at `now` would achieve on this scheduler's
    /// server — the virtual-time finish-tag estimation the cluster
    /// dispatcher's `cluster-vtime` placement compares across replicas.
    /// `None` for policies without a virtual clock (the dispatcher then
    /// falls back to its own mirror clocks).
    fn gps_finish_estimate(&mut self, _cost: f64, _now: f64) -> Option<f64> {
        None
    }

    /// Explain the head-of-line pick the engine is about to take (`picked`
    /// is what [`peek_next`](Self::peek_next) returned): the winning tag,
    /// the best losing agent and its tag, and whether the pick continues
    /// saturated consecutive service (selective pampering). Called only
    /// when tracing is on, *before* `pop_next`, so the policy's queues are
    /// intact. The default (`None`) records the pick without an
    /// explanation — correct for tag-free policies.
    fn explain_pick(&mut self, _picked: &TaskInfo, _now: f64) -> Option<PickExplanation> {
        None
    }

    /// The policy's current virtual time V(now), if it keeps a GPS clock
    /// (Justitia). The trace sampler combines it with
    /// [`virtual_finish_tag`](Self::virtual_finish_tag) into per-agent lag
    /// `V(t) − F_j` and the realized-vs-GPS max service gap. Advancing the
    /// clock here is safe: `VirtualClock::advance` is exact piecewise-linear
    /// integration, so extra calls never perturb later values.
    fn virtual_time(&mut self, _now: f64) -> Option<f64> {
        None
    }

    /// Engine-event hook (the event core's replacement for per-tick polling,
    /// DESIGN.md §12): the engine emits an [`EngineEvent`] the moment the
    /// state change it describes lands — a task admitted, a prefill chunk or
    /// decode batch retired, a swap-in or recompute re-entry completed, a
    /// child task spawned. Only called when `cfg.event_core` is on. The
    /// default ignores every event, so all built-in policies behave
    /// identically under both cores; policies that want event-driven state
    /// (e.g. aging timers keyed on real progress instead of wall polling)
    /// override it.
    fn on_event(&mut self, _event: &EngineEvent, _now: f64) {}
}

/// Construct a scheduler for a policy.
///
/// `capacity_tokens` is M; `service_rate_scale` converts cost units
/// (token·iterations) into per-second GPS service (tokens drained per second
/// = M × scale); it affects only GPS real-time finish estimates, never the
/// priority order.
pub fn build(
    policy: Policy,
    capacity_tokens: u64,
    service_rate_scale: f64,
) -> Box<dyn Scheduler> {
    match policy {
        Policy::Fcfs => Box::new(fcfs::Fcfs::new()),
        Policy::Sjf => Box::new(sjf::Sjf::new()),
        Policy::AgentFcfs => Box::new(agent_fcfs::AgentFcfs::new()),
        Policy::Vtc => Box::new(vtc::Vtc::new(CostModel::ComputeCentric)),
        Policy::Srjf => Box::new(srjf::Srjf::new()),
        Policy::Justitia => {
            Box::new(justitia::Justitia::new(capacity_tokens, service_rate_scale))
        }
        Policy::JustitiaComputeCost => {
            // Fig. 11 ablation: identical queuing, costs fed to it are
            // computed with the compute-centric model by the caller.
            Box::new(
                justitia::Justitia::new(capacity_tokens, service_rate_scale)
                    .with_label(Policy::JustitiaComputeCost),
            )
        }
    }
}

/// The cost model a policy's agent-level costs should be computed with.
pub fn cost_model_for(policy: Policy) -> CostModel {
    match policy {
        Policy::JustitiaComputeCost | Policy::Vtc | Policy::Sjf => CostModel::ComputeCentric,
        _ => CostModel::MemoryCentric,
    }
}

/// Shared helper: per-agent FIFO queues with a pluggable agent key. Agent-
/// level policies (Justitia, Parrot, VTC, SRJF) admit all tasks of the
/// chosen agent consecutively (paper §4.3: "all the inferences of a
/// high-priority agent can be served consecutively without being
/// interleaved").
#[derive(Debug, Default)]
pub struct AgentQueues {
    queues: std::collections::HashMap<AgentId, std::collections::VecDeque<TaskInfo>>,
    len: usize,
}

impl AgentQueues {
    /// Empty queue set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a task to its agent's FIFO.
    pub fn push(&mut self, task: TaskInfo) {
        self.queues.entry(task.id.agent).or_default().push_back(task);
        self.len += 1;
    }

    /// Total waiting tasks.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no tasks wait.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether `agent` has waiting tasks.
    pub fn has_agent(&self, agent: AgentId) -> bool {
        self.queues.get(&agent).map(|q| !q.is_empty()).unwrap_or(false)
    }

    /// Agents that currently have waiting tasks. Iteration order is the
    /// hash map's and therefore unspecified: every consumer must reduce it
    /// order-independently (`min_agent_by` takes a total-order minimum with
    /// an agent-id tie-break; policy `pick`s collect-and-sort first).
    pub fn waiting_agents(&self) -> impl Iterator<Item = AgentId> + '_ {
        // simlint::allow(unordered-iter): consumers reduce order-independently; min_agent_by ties broken by agent id
        self.queues.iter().filter(|(_, q)| !q.is_empty()).map(|(&a, _)| a)
    }

    /// Pop the head task of `agent`'s FIFO.
    pub fn pop_agent(&mut self, agent: AgentId) -> Option<TaskInfo> {
        let q = self.queues.get_mut(&agent)?;
        let t = q.pop_front();
        if t.is_some() {
            self.len -= 1;
        }
        if q.is_empty() {
            self.queues.remove(&agent);
        }
        t
    }

    /// Peek the head task of `agent`'s FIFO.
    pub fn peek_agent(&self, agent: AgentId) -> Option<&TaskInfo> {
        self.queues.get(&agent).and_then(|q| q.front())
    }

    /// Waiting tasks of one agent (pamper-status introspection).
    pub fn agent_len(&self, agent: AgentId) -> usize {
        self.queues.get(&agent).map(|q| q.len()).unwrap_or(0)
    }

    /// Linear scan for the waiting agent minimizing `key` (ties by agent id).
    /// O(A) with A = agents having waiting work; used by the dynamic-priority
    /// policies (VTC, SRJF) where keys change continuously.
    pub fn min_agent_by<F: FnMut(AgentId) -> f64>(&self, mut key: F) -> Option<AgentId> {
        self.waiting_agents()
            .map(|a| (a, key(a)))
            .min_by(|(a1, k1), (a2, k2)| k1.total_cmp(k2).then(a1.cmp(a2)))
            .map(|(a, _)| a)
    }
}

/// An f64 key usable in ordered collections (total order, NaN-free inputs).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // IEEE-754 total order: for the NaN-free keys documented above this
        // agrees with the old panicking comparison (except -0.0 < 0.0), and
        // a NaN that slips through sorts to a fixed slot instead of aborting
        // a replay mid-run.
        self.0.total_cmp(&other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo {
            id: TaskId { agent, index },
            prompt_tokens: 10,
            predicted_decode: 5.0,
            seq,
        }
    }

    #[test]
    fn agent_queues_fifo_within_agent() {
        let mut q = AgentQueues::new();
        q.push(task(1, 0, 0));
        q.push(task(1, 1, 1));
        q.push(task(2, 0, 2));
        assert_eq!(q.len(), 3);
        assert!(q.has_agent(1));
        assert_eq!(q.pop_agent(1).unwrap().id.index, 0);
        assert_eq!(q.pop_agent(1).unwrap().id.index, 1);
        assert!(q.pop_agent(1).is_none());
        assert!(!q.has_agent(1));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn min_agent_by_key() {
        let mut q = AgentQueues::new();
        q.push(task(1, 0, 0));
        q.push(task(2, 0, 1));
        q.push(task(3, 0, 2));
        let keys = std::collections::HashMap::from([(1u32, 5.0), (2u32, 1.0), (3u32, 9.0)]);
        assert_eq!(q.min_agent_by(|a| keys[&a]), Some(2));
        q.pop_agent(2);
        assert_eq!(q.min_agent_by(|a| keys[&a]), Some(1));
    }

    #[test]
    fn ordf64_total_order() {
        let mut v = vec![OrdF64(3.0), OrdF64(1.0), OrdF64(2.0)];
        v.sort();
        assert_eq!(v, vec![OrdF64(1.0), OrdF64(2.0), OrdF64(3.0)]);
    }

    #[test]
    fn build_all_policies() {
        for p in Policy::all_paper_baselines() {
            let s = build(p, 1000, 1.0);
            assert_eq!(s.policy(), p);
        }
        let s = build(Policy::JustitiaComputeCost, 1000, 1.0);
        assert_eq!(s.policy(), Policy::JustitiaComputeCost);
    }

    #[test]
    fn agent_len_tracks_per_agent_queue() {
        let mut q = AgentQueues::new();
        assert_eq!(q.agent_len(1), 0);
        q.push(task(1, 0, 0));
        q.push(task(1, 1, 1));
        q.push(task(2, 0, 2));
        assert_eq!(q.agent_len(1), 2);
        assert_eq!(q.agent_len(2), 1);
        q.pop_agent(1);
        assert_eq!(q.agent_len(1), 1);
    }

    #[test]
    fn default_trace_hooks_are_inert() {
        // Tag-free policies fall back to the trait defaults: no explanation,
        // no virtual clock.
        let mut s = build(Policy::Fcfs, 1000, 1.0);
        s.on_agent_arrival(&AgentInfo::new(1, 0.0, 10.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        let head = s.peek_next(0.0).unwrap();
        assert!(s.explain_pick(&head, 0.0).is_none());
        assert!(s.virtual_time(0.0).is_none());
    }

    #[test]
    fn cost_models_per_policy() {
        assert_eq!(cost_model_for(Policy::Justitia), CostModel::MemoryCentric);
        assert_eq!(cost_model_for(Policy::JustitiaComputeCost), CostModel::ComputeCentric);
        assert_eq!(cost_model_for(Policy::Vtc), CostModel::ComputeCentric);
        assert_eq!(cost_model_for(Policy::Srjf), CostModel::MemoryCentric);
    }
}
