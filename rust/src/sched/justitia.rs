//! The Justitia scheduler (paper §4.3): virtual-time fair queuing with
//! selective pampering.
//!
//! On agent arrival, compute the virtual finish tag F_j = V(a_j) + C_j once.
//! Agents are then served *saturated* — all their tasks admitted
//! consecutively — in ascending F_j order. Status refresh on arrival or
//! completion is O(log N); picking the next agent is O(log N) via a binary
//! heap with lazy deletion (paper §4.3 complexity claims).

use crate::config::Policy;
use crate::sched::vtime::VirtualClock;
use crate::sched::{AgentInfo, AgentQueues, OrdF64, PickExplanation, Scheduler, TaskInfo};
use crate::workload::AgentId;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Virtual-time fair-queuing scheduler.
pub struct Justitia {
    vclock: VirtualClock,
    /// F_j per agent (computed on arrival; re-derived only by §4.2 online
    /// correction via [`Scheduler::on_cost_update`]).
    tags: HashMap<AgentId, f64>,
    /// V(a_j) recorded at arrival, so a corrected total cost re-derives
    /// F_j = V(a_j) + Ĉ_j' without disturbing the arrival anchoring.
    v_arrival: HashMap<AgentId, f64>,
    /// Predicted critical-path cost per agent (introspection; the priority
    /// order itself keys on the total cost F_j).
    cpaths: HashMap<AgentId, f64>,
    waiting: AgentQueues,
    /// Min-heap over (F_j, agent) for O(log N) selection; entries are lazily
    /// dropped when the agent has no waiting tasks or was re-tagged (stale)
    /// and re-pushed when new tasks of a known agent arrive.
    heap: BinaryHeap<Reverse<(OrdF64, AgentId)>>,
    /// Agents currently represented in the heap (to avoid duplicate pushes).
    in_heap: std::collections::HashSet<AgentId>,
    label: Policy,
}

impl Justitia {
    /// Scheduler over capacity M = `capacity_tokens` with `rate_scale`
    /// iterations per second.
    pub fn new(capacity_tokens: u64, rate_scale: f64) -> Self {
        Justitia {
            vclock: VirtualClock::new(capacity_tokens, rate_scale),
            tags: HashMap::new(),
            v_arrival: HashMap::new(),
            cpaths: HashMap::new(),
            waiting: AgentQueues::new(),
            heap: BinaryHeap::new(),
            in_heap: std::collections::HashSet::new(),
            label: Policy::Justitia,
        }
    }

    /// Re-label (used by the Justitia/C cost-model ablation, which shares
    /// this queuing machinery but feeds compute-centric costs).
    pub fn with_label(mut self, label: Policy) -> Self {
        self.label = label;
        self
    }

    /// The virtual finish tag of an agent (for tests / introspection).
    pub fn tag(&self, agent: AgentId) -> Option<f64> {
        self.tags.get(&agent).copied()
    }

    /// The predicted critical-path cost recorded at arrival (remaining-DAG
    /// diagnostics; see [`AgentInfo::critical_path`]).
    pub fn critical_path(&self, agent: AgentId) -> Option<f64> {
        self.cpaths.get(&agent).copied()
    }

    /// Access the underlying virtual clock (GPS reference for metrics).
    pub fn vclock_mut(&mut self) -> &mut VirtualClock {
        &mut self.vclock
    }

    fn ensure_in_heap(&mut self, agent: AgentId) {
        if self.waiting.has_agent(agent) && self.in_heap.insert(agent) {
            let f = self.current_tag(agent);
            self.heap.push(Reverse((OrdF64(f), agent)));
        }
    }

    fn current_tag(&self, agent: AgentId) -> f64 {
        self.tags.get(&agent).copied().unwrap_or(f64::MAX)
    }

    /// Drop stale heap heads: entries whose agent has no waiting tasks, or
    /// whose recorded tag no longer matches the live one (the agent was
    /// re-tagged by online correction; its fresh entry is elsewhere in the
    /// heap). Without corrections every in-heap entry matches its tag, and
    /// this reduces to the original no-waiting-tasks skim.
    fn skim(&mut self) {
        while let Some(&Reverse((OrdF64(f), agent))) = self.heap.peek() {
            if f != self.current_tag(agent) {
                self.heap.pop();
                continue;
            }
            if self.waiting.has_agent(agent) {
                return;
            }
            self.heap.pop();
            self.in_heap.remove(&agent);
        }
    }
}

impl Scheduler for Justitia {
    fn policy(&self) -> Policy {
        self.label
    }

    fn on_agent_arrival(&mut self, info: &AgentInfo, now: f64) {
        // Paper Eq. 3 — computed once; refreshed only by §4.2 correction.
        let f = self.vclock.on_arrival(info.id, info.cost, now);
        self.tags.insert(info.id, f);
        // V(a_j) = F_j − Ĉ_j, kept so corrections re-anchor at arrival time.
        self.v_arrival.insert(info.id, f - info.cost.max(0.0));
        self.cpaths.insert(info.id, info.critical_path);
    }

    fn push_task(&mut self, task: TaskInfo, now: f64) {
        let _ = now;
        self.waiting.push(task);
        self.ensure_in_heap(task.id.agent);
    }

    fn pop_next(&mut self, now: f64) -> Option<TaskInfo> {
        let _ = now;
        self.skim();
        let &Reverse((_, agent)) = self.heap.peek()?;
        let task = self.waiting.pop_agent(agent);
        // Keep the agent's heap entry while it still has waiting tasks; skim
        // removes it lazily once drained.
        if !self.waiting.has_agent(agent) {
            self.heap.pop();
            self.in_heap.remove(&agent);
        }
        task
    }

    fn peek_next(&mut self, now: f64) -> Option<TaskInfo> {
        let _ = now;
        self.skim();
        let &Reverse((_, agent)) = self.heap.peek()?;
        self.waiting.peek_agent(agent).copied()
    }

    fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    fn on_agent_complete(&mut self, agent: AgentId, now: f64) {
        // Advance virtual time opportunistically; the tag itself stays (GPS
        // may lag or lead the real system). The correction-only maps are
        // pruned — completed agents can no longer be re-tagged, and a
        // long-lived server must not grow them unboundedly.
        self.vclock.advance(now);
        self.v_arrival.remove(&agent);
        self.cpaths.remove(&agent);
    }

    fn on_cost_update(&mut self, agent: AgentId, _remaining: f64, total: f64, now: f64) {
        // §4.2 correction: re-derive F_j = V(a_j) + Ĉ_j' with the corrected
        // end-to-end cost, keeping the arrival-time anchor (a later
        // correction must not push the agent behind work that arrived after
        // it merely because time passed). Both the selection heap and the
        // GPS virtual clock are re-tagged; stale entries die lazily.
        let Some(&v0) = self.v_arrival.get(&agent) else { return };
        let new_f = v0 + total.max(0.0);
        if self.tags.get(&agent).copied() == Some(new_f) {
            return;
        }
        self.vclock.advance(now);
        self.vclock.retag(agent, new_f);
        self.tags.insert(agent, new_f);
        // Refresh the waiting-queue entry (if any): drop the in-heap mark so
        // ensure_in_heap pushes a fresh entry; the old one is now stale.
        self.in_heap.remove(&agent);
        self.ensure_in_heap(agent);
    }

    fn preemption_rank(&self, agent: AgentId, _now: f64) -> f64 {
        // Preempt the agent with the LARGEST virtual finish tag first — the
        // one GPS would finish last.
        self.tags.get(&agent).copied().unwrap_or(f64::MAX)
    }

    fn virtual_finish_tag(&self, agent: AgentId) -> Option<f64> {
        self.tags.get(&agent).copied()
    }

    fn explain_pick(&mut self, picked: &TaskInfo, _now: f64) -> Option<PickExplanation> {
        let winner = picked.id.agent;
        // The runner-up is the smallest *live* heap entry of another agent:
        // skim first so the head is live, then scan past stale entries
        // (wrong tag, or no waiting tasks) — O(heap) but only on the traced
        // path, never in the hot scheduler.
        self.skim();
        let mut runner: Option<(f64, AgentId)> = None;
        for &Reverse((OrdF64(f), agent)) in self.heap.iter() {
            if agent == winner
                || f != self.current_tag(agent)
                || !self.waiting.has_agent(agent)
            {
                continue;
            }
            if runner.map_or(true, |(rf, ra)| (f, agent) < (rf, ra)) {
                runner = Some((f, agent));
            }
        }
        Some(PickExplanation {
            winner_tag: self.tags.get(&winner).copied(),
            runner_up: runner.map(|(_, a)| a),
            runner_up_tag: runner.map(|(f, _)| f),
            // Selective pampering: the winner keeps the seat while more of
            // its tasks wait (saturated consecutive service, §4.3).
            pampered: self.waiting.agent_len(winner) > 1,
        })
    }

    fn virtual_time(&mut self, now: f64) -> Option<f64> {
        Some(self.vclock.vt(now))
    }

    fn gps_finish_estimate(&mut self, cost: f64, now: f64) -> Option<f64> {
        // Probe the live virtual clock with a sentinel id (AgentId::MAX is
        // never assigned by Suite re-indexing); the clone-based simulation
        // leaves the clock untouched.
        Some(self.vclock.hypothetical_gps_finish(AgentId::MAX, cost, now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TaskId;

    fn info(id: u32, cost: f64, arrival: f64) -> AgentInfo {
        AgentInfo::new(id, arrival, cost)
    }

    fn task(agent: u32, index: u32, seq: u64) -> TaskInfo {
        TaskInfo { id: TaskId { agent, index }, prompt_tokens: 8, predicted_decode: 4.0, seq }
    }

    #[test]
    fn serves_in_virtual_finish_order() {
        let mut s = Justitia::new(100, 1.0);
        // Arrive together: cheap agent 2 must be fully served before 1.
        s.on_agent_arrival(&info(1, 1000.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 100.0, 0.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(1, 1, 1), 0.0);
        s.push_task(task(2, 0, 2), 0.0);
        s.push_task(task(2, 1, 3), 0.0);
        let order: Vec<u32> = (0..4).map(|_| s.pop_next(0.0).unwrap().id.agent).collect();
        assert_eq!(order, vec![2, 2, 1, 1]);
        assert!(s.pop_next(0.0).is_none());
    }

    #[test]
    fn tasks_of_agent_served_consecutively() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 50.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 60.0, 0.0), 0.0);
        for i in 0..3 {
            s.push_task(task(1, i, i as u64), 0.0);
            s.push_task(task(2, i, 10 + i as u64), 0.0);
        }
        let order: Vec<u32> = (0..6).map(|_| s.pop_next(0.0).unwrap().id.agent).collect();
        assert_eq!(order, vec![1, 1, 1, 2, 2, 2]);
    }

    #[test]
    fn late_cheap_agent_preempts_queue_position_only() {
        let mut s = Justitia::new(10, 1.0);
        s.on_agent_arrival(&info(1, 1000.0, 0.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        // At t=1, V=10; tiny agent gets F=10+5=15 < 1000.
        s.on_agent_arrival(&info(2, 5.0, 1.0), 1.0);
        s.push_task(task(2, 0, 1), 1.0);
        assert_eq!(s.pop_next(1.0).unwrap().id.agent, 2);
        assert_eq!(s.pop_next(1.0).unwrap().id.agent, 1);
    }

    #[test]
    fn late_stage_tasks_keep_agent_priority() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 10.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 500.0, 0.0), 0.0);
        s.push_task(task(2, 0, 0), 0.0);
        // Agent 1's stage-1 task shows up later (stage 0 completed) but its
        // F tag still beats agent 2's.
        s.push_task(task(1, 0, 1), 5.0);
        assert_eq!(s.peek_next(5.0).unwrap().id.agent, 1);
        assert_eq!(s.pop_next(5.0).unwrap().id.agent, 1);
        assert_eq!(s.pop_next(5.0).unwrap().id.agent, 2);
    }

    #[test]
    fn tags_are_stable_under_later_arrivals() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 300.0, 0.0), 0.0);
        let f1 = s.tag(1).unwrap();
        for k in 2..20 {
            s.on_agent_arrival(&info(k, 100.0, 0.1 * k as f64), 0.1 * k as f64);
        }
        assert_eq!(s.tag(1), Some(f1));
    }

    #[test]
    fn preemption_rank_prefers_largest_tag() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 10.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 999.0, 0.0), 0.0);
        assert!(s.preemption_rank(2, 0.0) > s.preemption_rank(1, 0.0));
    }

    #[test]
    fn gps_estimate_reflects_load() {
        let mut idle = Justitia::new(10, 1.0);
        let mut busy = Justitia::new(10, 1.0);
        busy.on_agent_arrival(&info(1, 500.0, 0.0), 0.0);
        let e_idle = idle.gps_finish_estimate(100.0, 0.0).unwrap();
        let e_busy = busy.gps_finish_estimate(100.0, 0.0).unwrap();
        assert!(e_idle < e_busy, "{e_idle} vs {e_busy}");
        // The probe must not perturb real tags.
        assert_eq!(busy.tag(1), Some(500.0));
    }

    #[test]
    fn cost_update_retags_and_reorders() {
        let mut s = Justitia::new(100, 1.0);
        // Agent 1 predicted huge, agent 2 medium: initial order 2 then 1.
        s.on_agent_arrival(&info(1, 1000.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 300.0, 0.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(2, 0, 1), 0.0);
        assert_eq!(s.peek_next(0.0).unwrap().id.agent, 2);
        // Correction: agent 1's true total is tiny → it re-tags ahead of 2.
        s.on_cost_update(1, 50.0, 50.0, 0.0);
        assert_eq!(s.tag(1), Some(50.0), "F = V(a)=0 + corrected 50");
        assert_eq!(s.peek_next(0.0).unwrap().id.agent, 1);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 1);
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 2);
        assert!(s.pop_next(0.0).is_none());
    }

    #[test]
    fn cost_update_keeps_arrival_anchor() {
        let mut s = Justitia::new(10, 1.0);
        s.on_agent_arrival(&info(1, 100.0, 0.0), 0.0);
        // At t=5 the clock has advanced (V=50); a correction to total 80
        // must anchor at V(arrival)=0, not V(now).
        s.on_cost_update(1, 30.0, 80.0, 5.0);
        assert_eq!(s.tag(1), Some(80.0));
    }

    #[test]
    fn cost_update_for_unknown_agent_is_noop() {
        let mut s = Justitia::new(10, 1.0);
        s.on_cost_update(9, 10.0, 10.0, 0.0);
        assert_eq!(s.tag(9), None);
        assert!(s.pop_next(0.0).is_none());
    }

    #[test]
    fn critical_path_is_recorded() {
        let mut s = Justitia::new(10, 1.0);
        s.on_agent_arrival(
            &AgentInfo { id: 4, arrival: 0.0, cost: 100.0, critical_path: 37.5 },
            0.0,
        );
        assert_eq!(s.critical_path(4), Some(37.5));
        assert_eq!(s.critical_path(5), None);
    }

    #[test]
    fn explain_pick_names_runner_up_and_pampering() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 50.0, 0.0), 0.0);
        s.on_agent_arrival(&info(2, 200.0, 0.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        s.push_task(task(1, 1, 1), 0.0);
        s.push_task(task(2, 0, 2), 0.0);
        let head = s.peek_next(0.0).unwrap();
        assert_eq!(head.id.agent, 1);
        let e = s.explain_pick(&head, 0.0).unwrap();
        assert_eq!(e.winner_tag, Some(50.0));
        assert_eq!(e.runner_up, Some(2));
        assert_eq!(e.runner_up_tag, Some(200.0));
        assert!(e.pampered, "a second task of agent 1 still waits");
        // Drain agent 1's first task: the final task is no longer pampered.
        s.pop_next(0.0);
        let head = s.peek_next(0.0).unwrap();
        let e = s.explain_pick(&head, 0.0).unwrap();
        assert_eq!(e.winner_tag, Some(50.0));
        assert!(!e.pampered);
        // Last agent standing has no runner-up.
        s.pop_next(0.0);
        let head = s.peek_next(0.0).unwrap();
        assert_eq!(head.id.agent, 2);
        let e = s.explain_pick(&head, 0.0).unwrap();
        assert_eq!(e.runner_up, None);
        assert_eq!(e.runner_up_tag, None);
        // Explaining must not perturb the pick order.
        assert_eq!(s.pop_next(0.0).unwrap().id.agent, 2);
        assert!(s.pop_next(0.0).is_none());
    }

    #[test]
    fn virtual_time_tracks_gps_clock() {
        let mut s = Justitia::new(10, 1.0);
        assert_eq!(s.virtual_time(0.0), Some(0.0));
        s.on_agent_arrival(&info(1, 100.0, 0.0), 0.0);
        // One active agent: dV/dt = M = 10 per second.
        assert_eq!(s.virtual_time(2.0), Some(20.0));
        // vt is exact piecewise-linear integration: re-asking at the same
        // instant returns the same value (path independence).
        assert_eq!(s.virtual_time(2.0), Some(20.0));
    }

    #[test]
    fn peek_matches_pop() {
        let mut s = Justitia::new(100, 1.0);
        s.on_agent_arrival(&info(1, 5.0, 0.0), 0.0);
        s.push_task(task(1, 0, 0), 0.0);
        let peeked = s.peek_next(0.0).unwrap();
        let popped = s.pop_next(0.0).unwrap();
        assert_eq!(peeked.id, popped.id);
        assert_eq!(s.waiting_len(), 0);
    }
}
