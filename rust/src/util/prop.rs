//! Miniature property-based testing framework (proptest is unavailable
//! offline). Provides seeded case generation with automatic shrinking for a
//! few core strategies. Used by `rust/tests/prop_*.rs` to check scheduler
//! invariants — most importantly the Theorem B.1 delay bound of Justitia
//! against the GPS reference simulator.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Inputs generated per property.
    pub cases: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Shrink-attempt budget on failure.
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Honor PROPTEST_CASES-style env override for CI tuning.
        let cases = std::env::var("JUSTITIA_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config { cases, seed: 0x5eed_cafe, max_shrink_steps: 400 }
    }
}

/// A generation + shrinking strategy for values of type `T`.
pub trait Strategy {
    type Value: Clone + std::fmt::Debug;
    /// Generate a random value.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Produce strictly "smaller" candidate values; empty when minimal.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value>;
}

/// Run a property: generate `config.cases` inputs; on failure, greedily
/// shrink to a minimal counterexample and panic with it.
pub fn check<S, F>(config: &Config, strategy: &S, prop: F)
where
    S: Strategy,
    F: Fn(&S::Value) -> Result<(), String>,
{
    let mut rng = Rng::new(config.seed);
    for case in 0..config.cases {
        let mut case_rng = rng.fork(case as u64);
        let value = strategy.generate(&mut case_rng);
        if let Err(msg) = prop(&value) {
            // Shrink.
            let mut best = value;
            let mut best_msg = msg;
            let mut steps = 0;
            'outer: while steps < config.max_shrink_steps {
                for candidate in strategy.shrink(&best) {
                    steps += 1;
                    if let Err(m) = prop(&candidate) {
                        best = candidate;
                        best_msg = m;
                        continue 'outer;
                    }
                    if steps >= config.max_shrink_steps {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                config.seed, best, best_msg
            );
        }
    }
}

/// Strategy: u64 in [lo, hi].
pub struct U64Range {
    /// Inclusive lower bound.
    pub lo: u64,
    /// Inclusive upper bound.
    pub hi: u64,
}

impl Strategy for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Rng) -> u64 {
        rng.range_u64(self.lo, self.hi)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out.retain(|x| x != v);
        out
    }
}

/// Strategy: f64 in [lo, hi).
pub struct F64Range {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl Strategy for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if (*v - self.lo).abs() > 1e-9 {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2.0);
        }
        out.retain(|x| (x - v).abs() > 1e-12);
        out
    }
}

/// Strategy: vector of `inner` values with length in [min_len, max_len].
pub struct VecOf<S: Strategy> {
    /// Element strategy.
    pub inner: S,
    /// Minimum length.
    pub min_len: usize,
    /// Maximum length.
    pub max_len: usize,
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<S::Value> {
        let len = rng.range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        // Remove halves, then single elements, then shrink one element.
        if v.len() > self.min_len {
            let half = (v.len() / 2).max(self.min_len);
            out.push(v[..half].to_vec());
            if v.len() > self.min_len {
                let mut w = v.clone();
                w.pop();
                out.push(w);
                let mut w = v.clone();
                w.remove(0);
                out.push(w);
            }
        }
        for (i, elem) in v.iter().enumerate().take(4) {
            for se in self.inner.shrink(elem).into_iter().take(2) {
                let mut w = v.clone();
                w[i] = se;
                out.push(w);
            }
        }
        out
    }
}

/// Strategy combinator: map a base strategy through a function
/// (no shrinking through the map; shrink candidates are re-mapped).
pub struct Map<S: Strategy, T, F: Fn(S::Value) -> T> {
    /// Base strategy.
    pub inner: S,
    /// Mapping function.
    pub f: F,
    /// Output-type marker.
    pub _marker: std::marker::PhantomData<T>,
}

impl<S: Strategy, T: Clone + std::fmt::Debug, F: Fn(S::Value) -> T> Map<S, T, F> {
    /// Map `inner` through `f`.
    pub fn new(inner: S, f: F) -> Self {
        Map { inner, f, _marker: std::marker::PhantomData }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        let cfg = Config { cases: 50, seed: 1, max_shrink_steps: 10 };
        check(&cfg, &U64Range { lo: 0, hi: 100 }, |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        let cfg = Config { cases: 200, seed: 2, max_shrink_steps: 50 };
        check(&cfg, &U64Range { lo: 0, hi: 1000 }, |&x| {
            if x < 500 {
                Ok(())
            } else {
                Err(format!("{x} too big"))
            }
        });
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        let cfg = Config { cases: 100, seed: 3, max_shrink_steps: 200 };
        let result = std::panic::catch_unwind(|| {
            check(&cfg, &U64Range { lo: 0, hi: 10_000 }, |&x| {
                if x < 777 {
                    Ok(())
                } else {
                    Err("boom".into())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The minimal counterexample is 777; shrinking should land at/near it.
        assert!(msg.contains("777") || msg.contains("input"), "{msg}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        let cfg = Config { cases: 50, seed: 4, max_shrink_steps: 10 };
        let strat = VecOf { inner: U64Range { lo: 1, hi: 9 }, min_len: 2, max_len: 6 };
        check(&cfg, &strat, |v| {
            if (2..=6).contains(&v.len()) && v.iter().all(|&x| (1..=9).contains(&x)) {
                Ok(())
            } else {
                Err(format!("bad vec {v:?}"))
            }
        });
    }
}
