# Convenience targets; see README.md for the full quickstart.

.PHONY: artifacts build test bench kick-tires clean

# AOT-compile the tiny JAX+Pallas model to HLO text + weights for the Rust
# PJRT runtime (Layer 2/1 → Layer 3 handoff; needs jax installed).
artifacts:
	cd python && python3 -m compile.aot --out-dir ../rust/artifacts

build:
	cd rust && cargo build --release

test:
	cd rust && cargo test -q

kick-tires:
	scripts/kick-tires.sh

clean:
	cd rust && cargo clean
	rm -rf out results
